//! Quickstart: the associative-array tour from the D4M papers — build,
//! query, and do linear algebra over heterogeneous string data.
//!
//! Run with: `cargo run --release --example quickstart`

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use d4m::assoc::io::display_full;
use d4m::assoc::{Assoc, KeySel};

fn main() {
    // -------------------------------------------------- construction
    // An entity-edge table from an (imaginary) document corpus.
    let a = Assoc::from_triples(&[
        ("doc01", "word|apple", 2.0),
        ("doc01", "word|berry", 1.0),
        ("doc02", "word|apple", 1.0),
        ("doc02", "word|cherry", 4.0),
        ("doc03", "word|berry", 3.0),
        ("doc03", "word|cherry", 1.0),
    ]);
    println!("A (doc x word counts):\n{}", display_full(&a));

    // string-valued arrays work too (D4M value-key encoding)
    let meta = Assoc::from_str_triples(&[
        ("doc01", "lang", "en"),
        ("doc02", "lang", "fr"),
        ("doc03", "lang", "en"),
    ]);
    println!("doc02 language: {:?}", meta.get_str("doc02", "lang"));

    // -------------------------------------------------- subsref
    // all docs mentioning apple-ish words: A(:, starts_with("word|a"))
    let apple = a.select_cols(&KeySel::Prefix("word|a".into()));
    println!("docs with word|a*: {:?}", apple.row_keys());

    // row range (D4M 'doc01,:,doc02,')
    let first_two = a.select_rows(&KeySel::Range("doc01".into(), "doc02".into()));
    println!("rows doc01..doc02 have {} entries", first_two.nnz());

    // -------------------------------------------------- algebra
    // word co-occurrence: C = A' * A (the TableMult of Figure 2)
    let c = a.transpose().matmul(&a);
    println!("\nword co-occurrence C = A'*A:\n{}", display_full(&c));

    // degree vectors
    println!("word degrees (sum down rows):\n{}", display_full(&a.sum(1)));

    // union-add and intersection-multiply
    let b = Assoc::from_triples(&[("doc01", "word|apple", 10.0), ("doc04", "word|durian", 1.0)]);
    println!("A + B has {} entries (union)", a.add(&b).nnz());
    println!("A & B has {} entries (intersection)", a.elem_mult(&b).nnz());

    // provenance-tracking multiply: which docs connect two words?
    let cat = a.transpose().catkeymul(&a);
    println!(
        "apple-berry connected through: {:?}",
        cat.get_str("word|apple", "word|berry")
    );

    // thresholding (A > 2)
    let heavy = a.filter_values(|v| v > 2.0);
    println!("entries with count > 2: {:?}", heavy.triples());
}
