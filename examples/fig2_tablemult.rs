//! Figure 2 reproduction: "Graphulo vs. D4M TableMult Scaling".
//!
//! Sweeps Kronecker graph SCALE and measures TableMult (C = A^T * A)
//! throughput for:
//!   * **Graphulo** — server-side, streaming, bounded memory;
//!   * **D4M client** — full tables pulled into RAM, under a client
//!     memory budget that reproduces the paper's memory wall.
//!
//! The paper's claim (its Figure 2): Graphulo multiplies at rates close
//! to in-memory D4M but keeps working where the client runs out of
//! memory. Expect the same *shape* here: comparable rates at small
//! SCALE, and `OOM` rows for the client at large SCALE.
//!
//! Run with: `cargo run --release --example fig2_tablemult`

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::sync::Arc;
use std::time::Instant;

use d4m::connectors::{AccumuloConnector, D4mTableConfig};
use d4m::gen::{kronecker_assoc, KroneckerParams};
use d4m::graphulo::{self, ClientCtx, TableMultOpts};
use d4m::kvstore::KvStore;
use d4m::util::{fmt_bytes, fmt_rate};

/// Client RAM budget (bytes) — small enough that the largest SCALEs blow
/// through it, as in the paper's testbed.
const CLIENT_MEM_LIMIT: usize = 24 << 20;

fn main() {
    let scales: Vec<u32> = std::env::args()
        .nth(1)
        .map(|s| s.split(',').map(|x| x.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![8, 9, 10, 11, 12]);
    println!("client memory budget: {}", fmt_bytes(CLIENT_MEM_LIMIT));
    println!(
        "{:<7} {:>10} {:>14} {:>16} {:>16} {:>8}",
        "SCALE", "edges", "partials", "graphulo", "d4m-client", "winner"
    );

    for scale in scales {
        let params = KroneckerParams::new(scale, 16, 0xF162);
        let g = kronecker_assoc(&params);

        // load into the store
        let store = Arc::new(KvStore::new());
        let acc = AccumuloConnector::with_store(store.clone());
        let cfg = D4mTableConfig { degrees: false, transpose: false, ..Default::default() };
        let t = acc.bind("G", &cfg).unwrap();
        t.put_assoc(&g).unwrap();

        // ---- Graphulo (server-side)
        let c = store.create_table("C", vec![]).unwrap();
        let t0 = Instant::now();
        let stats = graphulo::table_mult(&t.main(), &t.main(), &c, &TableMultOpts::default())
            .unwrap();
        let dt_server = t0.elapsed().as_secs_f64();
        let server_rate = stats.partial_products as f64 / dt_server;

        // ---- D4M client (memory-budgeted)
        let ctx = ClientCtx::with_limit(CLIENT_MEM_LIMIT);
        let t1 = Instant::now();
        let client = ctx.table_mult(&t.main(), &t.main());
        let (client_cell, winner) = match client {
            Ok(out) => {
                let dt = t1.elapsed().as_secs_f64();
                let rate = stats.partial_products as f64 / dt;
                let w = if rate > server_rate { "d4m" } else { "graphulo" };
                (fmt_rate(rate), w)
            }
            Err(_) => ("OOM".to_string(), "graphulo"),
        };

        println!(
            "{:<7} {:>10} {:>14} {:>16} {:>16} {:>8}",
            scale,
            g.nnz(),
            stats.partial_products,
            fmt_rate(server_rate),
            client_cell,
            winner
        );
    }
    println!("\n(rates are partial products per second; OOM = client memory wall, Fig. 2's right edge)");
}
