//! Graph analytics with Graphulo: ingest a Kronecker graph into the
//! embedded Accumulo substrate, run BFS / Jaccard / k-truss **inside the
//! database**, and verify every result against the client-side D4M
//! baselines.
//!
//! Run with: `cargo run --release --example graph_analytics`

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::sync::Arc;

use d4m::assoc::Assoc;
use d4m::connectors::{AccumuloConnector, D4mTableConfig};
use d4m::gen::{kronecker_assoc, vertex_key, KroneckerParams};
use d4m::graphulo;
use d4m::kvstore::KvStore;

fn main() {
    let params = KroneckerParams::new(9, 8, 42);
    println!(
        "generating Kronecker graph: SCALE={} (n={}, m={})",
        params.scale,
        params.num_vertices(),
        params.num_edges()
    );
    let g: Assoc = kronecker_assoc(&params);
    println!("adjacency: {} nnz over {} vertices", g.nnz(), g.row_keys().len());

    // ---- ingest into the store with the D4M schema
    let store = Arc::new(KvStore::new());
    let acc = AccumuloConnector::with_store(store.clone());
    let t = acc.bind("G", &D4mTableConfig::default()).unwrap();
    t.put_assoc(&g).unwrap();
    println!("ingested into tables: {:?}", store.list_tables());

    // ---- BFS: server-side vs client-side
    let seed = vertex_key(0);
    let server_bfs = graphulo::bfs_server(&t.main(), &[seed.clone()], 3);
    let client_bfs = graphulo::bfs_assoc(&g, &[seed.clone()], 3);
    assert_eq!(server_bfs, client_bfs, "BFS server/client mismatch");
    println!("BFS from {seed}: {} vertices within 3 hops (server == client ✓)", server_bfs.len());

    // ---- TableMult: the co-occurrence matrix A^T A
    let c_table = store.create_table("C", vec![]).unwrap();
    let stats =
        graphulo::table_mult(&t.main(), &t.main(), &c_table, &Default::default()).unwrap();
    let server_c = graphulo::read_product(&c_table).unwrap();
    let client_c = g.transpose().matmul(&g);
    assert_eq!(server_c.triples().len(), client_c.triples().len());
    println!(
        "TableMult: {} partial products -> {} output nnz, peak {} row entries (server == client ✓)",
        stats.partial_products,
        server_c.nnz(),
        stats.peak_row_entries
    );

    // ---- Jaccard
    let deg = t.degree_table().unwrap();
    let server_j = graphulo::jaccard_server(&store, &t.main(), &deg, "J").unwrap();
    let client_j = graphulo::jaccard_assoc(&g);
    assert_eq!(server_j.nnz(), client_j.nnz(), "Jaccard server/client mismatch");
    println!("Jaccard: {} vertex-pair coefficients (server == client ✓)", server_j.nnz());
    // top coefficient
    if let Some(top) = server_j
        .triples()
        .into_iter()
        .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
    {
        println!("  most similar pair: {} ~ {} (J = {:.3})", top.0, top.1, top.2);
    }

    // ---- k-truss
    let sym = graphulo::symmetrise_table(&store, &t.main(), "G_sym").unwrap();
    let server_kt = graphulo::ktruss_server(&store, &sym, 3, "KT").unwrap();
    let client_kt = graphulo::ktruss_assoc(&g, 3);
    assert_eq!(server_kt.triples(), client_kt.triples(), "k-truss server/client mismatch");
    println!(
        "3-truss: {} of {} (symmetrised) edges survive (server == client ✓)",
        server_kt.nnz(),
        g.nnz() * 2
    );
}
