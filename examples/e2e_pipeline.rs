//! End-to-end driver (DESIGN.md E2E): exercises every layer of the stack
//! on a real small workload and reports the paper's headline metrics.
//!
//!  1. generate a Kronecker (Graph500) graph — the D4M benchmark workload;
//!  2. stream it through the **parallel ingest pipeline** (sharding,
//!     bounded queues, backpressure) into the embedded Accumulo substrate
//!     with the full D4M 2.0 schema (edge + transpose + degree tables);
//!  3. compile a select → matmul → sum chain into **one plan** and
//!     execute it server-side in a single request, verifying it is
//!     bit-identical to the sequential round trips and that the fused
//!     executor materialised zero intermediates;
//!  4. run **Graphulo TableMult** server-side and the client-side D4M
//!     baseline, verifying agreement;
//!  5. run the dense-block TableMult through the **in-crate blocked
//!     dense GEMM** (parallel over row tiles), verifying against the
//!     CSR result;
//!  6. run BFS + Jaccard server-side;
//!  7. print the ingest rate and TableMult rate — the headline numbers
//!     recorded in EXPERIMENTS.md.
//!
//! Run with: `make e2e` or
//! `cargo run --release --example e2e_pipeline [SCALE]`

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::time::Instant;

use d4m::assoc::KeySel;
use d4m::connectors::TableQuery;
use d4m::coordinator::{D4mApi, D4mServer};
use d4m::gen::{kronecker_triples, vertex_key, KroneckerParams};
use d4m::pipeline::PipelineConfig;
use d4m::util::fmt_rate;
use d4m::Plan;

fn main() {
    let scale: u32 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let params = KroneckerParams::new(scale, 16, 20170710);
    println!("== D4M 3.0 end-to-end: Kronecker SCALE={scale} ef=16 ==");
    println!(
        "vertices={} edges={}\n",
        params.num_vertices(),
        params.num_edges()
    );

    let server = D4mServer::new();
    println!(
        "dense engine: {}",
        if server.has_engine() { "attached (native blocked GEMM)" } else { "absent" }
    );

    // ---- 1+2: generate + pipeline ingest (the example programs against
    // the D4mApi trait, so everything below runs unchanged against a
    // RemoteD4m — swap the constructor and the calls stay identical)
    let triples = kronecker_triples(&params);
    let ingest = server
        .ingest(
            "G",
            triples,
            PipelineConfig { num_workers: 4, batch_size: 4096, ..Default::default() },
        )
        .expect("ingest");
    println!("[ingest]    {ingest}");

    // ---- 2b: the unified T(r, c) surface — a row-range selector pushed
    // down into the engine through the coordinator's DbTable registry
    let range_q = TableQuery::all().rows(KeySel::Range(vertex_key(0), vertex_key(63)));
    let sub = server.query("G", range_q.clone()).expect("range query");
    println!(
        "[query]     T('{}:{}', :) -> {} rows, {} nnz",
        vertex_key(0),
        vertex_key(63),
        sub.row_keys().len(),
        sub.nnz()
    );

    // ---- 2c: the same selection as a streaming cursor scan — bounded
    // pages over a pinned snapshot, assembled bit-identically
    let mut pages = 0usize;
    let mut page_triples: Vec<(String, String, String)> = Vec::new();
    for page in server.scan_pages("G", range_q, 256) {
        page_triples.extend(page.expect("cursor page"));
        pages += 1;
    }
    let paged = d4m::assoc::io::parse_triples(page_triples).expect("assemble pages");
    assert_eq!(paged, sub, "paged scan diverged from one-shot query");
    println!("[cursor]    same selection in {pages} pages of <= 256 entries ✓");

    // ---- 3: the multi-op chain as ONE compiled plan. Sequentially this
    // is two Query round trips plus client-side matmul + sum; the plan
    // ships the whole expression server-side, folds the select into the
    // scan and streams the reduce through the contraction, so nothing the
    // answer doesn't need is ever materialised.
    let range = KeySel::Range(vertex_key(0), vertex_key(63));
    let ops = Plan::table("G")
        .select(range, KeySel::All)
        .matmul(&Plan::table("G"))
        .sum(2)
        .compile()
        .expect("compile plan");
    let t = Instant::now();
    let (planned, pstats) = server.plan(&ops).expect("plan");
    let dt_plan = t.elapsed().as_secs_f64();
    // the same chain in the compact text syntax compiles to the same ops
    let expr = format!("sum(G('{},:,{},', ':') * G, 2)", vertex_key(0), vertex_key(63));
    assert_eq!(
        Plan::parse(&expr).expect("parse").compile().expect("compile"),
        ops,
        "text syntax and builder compiled differently"
    );
    // sequential reference: what a pre-plan client had to do
    let g_full = server.query("G", TableQuery::all()).expect("full query");
    let sequential = sub.matmul(&g_full).sum(2);
    assert_eq!(planned, sequential, "plan diverged from sequential ops");
    assert_eq!(pstats.intermediates, 0, "fused plan materialised an intermediate");
    // the same plan drained through a streaming cursor, page by page
    let mut plan_triples: Vec<(String, String, String)> = Vec::new();
    for page in server.plan_pages(&ops, 256) {
        plan_triples.extend(page.expect("plan cursor page"));
    }
    let plan_paged = d4m::assoc::io::parse_triples(plan_triples).expect("assemble plan pages");
    assert_eq!(plan_paged, planned, "paged plan diverged from one-shot plan");
    println!(
        "[plan]      {expr}: {} nnz in {:.2}s, one request ({pstats}) ✓",
        planned.nnz(),
        dt_plan
    );

    // ---- 4: TableMult server vs client
    let t0 = Instant::now();
    let stats = server.tablemult("G", "G", "C").expect("server tablemult");
    let dt_server = t0.elapsed().as_secs_f64();
    let server_c = d4m::graphulo::read_product(&server.store().table("C").unwrap()).unwrap();
    println!(
        "[graphulo]  TableMult: {} partials in {:.2}s = {} (peak {} row entries)",
        stats.partial_products,
        dt_server,
        fmt_rate(stats.partial_products as f64 / dt_server),
        stats.peak_row_entries
    );

    let t1 = Instant::now();
    let client_c = server.tablemult_client("G", "G", usize::MAX).expect("client tablemult");
    let dt_client = t1.elapsed().as_secs_f64();
    println!(
        "[d4m]       TableMult: {} nnz in {:.2}s = {}",
        client_c.nnz(),
        dt_client,
        fmt_rate(stats.partial_products as f64 / dt_client)
    );
    assert_eq!(server_c.nnz(), client_c.nnz(), "server/client TableMult disagree");
    println!("[verify]    graphulo == d4m client ✓ ({} output nnz)", server_c.nnz());

    // ---- 5: dense path through the blocked GEMM. The raw Kronecker graph
    // is too sparse for dense tiles, but its co-occurrence product C is
    // dense-ish — exactly the operand profile the dense path targets. We
    // compute C^T C both ways and verify.
    if server.has_engine() {
        // subsample C's hub rows to keep the dense demo quick at any SCALE
        let hub = client_c.select_rows(&d4m::assoc::KeySel::Range(
            d4m::gen::vertex_key(0),
            d4m::gen::vertex_key(300),
        ));
        let engine = server.engine().unwrap();
        let t2 = Instant::now();
        let tile = d4m::runtime::blocks::best_tile(hub.row_keys().len(), hub.col_keys().len(), hub.col_keys().len());
        let dense = d4m::runtime::blocks::assoc_at_b_dense(engine, &hub, &hub, tile)
            .expect("dense tablemult");
        let dt = t2.elapsed().as_secs_f64();
        let csr = hub.transpose().matmul(&hub);
        assert_eq!(dense.nnz(), csr.nnz(), "dense path nnz mismatch");
        let probe = csr.triples();
        for t in probe.iter().step_by((probe.len() / 50).max(1)) {
            let got = dense.get(&t.0, &t.1);
            assert!(
                (got - t.2).abs() < 1e-2 * t.2.abs().max(1.0),
                "dense mismatch at ({}, {}): {} vs {}",
                t.0,
                t.1,
                got,
                t.2
            );
        }
        println!(
            "[dense]     blocked-GEMM C^T C: {} nnz in {:.2}s, {} kernel calls ✓",
            dense.nnz(),
            dt,
            engine.calls.get()
        );
    }

    // ---- 6: BFS + Jaccard
    let seed = vertex_key(1);
    let t3 = Instant::now();
    let d = server.bfs("G", &[seed.as_str()], 3).expect("bfs");
    println!("[bfs]       {} vertices within 3 hops of {seed} ({:.2}s)", d.len(), t3.elapsed().as_secs_f64());

    let t4 = Instant::now();
    let j = server.jaccard("G", "J").expect("jaccard");
    println!("[jaccard]   {} coefficients ({:.2}s)", j.nnz(), t4.elapsed().as_secs_f64());

    // ---- 7: headline metrics
    println!("\n== headline metrics (EXPERIMENTS.md) ==");
    println!("ingest rate:          {} logical / {} physical", fmt_rate(ingest.rate), fmt_rate(ingest.physical_rate));
    println!(
        "graphulo tablemult:   {} partial products/s",
        fmt_rate(stats.partial_products as f64 / dt_server)
    );
    println!("\nper-op metrics:");
    for s in server.snapshots() {
        println!("  {s}");
    }
}
