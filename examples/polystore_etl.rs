//! Polystore ETL: the BigDAWG text-island role of D4M. A document corpus
//! is ingested into the Accumulo (text) island, CAST through associative
//! arrays into the SciDB (array) island, multiplied *in the array store*,
//! and the result CAST into the relational island for SQL-style reads.
//!
//! Run with: `cargo run --release --example polystore_etl`


use d4m::connectors::D4mTableConfig;
use d4m::gen::doc_word_triples;
use d4m::polystore::{CrossOp, Island, Polystore};
use d4m::relational::Predicate;

fn main() {
    let p = Polystore::new();

    // ---- 1. land raw text triples in the text island (Accumulo engine)
    let raw = doc_word_triples(50, 20, 200, 7);
    println!("corpus: {} (doc, word, count) triples", raw.len());
    let t = p.text.bind("corpus", &D4mTableConfig::default()).unwrap();
    t.put_triples(&raw).unwrap();
    let a = t.get_assoc().unwrap();
    println!(
        "text island: {} docs x {} words, {} nnz",
        a.row_keys().len(),
        a.col_keys().len(),
        a.nnz()
    );

    // ---- 2. CAST text -> array island
    let a = p.cast(Island::Text, "corpus", Island::Array, "corpus_arr").unwrap();
    println!("cast into array island as corpus_arr ({} cells)", a.nnz());

    // ---- 3. compute word co-occurrence IN the array store (SciDB spgemm)
    let cooc = p.array.matmul_assocs(&a.transpose(), &a, "cooc", 64).unwrap();
    println!("in-store spgemm: co-occurrence has {} nnz", cooc.nnz());

    // ---- 4. CAST the result into the relational island
    p.put(Island::Relational, "cooc_rel", &cooc).unwrap();
    println!("cast into relational island as cooc_rel");

    // ---- 5. SQL-style read with a predicate pushed into the engine
    let pred: Predicate = Box::new(|row| row[2].as_f64().unwrap_or(0.0) >= 10.0);
    let heavy = p.relational.get_assoc_where("cooc_rel", Some(&pred)).unwrap();
    println!("word pairs with co-occurrence weight >= 10: {}", heavy.nnz());
    for (w1, w2, v) in heavy.triples().into_iter().take(5) {
        println!("  {w1} x {w2} = {v}");
    }

    // ---- 6. verify end-to-end: relational island agrees with a pure
    //         client-side recomputation from the text-island assoc.
    //         (Note: duplicate (doc, word) triples OVERWRITE in the
    //         key-value store — Accumulo versioning — so the ground truth
    //         is the assoc as stored, not the raw triple multiset.)
    let want = a.transpose().matmul(&a);
    let got = p.get(Island::Relational, "cooc_rel").unwrap();
    assert_eq!(want.nnz(), got.nnz(), "polystore round-trip diverged (nnz)");
    for t in want.triples().iter().step_by(101) {
        assert!(
            (got.get(&t.0, &t.1) - t.2).abs() < 1e-9,
            "polystore round-trip diverged at ({}, {})",
            t.0,
            t.1
        );
    }
    println!("verification: relational island == client recomputation ✓");

    // ---- 7. cross-island join for good measure
    let joined = p
        .cross_join(
            (Island::Array, "corpus_arr"),
            (Island::Relational, "cooc_rel"),
            CrossOp::MatMul,
            (Island::Text, "doc_word_scores"),
        )
        .unwrap();
    println!("cross-island matmul (array x relational -> text): {} nnz", joined.nnz());
}
