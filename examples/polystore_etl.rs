//! Polystore ETL: the BigDAWG text-island role of D4M. A document corpus
//! is ingested into the Accumulo (text) island, CAST through associative
//! arrays into the SciDB (array) island, multiplied *in the array store*,
//! and the result CAST into the relational island for SQL-style reads —
//! all through the unified `DbServer`/`DbTable` binding API, with
//! engine-native handles kept only for engine-specific ops (raw triple
//! ingest, in-store spgemm).
//!
//! Run with: `cargo run --release --example polystore_etl`

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use d4m::assoc::KeySel;
use d4m::connectors::{AccumuloConnector, D4mTableConfig, DbTable, SciDbConnector, TableQuery};
use d4m::gen::doc_word_triples;
use d4m::polystore::{CrossOp, Island, Polystore};

fn main() {
    // Register clonable engines so we keep native handles to the same
    // stores the polystore routes to (the paper's "one API, native
    // escape hatches" stance).
    let acc = AccumuloConnector::new();
    let scidb = SciDbConnector::new();
    let mut p = Polystore::new();
    p.register(Island::Text, Box::new(acc.clone()));
    p.register(Island::Array, Box::new(scidb.clone()));

    // ---- 1. land raw text triples in the text island (Accumulo engine;
    //         raw-triple ingest is a native op — duplicates OVERWRITE,
    //         Accumulo versioning)
    let raw = doc_word_triples(50, 20, 200, 7);
    println!("corpus: {} (doc, word, count) triples", raw.len());
    let t = acc.bind("corpus", &D4mTableConfig::default()).unwrap();
    t.put_triples(&raw).unwrap();
    let a = p.get(Island::Text, "corpus").unwrap();
    println!(
        "text island: {} docs x {} words, {} nnz",
        a.row_keys().len(),
        a.col_keys().len(),
        a.nnz()
    );

    // ---- 2. CAST text -> array island (two trait calls, no engine code)
    let a = p.cast(Island::Text, "corpus", Island::Array, "corpus_arr").unwrap();
    println!("cast into array island as corpus_arr ({} cells)", a.nnz());

    // ---- 3. compute word co-occurrence IN the array store (SciDB spgemm,
    //         via the native handle registered above)
    let cooc = scidb.matmul_assocs(&a.transpose(), &a, "cooc", 64).unwrap();
    println!("in-store spgemm: co-occurrence has {} nnz", cooc.nnz());

    // ---- 4. CAST the result into the relational island
    p.put(Island::Relational, "cooc_rel", &cooc).unwrap();
    println!("cast into relational island as cooc_rel");

    // ---- 5. engine-generic T(r, c) query with pushdown: word pairs in
    //         a key range, WHERE-filtered inside the relational engine
    let some_word = cooc.row_keys()[cooc.row_keys().len() / 2].clone();
    let q = TableQuery::all().rows(KeySel::Range(some_word.clone(), "zzzz".into()));
    let tail = p.query(Island::Relational, "cooc_rel", &q).unwrap();
    println!("co-occurrence rows from {some_word:?} on: {} nnz", tail.nnz());

    // ---- 6. verify end-to-end: relational island agrees with a pure
    //         client-side recomputation from the text-island assoc.
    let want = a.transpose().matmul(&a);
    let got = p.get(Island::Relational, "cooc_rel").unwrap();
    assert_eq!(want.nnz(), got.nnz(), "polystore round-trip diverged (nnz)");
    for t in want.triples().iter().step_by(101) {
        assert!(
            (got.get(&t.0, &t.1) - t.2).abs() < 1e-9,
            "polystore round-trip diverged at ({}, {})",
            t.0,
            t.1
        );
    }
    println!("verification: relational island == client recomputation ✓");

    // the same range query must agree on every island (the conformance
    // contract of the unified API)
    p.put(Island::Array, "cooc_arr", &cooc).unwrap();
    let from_arr = p.query(Island::Array, "cooc_arr", &q).unwrap();
    assert_eq!(tail.triples(), from_arr.triples(), "cross-engine query diverged");
    println!("verification: relational == array island on the same TableQuery ✓");

    // ---- 7. paged scan of the co-occurrence table (the D4M.jl
    //         table-iterator pattern; values fetched one page at a time)
    let scan_q = TableQuery::all().page_rows(8);
    let mut pages = 0usize;
    let mut scanned = 0usize;
    for page in p.bind(Island::Relational, "cooc_rel").unwrap().scan(&scan_q).unwrap() {
        let page = page.unwrap();
        pages += 1;
        scanned += page.nnz();
    }
    println!("paged scan: {scanned} entries over {pages} pages of ≤8 rows");
    assert_eq!(scanned, cooc.nnz());

    // ---- 8. cross-island join for good measure
    let joined = p
        .cross_join(
            (Island::Array, "corpus_arr"),
            (Island::Relational, "cooc_rel"),
            CrossOp::MatMul,
            (Island::Text, "doc_word_scores"),
        )
        .unwrap();
    println!("cross-island matmul (array x relational -> text): {} nnz", joined.nnz());
}
