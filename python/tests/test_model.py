"""L2 correctness: model-level graphs (the things AOT actually lowers)
match the oracle end-to-end, and the artifact registry is well-formed."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape).astype(np.float32))


def test_tablemult_fn():
    a, b = _rand((128, 128), 1), _rand((128, 128), 2)
    (got,) = model.tablemult_fn(a, b)
    np.testing.assert_allclose(got, ref.at_b(a, b), rtol=1e-4, atol=1e-3)


def test_matmul_fn():
    a, b = _rand((128, 128), 3), _rand((128, 128), 4)
    (got,) = model.matmul_fn(a, b)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-3)


def test_degree_fn():
    a = _rand((128, 128), 5)
    (got,) = model.degree_fn(a)
    np.testing.assert_allclose(got, ref.degree_rowsum(a), rtol=1e-5, atol=1e-4)


def test_jaccard_fn():
    a = jnp.asarray(
        (np.random.default_rng(6).random((128, 128)) < 0.1).astype(np.float32)
    )
    (got,) = model.jaccard_fn(a)
    want = ref.jaccard_end_to_end(a)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert not np.any(np.isnan(got))


def test_artifact_registry_shapes():
    assert len(model.ARTIFACTS) == 8  # 4 graphs x 2 tile configs
    for name, (fn, args) in model.ARTIFACTS.items():
        assert callable(fn)
        for a in args:
            assert all(s in (128, 512, 1) for s in a.shape), (name, a.shape)


def test_artifacts_lower_to_hlo_text():
    # lowering every artifact is what `make artifacts` does; make sure the
    # small config lowers and mentions the expected ops.
    from compile.aot import to_hlo_text

    fn, args = model.ARTIFACTS["tablemult_128x128x128"]
    text = to_hlo_text(jax.jit(fn).lower(*args))
    assert "HloModule" in text
    assert "dot(" in text or "dot " in text
