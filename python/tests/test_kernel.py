"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

hypothesis sweeps tile-divisible shapes and dtypes; assert_allclose
against the oracle is THE correctness signal for the compiled artifacts
the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import combine, ref, tablemult

# small tiles so the sweep stays fast under interpret=True
BM = BN = BK = 8


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    if dtype == jnp.bfloat16:
        return jnp.asarray(rng.standard_normal(shape, dtype=np.float32)).astype(dtype)
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


dims = st.integers(min_value=1, max_value=4).map(lambda t: t * BM)
dtypes = st.sampled_from([jnp.float32, jnp.bfloat16])


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, dtype, seed):
    x = _rand((m, k), dtype, seed)
    y = _rand((k, n), dtype, seed + 1)
    got = tablemult.matmul(x, y, bm=BM, bn=BN, bk=BK)
    want = ref.matmul(x, y)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * k)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_at_b_matches_ref(m, k, n, dtype, seed):
    a = _rand((k, m), dtype, seed)
    b = _rand((k, n), dtype, seed + 1)
    got = tablemult.at_b(a, b, bm=BM, bn=BN, bk=BK)
    want = ref.at_b(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * k)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_jaccard_combine_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    # counts are nonneg; degrees >= the counts so denominators behave
    cnt = jnp.asarray(rng.integers(0, 5, size=(m, n)).astype(np.float32))
    dr = jnp.asarray(rng.integers(0, 10, size=(m, 1)).astype(np.float32))
    dc = jnp.asarray(rng.integers(0, 10, size=(1, n)).astype(np.float32))
    got = combine.jaccard_combine(cnt, dr, dc, bm=BM, bn=BN)
    want = ref.jaccard_combine(cnt, dr, dc)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_degree_rowsum_matches_ref(m, n, seed):
    x = _rand((m, n), jnp.float32, seed)
    got = combine.degree_rowsum(x, bm=BM, bn=BN)
    want = ref.degree_rowsum(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_identity():
    eye = jnp.eye(16, dtype=jnp.float32)
    x = _rand((16, 16), jnp.float32, 7)
    np.testing.assert_allclose(tablemult.matmul(x, eye, bm=8, bn=8, bk=8), x, rtol=1e-6)


def test_at_b_equals_transpose_matmul():
    a = _rand((24, 16), jnp.float32, 3)
    b = _rand((24, 8), jnp.float32, 4)
    got = tablemult.at_b(a, b, bm=8, bn=8, bk=8)
    want = tablemult.matmul(jnp.asarray(a).T.copy(), b, bm=8, bn=8, bk=8)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_jaccard_zero_denominator_is_zero():
    n = jnp.zeros((8, 8), jnp.float32)
    d = jnp.zeros((8, 1), jnp.float32)
    out = combine.jaccard_combine(n, d, d.T, bm=8, bn=8)
    assert not np.any(np.isnan(out))
    np.testing.assert_array_equal(out, np.zeros((8, 8)))


def test_jaccard_self_similarity_is_one():
    # identical columns: N[i,i] = deg_i, so J on the diagonal = 1
    a = jnp.asarray((np.random.default_rng(0).random((16, 16)) < 0.5).astype(np.float32))
    j = ref.jaccard_end_to_end(a)
    deg = np.asarray(a).sum(axis=0)
    diag = np.diag(np.asarray(j))
    np.testing.assert_allclose(diag[deg > 0], 1.0, rtol=1e-6)


def test_shape_mismatch_raises():
    x = jnp.zeros((8, 8), jnp.float32)
    y = jnp.zeros((16, 8), jnp.float32)
    with pytest.raises(AssertionError):
        tablemult.matmul(x, y, bm=8, bn=8, bk=8)


def test_non_divisible_raises():
    x = jnp.zeros((9, 8), jnp.float32)
    y = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(AssertionError):
        tablemult.matmul(x, y, bm=8, bn=8, bk=8)
