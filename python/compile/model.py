"""L2: the D4M numeric compute graph, calling the L1 Pallas kernels.

Three exported computations, each AOT-lowered by aot.py into one HLO
artifact per tile configuration:

  tablemult   C = A^T B          (the TableMult dense-block hot path)
  degree      d = rowsum(A)      (degree-table primitive, sum(A, 2))
  jaccard     J = jacc(A^T A, deg A)   (fused Graphulo Jaccard block)

All dense-block shapes are fixed at lowering time (AOT); the L3 runtime
pads CSR blocks up to the artifact's shape and slices results back down.
Python never runs at request time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import combine, tablemult


def tablemult_fn(a, b):
    """TableMult dense-block product: (K, M) x (K, N) -> (M, N) = a^T b."""
    return (tablemult.at_b(a, b),)


def matmul_fn(a, b):
    """Plain block product (M, K) x (K, N) -> (M, N)."""
    return (tablemult.matmul(a, b),)


def degree_fn(a):
    """Row-degree of a block: (M, N) -> (M, 1)."""
    return (combine.degree_rowsum(a),)


def jaccard_fn(a):
    """Fused Jaccard over an incidence block a (K, M):
    N = a^T a; deg = colsum(a); J = N / (deg_i + deg_j - N).
    The colsum reuses the rowsum kernel on the implicit transpose by
    summing along axis 0 with a degree_rowsum over a^T a's structure —
    here computed via the tablemult kernel against a ones vector would
    cost a full pass, so we let XLA fuse a jnp colsum with the two
    pallas calls.
    """
    n = tablemult.at_b(a, a)
    deg = jnp.sum(a.astype(jnp.float32), axis=0, keepdims=True)  # (1, M)
    return (combine.jaccard_combine(n, deg.T, deg),)


#: artifact name -> (function, example-arg builder)
def _specs(k: int, m: int, n: int):
    f32 = jnp.float32
    return {
        f"tablemult_{k}x{m}x{n}": (
            tablemult_fn,
            (jax.ShapeDtypeStruct((k, m), f32), jax.ShapeDtypeStruct((k, n), f32)),
        ),
        f"matmul_{m}x{k}x{n}": (
            matmul_fn,
            (jax.ShapeDtypeStruct((m, k), f32), jax.ShapeDtypeStruct((k, n), f32)),
        ),
        f"degree_{m}x{n}": (
            degree_fn,
            (jax.ShapeDtypeStruct((m, n), f32),),
        ),
        f"jaccard_{k}x{m}": (
            jaccard_fn,
            (jax.ShapeDtypeStruct((k, m), f32),),
        ),
    }


#: the artifact set the rust runtime expects (see rust/src/runtime/).
#: one small config for tests, one production 512-block config.
ARTIFACTS = {}
for _k, _m, _n in [(128, 128, 128), (512, 512, 512)]:
    ARTIFACTS.update(_specs(_k, _m, _n))
