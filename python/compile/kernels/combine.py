"""L1 Pallas kernels: elementwise combiners used by the Graphulo algorithms.

jaccard_combine — given the co-occurrence counts N = A^T A and the vertex
degrees, produce the Jaccard coefficient matrix
    J[i,j] = N[i,j] / (deg[i] + deg[j] - N[i,j]).

degree_rowsum — row sums of a dense block (the D4M ``sum(A, 2)`` / degree
table primitive), emitted as an (m, 1) column so it fuses into the same
AOT artifact set.

Both are pure VPU elementwise/reduce work: one (bm, bn) tile per grid
step, trivially VMEM resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jaccard_kernel(n_ref, dr_ref, dc_ref, o_ref):
    n = n_ref[...]
    # deg rows broadcast down columns, deg cols across rows.
    denom = dr_ref[...] + dc_ref[...] - n
    # guard zero denominators (isolated vertex pairs): define J = 0 there.
    safe = jnp.where(denom > 0, denom, 1.0)
    o_ref[...] = jnp.where(denom > 0, n / safe, 0.0)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def jaccard_combine(
    n: jax.Array, deg_row: jax.Array, deg_col: jax.Array, *, bm: int = 128, bn: int = 128
):
    """J = n / (deg_row + deg_col - n), elementwise, tiled.

    n: (M, N) co-occurrence counts; deg_row: (M, 1); deg_col: (1, N).
    """
    m, nn = n.shape
    assert deg_row.shape == (m, 1) and deg_col.shape == (1, nn)
    assert m % bm == 0 and nn % bn == 0
    grid = (m // bm, nn // bn)
    return pl.pallas_call(
        _jaccard_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, nn), jnp.float32),
        interpret=True,
    )(n, deg_row, deg_col)


def _rowsum_kernel(x_ref, o_ref, *, n_j: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...], axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def degree_rowsum(x: jax.Array, *, bm: int = 128, bn: int = 128):
    """(M, N) -> (M, 1) row sums (vertex out-degrees of a block)."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_rowsum_kernel, n_j=grid[1]),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=True,
    )(x)
