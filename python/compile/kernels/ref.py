"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness signal: pytest asserts kernel output ==
oracle output (allclose) across a hypothesis sweep of shapes/dtypes.
No pallas, no tiling — just the mathematical definition.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul(x, y):
    """C = x @ y, f32 accumulation."""
    return jnp.dot(
        x.astype(jnp.float32), y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def at_b(a, b):
    """C = a^T @ b, f32 accumulation."""
    return jnp.dot(
        a.astype(jnp.float32).T, b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def jaccard_combine(n, deg_row, deg_col):
    """J = n / (deg_row + deg_col - n), 0 where the denominator is <= 0."""
    denom = deg_row + deg_col - n
    return jnp.where(denom > 0, n / jnp.where(denom > 0, denom, 1.0), 0.0)


def degree_rowsum(x):
    """(M, N) -> (M, 1) row sums."""
    return jnp.sum(x.astype(jnp.float32), axis=1, keepdims=True)


def jaccard_end_to_end(a):
    """Full Jaccard over an unweighted incidence block a (K, M):
    N = a^T a, deg = colsum(a), J = N / (deg_i + deg_j - N)."""
    n = at_b(a, a)
    deg = jnp.sum(a.astype(jnp.float32), axis=0, keepdims=True)  # (1, M)
    return jaccard_combine(n, deg.T, deg)
