"""L1 Pallas kernel: tiled dense-block matmul for the D4M TableMult hot path.

D4M's TableMult over numeric associative arrays reduces, after key
alignment, to C = A^T * B on the underlying sparse matrices.  The L3
coordinator blocks the aligned matrices into dense tiles and dispatches
the dense tile product to this kernel (via the AOT-compiled L2 graph).

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * grid = (M/bm, N/bn, K/bk); the K axis is the innermost grid dim so a
    given (i, j) output tile stays resident in VMEM across the whole K
    sweep (revisiting semantics of pallas grids).
  * tiles default to 128x128 — exactly one MXU systolic pass per
    jnp.dot, 3 * 64KiB = 192KiB of VMEM per step.
  * accumulation is f32 regardless of input dtype.

On this image kernels run under interpret=True (CPU); real-TPU lowering
would emit a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One grid step: o[i,j] (+)= x[i,k] @ y[k,j].

    The K grid axis is innermost; on k == 0 we initialise the output tile,
    afterwards we accumulate into it.  ``n_k`` is captured statically.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """C = x @ y with (bm, bn, bk) tiling.  Shapes must divide evenly.

    The L3 runtime pads CSR blocks to tile multiples before dispatch, so
    the even-division restriction never bites at runtime.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by tiles ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _at_b_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """One grid step of C = A^T @ B: o[i,j] (+)= a[k,i]^T @ b[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].T, b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def at_b(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """C = a^T @ b without materialising a^T (TableMult's native form).

    a: (K, M), b: (K, N) -> (M, N).  The transpose happens inside the
    tile (a VMEM-local relayout feeding the MXU), never in HBM.
    """
    k, m = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({k},{m})^T x ({k},{n}) not divisible by ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_at_b_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
