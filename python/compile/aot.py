"""AOT lowering: L2 jax graphs -> HLO *text* artifacts for the rust runtime.

HLO text, NOT .serialize(): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (what the published `xla` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits one `<name>.hlo.txt` per entry in model.ARTIFACTS plus a manifest.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, example_args) in model.ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(a.shape), "dtype": str(a.dtype)}
                for a in example_args
            ],
            "chars": len(text),
        }
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    # Back-compat with the scaffold Makefile target (--out file implies dir).
    p.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = p.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    manifest = lower_all(out_dir or ".")
    if args.out:
        # touch the sentinel the Makefile tracks
        with open(args.out, "w") as f:
            f.write(json.dumps(sorted(manifest)))
    print(f"wrote {len(manifest)} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
