#!/usr/bin/env python3
"""Promote CI-produced bench records into the committed baselines.

The bench-trajectory CI job uploads the post-run BENCH_*.json files
(committed records + the records this run appended) as the
``bench-trajectory`` artifact. ROADMAP's "commit fresh records
periodically" chore is this script: download the artifact, run

    python3 tools/bench_promote.py path/to/artifact-dir

and commit the rewritten BENCH files. The fresh records become the
regression baseline for every later run (tools/bench_check.py compares
against ``git show HEAD:<file>``).

To keep the committed trajectory from growing without bound, each
(op, backend, n) key retains at most ``--max-per-key`` most-recent
records (default 4 — enough to eyeball a trend in-repo; the full
history lives in the per-run artifacts).

Exit status: 0 = promoted, 2 = usage/IO error.
"""

import argparse
import json
import os
import sys

BENCH_FILES = ["BENCH_assoc.json", "BENCH_scan.json", "BENCH_net.json",
               "BENCH_ingest.json"]
REQUIRED_FIELDS = {"op", "backend", "n", "seconds", "entries_per_sec"}


def trim(records, max_per_key):
    """Keep at most the last `max_per_key` records per key, preserving
    overall append order."""
    key = lambda r: (r["op"], r["backend"], r["n"])
    keep = [False] * len(records)
    seen = {}
    for i in range(len(records) - 1, -1, -1):
        k = key(records[i])
        if seen.get(k, 0) < max_per_key:
            seen[k] = seen.get(k, 0) + 1
            keep[i] = True
    return [r for r, k in zip(records, keep) if k]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact_dir",
                    help="directory holding the downloaded bench-trajectory artifact")
    ap.add_argument("--max-per-key", type=int, default=4,
                    help="most-recent records kept per (op, backend, n) key")
    ap.add_argument("--repo-root", default=os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), help="where the committed BENCH files live")
    args = ap.parse_args()

    promoted = 0
    for name in BENCH_FILES:
        src = os.path.join(args.artifact_dir, name)
        if not os.path.exists(src):
            print(f"bench_promote: {name}: not in artifact — skipping")
            continue
        try:
            with open(src, encoding="utf-8") as f:
                records = json.load(f)
        except json.JSONDecodeError as e:
            print(f"bench_promote: {src}: invalid JSON ({e})")
            return 2
        bad = [r for r in records
               if not isinstance(r, dict) or not REQUIRED_FIELDS <= set(r)]
        if bad:
            print(f"bench_promote: {src}: {len(bad)} malformed record(s) — refusing")
            return 2
        trimmed = trim(records, max(1, args.max_per_key))
        dst = os.path.join(args.repo_root, name)
        with open(dst, "w", encoding="utf-8") as f:
            f.write("[\n")
            f.write(",\n".join(
                "  " + json.dumps(r, separators=(",", ":"), sort_keys=False)
                for r in trimmed))
            f.write("\n]\n")
        print(f"bench_promote: {name}: {len(records)} artifact record(s) -> "
              f"{len(trimmed)} committed (max {args.max_per_key}/key)")
        promoted += 1

    if promoted == 0:
        print("bench_promote: nothing promoted — is the artifact dir right?")
        return 2
    print("bench_promote: done — review `git diff BENCH_*.json` and commit")
    return 0


if __name__ == "__main__":
    sys.exit(main())
