pub fn parse(buf: &[u8]) -> u8 {
    let first = buf.first().copied().unwrap();
    let second = buf[1];
    first + second
}
