fn main() {
    let rows = vec![("net.requests", 1u64), ("net.bogus_counter", 2u64)];
    for (name, v) in rows {
        println!("{name} {v}");
    }
}
