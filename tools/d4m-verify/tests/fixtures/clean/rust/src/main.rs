fn main() {
    println!("{}", "net.requests");
}
