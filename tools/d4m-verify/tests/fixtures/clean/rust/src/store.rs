use std::sync::{Mutex, RwLock};
pub struct S { inner: Mutex<u32>, tablets: Vec<RwLock<u32>> }
impl S {
    pub fn ordered(&self) -> u32 {
        let g = self.inner.lock().unwrap();
        let tl = self.tablets[0].read().unwrap();
        let v = *g + *tl;
        drop(tl);
        drop(g);
        v
    }
}
