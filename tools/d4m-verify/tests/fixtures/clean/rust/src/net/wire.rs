pub enum WireError {
    Retired { what: &'static str, tag: u8 },
    Unknown(u8),
}

pub fn get_request(tag: u8) -> Result<u32, WireError> {
    match tag {
        0 => Ok(0),
        1 => Ok(1),
        tag @ (4 | 5) => Err(WireError::Retired { what: "Request", tag }),
        other => Err(WireError::Unknown(other)),
    }
}
