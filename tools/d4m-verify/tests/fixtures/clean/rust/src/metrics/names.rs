pub const NET_REQUESTS: &str = "net.requests";
pub const STORAGE_FLUSHES: &str = "storage.flushes";
