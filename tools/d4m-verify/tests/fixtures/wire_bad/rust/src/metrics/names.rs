pub const NET_REQUESTS: &str = "net.requests";
