pub enum WireError {
    Retired { what: &'static str, tag: u8 },
}

pub fn get_request(tag: u8) -> Result<u32, WireError> {
    match tag {
        0 => Ok(0),
        1 => Ok(1),
        1 => Ok(2),
        tag @ (4 | 5) => Err(WireError::Retired { what: "Request", tag }),
        _ => Ok(99),
    }
}
