use std::sync::{Mutex, RwLock};
pub struct S { inner: Mutex<u32>, tablets: Vec<RwLock<u32>> }
impl S {
    pub fn bad(&self) {
        let tl = self.tablets[0].read().unwrap();
        let g = self.inner.lock().unwrap();
        drop(g);
        drop(tl);
    }
    pub fn good(&self) {
        let g = self.inner.lock().unwrap();
        let tl = self.tablets[0].read().unwrap();
        drop(tl);
        drop(g);
    }
    pub fn bad_stream(&self, st: &Store) {
        let g = self.inner.lock().unwrap();
        let _it = st.scan_stream(0);
        drop(g);
    }
}
