//! Fixture tests for d4m-verify: each bad fixture is a miniature repo
//! seeded with exactly one class of violation; the tests assert the
//! exact `file:line` the tool reports and the non-zero exit code, and
//! the clean fixture asserts the zero-findings/exit-0 leg.

// Bench/example/test scaffolding: unwrap/expect on setup is idiomatic
// here; clippy.toml's disallowed-methods targets library code.
#![allow(clippy::disallowed_methods)]
use std::path::{Path, PathBuf};
use std::process::{Command, Output};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn run_on(root: &Path, extra: &[&str]) -> Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_d4m-verify"));
    cmd.arg("--root").arg(root);
    for a in extra {
        cmd.arg(a);
    }
    cmd.output().expect("spawn d4m-verify")
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn panic_fixture_reports_exact_sites_and_fails() {
    let out = run_on(&fixture("panic_bad"), &[]);
    let text = stdout_of(&out);
    assert_eq!(out.status.code(), Some(1), "expected exit 1, got {out:?}");
    assert!(
        text.contains("rust/src/net/server.rs:2: [panic/unwrap] in `parse`"),
        "missing unwrap finding at server.rs:2 in:\n{text}"
    );
    assert!(
        text.contains("rust/src/net/server.rs:3: [panic/index] in `parse`"),
        "missing index finding at server.rs:3 in:\n{text}"
    );
    assert!(text.contains("2 finding(s), 0 allowlisted"), "unexpected totals:\n{text}");
}

#[test]
fn lock_fixture_reports_inversion_and_stream_under_lock() {
    let out = run_on(&fixture("locks_bad"), &[]);
    let text = stdout_of(&out);
    assert_eq!(out.status.code(), Some(1), "expected exit 1, got {out:?}");
    assert!(
        text.contains("rust/src/store.rs:6: [locks/order] in `bad`"),
        "missing lock-order finding at store.rs:6 in:\n{text}"
    );
    assert!(
        text.contains("rust/src/store.rs:18: [locks/scan-stream] in `bad_stream`"),
        "missing scan-stream finding at store.rs:18 in:\n{text}"
    );
    // the correctly-ordered fn must NOT be flagged
    assert!(!text.contains("in `good`"), "false positive on correctly ordered fn:\n{text}");
    assert!(text.contains("2 finding(s), 0 allowlisted"), "unexpected totals:\n{text}");
}

#[test]
fn wire_fixture_reports_duplicate_tag() {
    let out = run_on(&fixture("wire_bad"), &[]);
    let text = stdout_of(&out);
    assert_eq!(out.status.code(), Some(1), "expected exit 1, got {out:?}");
    assert!(
        text.contains("rust/src/net/wire.rs:9: [wire/dup-tag] in `get_request`"),
        "missing dup-tag finding at wire.rs:9 in:\n{text}"
    );
    assert!(text.contains("1 finding(s), 0 allowlisted"), "unexpected totals:\n{text}");
}

#[test]
fn counter_fixture_reports_undeclared_name() {
    let out = run_on(&fixture("counters_bad"), &[]);
    let text = stdout_of(&out);
    assert_eq!(out.status.code(), Some(1), "expected exit 1, got {out:?}");
    assert!(
        text.contains("rust/src/main.rs:2: [counters/undeclared] in `main`"),
        "missing undeclared-counter finding at main.rs:2 in:\n{text}"
    );
    assert!(text.contains("net.bogus_counter"), "finding should name the literal:\n{text}");
    assert!(text.contains("1 finding(s), 0 allowlisted"), "unexpected totals:\n{text}");
}

#[test]
fn clean_fixture_exits_zero_with_no_findings() {
    let out = run_on(&fixture("clean"), &[]);
    let text = stdout_of(&out);
    assert_eq!(out.status.code(), Some(0), "expected exit 0 on clean fixture:\n{text}");
    assert!(text.contains("0 finding(s)"), "expected zero findings:\n{text}");
}

#[test]
fn json_output_is_machine_readable() {
    let out = run_on(&fixture("panic_bad"), &["--json"]);
    let text = stdout_of(&out);
    assert_eq!(out.status.code(), Some(1));
    assert!(text.starts_with("{\"findings\":["), "not a JSON object:\n{text}");
    assert!(text.contains("\"pass\":\"panic\""), "missing pass field:\n{text}");
    assert!(text.contains("\"file\":\"rust/src/net/server.rs\""), "missing file field:\n{text}");
    assert!(text.contains("\"total\":2"), "missing total:\n{text}");
}

#[test]
fn unknown_flag_is_a_usage_error() {
    let out = run_on(&fixture("clean"), &["--frobnicate"]);
    assert_eq!(out.status.code(), Some(2), "usage errors must exit 2, got {out:?}");
}

#[test]
fn repo_tree_has_no_unallowlisted_findings() {
    // CARGO_MANIFEST_DIR = <repo>/tools/d4m-verify
    let repo = Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("repo root")
        .to_path_buf();
    let out = run_on(&repo, &[]);
    let text = stdout_of(&out);
    assert_eq!(
        out.status.code(),
        Some(0),
        "the tree must be clean modulo the allowlist; findings:\n{text}"
    );
}

// ---------------------------------------------------- allowlist policy

#[test]
fn allow_entry_without_reason_is_a_finding() {
    let src = "[[allow]]\npass = \"panic\"\nfile = \"rust/src/x.rs\"\nreason = \"\"\n";
    let (entries, findings) = d4m_verify::allow::parse(src, "allow.toml");
    assert_eq!(entries.len(), 1);
    assert!(
        findings.iter().any(|f| f.what == "no-reason"),
        "empty reason must be rejected: {findings:?}"
    );
}

#[test]
fn blanket_suppression_of_protected_file_is_a_finding() {
    let src = "[[allow]]\npass = \"panic\"\nfile = \"rust/src/net/wire.rs\"\nreason = \"x\"\n";
    let (_, findings) = d4m_verify::allow::parse(src, "allow.toml");
    assert!(
        findings.iter().any(|f| f.what == "blanket"),
        "func-less entry for a protected file must be rejected: {findings:?}"
    );
}

#[test]
fn scoped_entry_for_protected_file_is_accepted() {
    let src = "[[allow]]\npass = \"panic\"\nfile = \"rust/src/net/wire.rs\"\n\
               func = \"f\"\nwhat = \"index\"\nreason = \"bounds proven\"\n";
    let (entries, findings) = d4m_verify::allow::parse(src, "allow.toml");
    assert_eq!(entries.len(), 1);
    assert!(findings.is_empty(), "scoped justified entry must parse clean: {findings:?}");
}

#[test]
fn stale_allow_entries_are_reported_unused() {
    let src = "[[allow]]\npass = \"panic\"\nfile = \"rust/src/x.rs\"\n\
               func = \"f\"\nreason = \"x\"\n";
    let (entries, _) = d4m_verify::allow::parse(src, "allow.toml");
    let (unallowed, allowed) = d4m_verify::allow::apply(&entries, Vec::new(), "allow.toml");
    assert_eq!(allowed, 0);
    assert!(
        unallowed.iter().any(|f| f.pass == "allow" && f.what == "unused"),
        "stale entry must surface as allow/unused: {unallowed:?}"
    );
}

#[test]
fn real_allowlist_parses_clean() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("allow.toml");
    let src = std::fs::read_to_string(&path).expect("read allow.toml");
    let (entries, findings) = d4m_verify::allow::parse(&src, "tools/d4m-verify/allow.toml");
    assert!(!entries.is_empty(), "allow.toml should carry the burned-down entries");
    assert!(findings.is_empty(), "allow.toml violates its own policy: {findings:?}");
}
