//! CLI: `d4m-verify [--root DIR] [--allow FILE] [--json]`
//!
//! Exit codes: 0 = clean, 1 = findings, 2 = usage error.

use std::path::PathBuf;
use std::process::ExitCode;

use d4m_verify::findings::report_json;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut allow: Option<PathBuf> = None;
    let mut json = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(v) => root = Some(PathBuf::from(v)),
                None => return usage("--root requires a directory argument"),
            },
            "--allow" => match args.next() {
                Some(v) => allow = Some(PathBuf::from(v)),
                None => return usage("--allow requires a file argument"),
            },
            "--help" | "-h" => {
                println!(
                    "d4m-verify [--root DIR] [--allow FILE] [--json]\n\
                     \n\
                     Static-analysis pass over rust/src enforcing repo invariants:\n\
                     panic-freedom, lock order, wire-tag registry, counter registry.\n\
                     Exit codes: 0 clean, 1 findings, 2 usage error."
                );
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }

    let root = root.unwrap_or_else(find_repo_root);
    if !root.join("rust/src").is_dir() {
        eprintln!(
            "d4m-verify: {} does not contain rust/src — pass --root pointing at \
             the repository root",
            root.display()
        );
        return ExitCode::from(2);
    }
    let allow = allow.unwrap_or_else(|| root.join("tools/d4m-verify/allow.toml"));

    let (unallowed, allowed) = d4m_verify::verify(&root, &allow);
    if json {
        println!("{}", report_json(&unallowed, allowed));
    } else {
        for f in &unallowed {
            println!("{}", f.render_text());
        }
        println!(
            "d4m-verify: {} finding(s), {} allowlisted",
            unallowed.len(),
            allowed
        );
    }
    if unallowed.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("d4m-verify: {msg}\nusage: d4m-verify [--root DIR] [--allow FILE] [--json]");
    ExitCode::from(2)
}

/// Walk up from the current directory to the first ancestor containing
/// `rust/src` (so the tool works from the workspace root and from
/// `tools/d4m-verify/` alike).
fn find_repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("rust/src").is_dir() {
            return dir;
        }
        if !dir.pop() {
            return PathBuf::from(".");
        }
    }
}
