//! Typed findings and their text/JSON rendering.

/// One violation: which pass, where, in what function, and why.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Pass name: `panic`, `locks`, `wire`, `counters`, `allow`.
    pub pass: String,
    /// Short machine-stable kind within the pass (`unwrap`, `index`,
    /// `dup-tag`, `undeclared`, ...). Allowlist entries match on it.
    pub what: String,
    /// Repo-relative path.
    pub file: String,
    /// 1-based line (0 when the finding is file- or registry-level).
    pub line: u32,
    /// Enclosing function name (empty when not inside a fn).
    pub func: String,
    /// Human-readable explanation.
    pub msg: String,
}

impl Finding {
    pub fn new(
        pass: &str,
        what: &str,
        file: &str,
        line: u32,
        func: &str,
        msg: String,
    ) -> Self {
        Finding {
            pass: pass.to_string(),
            what: what.to_string(),
            file: file.to_string(),
            line,
            func: func.to_string(),
            msg,
        }
    }

    pub fn render_text(&self) -> String {
        let func = if self.func.is_empty() {
            String::new()
        } else {
            format!(" in `{}`", self.func)
        };
        format!(
            "{}:{}: [{}/{}]{} {}",
            self.file, self.line, self.pass, self.what, func, self.msg
        )
    }
}

/// Minimal JSON string escaping (the only non-trivial JSON we emit).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn finding_json(f: &Finding) -> String {
    format!(
        "{{\"pass\":\"{}\",\"what\":\"{}\",\"file\":\"{}\",\"line\":{},\"func\":\"{}\",\"msg\":\"{}\"}}",
        json_escape(&f.pass),
        json_escape(&f.what),
        json_escape(&f.file),
        f.line,
        json_escape(&f.func),
        json_escape(&f.msg),
    )
}

/// The whole report as one JSON object:
/// `{"findings":[...],"allowed":N,"total":N}` where `findings` holds
/// only unallowlisted violations and `allowed` counts suppressed ones.
pub fn report_json(unallowed: &[Finding], allowed_count: usize) -> String {
    let items: Vec<String> = unallowed.iter().map(finding_json).collect();
    format!(
        "{{\"findings\":[{}],\"allowed\":{},\"total\":{}}}",
        items.join(","),
        allowed_count,
        unallowed.len(),
    )
}
