//! The explicit allowlist (`allow.toml`): legacy findings are burned
//! down deliberately, never silenced wholesale.
//!
//! Format — a TOML subset parsed by hand (array-of-tables with quoted
//! string values only):
//!
//! ```toml
//! [[allow]]
//! pass = "panic"            # required: pass name
//! file = "rust/src/....rs"  # required: repo-relative path
//! func = "crc_table"        # required for protected files
//! what = "index"            # optional: finding kind
//! reason = "why it's safe"  # required, must be non-empty
//! ```
//!
//! Policy, enforced as findings of pass `allow`:
//! - `reason` must be non-empty (`no-reason`)
//! - entries for the never-panic net/storage files must name a `func` —
//!   no blanket module suppressions (`blanket`)
//! - entries that match nothing are stale and must be removed (`unused`)

use crate::findings::Finding;

/// Files for which blanket (function-less) allow entries are rejected.
const PROTECTED: &[&str] = &[
    "rust/src/net/wire.rs",
    "rust/src/net/server.rs",
    "rust/src/kvstore/storage/",
];

#[derive(Debug, Clone, Default)]
pub struct AllowEntry {
    pub pass: String,
    pub file: String,
    pub func: String,
    pub what: String,
    pub reason: String,
    /// Line in allow.toml where the entry starts (for policy findings).
    pub line: u32,
}

impl AllowEntry {
    fn matches(&self, f: &Finding) -> bool {
        self.pass == f.pass
            && self.file == f.file
            && (self.func.is_empty() || self.func == f.func)
            && (self.what.is_empty() || self.what == f.what)
    }
}

/// Parse the allowlist. Unparseable lines are reported as `allow/parse`
/// findings rather than aborting — the tool must keep auditing.
pub fn parse(src: &str, path_label: &str) -> (Vec<AllowEntry>, Vec<Finding>) {
    let mut entries: Vec<AllowEntry> = Vec::new();
    let mut findings = Vec::new();
    let mut cur: Option<AllowEntry> = None;
    for (idx, raw) in src.lines().enumerate() {
        let lineno = (idx + 1) as u32;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[allow]]" {
            if let Some(e) = cur.take() {
                entries.push(e);
            }
            cur = Some(AllowEntry { line: lineno, ..AllowEntry::default() });
            continue;
        }
        let Some((key, value)) = parse_kv(line) else {
            findings.push(Finding::new(
                "allow",
                "parse",
                path_label,
                lineno,
                "",
                format!("unparseable allowlist line: {line:?}"),
            ));
            continue;
        };
        let Some(entry) = cur.as_mut() else {
            findings.push(Finding::new(
                "allow",
                "parse",
                path_label,
                lineno,
                "",
                "key/value outside any [[allow]] table".to_string(),
            ));
            continue;
        };
        match key {
            "pass" => entry.pass = value,
            "file" => entry.file = value,
            "func" => entry.func = value,
            "what" => entry.what = value,
            "reason" => entry.reason = value,
            other => findings.push(Finding::new(
                "allow",
                "parse",
                path_label,
                lineno,
                "",
                format!("unknown allowlist key `{other}`"),
            )),
        }
    }
    if let Some(e) = cur.take() {
        entries.push(e);
    }
    // policy checks
    for e in &entries {
        if e.pass.is_empty() || e.file.is_empty() {
            findings.push(Finding::new(
                "allow",
                "incomplete",
                path_label,
                e.line,
                "",
                "allow entry must set both `pass` and `file`".to_string(),
            ));
        }
        if e.reason.trim().is_empty() {
            findings.push(Finding::new(
                "allow",
                "no-reason",
                path_label,
                e.line,
                "",
                format!(
                    "allow entry for {} has no justification — `reason` must be non-empty",
                    e.file
                ),
            ));
        }
        let protected = PROTECTED.iter().any(|p| e.file.starts_with(p));
        if protected && e.func.is_empty() {
            findings.push(Finding::new(
                "allow",
                "blanket",
                path_label,
                e.line,
                "",
                format!(
                    "blanket module suppression for protected file {} — entries for \
                     net/wire.rs, net/server.rs and kvstore/storage/ must name a `func`",
                    e.file
                ),
            ));
        }
    }
    (entries, findings)
}

/// `key = "value"` with a double-quoted value (no escapes needed for
/// paths/reasons; a `\"` inside reasons is not supported by design).
fn parse_kv(line: &str) -> Option<(&str, String)> {
    let (key, rest) = line.split_once('=')?;
    let key = key.trim();
    let rest = rest.trim();
    // strip a trailing comment after the closing quote
    let inner = rest.strip_prefix('"')?;
    let (value, tail) = inner.split_once('"')?;
    let tail = tail.trim();
    if !tail.is_empty() && !tail.starts_with('#') {
        return None;
    }
    Some((key, value.to_string()))
}

/// Split `findings` into (unallowed, allowed_count) and append policy
/// findings for entries that matched nothing.
pub fn apply(
    entries: &[AllowEntry],
    findings: Vec<Finding>,
    path_label: &str,
) -> (Vec<Finding>, usize) {
    let mut used = vec![false; entries.len()];
    let mut unallowed = Vec::new();
    let mut allowed = 0usize;
    for f in findings {
        let mut hit = false;
        for (k, e) in entries.iter().enumerate() {
            if e.matches(&f) {
                if let Some(u) = used.get_mut(k) {
                    *u = true;
                }
                hit = true;
            }
        }
        if hit {
            allowed += 1;
        } else {
            unallowed.push(f);
        }
    }
    for (k, e) in entries.iter().enumerate() {
        if !used.get(k).copied().unwrap_or(true) {
            unallowed.push(Finding::new(
                "allow",
                "unused",
                path_label,
                e.line,
                "",
                format!(
                    "stale allow entry (pass={}, file={}, func={}) matches no finding — remove it",
                    e.pass, e.file, e.func
                ),
            ));
        }
    }
    (unallowed, allowed)
}
