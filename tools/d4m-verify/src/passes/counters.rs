//! Pass 4 — counter-name registry.
//!
//! Fixed metric names live in `rust/src/metrics/names.rs`, declared
//! exactly once each, following the `segment.segment` grammar with the
//! first segment drawn from the known namespaces. Stats-assembly sites
//! must reference declared names — a counter-shaped string literal in
//! a metric file that is not in the registry is a typo or an
//! undocumented metric, both findings.

use std::collections::BTreeMap;
use std::path::Path;

use crate::findings::Finding;
use crate::lexer::{containing_fn, Kind};

use super::{SourceFile, ALLOWED_NAMESPACES, METRIC_FILES, REGISTRY_FILE};

/// `segment.segment` with `[a-z][a-z0-9_]*` segments, exactly one dot.
fn counter_shaped(s: &str) -> bool {
    let Some((ns, rest)) = s.split_once('.') else { return false };
    segment_ok(ns) && segment_ok(rest)
}

fn segment_ok(s: &str) -> bool {
    let mut chars = s.chars();
    let Some(first) = chars.next() else { return false };
    first.is_ascii_lowercase()
        && chars.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// The inner value of a plain `"..."` string token (raw/byte strings
/// are not used for counter names and are skipped).
fn plain_str(text: &str) -> Option<&str> {
    text.strip_prefix('"')?.strip_suffix('"')
}

pub fn run(root: &Path, findings: &mut Vec<Finding>) {
    let mut declared: BTreeMap<String, u32> = BTreeMap::new();
    match SourceFile::load(root, REGISTRY_FILE) {
        Some(reg) => {
            for (i, t) in reg.toks.iter().enumerate() {
                if reg.masked.get(i).copied().unwrap_or(false) || t.kind != Kind::Str {
                    continue;
                }
                let Some(val) = plain_str(&t.text) else { continue };
                if !counter_shaped(val) {
                    findings.push(Finding::new(
                        "counters",
                        "grammar",
                        REGISTRY_FILE,
                        t.line,
                        "",
                        format!(
                            "declared counter \"{val}\" violates the segment.segment grammar"
                        ),
                    ));
                    continue;
                }
                let ns = val.split('.').next().unwrap_or("");
                if !ALLOWED_NAMESPACES.contains(&ns) {
                    findings.push(Finding::new(
                        "counters",
                        "namespace",
                        REGISTRY_FILE,
                        t.line,
                        "",
                        format!(
                            "declared counter \"{val}\" uses namespace \"{ns}\" \
                             (allowed: {ALLOWED_NAMESPACES:?})"
                        ),
                    ));
                }
                if declared.contains_key(val) {
                    findings.push(Finding::new(
                        "counters",
                        "dup-declare",
                        REGISTRY_FILE,
                        t.line,
                        "",
                        format!("counter \"{val}\" declared more than once"),
                    ));
                }
                declared.entry(val.to_string()).or_insert(t.line);
            }
        }
        None => {
            findings.push(Finding::new(
                "counters",
                "no-registry",
                REGISTRY_FILE,
                0,
                "",
                "counter registry file missing — every fixed metric name must be \
                 declared in metrics/names.rs"
                    .to_string(),
            ));
        }
    }

    for rel in METRIC_FILES {
        let Some(sf) = SourceFile::load(root, rel) else { continue };
        for (i, t) in sf.toks.iter().enumerate() {
            if sf.masked.get(i).copied().unwrap_or(false) || t.kind != Kind::Str {
                continue;
            }
            let Some(val) = plain_str(&t.text) else { continue };
            if !val.contains('.') || !counter_shaped(val) {
                continue;
            }
            if !declared.contains_key(val) {
                findings.push(Finding::new(
                    "counters",
                    "undeclared",
                    rel,
                    t.line,
                    &containing_fn(&sf.spans, i),
                    format!(
                        "counter-shaped literal \"{val}\" is not declared in \
                         metrics/names.rs (use the named constant)"
                    ),
                ));
            }
        }
    }
}
