//! Pass 3 — wire-tag registry.
//!
//! Every frame-type tag is assigned exactly once per registry; retired
//! tags (removed message types) keep a decode arm that returns
//! `WireError::Retired` forever — they are never reassigned, so an old
//! peer speaking a retired message gets a typed protocol error instead
//! of a misparse. The DESIGN.md tag tables (anchored by
//! `<!-- d4m-verify:tags NAME -->` comments) must match the code.

use std::collections::BTreeMap;
use std::path::Path;

use crate::findings::Finding;
use crate::lexer::{Kind, Tok};

use super::SourceFile;

/// Decode fn → registry name. Each registry's tag space is the set of
/// integer literals matched in that fn's top-level `match`.
const DECODE_FNS: &[(&str, &str)] = &[
    ("get_request", "Request"),
    ("get_response", "Response"),
    ("decode_client_frame", "ClientMsg"),
    ("decode_server_frame", "ServerMsg"),
    ("get_error", "Error"),
    ("get_keysel", "KeySel"),
];

/// Tags that were retired and must decode only to `WireError::Retired`.
const RETIRED: &[(&str, &[u32])] = &[("Request", &[4, 5])];

/// One DESIGN.md table row: tag → (retired?, DESIGN.md line).
pub type DesignTables = BTreeMap<String, BTreeMap<u32, (bool, u32)>>;

pub fn run(sf: &SourceFile, design: Option<&DesignTables>, findings: &mut Vec<Finding>) {
    // registry -> tag -> retired?
    let mut tag_map: BTreeMap<&str, BTreeMap<u32, bool>> = BTreeMap::new();
    for span in &sf.spans {
        let Some(&(_, reg)) =
            DECODE_FNS.iter().find(|(f, _)| span.name == *f)
        else {
            continue;
        };
        let arms = match_arms(&sf.toks, span.start, span.end);
        let seen = tag_map.entry(reg).or_default();
        for (pat, body) in &arms {
            let line = pat.first().map_or(0, |t| t.line);
            let retired = body.iter().take(400).any(|t| t.is("Retired"));
            for t in pat {
                if t.kind != Kind::Number {
                    continue;
                }
                let Ok(v) = t.text.parse::<u32>() else { continue };
                if seen.contains_key(&v) {
                    findings.push(Finding::new(
                        "wire",
                        "dup-tag",
                        &sf.rel,
                        line,
                        &span.name,
                        format!("duplicate {reg} tag {v} in decode match"),
                    ));
                }
                seen.insert(v, retired);
            }
        }
    }
    // retired-tag policy
    for &(reg, tags) in RETIRED {
        for &v in tags {
            match tag_map.get(reg).and_then(|m| m.get(&v)) {
                None => findings.push(Finding::new(
                    "wire",
                    "retired-missing",
                    &sf.rel,
                    0,
                    "",
                    format!(
                        "retired {reg} tag {v} has no decode arm — retired tags must \
                         decode to WireError::Retired forever"
                    ),
                )),
                Some(false) => findings.push(Finding::new(
                    "wire",
                    "retired-reassigned",
                    &sf.rel,
                    0,
                    "",
                    format!(
                        "retired {reg} tag {v} decodes to something other than \
                         WireError::Retired — retired tags are never reassigned"
                    ),
                )),
                Some(true) => {}
            }
        }
    }
    // DESIGN.md tables (only when DESIGN.md exists — fixtures may omit it)
    let Some(design) = design else { return };
    for (reg, rows) in design {
        let Some(code) = tag_map.get(reg.as_str()) else { continue };
        for (&v, &(_, doc_line)) in rows {
            if !code.contains_key(&v) {
                findings.push(Finding::new(
                    "wire",
                    "doc-extra",
                    "DESIGN.md",
                    doc_line,
                    reg,
                    format!("DESIGN.md lists {reg} tag {v} but wire.rs has no decode arm"),
                ));
            }
        }
        for (&v, &code_retired) in code {
            match rows.get(&v) {
                None => findings.push(Finding::new(
                    "wire",
                    "doc-missing",
                    "DESIGN.md",
                    0,
                    reg,
                    format!("wire.rs decodes {reg} tag {v} but the DESIGN.md table omits it"),
                )),
                Some(&(doc_retired, doc_line)) if doc_retired != code_retired => {
                    findings.push(Finding::new(
                        "wire",
                        "doc-retired",
                        "DESIGN.md",
                        doc_line,
                        reg,
                        format!(
                            "{reg} tag {v}: retired flag disagrees between DESIGN.md \
                             and wire.rs"
                        ),
                    ));
                }
                Some(_) => {}
            }
        }
    }
}

/// Parse DESIGN.md tag tables. A table is anchored by a line containing
/// `<!-- d4m-verify:tags NAME -->`; subsequent `| N | name |` rows are
/// its entries ("retired" anywhere in the name marks the tag retired).
/// A non-blank, non-`|` line ends the table.
pub fn parse_design_tables(path: &Path) -> Option<DesignTables> {
    let src = std::fs::read_to_string(path).ok()?;
    let mut tables: DesignTables = BTreeMap::new();
    let mut cur: Option<String> = None;
    for (idx, raw) in src.lines().enumerate() {
        let lineno = (idx + 1) as u32;
        let line = raw.trim();
        if let Some(name) = anchor_name(line) {
            tables.entry(name.clone()).or_default();
            cur = Some(name);
            continue;
        }
        let Some(reg) = cur.clone() else { continue };
        if let Some((tag, label)) = table_row(line) {
            let retired = label.to_ascii_lowercase().contains("retired");
            if let Some(t) = tables.get_mut(&reg) {
                t.insert(tag, (retired, lineno));
            }
        } else if !line.is_empty() && !line.starts_with('|') {
            cur = None;
        }
    }
    Some(tables)
}

/// `<!-- d4m-verify:tags NAME -->` → `NAME`.
fn anchor_name(line: &str) -> Option<String> {
    let rest = line.strip_prefix("<!--")?.trim_start();
    let rest = rest.strip_prefix("d4m-verify:tags")?.trim_start();
    let end = rest.find("-->")?;
    let name = rest.get(..end)?.trim();
    if name.is_empty() || name.contains(char::is_whitespace) {
        return None;
    }
    Some(name.to_string())
}

/// `| N | name ... |` → `(N, name)`. Separator rows (`|---|---|`) and
/// header rows fail the integer parse and are skipped.
fn table_row(line: &str) -> Option<(u32, String)> {
    let rest = line.strip_prefix('|')?;
    let mut cells = rest.split('|');
    let tag: u32 = cells.next()?.trim().parse().ok()?;
    let label = cells.next()?.trim().to_string();
    Some((tag, label))
}

/// Extract the arms of the first `match` inside token span `[s, e]`.
/// Returns `(pattern_tokens, body_tokens)` pairs. Handles block bodies
/// without trailing commas and struct patterns containing braces.
fn match_arms(toks: &[Tok], s: usize, e: usize) -> Vec<(Vec<Tok>, Vec<Tok>)> {
    let mut i = s;
    while i <= e && !toks.get(i).is_some_and(|t| t.kind == Kind::Ident && t.is("match")) {
        i += 1;
    }
    if i > e {
        return Vec::new();
    }
    // first `{` at paren/bracket level 0 after the scrutinee
    let mut lvl = 0i32;
    while i <= e {
        let Some(t) = toks.get(i) else { return Vec::new() };
        if t.is("(") || t.is("[") {
            lvl += 1;
        } else if t.is(")") || t.is("]") {
            lvl -= 1;
        } else if t.is("{") && lvl == 0 {
            break;
        }
        i += 1;
    }
    if i > e {
        return Vec::new();
    }
    let mut arms = Vec::new();
    let mut j = i + 1;
    while j <= e {
        if toks.get(j).is_some_and(|t| t.is("}")) {
            break; // end of the match block
        }
        // ---- pattern: tokens until `=>` at nest level 0
        let mut pat: Vec<Tok> = Vec::new();
        let mut lvl = 0i32;
        while j <= e {
            let Some(t) = toks.get(j) else { break };
            if t.is("(") || t.is("[") || t.is("{") {
                lvl += 1;
            } else if t.is(")") || t.is("]") || t.is("}") {
                lvl -= 1;
            }
            if lvl == 0 && t.is("=") && toks.get(j + 1).is_some_and(|x| x.is(">")) {
                j += 2;
                break;
            }
            pat.push(t.clone());
            j += 1;
        }
        // ---- body: balanced block (+ optional comma), or expression
        // up to a top-level comma / the match's closing brace
        let mut body: Vec<Tok> = Vec::new();
        if toks.get(j).is_some_and(|t| t.is("{")) {
            let mut d = 0i32;
            while j <= e {
                let Some(t) = toks.get(j) else { break };
                body.push(t.clone());
                if t.is("{") {
                    d += 1;
                } else if t.is("}") {
                    d -= 1;
                    if d == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
            if toks.get(j).is_some_and(|t| t.is(",")) {
                j += 1;
            }
        } else {
            let mut lvl = 0i32;
            while j <= e {
                let Some(t) = toks.get(j) else { break };
                if lvl == 0 && t.is(",") {
                    j += 1;
                    break;
                }
                if lvl == 0 && t.is("}") {
                    break; // closes the match itself
                }
                if t.is("(") || t.is("[") || t.is("{") {
                    lvl += 1;
                } else if t.is(")") || t.is("]") || t.is("}") {
                    lvl -= 1;
                }
                body.push(t.clone());
                j += 1;
            }
        }
        if !pat.is_empty() {
            arms.push((pat, body));
        } else if body.is_empty() {
            break; // no progress — malformed stream, stop rather than loop
        }
    }
    arms
}
