//! Pass 1 — panic-freedom audit.
//!
//! In never-panic modules ([`super::NEVER_PANIC`]) hostile input must
//! surface typed `WireError`/`D4mError` values. Flags, outside
//! `#[cfg(test)]` code:
//! - calls to panicking methods: `.unwrap()`, `.expect(..)`,
//!   `.unwrap_err()`, `.expect_err(..)`
//! - panicking macros: `panic!`, `unreachable!`, `todo!`,
//!   `unimplemented!`, `assert!` family excluded (debug_assert is
//!   compiled out of release builds; plain assert is not used on these
//!   paths)
//! - slice/array indexing in expression position (`x[i]`, `x[a..b]`),
//!   which panics out of bounds — use `.get()`/`.get_mut()` or a
//!   pattern instead

use crate::findings::Finding;
use crate::lexer::{containing_fn, Kind};

use super::SourceFile;

const PANIC_METHODS: &[&str] = &["unwrap", "expect", "unwrap_err", "expect_err"];
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Keywords that may directly precede `[` without it being an index
/// expression (slice patterns, array types after `as`, etc.).
const NON_EXPR_BEFORE_BRACKET: &[&str] = &[
    "mut", "ref", "in", "return", "else", "match", "if", "let", "move", "as", "dyn",
    "impl", "where", "box", "break", "const", "static", "type", "use", "pub", "fn",
];

pub fn run(sf: &SourceFile, findings: &mut Vec<Finding>) {
    let toks = &sf.toks;
    let n = toks.len();
    for i in 0..n {
        if sf.masked.get(i).copied().unwrap_or(false) {
            continue;
        }
        let Some(t) = toks.get(i) else { continue };

        // ---- panicking methods and macros
        if t.kind == Kind::Ident {
            let prev_dot = i > 0 && toks.get(i - 1).is_some_and(|p| p.is("."));
            let next_paren = toks.get(i + 1).is_some_and(|p| p.is("("));
            let next_bang = toks.get(i + 1).is_some_and(|p| p.is("!"));
            if prev_dot && next_paren && PANIC_METHODS.contains(&t.text.as_str()) {
                findings.push(Finding::new(
                    "panic",
                    &t.text,
                    &sf.rel,
                    t.line,
                    &containing_fn(&sf.spans, i),
                    format!(
                        "call to panicking method `{}` in never-panic module — return a \
                         typed error instead",
                        t.text
                    ),
                ));
            } else if next_bang && PANIC_MACROS.contains(&t.text.as_str()) {
                findings.push(Finding::new(
                    "panic",
                    &format!("{}!", t.text),
                    &sf.rel,
                    t.line,
                    &containing_fn(&sf.spans, i),
                    format!("`{}!` in never-panic module — return a typed error instead", t.text),
                ));
            }
        }

        // ---- slice-index-without-get: `[` in expression position
        if t.is("[") && i > 0 {
            let Some(prev) = toks.get(i - 1) else { continue };
            let expr_pos = match prev.kind {
                Kind::Ident => !NON_EXPR_BEFORE_BRACKET.contains(&prev.text.as_str()),
                Kind::Number => true,
                Kind::Punct => prev.is(")") || prev.is("]") || prev.is("?"),
                _ => false,
            };
            // `#[...]` attributes and `name![...]` macro brackets have
            // punct `#`/`!` before them and are already excluded above
            if expr_pos {
                findings.push(Finding::new(
                    "panic",
                    "index",
                    &sf.rel,
                    t.line,
                    &containing_fn(&sf.spans, i),
                    "slice/array index panics out of bounds in never-panic module — use \
                     `.get()`/`.get_mut()` or a pattern"
                        .to_string(),
                ));
            }
        }
    }
}
