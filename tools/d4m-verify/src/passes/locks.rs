//! Pass 2 — lock-order checker.
//!
//! Extracts `lock()` / `read()` / `write()` acquisition sequences per
//! function (token-level, intra-procedural) and verifies them against
//! the documented partial order (DESIGN.md §Durable storage):
//!
//! - the WAL/checkpoint lock `inner` is always acquired BEFORE any
//!   tablet lock — equivalently, never while a tablet guard is live
//! - no lock guard may be held across a `scan_stream` call
//!   (DESIGN.md §Snapshot/streaming: streams borrow no locks)
//!
//! Guard liveness is tracked through `let g = x.lock()...` bindings:
//! a guard lives until its enclosing block closes or `drop(g)` runs.
//! Unbound (transient) acquisitions like `x.lock().unwrap().method()`
//! release at the end of the statement and do not constrain ordering.
//! Receivers are classified by their last identifier — `inner` for the
//! WAL/checkpoint lock; `tablets`/`tablet`/`tl` for tablet locks (the
//! iteration-variable names the store uses).

use crate::findings::Finding;
use crate::lexer::{Kind, Tok};

use super::SourceFile;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LockClass {
    Inner,
    Tablet,
}

fn classify(receiver: &str) -> Option<LockClass> {
    match receiver {
        "inner" => Some(LockClass::Inner),
        "tablets" | "tablet" | "tl" => Some(LockClass::Tablet),
        _ => None,
    }
}

fn class_name(c: LockClass) -> &'static str {
    match c {
        LockClass::Inner => "inner",
        LockClass::Tablet => "tablet",
    }
}

/// The documented partial order: acquire `.0` before `.1`; i.e. a `.0`
/// acquisition while a `.1` guard is live is a violation.
const ORDER: &[(LockClass, LockClass)] = &[(LockClass::Inner, LockClass::Tablet)];

struct Guard {
    var: String,
    class: LockClass,
    depth: i32,
    line: u32,
}

pub fn run(sf: &SourceFile, findings: &mut Vec<Finding>) {
    for span in &sf.spans {
        // a fn entirely inside test code is exempt
        if sf.masked.get(span.start).copied().unwrap_or(false) {
            continue;
        }
        check_fn(sf, span.start, span.end, &span.name, findings);
    }
}

fn check_fn(
    sf: &SourceFile,
    start: usize,
    end: usize,
    fn_name: &str,
    findings: &mut Vec<Finding>,
) {
    let toks = &sf.toks;
    let mut guards: Vec<Guard> = Vec::new();
    let mut depth = 0i32;
    // the binding target of an in-progress `let name = ...` statement
    let mut pending_let: Option<(String, i32)> = None;
    let mut i = start;
    while i <= end {
        let Some(t) = toks.get(i) else { break };
        if t.is("{") {
            depth += 1;
        } else if t.is("}") {
            depth -= 1;
            guards.retain(|g| g.depth <= depth);
        } else if t.kind == Kind::Ident && t.is("let") {
            let mut j = i + 1;
            if toks.get(j).is_some_and(|x| x.is("mut")) {
                j += 1;
            }
            if let Some(name) = toks.get(j).filter(|x| x.kind == Kind::Ident) {
                pending_let = Some((name.text.clone(), depth));
            }
        } else if t.is(";") {
            pending_let = None;
        } else if t.kind == Kind::Ident
            && t.is("drop")
            && toks.get(i + 1).is_some_and(|x| x.is("("))
        {
            if let Some(dropped) = toks.get(i + 2).filter(|x| x.kind == Kind::Ident) {
                guards.retain(|g| g.var != dropped.text);
            }
        } else if t.kind == Kind::Ident
            && (t.is("lock") || t.is("read") || t.is("write"))
            && i > 0
            && toks.get(i - 1).is_some_and(|x| x.is("."))
            && toks.get(i + 1).is_some_and(|x| x.is("("))
            && toks.get(i + 2).is_some_and(|x| x.is(")"))
        {
            // an empty-arg .lock()/.read()/.write() call — io::Write's
            // write(buf) and io::Read's read(buf) always take arguments
            if let Some(class) = receiver_of(toks, i).as_deref().and_then(classify) {
                for g in &guards {
                    for &(first, second) in ORDER {
                        if g.class == second && class == first {
                            findings.push(Finding::new(
                                "locks",
                                "order",
                                &sf.rel,
                                t.line,
                                fn_name,
                                format!(
                                    "acquires `{}` lock while `{}` guard (line {}) is held — \
                                     the documented order is {} before {}",
                                    class_name(first),
                                    class_name(second),
                                    g.line,
                                    class_name(first),
                                    class_name(second),
                                ),
                            ));
                        }
                    }
                }
                if let Some((var, let_depth)) = pending_let.take() {
                    if let_depth == depth {
                        guards.push(Guard { var, class, depth, line: t.line });
                    }
                }
            }
        } else if t.kind == Kind::Ident && t.is("scan_stream") {
            if let Some(g) = guards.first() {
                findings.push(Finding::new(
                    "locks",
                    "scan-stream",
                    &sf.rel,
                    t.line,
                    fn_name,
                    format!(
                        "calls scan_stream while a `{}` guard (line {}) is held — no lock \
                         may be held across scan_stream consumption (DESIGN.md \
                         §Snapshot/streaming)",
                        class_name(g.class),
                        g.line,
                    ),
                ));
            }
        }
        i += 1;
    }
}

/// Walk back from the lock-method ident across a `.`-chain, skipping
/// balanced `(..)` / `[..]` groups, to the receiver's last identifier.
/// `self.tablets[t].write` → `tablets`; `self.inner.lock` → `inner`.
fn receiver_of(toks: &[Tok], method_idx: usize) -> Option<String> {
    let mut j = method_idx.checked_sub(1)?; // the `.` before the method
    if !toks.get(j)?.is(".") {
        return None;
    }
    loop {
        j = j.checked_sub(1)?;
        let t = toks.get(j)?;
        if t.is(")") || t.is("]") {
            let (open, close) = if t.is(")") { ("(", ")") } else { ("[", "]") };
            let mut d = 0i32;
            loop {
                let x = toks.get(j)?;
                if x.is(close) {
                    d += 1;
                } else if x.is(open) {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                j = j.checked_sub(1)?;
            }
            continue;
        }
        if t.is(".") {
            continue;
        }
        if t.kind == Kind::Ident {
            if t.is("self") || t.is("Self") {
                return None;
            }
            return Some(t.text.clone());
        }
        return None;
    }
}
