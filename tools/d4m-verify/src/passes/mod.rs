//! The four invariant passes plus the repo-specific configuration that
//! drives them. The configuration is code, not a config file, on
//! purpose: changing an invariant should be a reviewed diff here, in
//! DESIGN.md, and in the source — not an edit to a dotfile.

pub mod counters;
pub mod locks;
pub mod panic;
pub mod wire_tags;

use std::path::{Path, PathBuf};

use crate::findings::Finding;
use crate::lexer::{self, FnSpan, Tok};

/// Modules where hostile input must surface typed errors, never a
/// panic (DESIGN.md §Static analysis). Directory entries end in `/`.
pub const NEVER_PANIC: &[&str] = &[
    "rust/src/net/wire.rs",
    "rust/src/net/server.rs",
    "rust/src/kvstore/storage/",
    "rust/src/coordinator/plan.rs",
];

/// Files whose counter-shaped string literals must be declared in the
/// registry (the stats-assembly and stats-printing sites).
pub const METRIC_FILES: &[&str] = &[
    "rust/src/coordinator/mod.rs",
    "rust/src/coordinator/plan.rs",
    "rust/src/net/server.rs",
    "rust/src/net/client.rs",
    "rust/src/main.rs",
];

/// The counter-name registry: every fixed metric name, declared once.
pub const REGISTRY_FILE: &str = "rust/src/metrics/names.rs";

/// First-segment namespaces the counter grammar allows.
pub const ALLOWED_NAMESPACES: &[&str] = &["net", "kernels", "plan", "storage", "client"];

/// The wire codec all tag registries live in.
pub const WIRE_FILE: &str = "rust/src/net/wire.rs";

/// One lexed file ready for the passes.
pub struct SourceFile {
    pub rel: String,
    pub toks: Vec<Tok>,
    pub masked: Vec<bool>,
    pub spans: Vec<FnSpan>,
}

impl SourceFile {
    pub fn load(root: &Path, rel: &str) -> Option<SourceFile> {
        let src = std::fs::read_to_string(root.join(rel)).ok()?;
        Some(SourceFile::from_source(rel, &src))
    }

    pub fn from_source(rel: &str, src: &str) -> SourceFile {
        let toks = lexer::lex(src);
        let masked = lexer::mask_test_code(&toks);
        let spans = lexer::fn_spans(&toks);
        SourceFile { rel: rel.to_string(), toks, masked, spans }
    }
}

/// Recursively list `.rs` files under `root/rust/src`, repo-relative,
/// sorted for deterministic output.
pub fn rust_src_files(root: &Path) -> Vec<String> {
    let mut out = Vec::new();
    let mut stack = vec![root.join("rust/src")];
    while let Some(dir) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&dir) else { continue };
        for entry in entries.flatten() {
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                if let Ok(rel) = p.strip_prefix(root) {
                    out.push(path_to_rel(rel));
                }
            }
        }
    }
    out.sort();
    out
}

fn path_to_rel(p: &Path) -> String {
    // normalise to forward slashes so findings and allowlist entries
    // are byte-identical across platforms
    let mut s = String::new();
    for comp in p.components() {
        if !s.is_empty() {
            s.push('/');
        }
        s.push_str(&comp.as_os_str().to_string_lossy());
    }
    s
}

fn in_never_panic(rel: &str) -> bool {
    NEVER_PANIC.iter().any(|p| {
        if let Some(dir) = p.strip_suffix('/') {
            rel.starts_with(dir) && rel.len() > dir.len()
        } else {
            rel == *p
        }
    })
}

/// Run every pass over the repo at `root`; returns raw findings (the
/// allowlist is applied by the caller).
pub fn run_all(root: &Path) -> Vec<Finding> {
    let mut findings = Vec::new();
    let files = rust_src_files(root);
    for rel in &files {
        let Some(sf) = SourceFile::load(root, rel) else { continue };
        if in_never_panic(rel) {
            panic::run(&sf, &mut findings);
        }
        locks::run(&sf, &mut findings);
        if rel == WIRE_FILE {
            let design: PathBuf = root.join("DESIGN.md");
            let tables = wire_tags::parse_design_tables(&design);
            wire_tags::run(&sf, tables.as_ref(), &mut findings);
        }
    }
    counters::run(root, &mut findings);
    findings
}
