//! d4m-verify — repo-invariant static analysis for the d4m tree.
//!
//! Four token-level passes over `rust/src/`:
//! 1. `panic`    — panic-freedom audit of never-panic modules
//! 2. `locks`    — lock-acquisition partial order + scan_stream rule
//! 3. `wire`     — wire-tag registry (uniqueness, retired tags, docs)
//! 4. `counters` — counter-name registry and grammar
//!
//! Pure std, no dependencies; the lexer is hand-rolled (see
//! [`lexer`]). Findings are typed `file:line` records; the explicit
//! allowlist (`allow.toml`) requires a non-empty justification per
//! entry and forbids blanket suppressions for protected modules.

pub mod allow;
pub mod findings;
pub mod lexer;
pub mod passes;

use std::path::Path;

use findings::Finding;

/// Run every pass, apply the allowlist at `allow_path` (if it exists),
/// and return `(unallowed_findings, allowed_count)`.
pub fn verify(root: &Path, allow_path: &Path) -> (Vec<Finding>, usize) {
    let raw = passes::run_all(root);
    let label = allow_path
        .strip_prefix(root)
        .unwrap_or(allow_path)
        .to_string_lossy()
        .replace('\\', "/");
    match std::fs::read_to_string(allow_path) {
        Ok(src) => {
            let (entries, mut policy) = allow::parse(&src, &label);
            let (mut unallowed, allowed) = allow::apply(&entries, raw, &label);
            unallowed.append(&mut policy);
            (unallowed, allowed)
        }
        Err(_) => (raw, 0),
    }
}
