//! A token-level Rust lexer: just enough structure for invariant
//! checking — identifiers, numbers, string/char/lifetime literals and
//! single-character punctuation, with comments and whitespace dropped
//! and line numbers preserved. Deliberately NOT a parser: the passes
//! work on token patterns (`.` `unwrap` `(`, `match` arm shapes, brace
//! depth), which is robust to everything rustfmt does and avoids a
//! `syn` dependency in the offline build.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Number,
    Str,
    Char,
    Lifetime,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lex `src` into tokens. Unterminated literals and comments are
/// tolerated (the remainder becomes one token) — the tool must never
/// die on the code it is auditing.
pub fn lex(src: &str) -> Vec<Tok> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    let count_lines = |from: usize, to: usize| -> u32 {
        b.get(from..to).map_or(0, |s| s.iter().filter(|&&c| c == b'\n').count() as u32)
    };

    while i < n {
        let c = match b.get(i) {
            Some(&c) => c,
            None => break,
        };
        // ---- block comment (nested)
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1u32;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b.get(j) == Some(&b'/') && b.get(j + 1) == Some(&b'*') {
                    depth += 1;
                    j += 2;
                } else if b.get(j) == Some(&b'*') && b.get(j + 1) == Some(&b'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            line += count_lines(i, j);
            i = j;
            continue;
        }
        // ---- line comment
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            while i < n && b.get(i) != Some(&b'\n') {
                i += 1;
            }
            continue;
        }
        // ---- raw / byte-raw string: r"", r#""#, br#""#
        if c == b'r' || (c == b'b' && b.get(i + 1) == Some(&b'r')) {
            if let Some((hashes, body_start)) = raw_string_start(b, i) {
                let mut j = body_start;
                let close_len = 1 + hashes;
                loop {
                    if j >= n {
                        break;
                    }
                    if b.get(j) == Some(&b'"') && hashes_follow(b, j + 1, hashes) {
                        j += close_len;
                        break;
                    }
                    j += 1;
                }
                push_span(&mut toks, src, i, j, Kind::Str, line);
                line += count_lines(i, j);
                i = j;
                continue;
            }
        }
        // ---- plain / byte string
        if c == b'"' || (c == b'b' && b.get(i + 1) == Some(&b'"')) {
            let mut j = i + if c == b'b' { 2 } else { 1 };
            while j < n {
                match b.get(j) {
                    Some(&b'\\') => j += 2,
                    Some(&b'"') => {
                        j += 1;
                        break;
                    }
                    Some(_) => j += 1,
                    None => break,
                }
            }
            let j = j.min(n);
            push_span(&mut toks, src, i, j, Kind::Str, line);
            line += count_lines(i, j);
            i = j;
            continue;
        }
        // ---- char literal vs lifetime
        if c == b'\'' {
            if let Some(end) = char_literal_end(b, i) {
                push_span(&mut toks, src, i, end, Kind::Char, line);
                i = end;
                continue;
            }
            if b.get(i + 1).is_some_and(|&c2| is_ident_start(c2)) {
                let mut j = i + 1;
                while j < n && b.get(j).is_some_and(|&c2| is_ident_cont(c2)) {
                    j += 1;
                }
                push_span(&mut toks, src, i, j, Kind::Lifetime, line);
                i = j;
                continue;
            }
            push_span(&mut toks, src, i, i + 1, Kind::Punct, line);
            i += 1;
            continue;
        }
        // ---- whitespace
        if c.is_ascii_whitespace() {
            let mut j = i;
            while j < n && b.get(j).is_some_and(|c2| c2.is_ascii_whitespace()) {
                j += 1;
            }
            line += count_lines(i, j);
            i = j;
            continue;
        }
        // ---- identifier / keyword
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && b.get(j).is_some_and(|&c2| is_ident_cont(c2)) {
                j += 1;
            }
            push_span(&mut toks, src, i, j, Kind::Ident, line);
            i = j;
            continue;
        }
        // ---- number (no '.' so `0..n` and `0.99` split cleanly)
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && b.get(j).is_some_and(|&c2| is_ident_cont(c2)) {
                j += 1;
            }
            push_span(&mut toks, src, i, j, Kind::Number, line);
            i = j;
            continue;
        }
        // ---- punctuation (single byte; multibyte UTF-8 outside literals
        // is tolerated byte-by-byte — it only occurs inside literals in
        // well-formed Rust anyway)
        push_span(&mut toks, src, i, i + 1, Kind::Punct, line);
        i += 1;
    }
    toks
}

fn push_span(toks: &mut Vec<Tok>, src: &str, from: usize, to: usize, kind: Kind, line: u32) {
    let text = match src.get(from..to) {
        Some(s) => s.to_string(),
        // mid-UTF-8 span (stray multibyte punct): lossy-decode the bytes
        None => {
            let bytes = src.as_bytes().get(from..to.min(src.len())).unwrap_or(&[]);
            String::from_utf8_lossy(bytes).into_owned()
        }
    };
    toks.push(Tok { kind, text, line });
}

/// `r` / `br` + hashes + `"` → (hash count, index past the opening quote).
fn raw_string_start(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i + if b.get(i) == Some(&b'b') { 2 } else { 1 };
    let mut hashes = 0usize;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some((hashes, j + 1))
    } else {
        None
    }
}

fn hashes_follow(b: &[u8], at: usize, hashes: usize) -> bool {
    (0..hashes).all(|k| b.get(at + k) == Some(&b'#'))
}

/// If position `i` (a `'`) starts a char literal, the index one past its
/// closing quote; `None` if it reads as a lifetime.
fn char_literal_end(b: &[u8], i: usize) -> Option<usize> {
    match b.get(i + 1) {
        Some(&b'\\') => {
            // escape: scan to the closing quote (handles \', \\, \u{...})
            let mut j = i + 3;
            while j < b.len() && j < i + 12 {
                if b.get(j) == Some(&b'\'') {
                    return Some(j + 1);
                }
                j += 1;
            }
            None
        }
        Some(&c) if c != b'\'' => {
            // one char (possibly multibyte) then a closing quote; an
            // ident char NOT followed by a quote reads as a lifetime
            let mut j = i + 2;
            while j < b.len() && j <= i + 5 && b.get(j).is_some_and(|&c2| c2 >= 0x80) {
                j += 1;
            }
            if b.get(j) == Some(&b'\'') {
                Some(j + 1)
            } else {
                None
            }
        }
        _ => None,
    }
}

/// `masked[k]` is true when token `k` is inside `#[cfg(test)]` /
/// `#[test]`-attributed items (test modules and test fns) — those are
/// allowed to panic by design.
pub fn mask_test_code(toks: &[Tok]) -> Vec<bool> {
    let n = toks.len();
    let mut masked = vec![false; n];
    let mut i = 0usize;
    while i < n {
        let is_attr_start = toks.get(i).is_some_and(|t| t.is("#"))
            && toks.get(i + 1).is_some_and(|t| t.is("["));
        if !is_attr_start {
            i += 1;
            continue;
        }
        // collect the attribute text
        let mut j = i + 2;
        let mut depth = 1i32;
        let mut attr = String::new();
        while j < n && depth > 0 {
            let t = match toks.get(j) {
                Some(t) => t,
                None => break,
            };
            if t.is("[") {
                depth += 1;
            } else if t.is("]") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            attr.push_str(&t.text);
            j += 1;
        }
        let is_test_attr = attr.starts_with("cfg(test") || attr == "test";
        if !is_test_attr {
            i = j + 1;
            continue;
        }
        // skip any further attributes, then mask the following item's
        // brace span (mod body or fn body)
        let mut k = j + 1;
        while toks.get(k).is_some_and(|t| t.is("#"))
            && toks.get(k + 1).is_some_and(|t| t.is("["))
        {
            let mut d = 1i32;
            k += 2;
            while k < n && d > 0 {
                if toks.get(k).is_some_and(|t| t.is("[")) {
                    d += 1;
                } else if toks.get(k).is_some_and(|t| t.is("]")) {
                    d -= 1;
                }
                k += 1;
            }
        }
        let mut open = None;
        let mut m = k;
        while m < n {
            let t = match toks.get(m) {
                Some(t) => t,
                None => break,
            };
            if t.is(";") {
                break; // e.g. `mod foo;` — nothing inline to mask
            }
            if t.is("{") {
                open = Some(m);
                break;
            }
            m += 1;
        }
        let Some(open) = open else {
            i = j + 1;
            continue;
        };
        let mut d = 0i32;
        let mut close = open;
        while close < n {
            if toks.get(close).is_some_and(|t| t.is("{")) {
                d += 1;
            } else if toks.get(close).is_some_and(|t| t.is("}")) {
                d -= 1;
                if d == 0 {
                    break;
                }
            }
            close += 1;
        }
        for slot in masked.iter_mut().take((close + 1).min(n)).skip(i) {
            *slot = true;
        }
        i = close + 1;
    }
    masked
}

/// One function body: name plus the token span of its `{ ... }` block
/// (indices into the token stream, inclusive).
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub start: usize,
    pub end: usize,
}

/// Every `fn name ... { ... }` body span in the stream. Nested fns and
/// closures inside a body are attributed to the innermost named fn by
/// [`containing_fn`].
pub fn fn_spans(toks: &[Tok]) -> Vec<FnSpan> {
    let n = toks.len();
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < n {
        let is_fn = toks.get(i).is_some_and(|t| t.kind == Kind::Ident && t.is("fn"));
        let name = toks.get(i + 1).filter(|t| t.kind == Kind::Ident).map(|t| t.text.clone());
        if let (true, Some(name)) = (is_fn, name) {
            // find the body `{` at paren/bracket depth 0 (skips argument
            // lists, return types, where clauses)
            let mut j = i + 2;
            let mut level = 0i32;
            let mut open = None;
            while j < n {
                let t = match toks.get(j) {
                    Some(t) => t,
                    None => break,
                };
                if t.is("(") || t.is("[") {
                    level += 1;
                } else if t.is(")") || t.is("]") {
                    level -= 1;
                } else if t.is(";") && level == 0 {
                    break; // trait method / extern decl: no body
                } else if t.is("{") && level == 0 {
                    open = Some(j);
                    break;
                }
                j += 1;
            }
            if let Some(open) = open {
                let mut d = 0i32;
                let mut close = open;
                while close < n {
                    if toks.get(close).is_some_and(|t| t.is("{")) {
                        d += 1;
                    } else if toks.get(close).is_some_and(|t| t.is("}")) {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    }
                    close += 1;
                }
                spans.push(FnSpan { name, start: open, end: close.min(n.saturating_sub(1)) });
                i = open; // bodies may contain nested fns
            } else {
                i = j;
            }
        }
        i += 1;
    }
    spans
}

/// Innermost named fn whose body contains token `idx` (empty if none).
pub fn containing_fn(spans: &[FnSpan], idx: usize) -> String {
    let mut best: Option<&FnSpan> = None;
    for s in spans {
        if s.start <= idx && idx <= s.end {
            let better = best.map_or(true, |b| s.start > b.start);
            if better {
                best = Some(s);
            }
        }
    }
    best.map(|s| s.name.clone()).unwrap_or_default()
}
