#!/usr/bin/env python3
"""Bench-trajectory regression gate for the smoke benches.

Each bench driver appends machine-readable records to a committed
JSON-array file (BENCH_assoc.json / BENCH_scan.json / BENCH_net.json).
CI runs the drivers with --smoke, then this script compares the records
*appended during this run* (working tree) against the *committed*
trajectory (``git show <ref>:<file>``): for every (op, backend, n) key
present in both, the best fresh ``entries_per_sec`` must not fall more
than ``--threshold`` (default 40%) below the last committed record.

Keys with no committed baseline pass with a note — the trajectory
accumulates from whatever CI commits next. A missing/empty committed
file means "no baseline yet" and passes wholesale.

Usage:
    python3 tools/bench_check.py [--threshold 0.4] [--ref HEAD] FILE...

Exit status: 0 = no regression, 1 = regression, 2 = usage/IO error.
"""

import argparse
import json
import subprocess
import sys


def committed_records(ref, path):
    """Records of `path` at `ref`, or [] when absent there."""
    try:
        out = subprocess.run(
            ["git", "show", f"{ref}:{path}"],
            capture_output=True,
            text=True,
            check=False,
        )
    except OSError as e:
        print(f"bench_check: cannot run git ({e}); treating {path} as baseline-less")
        return []
    if out.returncode != 0:
        return []
    body = out.stdout.strip()
    if not body:
        return []
    try:
        return json.loads(body)
    except json.JSONDecodeError as e:
        print(f"bench_check: committed {path} is not valid JSON ({e}); ignoring baseline")
        return []


def key(rec):
    return (rec["op"], rec["backend"], rec["n"])


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threshold", type=float, default=0.4,
                    help="maximum tolerated fractional drop (default 0.4 = 40%%)")
    ap.add_argument("--ref", default="HEAD", help="git ref holding the baseline")
    ap.add_argument("files", nargs="+")
    args = ap.parse_args()

    failures = 0
    compared = 0
    for path in args.files:
        try:
            with open(path, encoding="utf-8") as f:
                current = json.load(f)
        except FileNotFoundError:
            print(f"bench_check: {path}: not produced by this run — skipping")
            continue
        except json.JSONDecodeError as e:
            print(f"bench_check: {path}: invalid JSON ({e})")
            return 2

        baseline_recs = committed_records(args.ref, path)
        fresh = current[len(baseline_recs):]
        if not fresh:
            print(f"bench_check: {path}: no new records appended this run")
            continue
        if not baseline_recs:
            print(f"bench_check: {path}: no committed baseline yet — "
                  f"{len(fresh)} fresh record(s) pass by default")
            continue

        # last committed record per key is the baseline; best fresh per
        # key is the candidate (smoke runs can repeat a key)
        baseline = {}
        for rec in baseline_recs:
            baseline[key(rec)] = rec["entries_per_sec"]
        best = {}
        for rec in fresh:
            k = key(rec)
            best[k] = max(best.get(k, 0.0), rec["entries_per_sec"])

        for k, got in sorted(best.items()):
            want = baseline.get(k)
            tag = "/".join(str(p) for p in k)
            if want is None:
                print(f"  {path}: {tag}: {got:,.0f}/s (new key, no baseline)")
                continue
            compared += 1
            floor = want * (1.0 - args.threshold)
            verdict = "OK" if got >= floor else "REGRESSION"
            print(f"  {path}: {tag}: {got:,.0f}/s vs baseline {want:,.0f}/s "
                  f"(floor {floor:,.0f}/s) {verdict}")
            if got < floor:
                failures += 1

        # A committed key the smoke run no longer produces loses its
        # regression coverage silently (e.g. a renamed scenario label).
        # Informational, not fatal: full-run records legitimately carry
        # sizes the smoke probe never revisits.
        for k in sorted(set(baseline) - set(best)):
            tag = "/".join(str(p) for p in k)
            print(f"  {path}: {tag}: baseline key not exercised by this run "
                  f"(no regression coverage)")

    print(f"bench_check: {compared} key(s) compared, {failures} regression(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
