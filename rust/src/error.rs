//! Error type shared across the D4M stack.

use std::fmt;

/// Errors surfaced by the D4M library.
#[derive(Debug)]
pub enum D4mError {
    /// Associative-array shape/key mismatch (e.g. matmul inner keys disjoint
    /// when strict alignment was requested).
    Shape(String),
    /// A table/array/database object was not found in the registry.
    NotFound(String),
    /// A table/array already exists and `create` was not `if_not_exists`.
    AlreadyExists(String),
    /// Client-side operation exceeded its configured memory budget —
    /// this is the Figure-2 "memory wall" condition.
    MemoryLimit { used: usize, limit: usize },
    /// Malformed input data (triples file, CSV, schema violation).
    Parse(String),
    /// Dense-runtime failure (kernel engine error).
    Runtime(String),
    /// Ingest pipeline failure (worker panic, channel closed).
    Pipeline(String),
    /// Invalid argument to a public API.
    InvalidArg(String),
    /// A typed API wrapper received a
    /// [`Response`](crate::coordinator::Response) variant other than the
    /// one its request produces — a protocol bug or a client/server
    /// skew, **not** a bad argument (which is what [`D4mError::InvalidArg`]
    /// reports).
    UnexpectedResponse { expected: String, got: String },
    /// I/O error wrapper.
    Io(std::io::Error),
    /// Wire-codec failure (malformed/truncated frame) on the network
    /// front-end — see [`crate::net::wire::WireError`].
    Wire(crate::net::wire::WireError),
    /// An error reported by a remote D4M server, carried across the
    /// wire (remote variants that wrap process-local types — I/O, wire —
    /// arrive as their message strings).
    Remote(String),
    /// Ingest was stalled by the durable store's compaction backlog for
    /// longer than the configured backpressure timeout — the write was
    /// **not** applied. Retry after the compactor drains the backlog.
    Backpressure { table: String, waited_ms: u64 },
    /// Durable-storage corruption or protocol violation (bad WAL/run/
    /// manifest bytes, checksum mismatch, unrecognised layout). Hostile
    /// or torn files surface here — never as a panic.
    Storage(String),
    /// The server shed this request/connection under load (conn pool or
    /// cursor table saturated) **before doing any work** — the request
    /// was not applied and is always safe to retry after roughly
    /// `retry_after_ms` milliseconds. Self-healing clients honor the
    /// hint as a backoff floor.
    Overloaded { retry_after_ms: u64 },
    /// A retryable (idempotent) request failed on every attempt the
    /// [`RetryPolicy`](crate::net::client::RetryPolicy) allowed; `last`
    /// is the final attempt's error rendered as a string.
    RetryExhausted { attempts: u32, last: String },
    /// A **non-idempotent** request (ingest, server-side accumulating
    /// multiply, …) may or may not have been applied: the connection
    /// died after the request bytes could have reached the server but
    /// before a reply arrived. The client refuses to replay it — doing
    /// so could double-apply — and surfaces this instead. The caller
    /// must reconcile (re-read and compare) before retrying.
    AmbiguousWrite(String),
}

impl fmt::Display for D4mError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            D4mError::Shape(s) => write!(f, "shape error: {s}"),
            D4mError::NotFound(s) => write!(f, "not found: {s}"),
            D4mError::AlreadyExists(s) => write!(f, "already exists: {s}"),
            D4mError::MemoryLimit { used, limit } => write!(
                f,
                "client-side memory limit exceeded: used {used} bytes of {limit}"
            ),
            D4mError::Parse(s) => write!(f, "parse error: {s}"),
            D4mError::Runtime(s) => write!(f, "runtime error: {s}"),
            D4mError::Pipeline(s) => write!(f, "pipeline error: {s}"),
            D4mError::InvalidArg(s) => write!(f, "invalid argument: {s}"),
            D4mError::UnexpectedResponse { expected, got } => {
                write!(f, "unexpected response: expected {expected}, got {got}")
            }
            D4mError::Io(e) => write!(f, "io error: {e}"),
            D4mError::Wire(e) => write!(f, "wire error: {e}"),
            D4mError::Remote(s) => write!(f, "remote error: {s}"),
            D4mError::Backpressure { table, waited_ms } => write!(
                f,
                "backpressure: ingest into {table} stalled {waited_ms} ms on the compaction backlog"
            ),
            D4mError::Storage(s) => write!(f, "storage error: {s}"),
            D4mError::Overloaded { retry_after_ms } => {
                write!(f, "server overloaded: retry after {retry_after_ms} ms")
            }
            D4mError::RetryExhausted { attempts, last } => {
                write!(f, "retry budget exhausted after {attempts} attempts: {last}")
            }
            D4mError::AmbiguousWrite(s) => write!(
                f,
                "ambiguous write (connection died mid-flight, request may or may not have been applied): {s}"
            ),
        }
    }
}

impl std::error::Error for D4mError {}

impl From<std::io::Error> for D4mError {
    fn from(e: std::io::Error) -> Self {
        D4mError::Io(e)
    }
}

impl From<crate::net::wire::WireError> for D4mError {
    fn from(e: crate::net::wire::WireError) -> Self {
        D4mError::Wire(e)
    }
}

/// Convenience alias used across the library.
pub type Result<T> = std::result::Result<T, D4mError>;
