//! TCP front-end over the coordinator: an accept loop sharing one
//! `Arc<D4mServer>` across a bounded thread-per-connection pool, with a
//! **per-connection demux** so one connection can have many requests in
//! flight at once (wire v2 request-id framing).
//!
//! §Thread model (DESIGN.md §Wire v2): one accept thread; per live
//! connection one *reader* thread plus [`NetOpts::workers_per_conn`]
//! *worker* threads (scoped to the connection). The reader decodes
//! frames and dispatches `(id, msg)` work items over a bounded channel —
//! when every worker is busy and the queue is full the reader blocks,
//! backpressuring the socket instead of buffering unboundedly. Workers
//! execute against the shared [`D4mServer`] concurrently and write each
//! reply frame under a shared writer lock **as it completes**, so
//! responses legitimately overtake each other; the client correlates by
//! request id. At most [`NetOpts::max_conns`] connections are served.
//!
//! §Load shedding (DESIGN.md §Fault model): when the pool is full the
//! accept loop waits up to [`NetOpts::shed_after`] for a slot, then
//! **sheds** the accepted connection with a framed
//! [`D4mError::Overloaded`] carrying a `retry_after_ms` hint (under the
//! reserved id 0) instead of queueing peers on the accept condvar
//! indefinitely. A shed happens before any frame is read, so nothing
//! was executed — the self-healing client treats it as safe to retry
//! everything after the hinted backoff.
//!
//! §Cursor ownership: every connection gets a distinct owner id;
//! `OpenCursor`/`CursorNext`/`CursorClose` act only on that owner's
//! cursors, and connection teardown (clean or poisoned) **orphans**
//! whatever the connection left open into the resume-grace window — a
//! reconnecting client presenting the resume token re-attaches to the
//! same pinned snapshot; everything else is dropped by the background
//! cursor sweep (which also enforces the idle TTL on a quiet server,
//! so leaked cursors are reaped even with zero cursor traffic).
//!
//! §Error framing: a malformed frame poisons only its own connection —
//! the server replies with a framed error carrying the reserved id 0
//! ([`wire::CONN_ERR_ID`], best effort) and closes that socket; the
//! listener and every other connection keep serving. A per-request
//! failure (unknown table, cursor cap, oversized response) is an
//! ordinary error `Reply` under the request's own id and the connection
//! keeps serving.
//!
//! §Shutdown protocol: `NetHandle::shutdown()` (or a client
//! [`ClientMsg::Shutdown`] frame) sets the shared flag, then pokes the
//! listener with a loopback connect to unblock `accept`. Idle readers
//! poll the flag every [`NetOpts::idle_poll`] while waiting for a
//! frame's first byte; in-flight requests run to completion and their
//! replies are written before the connection drains. The accept thread
//! exits only after the last connection thread has drained, so `wait()`
//! returning means the server is fully quiesced.

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{CursorPage, D4mServer};
use crate::error::{D4mError, Result};
use crate::metrics::{names, Counter, Histogram, Snapshot};
use crate::net::wire::{self, ClientMsg, ServerMsg, WireError};
use crate::util::lock_recover;

/// Cap on the `page_entries` a remote `OpenCursor` may request. The
/// per-page byte budget ([`crate::coordinator::cursor::PAGE_BYTE_BUDGET`])
/// is what actually bounds server memory; this keeps a hostile ask from
/// reserving absurd page buffers up front.
const MAX_PAGE_ENTRIES: usize = 1 << 20;

/// Approximate wire bytes of a cursor page (string bytes plus a bounded
/// per-triple varint/length overhead).
fn page_wire_bytes(page: &CursorPage) -> usize {
    let triples: usize =
        page.triples.iter().map(|(r, c, v)| r.len() + c.len() + v.len() + 15).sum();
    triples + 16
}

/// Tuning for [`serve`].
#[derive(Debug, Clone)]
pub struct NetOpts {
    /// Maximum simultaneously served connections (the thread-pool bound).
    pub max_conns: usize,
    /// Worker threads per connection — the per-connection concurrency of
    /// pipelined requests. The dispatch queue holds the same number
    /// again, so at most `2 * workers_per_conn` requests are in flight
    /// per connection before the reader backpressures the socket.
    pub workers_per_conn: usize,
    /// How often an idle connection re-checks the shutdown flag.
    pub idle_poll: Duration,
    /// Whole-frame deadline once a frame is in flight (and the write
    /// timeout): a peer that has not delivered a complete frame within
    /// this budget is dropped — dribbling one byte per poll cannot hold
    /// a pool slot forever.
    pub io_timeout: Duration,
    /// How long a full pool holds an accepted connection waiting for a
    /// slot before shedding it with a framed
    /// [`D4mError::Overloaded`] (`retry_after_ms` = this budget). Zero
    /// sheds immediately.
    pub shed_after: Duration,
}

impl Default for NetOpts {
    fn default() -> Self {
        NetOpts {
            max_conns: 64,
            workers_per_conn: 8,
            idle_poll: Duration::from_millis(200),
            io_timeout: Duration::from_secs(30),
            shed_after: Duration::from_millis(500),
        }
    }
}

/// Cadence of the background cursor sweep (TTL + orphan-grace eviction)
/// that runs from the accept-side sweeper thread, so cursor eviction no
/// longer depends on cursor traffic to make progress.
const SWEEP_EVERY: Duration = Duration::from_millis(500);

/// State shared between the accept loop, connection threads and the
/// [`NetHandle`].
struct Shared {
    server: Arc<D4mServer>,
    opts: NetOpts,
    addr: SocketAddr,
    shutdown: AtomicBool,
    /// Live connection-thread count; guarded waits on `pool_cv` bound the
    /// pool and let the accept loop drain on shutdown.
    active: Mutex<usize>,
    pool_cv: Condvar,
    /// Next per-connection cursor owner id (0 is the in-process owner).
    next_owner: AtomicU64,
    /// Net-layer counters, surfaced through [`NetHandle::snapshots`].
    requests: Histogram,
    bad_frames: Counter,
    bytes_in: Counter,
    bytes_out: Counter,
    /// Cursors dropped by the background sweep (idle TTL or expired
    /// orphan grace).
    cursors_reaped: Counter,
    /// Cursors parked into the resume-grace window at connection
    /// teardown.
    cursors_orphaned: Counter,
    /// Connections shed with `Overloaded` because the pool stayed full
    /// past `shed_after`.
    sheds: Counter,
}

impl Shared {
    /// The coordinator's per-op snapshots with the net-layer request
    /// histogram, byte counters and cursor gauges folded in.
    fn snapshots(&self) -> Vec<Snapshot> {
        let mut snaps = self.server.snapshots();
        snaps.push(Snapshot {
            name: names::NET_REQUESTS.into(),
            count: self.requests.count(),
            rate_per_sec: self.requests.rate_per_sec(),
            mean_latency_ns: self.requests.mean_ns(),
            p99_latency_ns: self.requests.quantile_ns(0.99),
        });
        for (name, count) in [
            (names::NET_BAD_FRAMES, self.bad_frames.get()),
            (names::NET_BYTES_IN, self.bytes_in.get()),
            (names::NET_BYTES_OUT, self.bytes_out.get()),
            (names::NET_CURSORS_OPEN, self.server.open_cursor_count() as u64),
            (names::NET_CURSORS_REAPED, self.cursors_reaped.get()),
            (names::NET_CURSORS_ORPHANED, self.cursors_orphaned.get()),
            (names::NET_SHEDS, self.sheds.get()),
        ] {
            snaps.push(Snapshot {
                name: name.into(),
                count,
                rate_per_sec: 0.0,
                mean_latency_ns: 0.0,
                p99_latency_ns: 0,
            });
        }
        snaps
    }

    fn initiate_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the accept loop (and re-check in any pool-full wait);
        // a wildcard bind is poked via the matching loopback family, and
        // the poke never hangs on a saturated backlog
        let mut poke = self.addr;
        if poke.ip().is_unspecified() {
            poke.set_ip(match poke.ip() {
                std::net::IpAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                std::net::IpAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
            });
        }
        let _ = TcpStream::connect_timeout(&poke, Duration::from_secs(2));
        self.pool_cv.notify_all();
    }
}

/// Handle to a running network front-end.
pub struct NetHandle {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    sweeper: Option<JoinHandle<()>>,
}

impl NetHandle {
    /// The bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Coordinator + net-layer metrics snapshots.
    pub fn snapshots(&self) -> Vec<Snapshot> {
        self.shared.snapshots()
    }

    /// True once shutdown has been initiated (locally or by a client).
    pub fn is_shutting_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Block until the server has fully quiesced (accept loop exited and
    /// every connection drained). Returns immediately if already joined.
    pub fn wait(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sweeper.take() {
            let _ = h.join();
        }
    }

    /// Initiate graceful shutdown and wait for full quiescence.
    pub fn shutdown(&mut self) {
        self.shared.initiate_shutdown();
        self.wait();
    }
}

impl Drop for NetHandle {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown();
        }
    }
}

/// Start serving `server` on `addr` (e.g. `"127.0.0.1:4950"`; port 0
/// picks an ephemeral port, readable from [`NetHandle::addr`]).
pub fn serve(server: Arc<D4mServer>, addr: &str, mut opts: NetOpts) -> Result<NetHandle> {
    // a pool of zero would park the accept loop forever; zero workers
    // would park every connection
    opts.max_conns = opts.max_conns.max(1);
    opts.workers_per_conn = opts.workers_per_conn.max(1);
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let shared = Arc::new(Shared {
        server,
        opts,
        addr: local,
        shutdown: AtomicBool::new(false),
        active: Mutex::new(0),
        pool_cv: Condvar::new(),
        next_owner: AtomicU64::new(1),
        requests: Histogram::new(),
        bad_frames: Counter::new(),
        bytes_in: Counter::new(),
        bytes_out: Counter::new(),
        cursors_reaped: Counter::new(),
        cursors_orphaned: Counter::new(),
        sheds: Counter::new(),
    });
    let sh = shared.clone();
    let accept = std::thread::Builder::new()
        .name("d4m-net-accept".into())
        .spawn(move || accept_loop(listener, sh))?;
    let sh = shared.clone();
    let sweeper = std::thread::Builder::new()
        .name("d4m-net-sweep".into())
        .spawn(move || sweep_loop(sh))?;
    Ok(NetHandle { shared, accept: Some(accept), sweeper: Some(sweeper) })
}

/// Background cursor sweep: evicts idle-TTL'd cursors and expired
/// orphans on a fixed cadence, independent of cursor traffic (the
/// cursor-op path used to be the only place eviction ran, so a leaked
/// cursor on a quiet server was never collected).
fn sweep_loop(sh: Arc<Shared>) {
    let tick = sh.opts.idle_poll.min(SWEEP_EVERY).max(Duration::from_millis(10));
    let mut since_sweep = Duration::ZERO;
    while !sh.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        since_sweep += tick;
        if since_sweep >= SWEEP_EVERY {
            since_sweep = Duration::ZERO;
            let n = sh.server.sweep_cursors();
            if n > 0 {
                sh.cursors_reaped.add(n as u64);
            }
        }
    }
}

fn accept_loop(listener: TcpListener, sh: Arc<Shared>) {
    for conn in listener.incoming() {
        if sh.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => {
                // e.g. EMFILE under fd pressure: back off instead of
                // spinning a core while the condition persists
                std::thread::sleep(sh.opts.idle_poll);
                continue;
            }
        };
        // bounded pool: hold the accepted socket briefly for a slot,
        // then shed with a typed Overloaded hint rather than queueing
        // the peer on the condvar indefinitely
        {
            let shed_deadline = Instant::now() + sh.opts.shed_after;
            let mut active = lock_recover(&sh.active);
            let mut shed_now = false;
            while *active >= sh.opts.max_conns && !sh.shutdown.load(Ordering::SeqCst) {
                let now = Instant::now();
                if now >= shed_deadline {
                    shed_now = true;
                    break;
                }
                let (g, _) = sh
                    .pool_cv
                    .wait_timeout(active, shed_deadline - now)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                active = g;
            }
            if sh.shutdown.load(Ordering::SeqCst) {
                break;
            }
            if shed_now {
                drop(active);
                shed(stream, &sh);
                continue;
            }
            *active += 1;
        }
        let sh2 = sh.clone();
        let builder = std::thread::Builder::new().name("d4m-net-conn".into());
        let spawned = builder.spawn(move || {
            // the guard's Drop releases the pool slot and orphans the
            // connection's cursors even if the demux panics (a worker
            // panic propagates through thread::scope and would otherwise
            // leak the slot forever and wedge the shutdown drain)
            let owner = sh2.next_owner.fetch_add(1, Ordering::SeqCst);
            let _guard = ConnGuard { sh: &sh2, owner };
            let _ = conn_demux(stream, &sh2, owner);
        });
        if spawned.is_err() {
            // never happened in practice; release the reserved slot
            let mut active = lock_recover(&sh.active);
            *active -= 1;
            sh.pool_cv.notify_all();
        }
    }
    // drain: connection readers notice the flag within one idle_poll,
    // hang up their dispatch queues, and join their workers —
    // in-flight requests run to completion first
    let mut active = lock_recover(&sh.active);
    while *active > 0 {
        active = sh
            .pool_cv
            .wait(active)
            .unwrap_or_else(std::sync::PoisonError::into_inner);
    }
}

/// End-of-connection cleanup that must run no matter how the connection
/// thread exits — clean return, error, or panic: orphan the
/// connection's cursors into the resume-grace window, release its pool
/// slot, and wake the accept loop. Runs in `Drop` so an unwinding demux
/// cannot leak a `max_conns` slot or pin a cursor snapshot beyond the
/// grace window.
struct ConnGuard<'a> {
    sh: &'a Shared,
    owner: u64,
}

impl Drop for ConnGuard<'_> {
    fn drop(&mut self) {
        // park (not drop) the connection's cursors: a reconnecting
        // client presenting the resume token re-attaches within the
        // grace window; the background sweep collects the rest
        let orphaned = self.sh.server.orphan_cursors(self.owner);
        if orphaned > 0 {
            self.sh.cursors_orphaned.add(orphaned as u64);
        }
        // recover a poisoned lock rather than double-panicking in drop:
        // the counter itself is always coherent (only ever touched under
        // the lock, never across a panic point)
        let mut active = lock_recover(&self.sh.active);
        *active -= 1;
        self.sh.pool_cv.notify_all();
    }
}

/// The per-connection demux: reader decodes and dispatches, scoped
/// workers execute and reply out of order (see the module docs).
fn conn_demux(mut stream: TcpStream, sh: &Shared, owner: u64) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_write_timeout(Some(sh.opts.io_timeout))?;
    // the write half shares the socket fd, so the write timeout set
    // above covers frames written through the clone too
    let writer = Mutex::new(stream.try_clone()?);
    let workers = sh.opts.workers_per_conn;
    let (tx, rx) = std::sync::mpsc::sync_channel::<(u64, ClientMsg)>(workers);
    let rx = Mutex::new(rx);
    // a worker whose reply write failed flags the connection dead; the
    // reader notices on its next poll tick and hangs up (workers keep
    // draining the queue meanwhile so the reader can never deadlock on a
    // full dispatch queue)
    let dead = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| worker_loop(&rx, &writer, sh, owner, &dead));
        }
        let r = reader_loop(&mut stream, sh, &tx, &writer, &dead);
        drop(tx); // hang up: workers finish in-flight work and exit
        r
    })
}

/// Decode frames and dispatch work items until the peer hangs up, a
/// frame poisons the connection, or shutdown/death is flagged.
fn reader_loop(
    stream: &mut TcpStream,
    sh: &Shared,
    tx: &SyncSender<(u64, ClientMsg)>,
    writer: &Mutex<TcpStream>,
    dead: &AtomicBool,
) -> Result<()> {
    loop {
        // check shutdown/death before every frame, not just on idle
        // timeouts — a peer that streams requests back-to-back never
        // goes idle, and must not keep a dead connection (or a shutting-
        // down server) dispatching work
        if sh.shutdown.load(Ordering::SeqCst) || dead.load(Ordering::SeqCst) {
            return Ok(());
        }
        // poll for a frame's first byte so an idle connection notices
        // shutdown (or a dead writer) without a dedicated waker
        stream.set_read_timeout(Some(sh.opts.idle_poll))?;
        let mut first = 0u8;
        match stream.read(std::slice::from_mut(&mut first)) {
            Ok(0) => return Ok(()), // peer closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if sh.shutdown.load(Ordering::SeqCst) || dead.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        // a frame is in flight: the rest of it must arrive within one
        // whole-frame deadline (the read timeout stays at idle_poll, so
        // the deadline reader re-checks wall clock + shutdown per poll —
        // a peer dribbling bytes cannot reset the budget)
        let deadline = Instant::now() + sh.opts.io_timeout;
        let mut reader = DeadlineReader { stream: &mut *stream, sh, deadline };
        let payload = match wire::read_frame_rest(first, &mut reader) {
            Ok(p) => p,
            // malformed frame: framed error back, close this connection
            Err(e @ D4mError::Wire(_)) => return poison(writer, sh, e),
            // I/O failure (peer gone, frame deadline): nothing to reply to
            Err(_) => return Ok(()),
        };
        sh.bytes_in.add((wire::HEADER_LEN + payload.len()) as u64);
        let (id, msg) = match wire::decode_client_frame(&payload) {
            Ok(m) => m,
            Err(we) => return poison(writer, sh, we.into()),
        };
        if tx.send((id, msg)).is_err() {
            return Ok(()); // workers gone (only happens on teardown)
        }
    }
}

/// Pull work items until the reader hangs up the channel; execute each
/// against the shared coordinator and write the reply as it completes.
fn worker_loop(
    rx: &Mutex<Receiver<(u64, ClientMsg)>>,
    writer: &Mutex<TcpStream>,
    sh: &Shared,
    owner: u64,
    dead: &AtomicBool,
) {
    loop {
        // the lock is held only across the blocking recv — the classic
        // shared-receiver pattern: one worker waits, the rest park on
        // the mutex, and execution happens after the lock is released
        let item = lock_recover(rx).recv();
        let (id, msg) = match item {
            Ok(it) => it,
            Err(_) => return, // reader hung up and the queue is drained
        };
        let (reply, shutdown_after) = execute(sh, owner, msg);
        if !dead.load(Ordering::SeqCst) && send_reply(writer, sh, id, reply).is_err() {
            dead.store(true, Ordering::SeqCst);
        }
        if shutdown_after {
            sh.initiate_shutdown();
        }
    }
}

/// Run one decoded message against the coordinator. Returns the reply
/// and whether the server should shut down after it is sent.
fn execute(sh: &Shared, owner: u64, msg: ClientMsg) -> (ServerMsg, bool) {
    match msg {
        ClientMsg::Api(req) => {
            let resp = sh.requests.time(|| sh.server.handle(req));
            (ServerMsg::Reply(resp), false)
        }
        // the frame-level header already enforces version equality; the
        // in-payload version lets a future vN+1 probe a vN server
        // explicitly (the client checks the Pong's version)
        ClientMsg::Ping { version: _ } => (ServerMsg::Pong { version: wire::VERSION }, false),
        ClientMsg::Stats => (ServerMsg::Stats(sh.snapshots()), false),
        ClientMsg::Shutdown => {
            // flush-before-ack: every memtable freezes into an on-disk
            // run and the WALs fsync before the ack leaves, so an acked
            // shutdown implies nothing was only in RAM. On checkpoint
            // failure the ack still goes out — every acked write is in
            // the WAL already, so recovery replays it; refusing to shut
            // down would just wedge the client.
            if let Err(e) = sh.server.checkpoint() {
                eprintln!("d4m-net: checkpoint on shutdown failed: {e}");
            }
            (ServerMsg::ShutdownAck, true)
        }
        ClientMsg::OpenCursor { table, query, page_entries, resume } => {
            let r = match resume {
                // a resume re-attaches to the surviving server-side
                // cursor (same pinned snapshot); table/query/page_entries
                // only describe the original open and are ignored here
                Some(rt) => sh.requests.time(|| sh.server.resume_cursor_owned(owner, &rt)),
                None => {
                    // clamp what a remote peer may ask for: the per-page
                    // byte budget (cursor::PAGE_BYTE_BUDGET) bounds
                    // memory anyway, but a sane entry cap keeps a
                    // hostile ask from reserving absurd page buffers
                    let pe = usize::try_from(page_entries)
                        .unwrap_or(MAX_PAGE_ENTRIES)
                        .clamp(1, MAX_PAGE_ENTRIES);
                    sh.requests.time(|| sh.server.open_cursor_owned(owner, &table, &query, pe))
                }
            };
            (
                match r {
                    Ok((cursor, token)) => ServerMsg::CursorOpened { cursor, token },
                    Err(e) => ServerMsg::Reply(Err(e)),
                },
                false,
            )
        }
        ClientMsg::CursorNext { cursor } => {
            let r = sh.requests.time(|| sh.server.cursor_next_owned(owner, cursor));
            let msg = match r {
                // a pathological page (single triples beyond the byte
                // budget) that cannot fit one frame: a retry after a
                // downgraded send would silently skip the dropped page,
                // so close the cursor and say why
                Ok(page) if page_wire_bytes(&page) > wire::MAX_FRAME - 1024 => {
                    let bytes = page_wire_bytes(&page);
                    let _ = sh.server.cursor_close_owned(owner, cursor);
                    ServerMsg::Reply(Err(oversized(bytes)))
                }
                Ok(page) => ServerMsg::CursorPage(page),
                Err(e) => ServerMsg::Reply(Err(e)),
            };
            (msg, false)
        }
        ClientMsg::CursorClose { cursor } => (
            match sh.server.cursor_close_owned(owner, cursor) {
                Ok(()) => ServerMsg::CursorClosed,
                Err(e) => ServerMsg::Reply(Err(e)),
            },
            false,
        ),
        ClientMsg::OpenPlanCursor { ops, page_entries } => {
            // same entry clamp as a scan cursor; the plan was already
            // SSA-revalidated at wire decode, so the executor only ever
            // sees well-formed programs
            let pe = usize::try_from(page_entries)
                .unwrap_or(MAX_PAGE_ENTRIES)
                .clamp(1, MAX_PAGE_ENTRIES);
            let r = sh.requests.time(|| sh.server.open_plan_cursor_owned(owner, &ops, pe));
            (
                match r {
                    Ok((cursor, token)) => ServerMsg::CursorOpened { cursor, token },
                    Err(e) => ServerMsg::Reply(Err(e)),
                },
                false,
            )
        }
    }
}

/// Write one reply frame, downgrading a too-big-for-one-frame response
/// to a framed error under the same id (detected before any bytes hit
/// the socket, so the connection stays clean and keeps serving).
fn send_reply(writer: &Mutex<TcpStream>, sh: &Shared, id: u64, mut reply: ServerMsg) -> Result<()> {
    // an assoc that cannot possibly fit the frame cap is rejected
    // *before* encoding — the cap must bound server memory too, not
    // just wire bytes (encode would otherwise materialise the whole
    // oversized buffer just to have write_frame refuse it)
    let oversize = match &reply {
        ServerMsg::Reply(Ok(crate::coordinator::Response::Assoc(a)))
            if a.mem_bytes() > wire::MAX_FRAME =>
        {
            Some(a.mem_bytes())
        }
        _ => None,
    };
    if let Some(n) = oversize {
        reply = ServerMsg::Reply(Err(oversized(n)));
    }
    match send(writer, sh, id, &reply) {
        Err(D4mError::Wire(WireError::FrameTooLarge(n))) => {
            send(writer, sh, id, &ServerMsg::Reply(Err(oversized(n))))
        }
        other => other,
    }
}

/// Reader over an in-flight frame: the underlying stream keeps the
/// short `idle_poll` read timeout, and every timeout tick re-checks one
/// wall-clock deadline for the *whole* frame plus the shutdown flag —
/// so a peer dribbling one byte per tick cannot reset its budget or
/// stall quiescence.
struct DeadlineReader<'a> {
    stream: &'a mut TcpStream,
    sh: &'a Shared,
    deadline: Instant,
}

impl Read for DeadlineReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            match self.stream.read(buf) {
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if self.sh.shutdown.load(Ordering::SeqCst)
                        || Instant::now() >= self.deadline
                    {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            "whole-frame deadline elapsed",
                        ));
                    }
                }
                other => return other,
            }
        }
    }
}

/// Shed an accepted-but-unserved connection: best-effort framed
/// [`D4mError::Overloaded`] under the reserved id 0, then close. The
/// shed happens before any frame is read off the socket, so the peer
/// knows nothing it sent was executed — a retry after the hint is
/// always safe, writes included.
fn shed(stream: TcpStream, sh: &Shared) {
    sh.sheds.inc();
    let retry_after_ms = (sh.opts.shed_after.as_millis() as u64).max(50);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let reply = ServerMsg::Reply(Err(D4mError::Overloaded { retry_after_ms }));
    let buf = wire::encode_server_frame(wire::CONN_ERR_ID, &reply);
    let mut stream = stream;
    if wire::write_frame(&mut stream, &buf).is_ok() {
        sh.bytes_out.add((wire::HEADER_LEN + buf.len()) as u64);
    }
}

/// A bad frame poisons the connection, never the server: best-effort
/// framed error (reserved id 0 — it answers no specific request) back
/// to the peer, then close (by returning). Only protocol-level failures
/// land here (`net.bad_frames` counts hostile or corrupt input, not
/// routine disconnects).
fn poison(writer: &Mutex<TcpStream>, sh: &Shared, e: D4mError) -> Result<()> {
    sh.bad_frames.inc();
    let _ = send(writer, sh, wire::CONN_ERR_ID, &ServerMsg::Reply(Err(e)));
    Ok(())
}

/// The error a too-big-for-one-frame response turns into.
fn oversized(bytes: usize) -> D4mError {
    D4mError::InvalidArg(format!(
        "response of ~{bytes} bytes exceeds the {} byte frame cap — \
         narrow the query, use a limit, or stream it with a cursor \
         (scan_pages)",
        wire::MAX_FRAME
    ))
}

fn send(writer: &Mutex<TcpStream>, sh: &Shared, id: u64, msg: &ServerMsg) -> Result<()> {
    let buf = wire::encode_server_frame(id, msg);
    if buf.len() > wire::MAX_FRAME {
        // check before taking the lock so an oversized encode can never
        // interleave a partial frame
        return Err(WireError::FrameTooLarge(buf.len()).into());
    }
    let mut stream = lock_recover(writer);
    wire::write_frame(&mut *stream, &buf)?;
    sh.bytes_out.add((wire::HEADER_LEN + buf.len()) as u64);
    Ok(())
}
