//! TCP front-end over the coordinator: an accept loop sharing one
//! `Arc<D4mServer>` across a bounded thread-per-connection pool.
//!
//! §Thread model (DESIGN.md §Network front-end): one accept thread, one
//! thread per live connection, at most [`NetOpts::max_conns`] of them —
//! the accept loop *blocks* on a condvar when the pool is full, so a
//! connection flood backpressures at the TCP backlog instead of spawning
//! unbounded threads. Every connection thread serves requests against
//! the same shared [`D4mServer`], which is what finally drives the PR-3
//! snapshot-isolated scan path from genuinely concurrent remote readers.
//!
//! §Error framing: a malformed frame poisons only its own connection —
//! the server replies with a framed error (best effort) and closes that
//! socket; the listener and every other connection keep serving.
//!
//! §Shutdown protocol: `NetHandle::shutdown()` (or a client
//! [`ClientMsg::Shutdown`] frame) sets the shared flag, then pokes the
//! listener with a loopback connect to unblock `accept`. Idle connection
//! threads poll the flag every [`NetOpts::idle_poll`] while waiting for
//! a frame's first byte; in-flight requests run to completion. The
//! accept thread exits only after the last connection thread has
//! drained, so `wait()` returning means the server is fully quiesced.

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::D4mServer;
use crate::error::{D4mError, Result};
use crate::metrics::{Counter, Histogram, Snapshot};
use crate::net::wire::{self, ClientMsg, ServerMsg, WireError};

/// Tuning for [`serve`].
#[derive(Debug, Clone)]
pub struct NetOpts {
    /// Maximum simultaneously served connections (the thread-pool bound).
    pub max_conns: usize,
    /// How often an idle connection re-checks the shutdown flag.
    pub idle_poll: Duration,
    /// Whole-frame deadline once a frame is in flight (and the write
    /// timeout): a peer that has not delivered a complete frame within
    /// this budget is dropped — dribbling one byte per poll cannot hold
    /// a pool slot forever.
    pub io_timeout: Duration,
}

impl Default for NetOpts {
    fn default() -> Self {
        NetOpts {
            max_conns: 64,
            idle_poll: Duration::from_millis(200),
            io_timeout: Duration::from_secs(30),
        }
    }
}

/// State shared between the accept loop, connection threads and the
/// [`NetHandle`].
struct Shared {
    server: Arc<D4mServer>,
    opts: NetOpts,
    addr: SocketAddr,
    shutdown: AtomicBool,
    /// Live connection-thread count; guarded waits on `pool_cv` bound the
    /// pool and let the accept loop drain on shutdown.
    active: Mutex<usize>,
    pool_cv: Condvar,
    /// Net-layer counters, surfaced through [`NetHandle::snapshots`].
    requests: Histogram,
    bad_frames: Counter,
    bytes_in: Counter,
    bytes_out: Counter,
}

impl Shared {
    /// The coordinator's per-op snapshots with the net-layer request
    /// histogram and byte counters folded in.
    fn snapshots(&self) -> Vec<Snapshot> {
        let mut snaps = self.server.snapshots();
        snaps.push(Snapshot {
            name: "net.requests".into(),
            count: self.requests.count(),
            rate_per_sec: self.requests.rate_per_sec(),
            mean_latency_ns: self.requests.mean_ns(),
            p99_latency_ns: self.requests.quantile_ns(0.99),
        });
        for (name, counter) in [
            ("net.bad_frames", &self.bad_frames),
            ("net.bytes_in", &self.bytes_in),
            ("net.bytes_out", &self.bytes_out),
        ] {
            snaps.push(Snapshot {
                name: name.into(),
                count: counter.get(),
                rate_per_sec: 0.0,
                mean_latency_ns: 0.0,
                p99_latency_ns: 0,
            });
        }
        snaps
    }

    fn initiate_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the accept loop (and re-check in any pool-full wait);
        // a wildcard bind is poked via the matching loopback family, and
        // the poke never hangs on a saturated backlog
        let mut poke = self.addr;
        if poke.ip().is_unspecified() {
            poke.set_ip(match poke.ip() {
                std::net::IpAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                std::net::IpAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
            });
        }
        let _ = TcpStream::connect_timeout(&poke, Duration::from_secs(2));
        self.pool_cv.notify_all();
    }
}

/// Handle to a running network front-end.
pub struct NetHandle {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl NetHandle {
    /// The bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Coordinator + net-layer metrics snapshots.
    pub fn snapshots(&self) -> Vec<Snapshot> {
        self.shared.snapshots()
    }

    /// True once shutdown has been initiated (locally or by a client).
    pub fn is_shutting_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Block until the server has fully quiesced (accept loop exited and
    /// every connection drained). Returns immediately if already joined.
    pub fn wait(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Initiate graceful shutdown and wait for full quiescence.
    pub fn shutdown(&mut self) {
        self.shared.initiate_shutdown();
        self.wait();
    }
}

impl Drop for NetHandle {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown();
        }
    }
}

/// Start serving `server` on `addr` (e.g. `"127.0.0.1:4950"`; port 0
/// picks an ephemeral port, readable from [`NetHandle::addr`]).
pub fn serve(server: Arc<D4mServer>, addr: &str, mut opts: NetOpts) -> Result<NetHandle> {
    // a pool of zero would park the accept loop forever
    opts.max_conns = opts.max_conns.max(1);
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let shared = Arc::new(Shared {
        server,
        opts,
        addr: local,
        shutdown: AtomicBool::new(false),
        active: Mutex::new(0),
        pool_cv: Condvar::new(),
        requests: Histogram::new(),
        bad_frames: Counter::new(),
        bytes_in: Counter::new(),
        bytes_out: Counter::new(),
    });
    let sh = shared.clone();
    let accept = std::thread::Builder::new()
        .name("d4m-net-accept".into())
        .spawn(move || accept_loop(listener, sh))?;
    Ok(NetHandle { shared, accept: Some(accept) })
}

fn accept_loop(listener: TcpListener, sh: Arc<Shared>) {
    for conn in listener.incoming() {
        if sh.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => {
                // e.g. EMFILE under fd pressure: back off instead of
                // spinning a core while the condition persists
                std::thread::sleep(sh.opts.idle_poll);
                continue;
            }
        };
        // bounded pool: hold the accepted socket until a slot frees
        {
            let mut active = sh.active.lock().unwrap();
            while *active >= sh.opts.max_conns && !sh.shutdown.load(Ordering::SeqCst) {
                active = sh.pool_cv.wait(active).unwrap();
            }
            if sh.shutdown.load(Ordering::SeqCst) {
                break;
            }
            *active += 1;
        }
        let sh2 = sh.clone();
        let builder = std::thread::Builder::new().name("d4m-net-conn".into());
        let spawned = builder.spawn(move || {
            let _ = serve_conn(stream, &sh2);
            let mut active = sh2.active.lock().unwrap();
            *active -= 1;
            sh2.pool_cv.notify_all();
        });
        if spawned.is_err() {
            // never happened in practice; release the reserved slot
            let mut active = sh.active.lock().unwrap();
            *active -= 1;
            sh.pool_cv.notify_all();
        }
    }
    // drain: connection threads notice the flag within one idle_poll;
    // in-flight requests run to completion first
    let mut active = sh.active.lock().unwrap();
    while *active > 0 {
        active = sh.pool_cv.wait(active).unwrap();
    }
}

/// Serve one connection until the peer hangs up, a frame poisons it, or
/// shutdown is initiated.
fn serve_conn(mut stream: TcpStream, sh: &Shared) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_write_timeout(Some(sh.opts.io_timeout))?;
    loop {
        // poll for a frame's first byte so an idle connection notices
        // shutdown without a dedicated waker
        stream.set_read_timeout(Some(sh.opts.idle_poll))?;
        let mut first = [0u8; 1];
        match stream.read(&mut first) {
            Ok(0) => return Ok(()), // peer closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if sh.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        // a frame is in flight: the rest of it must arrive within one
        // whole-frame deadline (the read timeout stays at idle_poll, so
        // the deadline reader re-checks wall clock + shutdown per poll —
        // a peer dribbling bytes cannot reset the budget)
        let deadline = Instant::now() + sh.opts.io_timeout;
        let mut reader = DeadlineReader { stream: &mut stream, sh, deadline };
        let payload = match wire::read_frame_rest(first[0], &mut reader) {
            Ok(p) => p,
            // malformed frame: framed error back, close this connection
            Err(e @ D4mError::Wire(_)) => return poison(&mut stream, sh, e),
            // I/O failure (peer gone, frame deadline): nothing to reply to
            Err(_) => return Ok(()),
        };
        sh.bytes_in.add((wire::HEADER_LEN + payload.len()) as u64);
        let msg = match wire::decode_client_msg(&payload) {
            Ok(m) => m,
            Err(we) => return poison(&mut stream, sh, we.into()),
        };
        let (mut reply, shutdown_after) = match msg {
            ClientMsg::Api(req) => {
                let resp = sh.requests.time(|| sh.server.handle(req));
                (ServerMsg::Reply(resp), false)
            }
            ClientMsg::Ping => (ServerMsg::Pong, false),
            ClientMsg::Stats => (ServerMsg::Stats(sh.snapshots()), false),
            ClientMsg::Shutdown => (ServerMsg::ShutdownAck, true),
        };
        // an assoc that cannot possibly fit the frame cap is rejected
        // *before* encoding — the cap must bound server memory too, not
        // just wire bytes (encode would otherwise materialise the whole
        // oversized buffer just to have write_frame refuse it)
        let oversize = match &reply {
            ServerMsg::Reply(Ok(crate::coordinator::Response::Assoc(a)))
                if a.mem_bytes() > wire::MAX_FRAME =>
            {
                Some(a.mem_bytes())
            }
            _ => None,
        };
        if let Some(n) = oversize {
            reply = ServerMsg::Reply(Err(oversized(n)));
        }
        match send(&mut stream, sh, &reply) {
            Ok(()) => {}
            // a response bigger than the frame cap is detected *before*
            // any bytes hit the socket, so the connection is still in a
            // clean state: tell the client why instead of vanishing, and
            // keep serving (the client can re-query with a limit)
            Err(D4mError::Wire(WireError::FrameTooLarge(n))) => {
                send(&mut stream, sh, &ServerMsg::Reply(Err(oversized(n))))?;
            }
            Err(e) => return Err(e),
        }
        if shutdown_after {
            sh.initiate_shutdown();
            return Ok(());
        }
    }
}

/// Reader over an in-flight frame: the underlying stream keeps the
/// short `idle_poll` read timeout, and every timeout tick re-checks one
/// wall-clock deadline for the *whole* frame plus the shutdown flag —
/// so a peer dribbling one byte per tick cannot reset its budget or
/// stall quiescence.
struct DeadlineReader<'a> {
    stream: &'a mut TcpStream,
    sh: &'a Shared,
    deadline: Instant,
}

impl Read for DeadlineReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            match self.stream.read(buf) {
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if self.sh.shutdown.load(Ordering::SeqCst)
                        || Instant::now() >= self.deadline
                    {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            "whole-frame deadline elapsed",
                        ));
                    }
                }
                other => return other,
            }
        }
    }
}

/// A bad frame poisons the connection, never the server: best-effort
/// framed error back to the peer, then close (by returning). Only
/// protocol-level failures land here (`net.bad_frames` counts hostile
/// or corrupt input, not routine disconnects).
fn poison(stream: &mut TcpStream, sh: &Shared, e: D4mError) -> Result<()> {
    sh.bad_frames.inc();
    let _ = send(stream, sh, &ServerMsg::Reply(Err(e)));
    Ok(())
}

/// The error a too-big-for-one-frame response turns into.
fn oversized(bytes: usize) -> D4mError {
    D4mError::InvalidArg(format!(
        "response of ~{bytes} bytes exceeds the {} byte frame cap — \
         narrow the query or use a limit",
        wire::MAX_FRAME
    ))
}

fn send(stream: &mut TcpStream, sh: &Shared, msg: &ServerMsg) -> Result<()> {
    let buf = wire::encode_server_msg(msg);
    wire::write_frame(stream, &buf)?;
    sh.bytes_out.add((wire::HEADER_LEN + buf.len()) as u64);
    Ok(())
}
