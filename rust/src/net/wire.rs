//! Length-prefixed binary frame codec for the network front-end.
//!
//! The crate has zero dependencies, so serialization is hand-rolled:
//! explicit little-endian / LEB128-varint encodings with a versioned
//! magic header per frame and typed decode errors ([`WireError`]) — a
//! corrupt or truncated frame is always an `Err`, never a panic.
//!
//! §Frame layout (see DESIGN.md §Wire v2):
//!
//! ```text
//! +-----------+---------+----------------+--------------------------+
//! | "D4M" (3) | ver (1) | len u32 LE (4) | id varint | msg (rest)   |
//! +-----------+---------+----------------+--------------------------+
//! ```
//!
//! **v2 is session-oriented**: every payload starts with a
//! client-assigned *request id* (LEB128 varint), followed by one message
//! — a [`ClientMsg`] (client→server) or a [`ServerMsg`] (server→client),
//! each a tag byte plus its body. A connection may have many requests in
//! flight; the server answers each with a frame carrying the same id,
//! **in any order**. Id `0` is reserved for connection-level server
//! errors (a frame the server could not attribute to a request); clients
//! assign ids from 1.
//!
//! Primitive encodings: `u64` as LEB128 varints (canonical-length not
//! required, overflow rejected), `f64` as 8 bytes LE of `to_bits` (bit
//! exact), strings as varint byte length + UTF-8, `Option` as a presence
//! byte, vectors as varint count + elements.
//!
//! §Versioning rules: the header's version byte is bumped on **any**
//! change to an existing message/tag encoding; adding a new trailing tag
//! value is the only compatible evolution. A peer seeing any other
//! version refuses the frame with [`WireError::Version`] *before*
//! reading the payload — so a v1 peer talking to a v2 peer gets one
//! typed version error instead of a decode failure mid-stream — and
//! `Ping`/`Pong` carry the sender's version in-payload so a client can
//! probe compatibility explicitly. v1 → v2: request-id prefix added to
//! every payload, `Ping`/`Pong` gained the version byte, cursor
//! messages (`OpenCursor`/`CursorNext`/`CursorClose` and
//! `CursorOpened`/`CursorPage`/`CursorClosed`) added. v2 → v3:
//! `OpenCursor` gained an optional resume token (cursor id + secret +
//! acked-page count, see [`CursorResume`]) and `CursorOpened` gained
//! the server-issued resume secret — both changes to existing tag
//! encodings, hence the bump; error tags 14–16 (`Overloaded`,
//! `RetryExhausted`, `AmbiguousWrite`) are compatible trailing
//! additions. v3 → v4: the three TableMult request tags collapsed into
//! one (tag 3 re-encoded with destination + execution-hint bytes; tags
//! 4/5 retired — decoding them is the typed [`WireError::Retired`],
//! never a silent re-interpretation), and the plan surface landed:
//! `Request::Plan` (tag 11), `Response::PlanResult` (tag 7), and
//! `ClientMsg::OpenPlanCursor` (tag 7). Decoded plans are re-validated
//! with [`crate::assoc::expr::validate_plan`] before they reach the
//! executor, so a hostile frame cannot smuggle forward references or
//! an over-cap program past the client-side compiler.
//!
//! [`Assoc`] frames carry the array structurally — sorted key vectors,
//! the optional value-key table and the raw CSR arrays — so a decoded
//! assoc is **bit-identical** (`PartialEq`) to the encoded one. Decoding
//! re-validates every CSR invariant (sorted unique keys, monotone
//! `indptr`, in-bounds sorted column indices, value indices inside the
//! dictionary), so a hostile frame cannot build an assoc that would
//! panic downstream.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::time::Duration;

use crate::assoc::expr::{self, PlanOp};
use crate::assoc::spmat::SpMat;
use crate::assoc::{Assoc, KeySel};
use crate::connectors::TableQuery;
use crate::coordinator::{
    CursorPage, CursorResume, ExecHint, MultDest, PlanStats, Request, Response,
};
use crate::error::D4mError;
use crate::graphulo::{PageRankOpts, PageRankResult, TableMultStats};
use crate::metrics::Snapshot;
use crate::pipeline::{IngestReport, PipelineConfig, TripleMsg};

/// Frame magic (the version byte follows it).
pub const MAGIC: [u8; 3] = *b"D4M";
/// Wire-protocol version carried in every frame header (v4: collapsed
/// TableMult + plan messages; v3: cursor resume tokens; v2: request-id
/// framing + cursor messages).
pub const VERSION: u8 = 4;
/// Request id reserved for connection-level server errors (a reply the
/// server could not attribute to any request). Clients assign from 1.
pub const CONN_ERR_ID: u64 = 0;
/// Bytes of frame header preceding the payload.
pub const HEADER_LEN: usize = 8;
/// Upper bound on a frame payload; a declared length beyond this is
/// rejected *before* allocating, so a corrupt header cannot OOM the peer.
pub const MAX_FRAME: usize = 256 * 1024 * 1024;

/// Cap on any single up-front `Vec::with_capacity` while decoding. The
/// byte-level [`Cursor::count`] guard bounds element *counts* by wire
/// bytes, but in-memory elements can be 8–24x larger than their wire
/// form (a `String` header alone is 24 bytes), so a hostile max-size
/// frame could otherwise force a multi-GiB reservation before the
/// per-element reads start failing. Legitimate decodes just grow past
/// this amortised.
const PREALLOC_CAP: usize = 1 << 16;

/// Typed decode failures. Every malformed input maps to one of these —
/// the codec never panics on hostile bytes (`wire::tests` pin this).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Input ended before the structure it promised.
    Truncated,
    /// Frame header did not start with `b"D4M"`.
    BadMagic([u8; 3]),
    /// Frame (or `Pong`) carried a protocol version this peer does not
    /// speak — the typed outcome of a v1↔v2 pairing, surfaced before any
    /// payload is read.
    Version { got: u8, want: u8 },
    /// Declared payload length exceeds [`MAX_FRAME`].
    FrameTooLarge(usize),
    /// A tag byte outside the known range for `what`.
    UnknownTag { what: &'static str, tag: u8 },
    /// A tag that existed in an earlier protocol version and was
    /// deliberately retired (not reused) — distinct from
    /// [`WireError::UnknownTag`] so a peer can tell "too old" from
    /// "garbage".
    Retired { what: &'static str, tag: u8 },
    /// A string field was not valid UTF-8.
    BadUtf8,
    /// A structural invariant failed (the message names it).
    Malformed(&'static str),
    /// Decode succeeded but `n` payload bytes were left over.
    TrailingBytes(usize),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated input"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            WireError::Version { got, want } => {
                write!(f, "unsupported wire version {got} (this peer speaks v{want})")
            }
            WireError::FrameTooLarge(n) => {
                write!(f, "frame of {n} bytes exceeds the {MAX_FRAME}-byte cap")
            }
            WireError::UnknownTag { what, tag } => write!(f, "unknown {what} tag {tag}"),
            WireError::Retired { what, tag } => {
                write!(f, "{what} tag {tag} was retired in wire v{VERSION}")
            }
            WireError::BadUtf8 => write!(f, "string field is not valid UTF-8"),
            WireError::Malformed(m) => write!(f, "malformed payload: {m}"),
            WireError::TrailingBytes(n) => write!(f, "{n} trailing bytes after message"),
        }
    }
}

impl std::error::Error for WireError {}

/// Codec-level result.
pub type WireResult<T> = std::result::Result<T, WireError>;

// ---------------------------------------------------------------------
// messages

/// Client→server messages: the coordinator API, the cursor ops, and the
/// three admin verbs the CLI and CI harness need. On the wire each is
/// prefixed by its client-assigned request id (see the module docs).
/// `Clone` so a self-healing client can replay an idempotent request
/// after reconnecting.
#[derive(Debug, Clone)]
pub enum ClientMsg {
    /// A coordinator [`Request`], answered by [`ServerMsg::Reply`].
    Api(Request),
    /// Liveness + version probe (carries the client's wire version),
    /// answered by [`ServerMsg::Pong`].
    Ping { version: u8 },
    /// Metrics snapshot request, answered by [`ServerMsg::Stats`].
    Stats,
    /// Graceful server shutdown, answered by [`ServerMsg::ShutdownAck`].
    Shutdown,
    /// Open a streaming scan cursor, answered by
    /// [`ServerMsg::CursorOpened`] (or an error [`ServerMsg::Reply`]).
    /// With `resume` set, re-attach to an existing cursor after a
    /// reconnect instead of opening a new one: `table`/`query`/
    /// `page_entries` are ignored server-side (the original pinned
    /// snapshot and page size continue) and the reply echoes the
    /// original cursor id.
    OpenCursor {
        table: String,
        query: TableQuery,
        page_entries: u64,
        resume: Option<CursorResume>,
    },
    /// Pull the next page of an open cursor, answered by
    /// [`ServerMsg::CursorPage`].
    CursorNext { cursor: u64 },
    /// Close a cursor early (idempotent), answered by
    /// [`ServerMsg::CursorClosed`].
    CursorClose { cursor: u64 },
    /// Execute a plan server-side and page its result back: answered by
    /// [`ServerMsg::CursorOpened`], then drained with the ordinary
    /// `CursorNext`/`CursorClose` ops (plan cursors and scan cursors
    /// share the id space and resume machinery).
    OpenPlanCursor { ops: Vec<PlanOp>, page_entries: u64 },
}

/// Server→client messages (each carries the request id it answers).
#[derive(Debug)]
pub enum ServerMsg {
    /// Outcome of [`ClientMsg::Api`]: the coordinator's response, or its
    /// error carried across the wire. Also the error shape for failed
    /// cursor/admin ops and (with id [`CONN_ERR_ID`]) connection-level
    /// failures.
    Reply(crate::error::Result<Response>),
    /// Answer to [`ClientMsg::Ping`], carrying the server's wire version.
    Pong { version: u8 },
    /// Per-op metrics snapshots plus the net-layer counters.
    Stats(Vec<Snapshot>),
    ShutdownAck,
    /// A cursor was opened (or resumed); `cursor` keys the follow-up
    /// ops and `token` is the server-issued resume secret the client
    /// must present in [`CursorResume`] to re-attach after a reconnect.
    CursorOpened { cursor: u64, token: u64 },
    /// One page of cursor results (at most the cursor's `page_entries`
    /// triples; `done` means the scan is exhausted and the snapshot
    /// released — the client should send `CursorClose` to free the
    /// handle, which otherwise falls to the idle-TTL sweep).
    CursorPage(CursorPage),
    /// Acknowledges [`ClientMsg::CursorClose`].
    CursorClosed,
}

// ---------------------------------------------------------------------
// framing

/// Write one frame: header + payload.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> crate::error::Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(WireError::FrameTooLarge(payload.len()).into());
    }
    let [l0, l1, l2, l3] = (payload.len() as u32).to_le_bytes();
    let [m0, m1, m2] = MAGIC;
    let head: [u8; HEADER_LEN] = [m0, m1, m2, VERSION, l0, l1, l2, l3];
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, returning its payload.
pub fn read_frame(r: &mut impl Read) -> crate::error::Result<Vec<u8>> {
    let mut first = 0u8;
    r.read_exact(std::slice::from_mut(&mut first)).map_err(eof_as_truncated)?;
    read_frame_rest(first, r)
}

/// Read a frame whose first header byte was already consumed (the
/// server reads that byte separately while polling an idle connection
/// for shutdown — see `net::server`).
pub fn read_frame_rest(first: u8, r: &mut impl Read) -> crate::error::Result<Vec<u8>> {
    let mut rest = [0u8; HEADER_LEN - 1];
    r.read_exact(&mut rest).map_err(eof_as_truncated)?;
    let [r1, r2, r3, r4, r5, r6, r7] = rest;
    let header: [u8; HEADER_LEN] = [first, r1, r2, r3, r4, r5, r6, r7];
    let len = frame_payload_len(&header)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(eof_as_truncated)?;
    Ok(payload)
}

/// Validate a frame header and return its payload length. Used by
/// incremental readers that buffer partial frames themselves (the
/// self-healing client's poll loop, the chaos proxy's frame splitter)
/// instead of blocking in [`read_frame`].
pub fn frame_payload_len(header: &[u8; HEADER_LEN]) -> crate::error::Result<usize> {
    let [m0, m1, m2, version, l0, l1, l2, l3] = *header;
    let magic = [m0, m1, m2];
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic).into());
    }
    if version != VERSION {
        return Err(WireError::Version { got: version, want: VERSION }.into());
    }
    let len = u32::from_le_bytes([l0, l1, l2, l3]) as usize;
    if len > MAX_FRAME {
        return Err(WireError::FrameTooLarge(len).into());
    }
    Ok(len)
}

/// A peer hanging up mid-frame surfaces as `UnexpectedEof`; report it as
/// the typed truncation error rather than a bare I/O error.
fn eof_as_truncated(e: std::io::Error) -> D4mError {
    if e.kind() == std::io::ErrorKind::UnexpectedEof {
        WireError::Truncated.into()
    } else {
        D4mError::Io(e)
    }
}

// ---------------------------------------------------------------------
// primitive encoders

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}

fn put_varint(b: &mut Vec<u8>, mut v: u64) {
    loop {
        if v < 0x80 {
            b.push(v as u8);
            return;
        }
        b.push((v as u8 & 0x7f) | 0x80);
        v >>= 7;
    }
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_bool(b: &mut Vec<u8>, v: bool) {
    b.push(v as u8);
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_varint(b, s.len() as u64);
    b.extend_from_slice(s.as_bytes());
}

fn put_str_slice(b: &mut Vec<u8>, v: &[String]) {
    put_varint(b, v.len() as u64);
    for s in v {
        put_str(b, s);
    }
}

// ---------------------------------------------------------------------
// primitive decoder

/// Bounds-checked reader over one frame payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn u8(&mut self) -> WireResult<u8> {
        let b = *self.buf.get(self.pos).ok_or(WireError::Truncated)?;
        self.pos += 1;
        Ok(b)
    }

    fn bytes(&mut self, n: usize) -> WireResult<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or(WireError::Truncated)?;
        let out = self.buf.get(self.pos..end).ok_or(WireError::Truncated)?;
        self.pos = end;
        Ok(out)
    }

    fn varint(&mut self) -> WireResult<u64> {
        let mut v: u64 = 0;
        let mut shift: u32 = 0;
        loop {
            let b = self.u8()?;
            if shift == 63 && b > 1 {
                return Err(WireError::Malformed("varint overflows u64"));
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(WireError::Malformed("varint overflows u64"));
            }
        }
    }

    /// A varint used as an element/byte count. Guarded against counts
    /// that could not possibly fit in the remaining payload (every
    /// element costs ≥ `min_elem_bytes`), so a corrupt count can never
    /// drive a huge allocation.
    fn count(&mut self, min_elem_bytes: usize) -> WireResult<usize> {
        let n = usize::try_from(self.varint()?)
            .map_err(|_| WireError::Malformed("count overflows usize"))?;
        match n.checked_mul(min_elem_bytes) {
            Some(bytes) if bytes <= self.remaining() => Ok(n),
            _ => Err(WireError::Truncated),
        }
    }

    fn f64(&mut self) -> WireResult<f64> {
        let raw = self.bytes(8)?;
        let mut le = [0u8; 8];
        le.copy_from_slice(raw);
        Ok(f64::from_bits(u64::from_le_bytes(le)))
    }

    fn bool(&mut self) -> WireResult<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(WireError::Malformed("bool byte not 0/1")),
        }
    }

    fn str(&mut self) -> WireResult<String> {
        let n = self.count(1)?;
        let raw = self.bytes(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| WireError::BadUtf8)
    }

    fn str_vec(&mut self) -> WireResult<Vec<String>> {
        let n = self.count(1)?;
        let mut out = Vec::with_capacity(n.min(PREALLOC_CAP));
        for _ in 0..n {
            out.push(self.str()?);
        }
        Ok(out)
    }

    fn finish(self) -> WireResult<()> {
        if self.pos != self.buf.len() {
            return Err(WireError::TrailingBytes(self.buf.len() - self.pos));
        }
        Ok(())
    }
}

fn to_usize(v: u64, what: &'static str) -> WireResult<usize> {
    usize::try_from(v).map_err(|_| WireError::Malformed(what))
}

// ---------------------------------------------------------------------
// KeySel / TableQuery

fn put_keysel(b: &mut Vec<u8>, sel: &KeySel) {
    match sel {
        KeySel::All => put_u8(b, 0),
        KeySel::Keys(ks) => {
            put_u8(b, 1);
            put_str_slice(b, ks);
        }
        KeySel::Range(lo, hi) => {
            put_u8(b, 2);
            put_str(b, lo);
            put_str(b, hi);
        }
        KeySel::Prefix(p) => {
            put_u8(b, 3);
            put_str(b, p);
        }
    }
}

fn get_keysel(c: &mut Cursor) -> WireResult<KeySel> {
    match c.u8()? {
        0 => Ok(KeySel::All),
        1 => Ok(KeySel::Keys(c.str_vec()?)),
        2 => Ok(KeySel::Range(c.str()?, c.str()?)),
        3 => Ok(KeySel::Prefix(c.str()?)),
        tag => Err(WireError::UnknownTag { what: "KeySel", tag }),
    }
}

fn put_query(b: &mut Vec<u8>, q: &TableQuery) {
    put_keysel(b, &q.rows);
    put_keysel(b, &q.cols);
    match q.limit {
        Some(n) => {
            put_u8(b, 1);
            put_varint(b, n as u64);
        }
        None => put_u8(b, 0),
    }
    put_varint(b, q.page_rows as u64);
}

fn get_query(c: &mut Cursor) -> WireResult<TableQuery> {
    let rows = get_keysel(c)?;
    let cols = get_keysel(c)?;
    let limit = if c.bool()? {
        Some(to_usize(c.varint()?, "limit overflows usize")?)
    } else {
        None
    };
    let page_rows = to_usize(c.varint()?, "page_rows overflows usize")?;
    Ok(TableQuery { rows, cols, limit, page_rows })
}

// ---------------------------------------------------------------------
// Assoc

/// Encode an [`Assoc`] structurally (keys + optional value table + raw
/// CSR), preserving it bit-for-bit.
pub fn encode_assoc(b: &mut Vec<u8>, a: &Assoc) {
    put_str_slice(b, a.row_keys());
    put_str_slice(b, a.col_keys());
    match a.val_keys() {
        Some(v) => {
            put_u8(b, 1);
            put_str_slice(b, v);
        }
        None => put_u8(b, 0),
    }
    let m = a.matrix();
    put_varint(b, m.nr as u64);
    put_varint(b, m.nc as u64);
    put_varint(b, m.indices.len() as u64);
    for &p in &m.indptr {
        put_varint(b, p as u64);
    }
    for &i in &m.indices {
        put_varint(b, i as u64);
    }
    for &v in &m.data {
        put_f64(b, v);
    }
}

fn get_assoc(c: &mut Cursor) -> WireResult<Assoc> {
    let row_keys = c.str_vec()?;
    let col_keys = c.str_vec()?;
    let vals = if c.bool()? { Some(c.str_vec()?) } else { None };
    let nr = to_usize(c.varint()?, "nr overflows usize")?;
    let nc = to_usize(c.varint()?, "nc overflows usize")?;
    let nnz = to_usize(c.varint()?, "nnz overflows usize")?;
    if nr != row_keys.len() || nc != col_keys.len() {
        return Err(WireError::Malformed("matrix shape disagrees with key counts"));
    }
    for keys in [&row_keys, &col_keys].into_iter().chain(vals.iter()) {
        if !keys.windows(2).all(|w| matches!(w, [a, b] if a < b)) {
            return Err(WireError::Malformed("key vector not sorted/unique"));
        }
    }
    // indptr: nr + 1 varints, starting at 0, monotone, ending at nnz
    if nnz > c.remaining() || nr >= c.remaining() {
        return Err(WireError::Truncated);
    }
    let mut indptr = Vec::with_capacity((nr + 1).min(PREALLOC_CAP));
    for _ in 0..nr + 1 {
        indptr.push(to_usize(c.varint()?, "indptr overflows usize")?);
    }
    if indptr.first() != Some(&0)
        || indptr.get(nr) != Some(&nnz)
        || indptr.windows(2).any(|w| matches!(w, [a, b] if a > b))
    {
        return Err(WireError::Malformed("indptr not a monotone 0..nnz row pointer"));
    }
    let mut indices = Vec::with_capacity(nnz.min(PREALLOC_CAP));
    for _ in 0..nnz {
        indices.push(to_usize(c.varint()?, "col index overflows usize")?);
    }
    // within each row: strictly increasing, in bounds (the CSR invariant
    // every kernel relies on)
    for w in indptr.windows(2) {
        let [s, e] = w else { continue };
        let Some(row) = indices.get(*s..*e) else {
            return Err(WireError::Malformed("row indices unsorted or out of bounds"));
        };
        if row.iter().any(|&i| i >= nc) || row.windows(2).any(|w| matches!(w, [a, b] if a >= b)) {
            return Err(WireError::Malformed("row indices unsorted or out of bounds"));
        }
    }
    if nnz.checked_mul(8).map(|b| b > c.remaining()).unwrap_or(true) {
        return Err(WireError::Truncated);
    }
    let mut data = Vec::with_capacity(nnz.min(PREALLOC_CAP));
    for _ in 0..nnz {
        data.push(c.f64()?);
    }
    if let Some(vals) = &vals {
        // string-valued entries are 1-based indices into the value table;
        // anything else would panic in `str_triples`
        let max = vals.len() as f64;
        if data.iter().any(|&v| v.fract() != 0.0 || v < 1.0 || v > max) {
            return Err(WireError::Malformed("string value index outside dictionary"));
        }
    }
    let mat = SpMat { nr, nc, indptr, indices, data };
    Ok(Assoc::from_parts(row_keys, col_keys, mat, vals))
}

/// Decode one [`Assoc`] occupying an entire payload (tests + tools).
pub fn decode_assoc(buf: &[u8]) -> WireResult<Assoc> {
    let mut c = Cursor::new(buf);
    let a = get_assoc(&mut c)?;
    c.finish()?;
    Ok(a)
}

// ---------------------------------------------------------------------
// Request

/// Encode a coordinator [`Request`].
pub fn encode_request(b: &mut Vec<u8>, req: &Request) {
    match req {
        Request::CreateTable { name, splits } => {
            put_u8(b, 0);
            put_str(b, name);
            put_str_slice(b, splits);
        }
        Request::Ingest { table, triples, pipeline } => {
            put_u8(b, 1);
            put_str(b, table);
            put_varint(b, triples.len() as u64);
            for (r, c, v) in triples {
                put_str(b, r);
                put_str(b, c);
                put_str(b, v);
            }
            put_varint(b, pipeline.num_workers as u64);
            put_varint(b, pipeline.queue_depth as u64);
            put_varint(b, pipeline.batch_size as u64);
            put_bool(b, pipeline.shard_by_row);
        }
        Request::Query { table, query } => {
            put_u8(b, 2);
            put_str(b, table);
            put_query(b, query);
        }
        Request::TableMult { a, b: rhs, dest, exec } => {
            put_u8(b, 3);
            put_str(b, a);
            put_str(b, rhs);
            match dest {
                MultDest::Table { out } => {
                    put_u8(b, 0);
                    put_str(b, out);
                }
                MultDest::Client => put_u8(b, 1),
            }
            match exec {
                ExecHint::Stream => put_u8(b, 0),
                ExecHint::Memory { limit } => {
                    put_u8(b, 1);
                    put_varint(b, *limit as u64);
                }
                ExecHint::Dense { tile } => {
                    put_u8(b, 2);
                    put_varint(b, *tile as u64);
                }
            }
        }
        Request::Bfs { table, seeds, hops } => {
            put_u8(b, 6);
            put_str(b, table);
            put_str_slice(b, seeds);
            put_varint(b, *hops as u64);
        }
        Request::Jaccard { table, out } => {
            put_u8(b, 7);
            put_str(b, table);
            put_str(b, out);
        }
        Request::KTruss { table, k } => {
            put_u8(b, 8);
            put_str(b, table);
            put_varint(b, *k as u64);
        }
        Request::PageRank { table, opts } => {
            put_u8(b, 9);
            put_str(b, table);
            put_f64(b, opts.damping);
            put_varint(b, opts.max_iters as u64);
            put_f64(b, opts.tol);
        }
        Request::ListTables => put_u8(b, 10),
        Request::Plan { ops } => {
            put_u8(b, 11);
            put_plan_ops(b, ops);
        }
    }
}

// ---------------------------------------------------------------------
// plans

fn put_limit(b: &mut Vec<u8>, limit: &Option<usize>) {
    match limit {
        Some(n) => {
            put_u8(b, 1);
            put_varint(b, *n as u64);
        }
        None => put_u8(b, 0),
    }
}

/// Encode a compiled plan (varint op count, then each op as a tag byte
/// in [`PlanOp`] variant order + its body).
fn put_plan_ops(b: &mut Vec<u8>, ops: &[PlanOp]) {
    put_varint(b, ops.len() as u64);
    for op in ops {
        match op {
            PlanOp::Load { table, rows, cols, limit } => {
                put_u8(b, 0);
                put_str(b, table);
                put_keysel(b, rows);
                put_keysel(b, cols);
                put_limit(b, limit);
            }
            PlanOp::Select { src, rows, cols } => {
                put_u8(b, 1);
                put_varint(b, *src as u64);
                put_keysel(b, rows);
                put_keysel(b, cols);
            }
            PlanOp::Transpose { src } => {
                put_u8(b, 2);
                put_varint(b, *src as u64);
            }
            PlanOp::MatMul { a, b: rhs } => {
                put_u8(b, 3);
                put_varint(b, *a as u64);
                put_varint(b, *rhs as u64);
            }
            PlanOp::CatKeyMul { a, b: rhs } => {
                put_u8(b, 4);
                put_varint(b, *a as u64);
                put_varint(b, *rhs as u64);
            }
            PlanOp::ElemAdd { a, b: rhs } => {
                put_u8(b, 5);
                put_varint(b, *a as u64);
                put_varint(b, *rhs as u64);
            }
            PlanOp::ElemSub { a, b: rhs } => {
                put_u8(b, 6);
                put_varint(b, *a as u64);
                put_varint(b, *rhs as u64);
            }
            PlanOp::ElemMult { a, b: rhs } => {
                put_u8(b, 7);
                put_varint(b, *a as u64);
                put_varint(b, *rhs as u64);
            }
            PlanOp::ElemMin { a, b: rhs } => {
                put_u8(b, 8);
                put_varint(b, *a as u64);
                put_varint(b, *rhs as u64);
            }
            PlanOp::ElemMax { a, b: rhs } => {
                put_u8(b, 9);
                put_varint(b, *a as u64);
                put_varint(b, *rhs as u64);
            }
            PlanOp::Reduce { src, dim } => {
                put_u8(b, 10);
                put_varint(b, *src as u64);
                put_u8(b, *dim as u8);
            }
            PlanOp::Scale { src, factor } => {
                put_u8(b, 11);
                put_varint(b, *src as u64);
                put_f64(b, *factor);
            }
            PlanOp::Store { src, table } => {
                put_u8(b, 12);
                put_varint(b, *src as u64);
                put_str(b, table);
            }
        }
    }
}

fn get_limit(c: &mut Cursor) -> WireResult<Option<usize>> {
    if c.bool()? {
        Ok(Some(to_usize(c.varint()?, "limit overflows usize")?))
    } else {
        Ok(None)
    }
}

/// Decode a plan and re-validate its SSA shape — forward/self refs, an
/// empty program, or one beyond [`expr::MAX_PLAN_OPS`] are rejected
/// here, before the executor ever sees the ops.
fn get_plan_ops(c: &mut Cursor) -> WireResult<Vec<PlanOp>> {
    let n = c.count(1)?;
    let slot = |c: &mut Cursor| -> WireResult<usize> {
        to_usize(c.varint()?, "plan slot overflows usize")
    };
    let mut ops = Vec::with_capacity(n.min(PREALLOC_CAP));
    for _ in 0..n {
        ops.push(match c.u8()? {
            0 => PlanOp::Load {
                table: c.str()?,
                rows: get_keysel(c)?,
                cols: get_keysel(c)?,
                limit: get_limit(c)?,
            },
            1 => PlanOp::Select { src: slot(c)?, rows: get_keysel(c)?, cols: get_keysel(c)? },
            2 => PlanOp::Transpose { src: slot(c)? },
            3 => PlanOp::MatMul { a: slot(c)?, b: slot(c)? },
            4 => PlanOp::CatKeyMul { a: slot(c)?, b: slot(c)? },
            5 => PlanOp::ElemAdd { a: slot(c)?, b: slot(c)? },
            6 => PlanOp::ElemSub { a: slot(c)?, b: slot(c)? },
            7 => PlanOp::ElemMult { a: slot(c)?, b: slot(c)? },
            8 => PlanOp::ElemMin { a: slot(c)?, b: slot(c)? },
            9 => PlanOp::ElemMax { a: slot(c)?, b: slot(c)? },
            10 => PlanOp::Reduce { src: slot(c)?, dim: c.u8()? as usize },
            11 => PlanOp::Scale { src: slot(c)?, factor: c.f64()? },
            12 => PlanOp::Store { src: slot(c)?, table: c.str()? },
            tag => return Err(WireError::UnknownTag { what: "PlanOp", tag }),
        });
    }
    if expr::validate_plan(&ops).is_err() {
        return Err(WireError::Malformed("plan fails SSA validation"));
    }
    Ok(ops)
}

fn get_request(c: &mut Cursor) -> WireResult<Request> {
    match c.u8()? {
        0 => Ok(Request::CreateTable { name: c.str()?, splits: c.str_vec()? }),
        1 => {
            let table = c.str()?;
            let n = c.count(3)?; // each triple: 3 length bytes minimum
            let mut triples: Vec<TripleMsg> = Vec::with_capacity(n.min(PREALLOC_CAP));
            for _ in 0..n {
                triples.push((c.str()?, c.str()?, c.str()?));
            }
            let pipeline = PipelineConfig {
                num_workers: to_usize(c.varint()?, "num_workers overflows usize")?,
                queue_depth: to_usize(c.varint()?, "queue_depth overflows usize")?,
                batch_size: to_usize(c.varint()?, "batch_size overflows usize")?,
                shard_by_row: c.bool()?,
            };
            Ok(Request::Ingest { table, triples, pipeline })
        }
        2 => Ok(Request::Query { table: c.str()?, query: get_query(c)? }),
        3 => {
            let a = c.str()?;
            let b = c.str()?;
            let dest = match c.u8()? {
                0 => MultDest::Table { out: c.str()? },
                1 => MultDest::Client,
                tag => return Err(WireError::UnknownTag { what: "MultDest", tag }),
            };
            let exec = match c.u8()? {
                0 => ExecHint::Stream,
                1 => ExecHint::Memory {
                    limit: to_usize(c.varint()?, "memory limit overflows usize")?,
                },
                2 => ExecHint::Dense { tile: to_usize(c.varint()?, "tile overflows usize")? },
                tag => return Err(WireError::UnknownTag { what: "ExecHint", tag }),
            };
            Ok(Request::TableMult { a, b, dest, exec })
        }
        // v3 tags 4/5 (TableMultClient / TableMultDense) collapsed into
        // tag 3's dest/exec bytes; the tags stay burned so old frames
        // fail typed instead of decoding as something else
        tag @ (4 | 5) => Err(WireError::Retired { what: "Request", tag }),
        6 => Ok(Request::Bfs {
            table: c.str()?,
            seeds: c.str_vec()?,
            hops: to_usize(c.varint()?, "hops overflows usize")?,
        }),
        7 => Ok(Request::Jaccard { table: c.str()?, out: c.str()? }),
        8 => Ok(Request::KTruss {
            table: c.str()?,
            k: to_usize(c.varint()?, "k overflows usize")?,
        }),
        9 => {
            let table = c.str()?;
            let opts = PageRankOpts {
                damping: c.f64()?,
                max_iters: to_usize(c.varint()?, "max_iters overflows usize")?,
                tol: c.f64()?,
            };
            Ok(Request::PageRank { table, opts })
        }
        10 => Ok(Request::ListTables),
        11 => Ok(Request::Plan { ops: get_plan_ops(c)? }),
        tag => Err(WireError::UnknownTag { what: "Request", tag }),
    }
}

/// Decode one [`Request`] occupying an entire payload.
pub fn decode_request(buf: &[u8]) -> WireResult<Request> {
    let mut c = Cursor::new(buf);
    let r = get_request(&mut c)?;
    c.finish()?;
    Ok(r)
}

// ---------------------------------------------------------------------
// Response

/// Encode a coordinator [`Response`].
pub fn encode_response(b: &mut Vec<u8>, resp: &Response) {
    match resp {
        Response::Ok => put_u8(b, 0),
        Response::Tables(ts) => {
            put_u8(b, 1);
            put_str_slice(b, ts);
        }
        Response::Ingested(r) => {
            put_u8(b, 2);
            put_varint(b, r.triples);
            put_varint(b, r.elapsed.as_nanos().min(u64::MAX as u128) as u64);
            put_f64(b, r.rate);
            put_f64(b, r.physical_rate);
            put_varint(b, r.per_worker.len() as u64);
            for &w in &r.per_worker {
                put_varint(b, w);
            }
            put_varint(b, r.backpressure_stalls);
            put_varint(b, r.num_workers as u64);
        }
        Response::Assoc(a) => {
            put_u8(b, 3);
            encode_assoc(b, a);
        }
        Response::Distances(d) => {
            put_u8(b, 4);
            put_varint(b, d.len() as u64);
            for (k, &v) in d {
                put_str(b, k);
                put_varint(b, v as u64);
            }
        }
        Response::Ranks(r) => {
            put_u8(b, 5);
            put_varint(b, r.scores.len() as u64);
            for (k, &v) in &r.scores {
                put_str(b, k);
                put_f64(b, v);
            }
            put_varint(b, r.iterations as u64);
            put_bool(b, r.converged);
        }
        Response::MultStats(s) => {
            put_u8(b, 6);
            put_varint(b, s.rows_contracted);
            put_varint(b, s.partial_products);
            put_varint(b, s.peak_row_entries as u64);
        }
        Response::PlanResult { result, stats } => {
            put_u8(b, 7);
            encode_assoc(b, result);
            put_varint(b, stats.ops);
            put_varint(b, stats.fused_selects);
            put_varint(b, stats.fused_reduces);
            put_varint(b, stats.intermediates);
        }
    }
}

fn get_response(c: &mut Cursor) -> WireResult<Response> {
    match c.u8()? {
        0 => Ok(Response::Ok),
        1 => Ok(Response::Tables(c.str_vec()?)),
        2 => {
            let triples = c.varint()?;
            let elapsed = Duration::from_nanos(c.varint()?);
            let rate = c.f64()?;
            let physical_rate = c.f64()?;
            let n = c.count(1)?;
            let mut per_worker = Vec::with_capacity(n.min(PREALLOC_CAP));
            for _ in 0..n {
                per_worker.push(c.varint()?);
            }
            let backpressure_stalls = c.varint()?;
            let num_workers = to_usize(c.varint()?, "num_workers overflows usize")?;
            Ok(Response::Ingested(IngestReport {
                triples,
                elapsed,
                rate,
                physical_rate,
                per_worker,
                backpressure_stalls,
                num_workers,
            }))
        }
        3 => Ok(Response::Assoc(get_assoc(c)?)),
        4 => {
            let n = c.count(2)?;
            let mut d = BTreeMap::new();
            for _ in 0..n {
                let k = c.str()?;
                let v = to_usize(c.varint()?, "distance overflows usize")?;
                d.insert(k, v);
            }
            Ok(Response::Distances(d))
        }
        5 => {
            let n = c.count(9)?;
            let mut scores = BTreeMap::new();
            for _ in 0..n {
                let k = c.str()?;
                let v = c.f64()?;
                scores.insert(k, v);
            }
            let iterations = to_usize(c.varint()?, "iterations overflows usize")?;
            let converged = c.bool()?;
            Ok(Response::Ranks(PageRankResult { scores, iterations, converged }))
        }
        6 => Ok(Response::MultStats(TableMultStats {
            rows_contracted: c.varint()?,
            partial_products: c.varint()?,
            peak_row_entries: to_usize(c.varint()?, "peak_row_entries overflows usize")?,
        })),
        7 => {
            let result = get_assoc(c)?;
            let stats = PlanStats {
                ops: c.varint()?,
                fused_selects: c.varint()?,
                fused_reduces: c.varint()?,
                intermediates: c.varint()?,
            };
            Ok(Response::PlanResult { result, stats })
        }
        tag => Err(WireError::UnknownTag { what: "Response", tag }),
    }
}

/// Decode one [`Response`] occupying an entire payload.
pub fn decode_response(buf: &[u8]) -> WireResult<Response> {
    let mut c = Cursor::new(buf);
    let r = get_response(&mut c)?;
    c.finish()?;
    Ok(r)
}

// ---------------------------------------------------------------------
// errors across the wire

/// Encode a [`D4mError`] for transport. String-payload variants
/// round-trip exactly; `Io` and `Wire` errors arrive as
/// [`D4mError::Remote`] (they wrap process-local types).
fn put_error(b: &mut Vec<u8>, e: &D4mError) {
    match e {
        D4mError::Shape(s) => {
            put_u8(b, 0);
            put_str(b, s);
        }
        D4mError::NotFound(s) => {
            put_u8(b, 1);
            put_str(b, s);
        }
        D4mError::AlreadyExists(s) => {
            put_u8(b, 2);
            put_str(b, s);
        }
        D4mError::MemoryLimit { used, limit } => {
            put_u8(b, 3);
            put_varint(b, *used as u64);
            put_varint(b, *limit as u64);
        }
        D4mError::Parse(s) => {
            put_u8(b, 4);
            put_str(b, s);
        }
        D4mError::Runtime(s) => {
            put_u8(b, 5);
            put_str(b, s);
        }
        D4mError::Pipeline(s) => {
            put_u8(b, 6);
            put_str(b, s);
        }
        D4mError::InvalidArg(s) => {
            put_u8(b, 7);
            put_str(b, s);
        }
        D4mError::UnexpectedResponse { expected, got } => {
            put_u8(b, 11);
            put_str(b, expected);
            put_str(b, got);
        }
        D4mError::Io(e) => {
            put_u8(b, 8);
            put_str(b, &e.to_string());
        }
        D4mError::Wire(e) => {
            put_u8(b, 9);
            put_str(b, &e.to_string());
        }
        D4mError::Remote(s) => {
            put_u8(b, 10);
            put_str(b, s);
        }
        D4mError::Backpressure { table, waited_ms } => {
            put_u8(b, 12);
            put_str(b, table);
            put_varint(b, *waited_ms);
        }
        D4mError::Storage(s) => {
            put_u8(b, 13);
            put_str(b, s);
        }
        D4mError::Overloaded { retry_after_ms } => {
            put_u8(b, 14);
            put_varint(b, *retry_after_ms);
        }
        D4mError::RetryExhausted { attempts, last } => {
            put_u8(b, 15);
            put_varint(b, *attempts as u64);
            put_str(b, last);
        }
        D4mError::AmbiguousWrite(s) => {
            put_u8(b, 16);
            put_str(b, s);
        }
    }
}

fn get_error(c: &mut Cursor) -> WireResult<D4mError> {
    Ok(match c.u8()? {
        0 => D4mError::Shape(c.str()?),
        1 => D4mError::NotFound(c.str()?),
        2 => D4mError::AlreadyExists(c.str()?),
        3 => D4mError::MemoryLimit {
            used: to_usize(c.varint()?, "used overflows usize")?,
            limit: to_usize(c.varint()?, "limit overflows usize")?,
        },
        4 => D4mError::Parse(c.str()?),
        5 => D4mError::Runtime(c.str()?),
        6 => D4mError::Pipeline(c.str()?),
        7 => D4mError::InvalidArg(c.str()?),
        8 => D4mError::Remote(format!("io: {}", c.str()?)),
        9 => D4mError::Remote(format!("wire: {}", c.str()?)),
        10 => D4mError::Remote(c.str()?),
        11 => D4mError::UnexpectedResponse { expected: c.str()?, got: c.str()? },
        12 => D4mError::Backpressure { table: c.str()?, waited_ms: c.varint()? },
        13 => D4mError::Storage(c.str()?),
        14 => D4mError::Overloaded { retry_after_ms: c.varint()? },
        15 => D4mError::RetryExhausted {
            attempts: c.varint()?.min(u32::MAX as u64) as u32,
            last: c.str()?,
        },
        16 => D4mError::AmbiguousWrite(c.str()?),
        tag => return Err(WireError::UnknownTag { what: "error", tag }),
    })
}

// ---------------------------------------------------------------------
// top-level frames (request id + message)

/// Encode a client frame payload: request `id` varint, then the message.
pub fn encode_client_frame(id: u64, m: &ClientMsg) -> Vec<u8> {
    let mut b = Vec::new();
    put_varint(&mut b, id);
    match m {
        ClientMsg::Api(req) => {
            put_u8(&mut b, 0);
            encode_request(&mut b, req);
        }
        ClientMsg::Ping { version } => {
            put_u8(&mut b, 1);
            put_u8(&mut b, *version);
        }
        ClientMsg::Stats => put_u8(&mut b, 2),
        ClientMsg::Shutdown => put_u8(&mut b, 3),
        ClientMsg::OpenCursor { table, query, page_entries, resume } => {
            put_u8(&mut b, 4);
            put_str(&mut b, table);
            put_query(&mut b, query);
            put_varint(&mut b, *page_entries);
            put_bool(&mut b, resume.is_some());
            if let Some(r) = resume {
                put_varint(&mut b, r.cursor);
                put_varint(&mut b, r.token);
                put_varint(&mut b, r.pages_acked);
            }
        }
        ClientMsg::CursorNext { cursor } => {
            put_u8(&mut b, 5);
            put_varint(&mut b, *cursor);
        }
        ClientMsg::CursorClose { cursor } => {
            put_u8(&mut b, 6);
            put_varint(&mut b, *cursor);
        }
        ClientMsg::OpenPlanCursor { ops, page_entries } => {
            put_u8(&mut b, 7);
            put_plan_ops(&mut b, ops);
            put_varint(&mut b, *page_entries);
        }
    }
    b
}

/// Decode a client frame payload into `(request id, message)` (must
/// consume every byte).
pub fn decode_client_frame(buf: &[u8]) -> WireResult<(u64, ClientMsg)> {
    let mut c = Cursor::new(buf);
    let id = c.varint()?;
    let m = match c.u8()? {
        0 => ClientMsg::Api(get_request(&mut c)?),
        1 => ClientMsg::Ping { version: c.u8()? },
        2 => ClientMsg::Stats,
        3 => ClientMsg::Shutdown,
        4 => ClientMsg::OpenCursor {
            table: c.str()?,
            query: get_query(&mut c)?,
            page_entries: c.varint()?,
            resume: if c.bool()? {
                Some(CursorResume {
                    cursor: c.varint()?,
                    token: c.varint()?,
                    pages_acked: c.varint()?,
                })
            } else {
                None
            },
        },
        5 => ClientMsg::CursorNext { cursor: c.varint()? },
        6 => ClientMsg::CursorClose { cursor: c.varint()? },
        7 => ClientMsg::OpenPlanCursor {
            ops: get_plan_ops(&mut c)?,
            page_entries: c.varint()?,
        },
        tag => return Err(WireError::UnknownTag { what: "ClientMsg", tag }),
    };
    c.finish()?;
    Ok((id, m))
}

/// Encode a server frame payload: the answered request `id`, then the
/// message.
pub fn encode_server_frame(id: u64, m: &ServerMsg) -> Vec<u8> {
    let mut b = Vec::new();
    put_varint(&mut b, id);
    match m {
        ServerMsg::Reply(Ok(resp)) => {
            put_u8(&mut b, 0);
            encode_response(&mut b, resp);
        }
        ServerMsg::Reply(Err(e)) => {
            put_u8(&mut b, 1);
            put_error(&mut b, e);
        }
        ServerMsg::Pong { version } => {
            put_u8(&mut b, 2);
            put_u8(&mut b, *version);
        }
        ServerMsg::Stats(snaps) => {
            put_u8(&mut b, 3);
            put_varint(&mut b, snaps.len() as u64);
            for s in snaps {
                put_str(&mut b, &s.name);
                put_varint(&mut b, s.count);
                put_f64(&mut b, s.rate_per_sec);
                put_f64(&mut b, s.mean_latency_ns);
                put_varint(&mut b, s.p99_latency_ns);
            }
        }
        ServerMsg::ShutdownAck => put_u8(&mut b, 4),
        ServerMsg::CursorOpened { cursor, token } => {
            put_u8(&mut b, 5);
            put_varint(&mut b, *cursor);
            put_varint(&mut b, *token);
        }
        ServerMsg::CursorPage(page) => {
            put_u8(&mut b, 6);
            put_varint(&mut b, page.triples.len() as u64);
            for (r, col, v) in &page.triples {
                put_str(&mut b, r);
                put_str(&mut b, col);
                put_str(&mut b, v);
            }
            put_bool(&mut b, page.done);
        }
        ServerMsg::CursorClosed => put_u8(&mut b, 7),
    }
    b
}

/// Decode a server frame payload into `(request id, message)` (must
/// consume every byte).
pub fn decode_server_frame(buf: &[u8]) -> WireResult<(u64, ServerMsg)> {
    let mut c = Cursor::new(buf);
    let id = c.varint()?;
    let m = match c.u8()? {
        0 => ServerMsg::Reply(Ok(get_response(&mut c)?)),
        1 => ServerMsg::Reply(Err(get_error(&mut c)?)),
        2 => ServerMsg::Pong { version: c.u8()? },
        3 => {
            let n = c.count(18)?; // name len + count + 2 f64s + p99
            let mut snaps = Vec::with_capacity(n.min(PREALLOC_CAP));
            for _ in 0..n {
                snaps.push(Snapshot {
                    name: c.str()?,
                    count: c.varint()?,
                    rate_per_sec: c.f64()?,
                    mean_latency_ns: c.f64()?,
                    p99_latency_ns: c.varint()?,
                });
            }
            ServerMsg::Stats(snaps)
        }
        4 => ServerMsg::ShutdownAck,
        5 => ServerMsg::CursorOpened { cursor: c.varint()?, token: c.varint()? },
        6 => {
            let n = c.count(3)?; // each triple: 3 length bytes minimum
            let mut triples: Vec<TripleMsg> = Vec::with_capacity(n.min(PREALLOC_CAP));
            for _ in 0..n {
                triples.push((c.str()?, c.str()?, c.str()?));
            }
            ServerMsg::CursorPage(CursorPage { triples, done: c.bool()? })
        }
        7 => ServerMsg::CursorClosed,
        tag => return Err(WireError::UnknownTag { what: "ServerMsg", tag }),
    };
    c.finish()?;
    Ok((id, m))
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;
    use crate::util::XorShift64;

    // ---------------------------------------------------------------
    // randomized value generators (xorshift-seeded, reproducible)

    fn rand_str(rng: &mut XorShift64) -> String {
        const ALPHABET: &[&str] =
            &["a", "b", "z", "0", "9", "|", ",", " ", "é", "✓", "\u{10FFFF}", "\\", "\""];
        let len = rng.below(8) as usize;
        (0..len).map(|_| ALPHABET[rng.below(ALPHABET.len() as u64) as usize]).collect()
    }

    fn rand_keysel(rng: &mut XorShift64) -> KeySel {
        match rng.below(4) {
            0 => KeySel::All,
            1 => {
                let n = rng.below(4) as usize;
                KeySel::Keys((0..n).map(|_| rand_str(rng)).collect())
            }
            2 => KeySel::Range(rand_str(rng), rand_str(rng)),
            _ => KeySel::Prefix(rand_str(rng)),
        }
    }

    fn rand_query(rng: &mut XorShift64) -> TableQuery {
        TableQuery {
            rows: rand_keysel(rng),
            cols: rand_keysel(rng),
            limit: if rng.below(2) == 0 { None } else { Some(rng.below(1 << 20) as usize) },
            page_rows: 1 + rng.below(4096) as usize,
        }
    }

    /// A random well-formed assoc: empty, numeric, or string-valued
    /// (construction through the public builders guarantees every CSR
    /// invariant the decoder re-checks).
    fn rand_assoc(rng: &mut XorShift64) -> Assoc {
        let n = rng.below(12) as usize; // 0 => empty
        match rng.below(3) {
            0 => Assoc::empty(),
            1 => {
                let triples: Vec<(String, String, f64)> = (0..n)
                    .map(|_| {
                        let v = (rng.below(1000) as f64 - 500.0) / 8.0;
                        (rand_str(rng), rand_str(rng), v)
                    })
                    .collect();
                Assoc::from_triples(&triples)
            }
            _ => {
                let triples: Vec<(String, String, String)> = (0..n)
                    .map(|_| (rand_str(rng), rand_str(rng), rand_str(rng)))
                    .collect();
                Assoc::from_str_triples(&triples)
            }
        }
    }

    /// A random **valid** plan: op 0 is a Load, every later op only
    /// references earlier slots, dims stay in {1, 2} — so the decoder's
    /// revalidation pass accepts it.
    fn rand_plan_ops(rng: &mut XorShift64) -> Vec<PlanOp> {
        let n = 1 + rng.below(8) as usize;
        let mut ops = vec![PlanOp::Load {
            table: rand_str(rng),
            rows: rand_keysel(rng),
            cols: rand_keysel(rng),
            limit: if rng.below(2) == 0 { None } else { Some(rng.below(1 << 20) as usize) },
        }];
        for i in 1..n {
            let src = rng.below(i as u64) as usize;
            let b = rng.below(i as u64) as usize;
            ops.push(match rng.below(13) {
                0 => PlanOp::Load {
                    table: rand_str(rng),
                    rows: rand_keysel(rng),
                    cols: rand_keysel(rng),
                    limit: if rng.below(2) == 0 { None } else { Some(rng.below(64) as usize) },
                },
                1 => PlanOp::Select { src, rows: rand_keysel(rng), cols: rand_keysel(rng) },
                2 => PlanOp::Transpose { src },
                3 => PlanOp::MatMul { a: src, b },
                4 => PlanOp::CatKeyMul { a: src, b },
                5 => PlanOp::ElemAdd { a: src, b },
                6 => PlanOp::ElemSub { a: src, b },
                7 => PlanOp::ElemMult { a: src, b },
                8 => PlanOp::ElemMin { a: src, b },
                9 => PlanOp::ElemMax { a: src, b },
                10 => PlanOp::Reduce { src, dim: 1 + rng.below(2) as usize },
                11 => PlanOp::Scale { src, factor: rng.f64() * 16.0 - 8.0 },
                _ => PlanOp::Store { src, table: rand_str(rng) },
            });
        }
        ops
    }

    fn rand_request(rng: &mut XorShift64) -> Request {
        match rng.below(12) {
            0 => Request::CreateTable {
                name: rand_str(rng),
                splits: (0..rng.below(4)).map(|_| rand_str(rng)).collect(),
            },
            1 => Request::Ingest {
                table: rand_str(rng),
                triples: (0..rng.below(8))
                    .map(|_| (rand_str(rng), rand_str(rng), rand_str(rng)))
                    .collect(),
                pipeline: PipelineConfig {
                    num_workers: 1 + rng.below(8) as usize,
                    queue_depth: 1 + rng.below(16) as usize,
                    batch_size: 1 + rng.below(4096) as usize,
                    shard_by_row: rng.below(2) == 0,
                },
            },
            2 => Request::Query { table: rand_str(rng), query: rand_query(rng) },
            3 | 4 | 5 => {
                let dest = if rng.below(2) == 0 {
                    MultDest::Table { out: rand_str(rng) }
                } else {
                    MultDest::Client
                };
                let exec = match rng.below(3) {
                    0 => ExecHint::Stream,
                    1 => {
                        let unlimited = rng.below(2) == 0;
                        ExecHint::Memory {
                            limit: if unlimited {
                                usize::MAX
                            } else {
                                rng.below(1 << 30) as usize
                            },
                        }
                    }
                    _ => ExecHint::Dense { tile: 1 + rng.below(512) as usize },
                };
                Request::TableMult { a: rand_str(rng), b: rand_str(rng), dest, exec }
            }
            6 => Request::Bfs {
                table: rand_str(rng),
                seeds: (0..rng.below(5)).map(|_| rand_str(rng)).collect(),
                hops: rng.below(10) as usize,
            },
            7 => Request::Jaccard { table: rand_str(rng), out: rand_str(rng) },
            8 => Request::KTruss { table: rand_str(rng), k: rng.below(8) as usize },
            9 => Request::PageRank {
                table: rand_str(rng),
                opts: PageRankOpts {
                    damping: rng.f64(),
                    max_iters: rng.below(500) as usize,
                    tol: rng.f64() / 1e6,
                },
            },
            10 => Request::Plan { ops: rand_plan_ops(rng) },
            _ => Request::ListTables,
        }
    }

    fn rand_response(rng: &mut XorShift64) -> Response {
        match rng.below(8) {
            0 => Response::Ok,
            1 => Response::Tables((0..rng.below(6)).map(|_| rand_str(rng)).collect()),
            2 => Response::Ingested(IngestReport {
                triples: rng.below(1 << 40),
                elapsed: Duration::from_nanos(rng.below(1 << 50)),
                rate: rng.f64() * 1e8,
                physical_rate: rng.f64() * 3e8,
                per_worker: (0..rng.below(8)).map(|_| rng.below(1 << 30)).collect(),
                backpressure_stalls: rng.below(100),
                num_workers: 1 + rng.below(8) as usize,
            }),
            3 => Response::Assoc(rand_assoc(rng)),
            4 => Response::Distances(
                (0..rng.below(8)).map(|_| (rand_str(rng), rng.below(30) as usize)).collect(),
            ),
            5 => Response::Ranks(PageRankResult {
                scores: (0..rng.below(8)).map(|_| (rand_str(rng), rng.f64())).collect(),
                iterations: rng.below(200) as usize,
                converged: rng.below(2) == 0,
            }),
            6 => Response::MultStats(TableMultStats {
                rows_contracted: rng.below(1 << 20),
                partial_products: rng.below(1 << 30),
                peak_row_entries: rng.below(1 << 16) as usize,
            }),
            _ => Response::PlanResult {
                result: rand_assoc(rng),
                stats: PlanStats {
                    ops: rng.below(64),
                    fused_selects: rng.below(8),
                    fused_reduces: rng.below(8),
                    intermediates: rng.below(8),
                },
            },
        }
    }

    // ---------------------------------------------------------------
    // round trips

    #[test]
    fn request_roundtrip_randomized() {
        crate::util::forall(500, 0xD4A1, |rng| {
            let req = rand_request(rng);
            let mut b = Vec::new();
            encode_request(&mut b, &req);
            let back = decode_request(&b).expect("decode");
            assert_eq!(req, back);
        });
    }

    #[test]
    fn response_roundtrip_randomized_with_ids() {
        crate::util::forall(500, 0xD4A2, |rng| {
            let resp = rand_response(rng);
            let id = rng.below(1 << 40);
            let b = encode_server_frame(id, &ServerMsg::Reply(Ok(resp.clone())));
            match decode_server_frame(&b).expect("decode") {
                (back_id, ServerMsg::Reply(Ok(back))) => {
                    assert_eq!(id, back_id, "request id did not round-trip");
                    assert_eq!(resp, back);
                }
                other => panic!("wrong message shape: {other:?}"),
            }
        });
    }

    #[test]
    fn client_frame_roundtrip_randomized_with_ids() {
        crate::util::forall(300, 0xD4A7, |rng| {
            let req = rand_request(rng);
            let id = 1 + rng.below(1 << 40);
            let b = encode_client_frame(id, &ClientMsg::Api(req.clone()));
            match decode_client_frame(&b).expect("decode") {
                (back_id, ClientMsg::Api(back)) => {
                    assert_eq!(id, back_id);
                    assert_eq!(req, back);
                }
                other => panic!("wrong message shape: {other:?}"),
            }
        });
    }

    #[test]
    fn cursor_msgs_roundtrip() {
        let mut rng = XorShift64::new(0xD4C0);
        for _ in 0..50 {
            let id = rng.below(1 << 30);
            let open = ClientMsg::OpenCursor {
                table: rand_str(&mut rng),
                query: rand_query(&mut rng),
                page_entries: 1 + rng.below(1 << 20),
                resume: if rng.below(2) == 0 {
                    None
                } else {
                    Some(CursorResume {
                        cursor: rng.below(1 << 30),
                        token: rng.next_u64(),
                        pages_acked: rng.below(1 << 20),
                    })
                },
            };
            let b = encode_client_frame(id, &open);
            match (decode_client_frame(&b).unwrap(), &open) {
                (
                    (bid, ClientMsg::OpenCursor { table, query, page_entries, resume }),
                    ClientMsg::OpenCursor {
                        table: t0,
                        query: q0,
                        page_entries: p0,
                        resume: r0,
                    },
                ) => {
                    assert_eq!(bid, id);
                    assert_eq!(&table, t0);
                    assert_eq!(&query, q0);
                    assert_eq!(&page_entries, p0);
                    assert_eq!(&resume, r0);
                }
                other => panic!("wrong shape: {other:?}"),
            }
            for m in [
                ClientMsg::CursorNext { cursor: rng.below(1 << 30) },
                ClientMsg::CursorClose { cursor: rng.below(1 << 30) },
            ] {
                let b = encode_client_frame(id, &m);
                let (bid, back) = decode_client_frame(&b).unwrap();
                assert_eq!(bid, id);
                match (&m, &back) {
                    (
                        ClientMsg::CursorNext { cursor: a },
                        ClientMsg::CursorNext { cursor: b },
                    )
                    | (
                        ClientMsg::CursorClose { cursor: a },
                        ClientMsg::CursorClose { cursor: b },
                    ) => assert_eq!(a, b),
                    other => panic!("wrong shape: {other:?}"),
                }
            }
            let page = CursorPage {
                triples: (0..rng.below(6))
                    .map(|_| (rand_str(&mut rng), rand_str(&mut rng), rand_str(&mut rng)))
                    .collect(),
                done: rng.below(2) == 0,
            };
            let b = encode_server_frame(id, &ServerMsg::CursorPage(page.clone()));
            match decode_server_frame(&b).unwrap() {
                (bid, ServerMsg::CursorPage(back)) => {
                    assert_eq!(bid, id);
                    assert_eq!(back, page);
                }
                other => panic!("wrong shape: {other:?}"),
            }
            let b =
                encode_server_frame(id, &ServerMsg::CursorOpened { cursor: 42, token: 0xBEEF });
            assert!(matches!(
                decode_server_frame(&b).unwrap(),
                (_, ServerMsg::CursorOpened { cursor: 42, token: 0xBEEF })
            ));
            let b = encode_server_frame(id, &ServerMsg::CursorClosed);
            assert!(matches!(decode_server_frame(&b).unwrap(), (_, ServerMsg::CursorClosed)));
        }
    }

    #[test]
    fn plan_request_and_cursor_roundtrip() {
        crate::util::forall(300, 0xD4B0, |rng| {
            let ops = rand_plan_ops(rng);
            let req = Request::Plan { ops: ops.clone() };
            let mut b = Vec::new();
            encode_request(&mut b, &req);
            assert_eq!(decode_request(&b).expect("decode"), req);

            let id = 1 + rng.below(1 << 30);
            let msg = ClientMsg::OpenPlanCursor { ops, page_entries: 1 + rng.below(1 << 16) };
            let b = encode_client_frame(id, &msg);
            match (decode_client_frame(&b).expect("decode"), &msg) {
                (
                    (bid, ClientMsg::OpenPlanCursor { ops, page_entries }),
                    ClientMsg::OpenPlanCursor { ops: o0, page_entries: p0 },
                ) => {
                    assert_eq!(bid, id);
                    assert_eq!(&ops, o0);
                    assert_eq!(&page_entries, p0);
                }
                other => panic!("wrong shape: {other:?}"),
            }
        });
    }

    #[test]
    fn retired_tablemult_tags_fail_typed() {
        // hand-build v3-era tag-4/5 payloads: a retired tag must decode
        // to the dedicated error, not UnknownTag and not a misparse
        for tag in [4u8, 5] {
            let mut b = Vec::new();
            put_u8(&mut b, tag);
            put_str(&mut b, "A");
            put_str(&mut b, "B");
            put_varint(&mut b, 64);
            assert_eq!(
                decode_request(&b),
                Err(WireError::Retired { what: "Request", tag })
            );
        }
    }

    #[test]
    fn hostile_plans_rejected_at_decode() {
        // forward reference: op 0 selecting from slot 5
        let mut b = Vec::new();
        put_u8(&mut b, 11);
        put_varint(&mut b, 1);
        put_u8(&mut b, 1); // Select
        put_varint(&mut b, 5);
        put_keysel(&mut b, &KeySel::All);
        put_keysel(&mut b, &KeySel::All);
        assert_eq!(decode_request(&b), Err(WireError::Malformed("plan fails SSA validation")));

        // empty program
        let mut b = Vec::new();
        put_u8(&mut b, 11);
        put_varint(&mut b, 0);
        assert_eq!(decode_request(&b), Err(WireError::Malformed("plan fails SSA validation")));

        // reduce dim outside {1, 2}
        let mut b = Vec::new();
        put_u8(&mut b, 11);
        put_varint(&mut b, 2);
        put_u8(&mut b, 0); // Load
        put_str(&mut b, "T");
        put_keysel(&mut b, &KeySel::All);
        put_keysel(&mut b, &KeySel::All);
        put_u8(&mut b, 0); // no limit
        put_u8(&mut b, 10); // Reduce
        put_varint(&mut b, 0);
        put_u8(&mut b, 3); // bad dim
        assert_eq!(decode_request(&b), Err(WireError::Malformed("plan fails SSA validation")));

        // unknown op tag
        let mut b = Vec::new();
        put_u8(&mut b, 11);
        put_varint(&mut b, 1);
        put_u8(&mut b, 13);
        assert_eq!(
            decode_request(&b),
            Err(WireError::UnknownTag { what: "PlanOp", tag: 13 })
        );

        // random bytes after a Plan tag never panic
        crate::util::forall(300, 0xD4B1, |rng| {
            let n = rng.below(64) as usize;
            let mut b = vec![11u8];
            for _ in 0..n {
                b.push(rng.below(256) as u8);
            }
            let _ = decode_request(&b); // Ok or Err — never a panic
        });
    }

    #[test]
    fn plan_result_roundtrip() {
        let result = Assoc::from_triples(&[("r0", "", 6.5), ("r1", "", 2.0)]);
        let stats =
            PlanStats { ops: 4, fused_selects: 1, fused_reduces: 1, intermediates: 0 };
        let resp = Response::PlanResult { result: result.clone(), stats };
        let mut b = Vec::new();
        encode_response(&mut b, &resp);
        match decode_response(&b).unwrap() {
            Response::PlanResult { result: r, stats: s } => {
                assert_eq!(r, result);
                assert_eq!(s, stats);
            }
            other => panic!("wrong shape: {other:?}"),
        }
    }

    #[test]
    fn assoc_roundtrip_bit_identical() {
        crate::util::forall(300, 0xD4A3, |rng| {
            let a = rand_assoc(rng);
            let mut b = Vec::new();
            encode_assoc(&mut b, &a);
            let back = decode_assoc(&b).expect("decode");
            assert_eq!(a, back, "assoc did not round-trip bit-identically");
            assert_eq!(a.matrix(), back.matrix());
        });
    }

    #[test]
    fn string_and_empty_assocs_roundtrip() {
        for a in [
            Assoc::empty(),
            Assoc::from_str_triples(&[("r", "c", "hello"), ("r", "d", "wörld")]),
            Assoc::from_triples(&[("only", "one", -3.25)]),
        ] {
            let mut b = Vec::new();
            encode_assoc(&mut b, &a);
            assert_eq!(decode_assoc(&b).unwrap(), a);
        }
    }

    #[test]
    fn error_roundtrip() {
        let errs = vec![
            D4mError::Shape("s".into()),
            D4mError::NotFound("t".into()),
            D4mError::AlreadyExists("u".into()),
            D4mError::MemoryLimit { used: 10, limit: 7 },
            D4mError::Parse("p".into()),
            D4mError::Runtime("r".into()),
            D4mError::Pipeline("l".into()),
            D4mError::InvalidArg("i".into()),
            D4mError::UnexpectedResponse { expected: "Assoc".into(), got: "Tables".into() },
            D4mError::Remote("far away".into()),
            D4mError::Backpressure { table: "G".into(), waited_ms: 1234 },
            D4mError::Storage("bad run footer".into()),
            D4mError::Overloaded { retry_after_ms: 250 },
            D4mError::RetryExhausted { attempts: 5, last: "connection refused".into() },
            D4mError::AmbiguousWrite("ingest into G".into()),
        ];
        for e in errs {
            let expect = e.to_string();
            let b = encode_server_frame(9, &ServerMsg::Reply(Err(e)));
            match decode_server_frame(&b).unwrap() {
                (9, ServerMsg::Reply(Err(back))) => assert_eq!(back.to_string(), expect),
                other => panic!("wrong message shape: {other:?}"),
            }
        }
        // the shape-check error stays structured across the wire
        let e = D4mError::UnexpectedResponse { expected: "Ok".into(), got: "Assoc".into() };
        let b = encode_server_frame(1, &ServerMsg::Reply(Err(e)));
        match decode_server_frame(&b).unwrap() {
            (_, ServerMsg::Reply(Err(D4mError::UnexpectedResponse { expected, got }))) => {
                assert_eq!(expected, "Ok");
                assert_eq!(got, "Assoc");
            }
            other => panic!("expected UnexpectedResponse, got {other:?}"),
        }
        // Io / Wire arrive as Remote (process-local payloads)
        let io = D4mError::Io(std::io::Error::other("disk gone"));
        let b = encode_server_frame(2, &ServerMsg::Reply(Err(io)));
        match decode_server_frame(&b).unwrap() {
            (_, ServerMsg::Reply(Err(D4mError::Remote(s)))) => assert!(s.contains("disk gone")),
            other => panic!("io error should decode as Remote, got {other:?}"),
        }
        // the shed hint stays structured — self-healing clients read the
        // retry_after_ms field, not the message string
        let e = D4mError::Overloaded { retry_after_ms: 75 };
        let b = encode_server_frame(3, &ServerMsg::Reply(Err(e)));
        match decode_server_frame(&b).unwrap() {
            (_, ServerMsg::Reply(Err(D4mError::Overloaded { retry_after_ms }))) => {
                assert_eq!(retry_after_ms, 75);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }

    #[test]
    fn admin_msgs_roundtrip() {
        for m in [ClientMsg::Ping { version: VERSION }, ClientMsg::Stats, ClientMsg::Shutdown] {
            let b = encode_client_frame(3, &m);
            let (id, back) = decode_client_frame(&b).unwrap();
            assert_eq!(id, 3);
            assert_eq!(std::mem::discriminant(&m), std::mem::discriminant(&back));
        }
        // ping/pong carry the wire version for explicit negotiation
        let b = encode_client_frame(1, &ClientMsg::Ping { version: VERSION });
        assert!(matches!(
            decode_client_frame(&b).unwrap(),
            (1, ClientMsg::Ping { version: VERSION })
        ));
        let b = encode_server_frame(1, &ServerMsg::Pong { version: VERSION });
        assert!(matches!(
            decode_server_frame(&b).unwrap(),
            (1, ServerMsg::Pong { version: VERSION })
        ));
        let snaps = vec![Snapshot {
            name: "net.requests".into(),
            count: 42,
            rate_per_sec: 1000.5,
            mean_latency_ns: 12.0,
            p99_latency_ns: 99,
        }];
        let b = encode_server_frame(4, &ServerMsg::Stats(snaps.clone()));
        match decode_server_frame(&b).unwrap() {
            (4, ServerMsg::Stats(back)) => assert_eq!(back, snaps),
            other => panic!("wrong message shape: {other:?}"),
        }
    }

    // ---------------------------------------------------------------
    // framing

    #[test]
    fn frame_roundtrip() {
        let payload = encode_client_frame(12, &ClientMsg::Api(Request::ListTables));
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        assert_eq!(buf.len(), HEADER_LEN + payload.len());
        let back = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(back, payload);
    }

    #[test]
    fn truncated_frame_is_typed_error_at_every_cut() {
        let mut rng = XorShift64::new(0xD4A4);
        let req = rand_request(&mut rng);
        let payload = encode_client_frame(1, &ClientMsg::Api(req));
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        for cut in 0..buf.len() {
            let r = read_frame(&mut &buf[..cut]);
            match r {
                Err(D4mError::Wire(_)) => {}
                Err(other) => panic!("cut {cut}: non-wire error {other}"),
                Ok(_) => panic!("cut {cut}: truncated frame decoded"),
            }
        }
    }

    #[test]
    fn truncated_payload_is_typed_error_at_every_cut() {
        let mut rng = XorShift64::new(0xD4A5);
        for _ in 0..20 {
            let resp = rand_response(&mut rng);
            let b = encode_server_frame(rng.below(1 << 20), &ServerMsg::Reply(Ok(resp)));
            for cut in 0..b.len() {
                assert!(
                    decode_server_frame(&b[..cut]).is_err(),
                    "cut {cut} of {} decoded",
                    b.len()
                );
            }
        }
    }

    #[test]
    fn corrupt_bytes_never_panic() {
        let mut rng = XorShift64::new(0xD4A6);
        for _ in 0..20 {
            let req = rand_request(&mut rng);
            let mut b = encode_client_frame(rng.below(1 << 20), &ClientMsg::Api(req));
            for i in 0..b.len() {
                let orig = b[i];
                b[i] ^= 0xFF;
                let _ = decode_client_frame(&b); // Ok or Err — never a panic
                b[i] = orig;
            }
            let resp = rand_response(&mut rng);
            let mut b = encode_server_frame(rng.below(1 << 20), &ServerMsg::Reply(Ok(resp)));
            for i in 0..b.len() {
                let orig = b[i];
                b[i] = b[i].wrapping_add(0x55);
                let _ = decode_server_frame(&b);
                b[i] = orig;
            }
        }
    }

    #[test]
    fn bad_magic_and_version_and_size() {
        let payload = encode_client_frame(1, &ClientMsg::Ping { version: VERSION });
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();

        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(D4mError::Wire(WireError::BadMagic(_)))
        ));

        // a v1 frame against this v2 codec (and any other version skew)
        // is one typed error before the payload is touched — never a
        // decode failure mid-stream
        for got in [1u8, VERSION + 1] {
            let mut bad = buf.clone();
            bad[3] = got;
            match read_frame(&mut &bad[..]) {
                Err(D4mError::Wire(WireError::Version { got: g, want })) => {
                    assert_eq!(g, got);
                    assert_eq!(want, VERSION);
                }
                other => panic!("expected Version error, got {other:?}"),
            }
        }

        // a header declaring an over-cap length is rejected before any
        // allocation — no 4 GiB Vec for a 12-byte input
        let mut huge = Vec::new();
        huge.extend_from_slice(&MAGIC);
        huge.push(VERSION);
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(
            read_frame(&mut &huge[..]),
            Err(D4mError::Wire(WireError::FrameTooLarge(_)))
        ));
        assert!(matches!(
            write_frame(&mut Vec::new(), &vec![0u8; MAX_FRAME + 1]),
            Err(D4mError::Wire(WireError::FrameTooLarge(_)))
        ));
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut b = encode_client_frame(1, &ClientMsg::Ping { version: VERSION });
        b.push(0);
        assert!(matches!(decode_client_frame(&b), Err(WireError::TrailingBytes(1))));
    }

    #[test]
    fn hostile_assoc_invariants_rejected() {
        // out-of-dictionary string value index
        let a = Assoc::from_str_triples(&[("r", "c", "v")]);
        let mut b = Vec::new();
        encode_assoc(&mut b, &a);
        // the single data value is the f64 1.0 in the last 8 bytes; bump it
        let n = b.len();
        b[n - 8..].copy_from_slice(&2.0f64.to_bits().to_le_bytes());
        assert!(matches!(decode_assoc(&b), Err(WireError::Malformed(_))));

        // unsorted key vector
        let mut b = Vec::new();
        put_str_slice(&mut b, &["b".into(), "a".into()]);
        put_str_slice(&mut b, &[]);
        put_u8(&mut b, 0);
        put_varint(&mut b, 2); // nr
        put_varint(&mut b, 0); // nc
        put_varint(&mut b, 0); // nnz
        for _ in 0..3 {
            put_varint(&mut b, 0); // indptr
        }
        assert!(matches!(decode_assoc(&b), Err(WireError::Malformed(_))));
    }

    #[test]
    fn varint_overflow_rejected() {
        let mut c = Cursor::new(&[0xFF; 11]);
        assert!(matches!(c.varint(), Err(WireError::Malformed(_))));
    }
}
