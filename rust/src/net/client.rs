//! `RemoteD4m` — a network client whose API mirrors
//! [`D4mServer::handle`](crate::coordinator::D4mServer::handle), so any
//! code written against the in-process coordinator runs remote by
//! swapping the constructor:
//!
//! ```text
//! let server = D4mServer::new();          // in-process
//! let server = RemoteD4m::connect(addr)?; // remote — same .handle(req)
//! ```
//!
//! One `RemoteD4m` owns one TCP connection and serialises its requests
//! over it (the stream is behind a mutex, so a shared reference works
//! from multiple threads — but concurrent *throughput* wants one client
//! per thread, which is exactly what the e2e and bench harnesses do).

use std::collections::BTreeMap;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

use crate::assoc::Assoc;
use crate::connectors::TableQuery;
use crate::coordinator::{Request, Response};
use crate::error::{D4mError, Result};
use crate::graphulo::{PageRankOpts, PageRankResult, TableMultStats};
use crate::metrics::Snapshot;
use crate::net::wire::{self, ClientMsg, ServerMsg};
use crate::pipeline::{IngestReport, PipelineConfig, TripleMsg};

/// A connection to a remote `d4m serve` coordinator.
pub struct RemoteD4m {
    stream: Mutex<TcpStream>,
}

impl RemoteD4m {
    /// Connect to a serving coordinator (`"host:port"`).
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(RemoteD4m { stream: Mutex::new(stream) })
    }

    /// Connect with retries — the CI/e2e readiness probe for a server
    /// process that is still binding its port.
    pub fn connect_retry(addr: &str, attempts: u32, delay: Duration) -> Result<Self> {
        let mut last: Option<D4mError> = None;
        for _ in 0..attempts.max(1) {
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    last = Some(e);
                    std::thread::sleep(delay);
                }
            }
        }
        Err(last.unwrap_or_else(|| D4mError::InvalidArg("connect_retry: 0 attempts".into())))
    }

    /// One framed round trip.
    fn rpc(&self, msg: &ClientMsg) -> Result<ServerMsg> {
        let payload = wire::encode_client_msg(msg);
        let mut stream = self.stream.lock().unwrap();
        wire::write_frame(&mut *stream, &payload)?;
        let reply = wire::read_frame(&mut *stream)?;
        Ok(wire::decode_server_msg(&reply)?)
    }

    /// Serve one request remotely — the mirror of `D4mServer::handle`.
    pub fn handle(&self, req: Request) -> Result<Response> {
        match self.rpc(&ClientMsg::Api(req))? {
            ServerMsg::Reply(r) => r,
            other => Err(unexpected(&other)),
        }
    }

    /// Liveness probe.
    pub fn ping(&self) -> Result<()> {
        match self.rpc(&ClientMsg::Ping)? {
            ServerMsg::Pong => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    /// Remote metrics: the coordinator's per-op snapshots plus the
    /// server's net-layer counters.
    pub fn stats(&self) -> Result<Vec<Snapshot>> {
        match self.rpc(&ClientMsg::Stats)? {
            ServerMsg::Stats(s) => Ok(s),
            other => Err(unexpected(&other)),
        }
    }

    /// Ask the server to shut down gracefully; returns once acknowledged.
    pub fn shutdown_server(&self) -> Result<()> {
        match self.rpc(&ClientMsg::Shutdown)? {
            ServerMsg::ShutdownAck => Ok(()),
            other => Err(unexpected(&other)),
        }
    }

    // ------------------------------------------------------------------
    // convenience mirrors of the coordinator API

    pub fn create_table(&self, name: &str, splits: Vec<String>) -> Result<()> {
        match self.handle(Request::CreateTable { name: name.into(), splits })? {
            Response::Ok => Ok(()),
            other => Err(mismatch("Ok", &other)),
        }
    }

    pub fn ingest(
        &self,
        table: &str,
        triples: Vec<TripleMsg>,
        pipeline: PipelineConfig,
    ) -> Result<IngestReport> {
        match self.handle(Request::Ingest { table: table.into(), triples, pipeline })? {
            Response::Ingested(r) => Ok(r),
            other => Err(mismatch("Ingested", &other)),
        }
    }

    pub fn query(&self, table: &str, query: TableQuery) -> Result<Assoc> {
        self.handle(Request::Query { table: table.into(), query })?.into_assoc()
    }

    pub fn tablemult(&self, a: &str, b: &str, out: &str) -> Result<TableMultStats> {
        match self.handle(Request::TableMult { a: a.into(), b: b.into(), out: out.into() })? {
            Response::MultStats(s) => Ok(s),
            other => Err(mismatch("MultStats", &other)),
        }
    }

    pub fn tablemult_client(&self, a: &str, b: &str, memory_limit: usize) -> Result<Assoc> {
        self.handle(Request::TableMultClient { a: a.into(), b: b.into(), memory_limit })?
            .into_assoc()
    }

    pub fn bfs(&self, table: &str, seeds: &[&str], hops: usize) -> Result<BTreeMap<String, usize>> {
        let seeds = seeds.iter().map(|s| s.to_string()).collect();
        match self.handle(Request::Bfs { table: table.into(), seeds, hops })? {
            Response::Distances(d) => Ok(d),
            other => Err(mismatch("Distances", &other)),
        }
    }

    pub fn jaccard(&self, table: &str, out: &str) -> Result<Assoc> {
        self.handle(Request::Jaccard { table: table.into(), out: out.into() })?.into_assoc()
    }

    pub fn ktruss(&self, table: &str, k: usize) -> Result<Assoc> {
        self.handle(Request::KTruss { table: table.into(), k })?.into_assoc()
    }

    pub fn pagerank(&self, table: &str, opts: PageRankOpts) -> Result<PageRankResult> {
        match self.handle(Request::PageRank { table: table.into(), opts })? {
            Response::Ranks(r) => Ok(r),
            other => Err(mismatch("Ranks", &other)),
        }
    }

    pub fn list_tables(&self) -> Result<Vec<String>> {
        match self.handle(Request::ListTables)? {
            Response::Tables(t) => Ok(t),
            other => Err(mismatch("Tables", &other)),
        }
    }
}

fn unexpected(msg: &ServerMsg) -> D4mError {
    D4mError::Remote(format!("unexpected reply frame: {}", frame_name(msg)))
}

fn mismatch(expected: &str, got: &Response) -> D4mError {
    // mirror Response::into_assoc: never Debug-print a payload into an
    // error string
    D4mError::Remote(format!("expected {expected} response, got {}", got.variant_name()))
}

fn frame_name(msg: &ServerMsg) -> &'static str {
    match msg {
        ServerMsg::Reply(_) => "Reply",
        ServerMsg::Pong => "Pong",
        ServerMsg::Stats(_) => "Stats",
        ServerMsg::ShutdownAck => "ShutdownAck",
    }
}
