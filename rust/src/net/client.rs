//! `RemoteD4m` — a pipelined network client implementing the
//! [`D4mApi`] trait, so any code written against the in-process
//! coordinator runs remote by swapping a constructor:
//!
//! ```text
//! let api: &dyn D4mApi = &D4mServer::new();           // in-process
//! let api: &dyn D4mApi = &RemoteD4m::connect(addr)?;  // remote
//! ```
//!
//! One `RemoteD4m` owns one TCP connection, **multiplexed**: any thread
//! may [`RemoteD4m::submit`] a request (assigned a fresh request id and
//! written immediately) and later [`RemoteD4m::wait`] for that id's
//! response. Responses arrive in whatever order the server completes
//! them; a correlation map parks early arrivals until their waiter shows
//! up. There is no dedicated reader thread — whichever waiting thread
//! gets there first reads frames off the socket (parking frames that
//! answer other ids and waking their waiters), so a single-threaded
//! caller pays no thread overhead and a multi-threaded caller shares
//! one connection safely.
//!
//! Streaming scans ride the same session: [`D4mApi::scan_pages`]
//! (via the trait) opens a server-side cursor and lazily pulls bounded
//! pages — see `coordinator::api`.

use std::collections::{HashMap, HashSet};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::connectors::TableQuery;
use crate::coordinator::{CursorPage, D4mApi, Request, Response};
use crate::error::{D4mError, Result};
use crate::metrics::Snapshot;
use crate::net::wire::{self, ClientMsg, ServerMsg, WireError};

/// Correlation state shared by every waiter on one connection.
struct Pending {
    /// Ids submitted but not yet answered. A frame for an id outside
    /// this set is dropped (stale reply to a forgotten id), and a wait
    /// on an id outside it fails typed instead of hanging — so the map
    /// below cannot grow unboundedly and a double-wait cannot deadlock.
    outstanding: HashSet<u64>,
    /// Frames that arrived before their waiter: id → message.
    ready: HashMap<u64, ServerMsg>,
    /// True while some thread is blocked reading the socket on behalf of
    /// everyone (at most one reader at a time).
    reader_active: bool,
    /// First fatal transport error; once set, every current and future
    /// wait fails with it (the connection is unusable).
    dead: Option<String>,
}

/// A pipelined connection to a remote `d4m serve` coordinator.
pub struct RemoteD4m {
    /// Write half (a `try_clone` of the socket) — frames are written
    /// whole under this lock, so submissions from many threads interleave
    /// at frame granularity only.
    writer: Mutex<TcpStream>,
    /// Read half — held only by the thread currently playing reader.
    reader: Mutex<TcpStream>,
    /// Next request id (ids start at 1; 0 is the server's
    /// connection-error id).
    next_id: AtomicU64,
    pending: Mutex<Pending>,
    wakeup: Condvar,
}

impl RemoteD4m {
    /// Connect to a serving coordinator (`"host:port"`).
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let reader = stream.try_clone()?;
        Ok(RemoteD4m {
            writer: Mutex::new(stream),
            reader: Mutex::new(reader),
            next_id: AtomicU64::new(1),
            pending: Mutex::new(Pending {
                outstanding: HashSet::new(),
                ready: HashMap::new(),
                reader_active: false,
                dead: None,
            }),
            wakeup: Condvar::new(),
        })
    }

    /// Connect with retries — the CI/e2e readiness probe for a server
    /// process that is still binding its port.
    pub fn connect_retry(addr: &str, attempts: u32, delay: Duration) -> Result<Self> {
        let mut last: Option<D4mError> = None;
        for _ in 0..attempts.max(1) {
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    last = Some(e);
                    std::thread::sleep(delay);
                }
            }
        }
        Err(last.unwrap_or_else(|| D4mError::InvalidArg("connect_retry: 0 attempts".into())))
    }

    // ------------------------------------------------------------------
    // pipelining: submit / wait

    /// Submit a coordinator request without waiting: the frame is written
    /// now and the returned id claims its response later via
    /// [`RemoteD4m::wait`]. Any number of requests may be in flight on
    /// the connection; the server answers them in completion order.
    /// Every submitted id should eventually be [`RemoteD4m::wait`]ed or
    /// [`RemoteD4m::forget`]ten — an id that is neither keeps its parked
    /// response buffered until the connection drops.
    pub fn submit(&self, req: Request) -> Result<u64> {
        self.submit_msg(&ClientMsg::Api(req))
    }

    /// Claim the response to a previously [`RemoteD4m::submit`]ted id
    /// (block until its frame arrives). Each id is claimable exactly
    /// once; a wait on an id that is not in flight (never submitted,
    /// already claimed, or forgotten) fails with a typed error instead
    /// of hanging. Waiting threads cooperate — whoever waits first reads
    /// the socket for everyone.
    pub fn wait(&self, id: u64) -> Result<Response> {
        match self.wait_msg(id)? {
            ServerMsg::Reply(r) => r,
            other => Err(unexpected_frame("Reply", &other)),
        }
    }

    /// Abandon a submitted id: its response, whether already parked or
    /// still to arrive, is discarded instead of buffered forever. Use on
    /// error paths that bail out of a pipelined window without claiming
    /// every id.
    pub fn forget(&self, id: u64) {
        let mut g = self.pending.lock().unwrap();
        g.outstanding.remove(&id);
        g.ready.remove(&id);
        // wake any thread currently waiting on this id so it errors out
        // instead of sleeping until the next frame happens to land
        self.wakeup.notify_all();
    }

    fn submit_msg(&self, msg: &ClientMsg) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut g = self.pending.lock().unwrap();
            if let Some(e) = &g.dead {
                return Err(D4mError::Remote(format!("connection failed: {e}")));
            }
            g.outstanding.insert(id);
        }
        let payload = wire::encode_client_frame(id, msg);
        let mut w = self.writer.lock().unwrap();
        if let Err(e) = wire::write_frame(&mut *w, &payload) {
            self.pending.lock().unwrap().outstanding.remove(&id);
            return Err(e);
        }
        Ok(id)
    }

    /// Block until the frame answering `id` arrives (or the connection
    /// dies, or the id turns out not to be in flight). See the module
    /// docs for the cooperative-reader protocol.
    fn wait_msg(&self, id: u64) -> Result<ServerMsg> {
        let mut g = self.pending.lock().unwrap();
        loop {
            if let Some(m) = g.ready.remove(&id) {
                return Ok(m);
            }
            if let Some(e) = &g.dead {
                return Err(D4mError::Remote(format!("connection failed: {e}")));
            }
            if !g.outstanding.contains(&id) {
                return Err(D4mError::InvalidArg(format!(
                    "request id {id} is not in flight \
                     (never submitted, already claimed, or forgotten)"
                )));
            }
            if g.reader_active {
                // someone else is reading; they'll wake us when a frame
                // lands (maybe ours)
                g = self.wakeup.wait(g).unwrap();
                continue;
            }
            // become the reader for everyone
            g.reader_active = true;
            drop(g);
            let read = self.read_one();
            g = self.pending.lock().unwrap();
            g.reader_active = false;
            match read {
                Ok((rid, msg)) if rid == wire::CONN_ERR_ID => {
                    // connection-level server error: fatal for all waits
                    let detail = match msg {
                        ServerMsg::Reply(Err(e)) => e.to_string(),
                        other => format!("unattributed {} frame", frame_name(&other)),
                    };
                    g.dead = Some(detail);
                }
                Ok((rid, msg)) => {
                    // park only frames someone can still claim; a reply
                    // to a forgotten id is dropped here
                    if g.outstanding.remove(&rid) {
                        g.ready.insert(rid, msg);
                    }
                }
                Err(e) => {
                    g.dead = Some(e.to_string());
                }
            }
            self.wakeup.notify_all();
        }
    }

    fn read_one(&self) -> Result<(u64, ServerMsg)> {
        let mut r = self.reader.lock().unwrap();
        let payload = wire::read_frame(&mut *r)?;
        Ok(wire::decode_server_frame(&payload)?)
    }

    fn rpc(&self, msg: &ClientMsg) -> Result<ServerMsg> {
        let id = self.submit_msg(msg)?;
        self.wait_msg(id)
    }

    // ------------------------------------------------------------------
    // admin verbs (not part of the coordinator API surface)

    /// Liveness + version probe: checks the server's `Pong` carries the
    /// wire version this client speaks, failing with a typed
    /// [`WireError::Version`] on skew.
    pub fn ping(&self) -> Result<()> {
        match self.rpc(&ClientMsg::Ping { version: wire::VERSION })? {
            ServerMsg::Pong { version } if version == wire::VERSION => Ok(()),
            ServerMsg::Pong { version } => {
                Err(WireError::Version { got: version, want: wire::VERSION }.into())
            }
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("Pong", &other)),
        }
    }

    /// Remote metrics: the coordinator's per-op snapshots plus the
    /// server's net-layer counters.
    pub fn stats(&self) -> Result<Vec<Snapshot>> {
        match self.rpc(&ClientMsg::Stats)? {
            ServerMsg::Stats(s) => Ok(s),
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("Stats", &other)),
        }
    }

    /// Ask the server to shut down gracefully; returns once acknowledged.
    pub fn shutdown_server(&self) -> Result<()> {
        match self.rpc(&ClientMsg::Shutdown)? {
            ServerMsg::ShutdownAck => Ok(()),
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("ShutdownAck", &other)),
        }
    }
}

impl D4mApi for RemoteD4m {
    /// One request, one response — `submit` + `wait` back to back. For
    /// overlap, use those two directly.
    fn handle(&self, req: Request) -> Result<Response> {
        let id = self.submit(req)?;
        self.wait(id)
    }

    fn open_cursor(&self, table: &str, query: &TableQuery, page_entries: usize) -> Result<u64> {
        let msg = ClientMsg::OpenCursor {
            table: table.into(),
            query: query.clone(),
            page_entries: page_entries as u64,
        };
        match self.rpc(&msg)? {
            ServerMsg::CursorOpened { cursor } => Ok(cursor),
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("CursorOpened", &other)),
        }
    }

    fn cursor_next(&self, cursor: u64) -> Result<CursorPage> {
        match self.rpc(&ClientMsg::CursorNext { cursor })? {
            ServerMsg::CursorPage(page) => Ok(page),
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("CursorPage", &other)),
        }
    }

    fn cursor_close(&self, cursor: u64) -> Result<()> {
        match self.rpc(&ClientMsg::CursorClose { cursor })? {
            ServerMsg::CursorClosed => Ok(()),
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("CursorClosed", &other)),
        }
    }
}

fn unexpected_frame(expected: &str, msg: &ServerMsg) -> D4mError {
    D4mError::UnexpectedResponse { expected: expected.into(), got: frame_name(msg).into() }
}

fn frame_name(msg: &ServerMsg) -> &'static str {
    match msg {
        ServerMsg::Reply(_) => "Reply",
        ServerMsg::Pong { .. } => "Pong",
        ServerMsg::Stats(_) => "Stats",
        ServerMsg::ShutdownAck => "ShutdownAck",
        ServerMsg::CursorOpened { .. } => "CursorOpened",
        ServerMsg::CursorPage(_) => "CursorPage",
        ServerMsg::CursorClosed => "CursorClosed",
    }
}
