//! `RemoteD4m` — a pipelined, **self-healing** network client
//! implementing the [`D4mApi`] trait, so any code written against the
//! in-process coordinator runs remote by swapping a constructor:
//!
//! ```text
//! let api: &dyn D4mApi = &D4mServer::new();           // in-process
//! let api: &dyn D4mApi = &RemoteD4m::connect(addr)?;  // remote
//! ```
//!
//! One `RemoteD4m` owns one TCP connection at a time, **multiplexed**:
//! any thread may [`RemoteD4m::submit`] a request (assigned a fresh
//! request id and written immediately) and later [`RemoteD4m::wait`]
//! for that id's response. Responses arrive in whatever order the
//! server completes them; a correlation map parks early arrivals until
//! their waiter shows up. There is no dedicated reader thread —
//! whichever waiting thread gets there first polls frames off the
//! socket (parking frames that answer other ids and waking their
//! waiters), so a single-threaded caller pays no thread overhead and a
//! multi-threaded caller shares one connection safely.
//!
//! §Self-healing (DESIGN.md §Fault model): every **typed** call (the
//! `D4mApi` surface plus `ping`/`stats`) runs under a [`RetryPolicy`] —
//! exponential backoff with jitter, a retry budget, and a per-request
//! deadline. A dead connection is transparently re-established and the
//! request replayed **iff it is safe**:
//!
//! * a request that provably never reached the socket is replayed
//!   unconditionally;
//! * an *idempotent* request ([`Request::is_idempotent`]) is replayed
//!   even when the connection died after the frame was sent;
//! * a non-idempotent request that *may* have been applied surfaces
//!   [`D4mError::AmbiguousWrite`] — never a silent double-apply;
//! * a server [`D4mError::Overloaded`] (load shed / cursor cap) means
//!   the server did **no** work, so everything retries after the
//!   `retry_after_ms` hint.
//!
//! Cursor pulls additionally survive reconnects: the client remembers
//! each cursor's resume token and acked page count, re-attaches via
//! `OpenCursor { resume }` on the next connection, and the server
//! replays the one possibly-lost page — a paged scan interrupted by a
//! connection drop completes bit-identical to an uninterrupted one.
//!
//! The **raw** pipelining surface (`submit`/`wait`/`forget`) stays
//! single-connection and never retries: ids are claimed against the
//! connection current at submit time, exactly as before.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::collections::{HashMap, HashSet};
use std::io::Read;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::assoc::expr::{self, PlanOp};
use crate::connectors::TableQuery;
use crate::coordinator::{CursorPage, CursorResume, D4mApi, Request, Response};
use crate::error::{D4mError, Result};
use crate::metrics::{Counter, Snapshot};
use crate::net::wire::{self, ClientMsg, ServerMsg, WireError};
use crate::util::rng::XorShift64;

/// How often a polling reader (or a parked waiter) wakes to re-check
/// deadlines and connection death.
const POLL: Duration = Duration::from_millis(100);

/// Write timeout on the client socket — a wedged server cannot park a
/// submitting thread forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Retry/backoff/deadline budget for the self-healing request path.
///
/// `attempt` 1 is the first try; attempt `n` retries after
/// `base_delay * 2^(n-1)` (capped at `max_delay`, jittered to 50–100%
/// of the nominal value so synchronized clients fan out). A server
/// `retry_after_ms` hint raises the delay floor. When the budget is
/// spent the last error surfaces as [`D4mError::RetryExhausted`].
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts, including the first (minimum 1).
    pub max_attempts: u32,
    /// Backoff before the first retry.
    pub base_delay: Duration,
    /// Backoff cap — the exponential stops growing here.
    pub max_delay: Duration,
    /// Wall-clock budget per typed call, spanning every attempt and
    /// backoff sleep. `None` means attempts alone bound the retries.
    pub deadline: Option<Duration>,
    /// Jitter seed — same seed, same jitter sequence (determinism for
    /// tests).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 8,
            base_delay: Duration::from_millis(25),
            max_delay: Duration::from_secs(2),
            deadline: Some(Duration::from_secs(60)),
            seed: 0x5EED_D4A1,
        }
    }
}

impl RetryPolicy {
    /// A policy that never retries (first error surfaces raw, wrapped
    /// in [`D4mError::RetryExhausted`] only for transport failures).
    pub fn no_retry() -> Self {
        RetryPolicy { max_attempts: 1, ..Self::default() }
    }

    /// Fixed-interval probe: `attempts` tries `delay` apart — the shape
    /// of the old `connect_retry` readiness loop.
    pub fn probe(attempts: u32, delay: Duration) -> Self {
        RetryPolicy {
            max_attempts: attempts.max(1),
            base_delay: delay,
            max_delay: delay,
            deadline: None,
            ..Self::default()
        }
    }
}

/// Why a connection is unusable — kept typed so waiters can tell a load
/// shed (nothing executed, retry everything) from a mid-flight death
/// (in-flight requests may have been applied).
#[derive(Debug, Clone)]
enum Dead {
    /// The server shed this connection at accept with a framed
    /// `Overloaded` under the reserved id 0 — it read no frames, so no
    /// request was executed.
    Overloaded { retry_after_ms: u64 },
    /// Transport or protocol failure; anything in flight is ambiguous.
    Gone(String),
}

impl Dead {
    fn to_error(&self) -> D4mError {
        match self {
            Dead::Overloaded { retry_after_ms } => {
                D4mError::Overloaded { retry_after_ms: *retry_after_ms }
            }
            Dead::Gone(s) => D4mError::Remote(format!("connection failed: {s}")),
        }
    }
}

/// Correlation state shared by every waiter on one connection.
struct Pending {
    /// Ids submitted but not yet answered. A frame for an id outside
    /// this set is dropped (stale reply to a forgotten id), and a wait
    /// on an id outside it fails typed instead of hanging — so the map
    /// below cannot grow unboundedly and a double-wait cannot deadlock.
    outstanding: HashSet<u64>,
    /// Frames that arrived before their waiter: id → message.
    ready: HashMap<u64, ServerMsg>,
    /// True while some thread is polling the socket on behalf of
    /// everyone (at most one reader at a time).
    reader_active: bool,
    /// First fatal transport error; once set, every current and future
    /// wait fails with it (the connection is unusable).
    dead: Option<Dead>,
}

/// Incremental frame reader: buffers partial bytes across short read
/// timeouts so a poll tick can return "nothing yet" without losing the
/// prefix of an in-flight frame (a plain `read_exact` would).
struct FrameReader {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl FrameReader {
    /// One poll tick: try to complete a frame within roughly one
    /// [`POLL`] of socket waiting. `Ok(None)` means no full frame yet.
    fn poll(&mut self) -> Result<Option<Vec<u8>>> {
        loop {
            if self.buf.len() >= wire::HEADER_LEN {
                let mut header = [0u8; wire::HEADER_LEN];
                header.copy_from_slice(&self.buf[..wire::HEADER_LEN]);
                let len = wire::frame_payload_len(&header)?;
                if self.buf.len() >= wire::HEADER_LEN + len {
                    let payload = self.buf[wire::HEADER_LEN..wire::HEADER_LEN + len].to_vec();
                    self.buf.drain(..wire::HEADER_LEN + len);
                    return Ok(Some(payload));
                }
            }
            let mut chunk = [0u8; 64 * 1024];
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    return Err(D4mError::Remote("server closed the connection".into()));
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Ok(None);
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

/// One live connection: sockets plus the correlation state. Replaced
/// wholesale on reconnect — waiters on the old one fail with its `dead`
/// reason and the healing layer retries on the new one.
struct Conn {
    /// Monotonic per-client connection number; cursor metadata records
    /// the epoch it is attached on, so a pull on a newer connection
    /// knows to re-attach first.
    epoch: u64,
    /// Write half (a `try_clone` of the socket) — frames are written
    /// whole under this lock, so submissions from many threads
    /// interleave at frame granularity only.
    writer: Mutex<TcpStream>,
    /// Read half — held only by the thread currently playing reader.
    reader: Mutex<FrameReader>,
    pending: Mutex<Pending>,
    wakeup: Condvar,
}

impl Conn {
    fn open(addr: &str, epoch: u64) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
        let reader = stream.try_clone()?;
        // short read timeout: the polling reader wakes every tick to
        // re-check deadlines; FrameReader buffers partial frames across
        // ticks
        reader.set_read_timeout(Some(POLL)).ok();
        Ok(Conn {
            epoch,
            writer: Mutex::new(stream),
            reader: Mutex::new(FrameReader { stream: reader, buf: Vec::new() }),
            pending: Mutex::new(Pending {
                outstanding: HashSet::new(),
                ready: HashMap::new(),
                reader_active: false,
                dead: None,
            }),
            wakeup: Condvar::new(),
        })
    }

    fn is_dead(&self) -> bool {
        self.pending.lock().unwrap().dead.is_some()
    }

    /// Write one request frame under `id`. A write failure kills the
    /// connection (TCP gives no way to resync mid-frame) — but the
    /// frame provably never fully reached the kernel, so the caller may
    /// replay it on a fresh connection unconditionally.
    fn submit_msg(&self, id: u64, msg: &ClientMsg) -> Result<()> {
        {
            let mut g = self.pending.lock().unwrap();
            if let Some(d) = &g.dead {
                return Err(d.to_error());
            }
            g.outstanding.insert(id);
        }
        let payload = wire::encode_client_frame(id, msg);
        let mut w = self.writer.lock().unwrap();
        if let Err(e) = wire::write_frame(&mut *w, &payload) {
            drop(w);
            let mut g = self.pending.lock().unwrap();
            g.outstanding.remove(&id);
            if g.dead.is_none() {
                g.dead = Some(Dead::Gone(e.to_string()));
            }
            drop(g);
            self.wakeup.notify_all();
            return Err(e);
        }
        Ok(())
    }

    /// Block until the frame answering `id` arrives, the connection
    /// dies, the id turns out not to be in flight, or `deadline`
    /// passes. See the module docs for the cooperative-reader protocol.
    fn wait_msg(&self, id: u64, deadline: Option<Instant>) -> Result<ServerMsg> {
        let mut g = self.pending.lock().unwrap();
        loop {
            if let Some(m) = g.ready.remove(&id) {
                return Ok(m);
            }
            if let Some(d) = &g.dead {
                return Err(d.to_error());
            }
            if !g.outstanding.contains(&id) {
                return Err(D4mError::InvalidArg(format!(
                    "request id {id} is not in flight \
                     (never submitted, already claimed, or forgotten)"
                )));
            }
            if let Some(dl) = deadline {
                if Instant::now() >= dl {
                    // forget the id so its late reply is dropped, not
                    // parked forever
                    g.outstanding.remove(&id);
                    return Err(D4mError::Remote(format!(
                        "deadline exceeded waiting for reply to request id {id}"
                    )));
                }
            }
            if g.reader_active {
                // someone else is polling; they'll wake us when a frame
                // lands (maybe ours) — bounded wait so our deadline
                // stays live even if they stall
                let (g2, _) = self.wakeup.wait_timeout(g, POLL).unwrap();
                g = g2;
                continue;
            }
            // become the reader for everyone
            g.reader_active = true;
            drop(g);
            let polled = self.reader.lock().unwrap().poll();
            g = self.pending.lock().unwrap();
            g.reader_active = false;
            match polled {
                Ok(None) => {} // poll tick: loop re-checks deadline/death
                Ok(Some(payload)) => match wire::decode_server_frame(&payload) {
                    Ok((rid, msg)) if rid == wire::CONN_ERR_ID => {
                        // connection-level server error: fatal for all
                        // waits — but a shed stays typed so the healing
                        // layer knows nothing was executed
                        g.dead = Some(match msg {
                            ServerMsg::Reply(Err(D4mError::Overloaded { retry_after_ms })) => {
                                Dead::Overloaded { retry_after_ms }
                            }
                            ServerMsg::Reply(Err(e)) => Dead::Gone(e.to_string()),
                            other => Dead::Gone(format!(
                                "unattributed {} frame",
                                frame_name(&other)
                            )),
                        });
                    }
                    Ok((rid, msg)) => {
                        // park only frames someone can still claim; a
                        // reply to a forgotten id is dropped here
                        if g.outstanding.remove(&rid) {
                            g.ready.insert(rid, msg);
                        }
                    }
                    Err(we) => g.dead = Some(Dead::Gone(we.to_string())),
                },
                Err(e) => {
                    if g.dead.is_none() {
                        g.dead = Some(Dead::Gone(e.to_string()));
                    }
                }
            }
            self.wakeup.notify_all();
        }
    }

    fn forget(&self, id: u64) {
        let mut g = self.pending.lock().unwrap();
        g.outstanding.remove(&id);
        g.ready.remove(&id);
        // wake any thread currently waiting on this id so it errors out
        // instead of sleeping until the next frame happens to land
        self.wakeup.notify_all();
    }
}

/// Client-side cursor bookkeeping for reconnect resume.
struct CursorMeta {
    /// The server-issued resume token.
    token: u64,
    /// Pages successfully received by this client — the server replays
    /// the `pages_acked + 1`-th page if its reply was lost.
    pages_acked: u64,
    /// Connection epoch the cursor is currently attached on.
    epoch: u64,
}

/// A pipelined, self-healing connection to a remote `d4m serve`
/// coordinator (see the module docs for the retry/replay contract).
pub struct RemoteD4m {
    addr: String,
    policy: RetryPolicy,
    /// The current connection; `None` until (re)established. Swapped
    /// under this lock on reconnect.
    conn: Mutex<Option<Arc<Conn>>>,
    /// Next request id (ids start at 1; 0 is the server's
    /// connection-error id). Global across reconnects so a stale reply
    /// can never be claimed by a later request.
    next_id: AtomicU64,
    /// Next connection epoch.
    next_epoch: AtomicU64,
    ever_connected: AtomicBool,
    /// Jitter source for backoff.
    rng: Mutex<XorShift64>,
    /// Per-cursor resume state, keyed by server cursor id.
    cursors: Mutex<HashMap<u64, CursorMeta>>,
    retries: Counter,
    reconnects: Counter,
    cursor_resumes: Counter,
}

impl RemoteD4m {
    /// Connect to a serving coordinator (`"host:port"`), one attempt,
    /// with the default [`RetryPolicy`] governing subsequent requests.
    pub fn connect(addr: &str) -> Result<Self> {
        let c = Self::unconnected(addr, RetryPolicy::default());
        c.current()?;
        Ok(c)
    }

    /// Connect under an explicit policy; the *initial* connect is also
    /// retried within the policy's attempt budget (the CI/e2e readiness
    /// probe for a server process that is still binding its port).
    pub fn connect_with(addr: &str, policy: RetryPolicy) -> Result<Self> {
        let c = Self::unconnected(addr, policy);
        let mut attempt: u32 = 0;
        loop {
            attempt += 1;
            match c.current() {
                Ok(_) => return Ok(c),
                Err(e) => {
                    if attempt >= c.policy.max_attempts.max(1) {
                        return Err(e);
                    }
                    std::thread::sleep(c.backoff(attempt, None));
                }
            }
        }
    }

    /// Connect with retries — the old fixed-interval readiness probe.
    #[deprecated(note = "use connect_with(addr, RetryPolicy::probe(attempts, delay))")]
    pub fn connect_retry(addr: &str, attempts: u32, delay: Duration) -> Result<Self> {
        Self::connect_with(addr, RetryPolicy::probe(attempts, delay))
    }

    fn unconnected(addr: &str, policy: RetryPolicy) -> Self {
        let seed = policy.seed;
        RemoteD4m {
            addr: addr.to_string(),
            policy,
            conn: Mutex::new(None),
            next_id: AtomicU64::new(1),
            next_epoch: AtomicU64::new(1),
            ever_connected: AtomicBool::new(false),
            rng: Mutex::new(XorShift64::new(seed)),
            cursors: Mutex::new(HashMap::new()),
            retries: Counter::new(),
            reconnects: Counter::new(),
            cursor_resumes: Counter::new(),
        }
    }

    /// The policy this client heals under.
    pub fn policy(&self) -> &RetryPolicy {
        &self.policy
    }

    /// Attempts beyond the first across all typed calls.
    pub fn retry_count(&self) -> u64 {
        self.retries.get()
    }

    /// Connections established after the first.
    pub fn reconnect_count(&self) -> u64 {
        self.reconnects.get()
    }

    /// Cursors re-attached via a resume token after a reconnect.
    pub fn cursor_resume_count(&self) -> u64 {
        self.cursor_resumes.get()
    }

    /// Client-side healing counters in the same [`Snapshot`] shape the
    /// server's `stats` uses, so CLI output can print both uniformly.
    pub fn client_snapshots(&self) -> Vec<Snapshot> {
        [
            (crate::metrics::names::CLIENT_RETRIES, self.retries.get()),
            (crate::metrics::names::CLIENT_RECONNECTS, self.reconnects.get()),
            (crate::metrics::names::CLIENT_CURSOR_RESUMES, self.cursor_resumes.get()),
        ]
        .into_iter()
        .map(|(name, count)| Snapshot {
            name: name.into(),
            count,
            rate_per_sec: 0.0,
            mean_latency_ns: 0.0,
            p99_latency_ns: 0,
        })
        .collect()
    }

    // ------------------------------------------------------------------
    // connection management

    /// The live connection, (re)establishing one if needed. A fresh
    /// connection after the first counts as a reconnect.
    fn current(&self) -> Result<Arc<Conn>> {
        let mut g = self.conn.lock().unwrap();
        if let Some(c) = g.as_ref() {
            if !c.is_dead() {
                return Ok(c.clone());
            }
        }
        let epoch = self.next_epoch.fetch_add(1, Ordering::Relaxed);
        let conn = Arc::new(Conn::open(&self.addr, epoch)?);
        if self.ever_connected.swap(true, Ordering::Relaxed) {
            self.reconnects.inc();
        }
        *g = Some(conn.clone());
        Ok(conn)
    }

    /// Drop `conn` from the current slot if it died (another thread may
    /// already have reconnected; leave its connection alone).
    fn invalidate(&self, conn: &Arc<Conn>) {
        if !conn.is_dead() {
            return;
        }
        let mut g = self.conn.lock().unwrap();
        if let Some(cur) = g.as_ref() {
            if Arc::ptr_eq(cur, conn) {
                *g = None;
            }
        }
    }

    /// Backoff before retry number `attempt` (1-based), jittered,
    /// raised to at least a server `retry_after_ms` hint.
    fn backoff(&self, attempt: u32, hint_ms: Option<u64>) -> Duration {
        let shift = attempt.saturating_sub(1).min(16);
        let nominal = self
            .policy
            .base_delay
            .saturating_mul(1u32 << shift)
            .min(self.policy.max_delay);
        let jitter = 0.5 + self.rng.lock().unwrap().f64() * 0.5;
        let mut d = nominal.mul_f64(jitter);
        if let Some(ms) = hint_ms {
            d = d.max(Duration::from_millis(ms));
        }
        d
    }

    // ------------------------------------------------------------------
    // the healing driver

    /// One submit+wait on `conn`. On failure the second tuple slot says
    /// whether the frame may have reached the server (`true` = the
    /// write succeeded, so a non-idempotent request is now ambiguous).
    /// A typed server `Overloaded` reply is converted to a retryable
    /// failure here — the server sheds *before* doing any work, so it
    /// is never ambiguous.
    fn attempt(
        &self,
        conn: &Arc<Conn>,
        msg: &ClientMsg,
        deadline: Option<Instant>,
    ) -> std::result::Result<ServerMsg, (D4mError, bool)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if let Err(e) = conn.submit_msg(id, msg) {
            self.invalidate(conn);
            return Err((e, false));
        }
        match conn.wait_msg(id, deadline) {
            Ok(ServerMsg::Reply(Err(D4mError::Overloaded { retry_after_ms }))) => {
                Err((D4mError::Overloaded { retry_after_ms }, false))
            }
            Ok(m) => Ok(m),
            Err(e) => {
                self.invalidate(conn);
                Err((e, true))
            }
        }
    }

    /// Run `step` under the retry policy. `step` performs one attempt
    /// end to end and reports failures as `(error, may_have_sent)`;
    /// this driver decides whether a retry is safe (see the module
    /// docs), sleeps the backoff, and converts an exhausted budget into
    /// [`D4mError::RetryExhausted`].
    fn with_retry<T>(
        &self,
        idempotent: bool,
        step: &mut dyn FnMut(Option<Instant>) -> std::result::Result<T, (D4mError, bool)>,
    ) -> Result<T> {
        let deadline = self.policy.deadline.map(|d| Instant::now() + d);
        let mut attempt: u32 = 0;
        loop {
            attempt += 1;
            let (err, sent) = match step(deadline) {
                Ok(v) => return Ok(v),
                Err(pair) => pair,
            };
            let hint_ms = match &err {
                D4mError::Overloaded { retry_after_ms } => Some(*retry_after_ms),
                _ if is_transport(&err) => None,
                // typed server-side failure (NotFound, InvalidArg, …):
                // the request executed and failed; retrying cannot help
                _ => return Err(err),
            };
            if sent && hint_ms.is_none() && !idempotent {
                return Err(D4mError::AmbiguousWrite(err.to_string()));
            }
            let delay = self.backoff(attempt, hint_ms);
            let out_of_time = match deadline {
                Some(dl) => Instant::now() + delay >= dl,
                None => false,
            };
            if attempt >= self.policy.max_attempts.max(1) || out_of_time {
                return Err(D4mError::RetryExhausted { attempts: attempt, last: err.to_string() });
            }
            self.retries.inc();
            std::thread::sleep(delay);
        }
    }

    /// A whole typed request under the policy: fresh connection if
    /// needed, one attempt per loop turn.
    fn heal_rpc(&self, msg: &ClientMsg, idempotent: bool) -> Result<ServerMsg> {
        self.with_retry(idempotent, &mut |deadline| {
            let conn = self.current().map_err(|e| (e, false))?;
            self.attempt(&conn, msg, deadline)
        })
    }

    /// Re-attach `cursor` on `conn` if it is parked on an older
    /// connection epoch: send `OpenCursor { resume }` with the stored
    /// token and acked page count. The table/query/page_entries fields
    /// are ignored by the server on resume.
    fn reattach(
        &self,
        conn: &Arc<Conn>,
        cursor: u64,
        deadline: Option<Instant>,
    ) -> std::result::Result<(), (D4mError, bool)> {
        let resume = {
            let g = self.cursors.lock().unwrap();
            match g.get(&cursor) {
                Some(m) if m.epoch != conn.epoch => Some(CursorResume {
                    cursor,
                    token: m.token,
                    pages_acked: m.pages_acked,
                }),
                _ => None,
            }
        };
        let Some(r) = resume else { return Ok(()) };
        let msg = ClientMsg::OpenCursor {
            table: String::new(),
            query: TableQuery::all(),
            page_entries: 0,
            resume: Some(r),
        };
        match self.attempt(conn, &msg, deadline)? {
            ServerMsg::CursorOpened { cursor: cid, token } => {
                debug_assert_eq!(cid, cursor);
                self.cursor_resumes.inc();
                let mut g = self.cursors.lock().unwrap();
                if let Some(m) = g.get_mut(&cursor) {
                    m.epoch = conn.epoch;
                    m.token = token;
                }
                Ok(())
            }
            ServerMsg::Reply(Err(e)) => Err((e, true)),
            other => Err((unexpected_frame("CursorOpened", &other), true)),
        }
    }

    // ------------------------------------------------------------------
    // pipelining: submit / wait (raw, non-healing)

    /// Submit a coordinator request without waiting: the frame is written
    /// now and the returned id claims its response later via
    /// [`RemoteD4m::wait`]. Any number of requests may be in flight on
    /// the connection; the server answers them in completion order.
    /// Every submitted id should eventually be [`RemoteD4m::wait`]ed or
    /// [`RemoteD4m::forget`]ten — an id that is neither keeps its parked
    /// response buffered until the connection drops. This raw surface
    /// never retries and is pinned to the connection current at submit
    /// time.
    pub fn submit(&self, req: Request) -> Result<u64> {
        let conn = self.current()?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        conn.submit_msg(id, &ClientMsg::Api(req))?;
        Ok(id)
    }

    /// Claim the response to a previously [`RemoteD4m::submit`]ted id
    /// (block until its frame arrives). Each id is claimable exactly
    /// once; a wait on an id that is not in flight (never submitted,
    /// already claimed, forgotten, or lost with a replaced connection)
    /// fails with a typed error instead of hanging. Waiting threads
    /// cooperate — whoever waits first reads the socket for everyone.
    pub fn wait(&self, id: u64) -> Result<Response> {
        let conn = self.current()?;
        match conn.wait_msg(id, None)? {
            ServerMsg::Reply(r) => r,
            other => Err(unexpected_frame("Reply", &other)),
        }
    }

    /// Abandon a submitted id: its response, whether already parked or
    /// still to arrive, is discarded instead of buffered forever. Use on
    /// error paths that bail out of a pipelined window without claiming
    /// every id.
    pub fn forget(&self, id: u64) {
        let conn = self.conn.lock().unwrap().clone();
        if let Some(c) = conn {
            c.forget(id);
        }
    }

    // ------------------------------------------------------------------
    // admin verbs (not part of the coordinator API surface)

    /// Liveness + version probe: checks the server's `Pong` carries the
    /// wire version this client speaks, failing with a typed
    /// [`WireError::Version`] on skew. Heals like any idempotent call.
    pub fn ping(&self) -> Result<()> {
        match self.heal_rpc(&ClientMsg::Ping { version: wire::VERSION }, true)? {
            ServerMsg::Pong { version } if version == wire::VERSION => Ok(()),
            ServerMsg::Pong { version } => {
                Err(WireError::Version { got: version, want: wire::VERSION }.into())
            }
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("Pong", &other)),
        }
    }

    /// Remote metrics: the coordinator's per-op snapshots plus the
    /// server's net-layer counters (client-side healing counters are
    /// separate — [`RemoteD4m::client_snapshots`]).
    pub fn stats(&self) -> Result<Vec<Snapshot>> {
        match self.heal_rpc(&ClientMsg::Stats, true)? {
            ServerMsg::Stats(s) => Ok(s),
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("Stats", &other)),
        }
    }

    /// Ask the server to shut down gracefully; returns once
    /// acknowledged. Deliberately **not** healed: a lost ack would
    /// otherwise have the client retrying against a server that is
    /// already gone.
    pub fn shutdown_server(&self) -> Result<()> {
        let conn = self.current()?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        conn.submit_msg(id, &ClientMsg::Shutdown)?;
        match conn.wait_msg(id, None)? {
            ServerMsg::ShutdownAck => Ok(()),
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("ShutdownAck", &other)),
        }
    }
}

impl D4mApi for RemoteD4m {
    /// One request, one response, under the retry policy. Idempotent
    /// requests replay transparently across reconnects; a
    /// non-idempotent request that may have reached the server surfaces
    /// [`D4mError::AmbiguousWrite`]. For pipelined overlap use the raw
    /// `submit`/`wait` pair (which never retries).
    fn handle(&self, req: Request) -> Result<Response> {
        let idempotent = req.is_idempotent();
        match self.heal_rpc(&ClientMsg::Api(req), idempotent)? {
            ServerMsg::Reply(r) => r,
            other => Err(unexpected_frame("Reply", &other)),
        }
    }

    fn open_cursor(&self, table: &str, query: &TableQuery, page_entries: usize) -> Result<u64> {
        let msg = ClientMsg::OpenCursor {
            table: table.into(),
            query: query.clone(),
            page_entries: page_entries as u64,
            resume: None,
        };
        let mut epoch = 0u64;
        let reply = self.with_retry(true, &mut |deadline| {
            let conn = self.current().map_err(|e| (e, false))?;
            epoch = conn.epoch;
            self.attempt(&conn, &msg, deadline)
        })?;
        match reply {
            ServerMsg::CursorOpened { cursor, token } => {
                self.cursors
                    .lock()
                    .unwrap()
                    .insert(cursor, CursorMeta { token, pages_acked: 0, epoch });
                Ok(cursor)
            }
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("CursorOpened", &other)),
        }
    }

    fn cursor_next(&self, cursor: u64) -> Result<CursorPage> {
        let msg = ClientMsg::CursorNext { cursor };
        let reply = self.with_retry(true, &mut |deadline| {
            let conn = self.current().map_err(|e| (e, false))?;
            // if the cursor is parked on a dead connection's epoch,
            // re-attach with the resume token first — the server then
            // continues (or replays the one lost page) bit-identically
            self.reattach(&conn, cursor, deadline)?;
            self.attempt(&conn, &msg, deadline)
        })?;
        match reply {
            ServerMsg::CursorPage(page) => {
                if let Some(m) = self.cursors.lock().unwrap().get_mut(&cursor) {
                    m.pages_acked += 1;
                }
                Ok(page)
            }
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("CursorPage", &other)),
        }
    }

    fn open_plan_cursor(&self, ops: &[PlanOp], page_entries: usize) -> Result<u64> {
        let msg = ClientMsg::OpenPlanCursor {
            ops: ops.to_vec(),
            page_entries: page_entries as u64,
        };
        // a plan containing a Store writes server state, so replaying it
        // after an ambiguous send is not safe — same gate as handle()
        let idempotent = expr::plan_is_idempotent(ops);
        let mut epoch = 0u64;
        let reply = self.with_retry(idempotent, &mut |deadline| {
            let conn = self.current().map_err(|e| (e, false))?;
            epoch = conn.epoch;
            self.attempt(&conn, &msg, deadline)
        })?;
        match reply {
            ServerMsg::CursorOpened { cursor, token } => {
                self.cursors
                    .lock()
                    .unwrap()
                    .insert(cursor, CursorMeta { token, pages_acked: 0, epoch });
                Ok(cursor)
            }
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("CursorOpened", &other)),
        }
    }

    fn cursor_close(&self, cursor: u64) -> Result<()> {
        let msg = ClientMsg::CursorClose { cursor };
        let r = self.with_retry(true, &mut |deadline| {
            let conn = self.current().map_err(|e| (e, false))?;
            // re-own the cursor first, else the close is a NotFound no-op
            // and the server-side handle lingers until swept
            self.reattach(&conn, cursor, deadline)?;
            self.attempt(&conn, &msg, deadline)
        });
        self.cursors.lock().unwrap().remove(&cursor);
        match r? {
            ServerMsg::CursorClosed => Ok(()),
            ServerMsg::Reply(Err(e)) => Err(e),
            other => Err(unexpected_frame("CursorClosed", &other)),
        }
    }
}

/// Errors that indicate the transport (not the request) failed —
/// reconnect-and-retry is the right response when it is safe.
fn is_transport(e: &D4mError) -> bool {
    matches!(e, D4mError::Io(_) | D4mError::Remote(_) | D4mError::Wire(_))
}

fn unexpected_frame(expected: &str, msg: &ServerMsg) -> D4mError {
    D4mError::UnexpectedResponse { expected: expected.into(), got: frame_name(msg).into() }
}

fn frame_name(msg: &ServerMsg) -> &'static str {
    match msg {
        ServerMsg::Reply(_) => "Reply",
        ServerMsg::Pong { .. } => "Pong",
        ServerMsg::Stats(_) => "Stats",
        ServerMsg::ShutdownAck => "ShutdownAck",
        ServerMsg::CursorOpened { .. } => "CursorOpened",
        ServerMsg::CursorPage(_) => "CursorPage",
        ServerMsg::CursorClosed => "CursorClosed",
    }
}
