//! Fault-injection TCP proxy — a frame-aware chaos layer between a
//! `RemoteD4m` client and a `d4m serve` coordinator, so every network
//! failure mode the self-healing client must survive is **reproducible**:
//!
//! ```text
//! client ──► ChaosProxy(listen) ──► upstream d4m server
//! ```
//!
//! The proxy splits each direction into wire frames (same header codec
//! as [`wire`]) and consults a fault schedule per
//! `(connection, direction, frame index)`:
//!
//! * [`Fault::Cut`] — close both sockets *before* relaying the frame
//!   (the mid-flight connection drop);
//! * [`Fault::Truncate`] — relay only a prefix of the frame, then cut
//!   (the dribbled partial frame);
//! * [`Fault::Duplicate`] — relay the frame twice (a stale retransmit);
//! * [`Fault::CorruptByte`] — XOR one byte of the relayed frame (offset
//!   0 hits the magic, which the receiver is guaranteed to detect — the
//!   wire format carries no checksum, so payload corruption may pass
//!   silently; tests corrupt headers);
//! * [`Fault::Delay`] — sleep before relaying (latency spike).
//!
//! Faults come from an explicit script ([`ScriptedFault`], exact and
//! deterministic — what the chaos e2e tests use) and/or a seeded
//! probabilistic [`Profile`] (what the degraded bench and the CI chaos
//! leg use). Per-direction RNG streams are derived from
//! `(seed, connection, direction)`, so a given seed always produces the
//! same fault sequence for the same traffic shape.
//!
//! The proxy never originates frames and never reorders within a
//! direction; with an empty schedule it is a transparent relay (the
//! `Passthrough` profile), which the tests use to pin "proxy present,
//! no faults" as a bit-identical baseline.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::Result;
use crate::metrics::Counter;
use crate::net::wire;
use crate::util::rng::XorShift64;

/// How often relay threads re-check the shutdown flag while idle.
const POLL: Duration = Duration::from_millis(100);

/// Relay direction, relative to the proxied client.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    /// Client → server (requests).
    Up,
    /// Server → client (replies).
    Down,
}

/// One injectable fault, applied to a specific relayed frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Close both sides of the connection instead of relaying the frame.
    Cut,
    /// Relay only the first `bytes` of the raw frame, then cut.
    Truncate { bytes: usize },
    /// Relay the frame twice back to back.
    Duplicate,
    /// XOR the byte at `offset` (into the raw frame, header included)
    /// with `xor` before relaying.
    CorruptByte { offset: usize, xor: u8 },
    /// Sleep `ms` milliseconds before relaying the frame.
    Delay { ms: u64 },
}

/// A deterministic, scripted fault: applied to frame number `frame`
/// (0-based, counted per direction) of connection number `conn`
/// (0-based, in accept order).
#[derive(Debug, Clone, Copy)]
pub struct ScriptedFault {
    pub conn: u64,
    pub dir: Dir,
    pub frame: u64,
    pub fault: Fault,
}

/// Seeded probabilistic fault mix, drawn independently per frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Profile {
    /// Transparent relay (scripted faults still apply).
    Passthrough,
    /// Cut the connection at a frame with probability `rate`.
    Drop { rate: f64 },
    /// Delay a frame `ms` with probability `rate`.
    Delay { rate: f64, ms: u64 },
    /// Corrupt a frame's magic byte with probability `rate` (always
    /// detected by the receiver).
    Corrupt { rate: f64 },
    /// Uniform mix of cut / delay / corrupt / duplicate, each frame
    /// faulted with probability `rate`.
    Mixed { rate: f64 },
}

impl Profile {
    /// Parse a CLI profile name. `rate`/`ms` parameterize it.
    pub fn parse(name: &str, rate: f64, ms: u64) -> Option<Profile> {
        match name {
            "passthrough" | "none" => Some(Profile::Passthrough),
            "drop" => Some(Profile::Drop { rate }),
            "delay" => Some(Profile::Delay { rate, ms }),
            "corrupt" => Some(Profile::Corrupt { rate }),
            "mixed" => Some(Profile::Mixed { rate }),
            _ => None,
        }
    }
}

/// Proxy configuration.
#[derive(Debug, Clone)]
pub struct ChaosOpts {
    /// Seed for the per-direction fault RNG streams.
    pub seed: u64,
    /// Probabilistic fault mix (on top of any scripted faults).
    pub profile: Profile,
    /// Exact faults for specific frames (tests).
    pub scripted: Vec<ScriptedFault>,
}

impl Default for ChaosOpts {
    fn default() -> Self {
        ChaosOpts { seed: 0xC4A0_5EED, profile: Profile::Passthrough, scripted: Vec::new() }
    }
}

/// Counters observable while the proxy runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosStats {
    /// Connections accepted.
    pub conns: u64,
    /// Frames relayed (both directions, pre-fault).
    pub frames: u64,
    /// Faults injected.
    pub faults: u64,
}

struct Shared {
    upstream: String,
    opts: ChaosOpts,
    /// `(conn, dir, frame)` → scripted faults for that frame.
    script: HashMap<(u64, Dir, u64), Vec<Fault>>,
    addr: SocketAddr,
    shutdown: AtomicBool,
    conns: Counter,
    frames: Counter,
    faults: Counter,
}

/// A running fault-injection proxy; dropping it shuts it down.
pub struct ChaosProxy {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl ChaosProxy {
    /// Listen on `listen` (port 0 picks an ephemeral port) and relay
    /// every accepted connection to `upstream` under the fault schedule.
    pub fn start(listen: &str, upstream: &str, opts: ChaosOpts) -> Result<ChaosProxy> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let mut script: HashMap<(u64, Dir, u64), Vec<Fault>> = HashMap::new();
        for s in &opts.scripted {
            script.entry((s.conn, s.dir, s.frame)).or_default().push(s.fault);
        }
        let shared = Arc::new(Shared {
            upstream: upstream.to_string(),
            opts,
            script,
            addr,
            shutdown: AtomicBool::new(false),
            conns: Counter::new(),
            frames: Counter::new(),
            faults: Counter::new(),
        });
        let sh = shared.clone();
        let accept = std::thread::Builder::new()
            .name("d4m-chaos-accept".into())
            .spawn(move || accept_loop(listener, sh))?;
        Ok(ChaosProxy { shared, accept: Some(accept) })
    }

    /// The proxy's listen address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Current relay/fault counters.
    pub fn stats(&self) -> ChaosStats {
        ChaosStats {
            conns: self.shared.conns.get(),
            frames: self.shared.frames.get(),
            faults: self.shared.faults.get(),
        }
    }

    /// Stop accepting, cut every live relay, and join all threads.
    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // poke the blocking accept with a loopback connect
        let mut poke = self.shared.addr;
        if poke.ip().is_unspecified() {
            poke.set_ip(match poke.ip() {
                std::net::IpAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                std::net::IpAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
            });
        }
        let _ = TcpStream::connect_timeout(&poke, Duration::from_secs(2));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown();
        }
    }
}

fn accept_loop(listener: TcpListener, sh: Arc<Shared>) {
    let mut relays: Vec<JoinHandle<()>> = Vec::new();
    let mut conn_idx: u64 = 0;
    for conn in listener.incoming() {
        if sh.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let client = match conn {
            Ok(s) => s,
            Err(_) => {
                std::thread::sleep(POLL);
                continue;
            }
        };
        let server = match TcpStream::connect(&sh.upstream) {
            Ok(s) => s,
            Err(_) => continue, // upstream down: drop the client socket
        };
        client.set_nodelay(true).ok();
        server.set_nodelay(true).ok();
        sh.conns.inc();
        let conn = conn_idx;
        conn_idx += 1;
        // two half-duplex relays; a fault in either direction cuts both
        // sockets, so the peer sees a dead connection promptly
        for (dir, src, dst) in [
            (Dir::Up, client.try_clone(), server.try_clone()),
            (Dir::Down, server.try_clone(), client.try_clone()),
        ] {
            let (src, dst) = match (src, dst) {
                (Ok(s), Ok(d)) => (s, d),
                _ => break,
            };
            let sh = sh.clone();
            if let Ok(h) = std::thread::Builder::new()
                .name("d4m-chaos-relay".into())
                .spawn(move || relay(src, dst, conn, dir, &sh))
            {
                relays.push(h);
            }
        }
    }
    // relay threads notice the flag within one poll tick
    for h in relays {
        let _ = h.join();
    }
}

/// Relay one direction frame by frame, injecting faults. Returns when
/// either socket dies, a cut fault fires, or the proxy shuts down.
fn relay(src: TcpStream, mut dst: TcpStream, conn: u64, dir: Dir, sh: &Shared) {
    src.set_read_timeout(Some(POLL)).ok();
    dst.set_write_timeout(Some(Duration::from_secs(30))).ok();
    let salt = match dir {
        Dir::Up => 0x55,
        Dir::Down => 0xAA,
    };
    let mut rng =
        XorShift64::new(sh.opts.seed ^ conn.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ salt);
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 64 * 1024];
    let mut frame_idx: u64 = 0;
    // set when a header fails to parse (should not happen with our own
    // endpoints): fall back to a dumb byte pipe rather than stalling
    let mut passthrough = false;
    loop {
        while !passthrough {
            let frame = match take_frame(&mut buf) {
                Ok(Some(f)) => f,
                Ok(None) => break,
                Err(()) => {
                    passthrough = true;
                    if !buf.is_empty() && dst.write_all(&buf).is_err() {
                        cut(&src, &dst);
                        return;
                    }
                    buf.clear();
                    break;
                }
            };
            sh.frames.inc();
            let idx = frame_idx;
            frame_idx += 1;
            if !forward(&frame, &mut dst, conn, dir, idx, sh, &mut rng) {
                cut(&src, &dst);
                return;
            }
        }
        if sh.shutdown.load(Ordering::SeqCst) {
            cut(&src, &dst);
            return;
        }
        match (&src).read(&mut chunk) {
            Ok(0) => {
                // peer hung up cleanly: flush nothing (partial frames
                // die with the connection) and propagate the close
                cut(&src, &dst);
                return;
            }
            Ok(n) => {
                if passthrough {
                    if dst.write_all(&chunk[..n]).is_err() {
                        cut(&src, &dst);
                        return;
                    }
                } else {
                    buf.extend_from_slice(&chunk[..n]);
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => {
                cut(&src, &dst);
                return;
            }
        }
    }
}

/// Pop one complete raw frame (header + payload) off `buf`, if present.
/// `Err(())` means the header is not a valid frame (degrade to a dumb
/// pipe).
fn take_frame(buf: &mut Vec<u8>) -> std::result::Result<Option<Vec<u8>>, ()> {
    if buf.len() < wire::HEADER_LEN {
        return Ok(None);
    }
    let mut header = [0u8; wire::HEADER_LEN];
    header.copy_from_slice(&buf[..wire::HEADER_LEN]);
    let len = wire::frame_payload_len(&header).map_err(|_| ())?;
    let total = wire::HEADER_LEN + len;
    if buf.len() < total {
        return Ok(None);
    }
    let frame = buf[..total].to_vec();
    buf.drain(..total);
    Ok(Some(frame))
}

/// Apply this frame's faults and write it (or don't). Returns false
/// when the connection must be cut.
fn forward(
    frame: &[u8],
    dst: &mut TcpStream,
    conn: u64,
    dir: Dir,
    idx: u64,
    sh: &Shared,
    rng: &mut XorShift64,
) -> bool {
    let mut faults: Vec<Fault> = sh.script.get(&(conn, dir, idx)).cloned().unwrap_or_default();
    if let Some(f) = draw(&sh.opts.profile, rng) {
        faults.push(f);
    }
    if faults.is_empty() {
        return dst.write_all(frame).is_ok();
    }
    sh.faults.add(faults.len() as u64);
    // delays first (they compose with whatever happens to the frame)
    for f in &faults {
        if let Fault::Delay { ms } = f {
            std::thread::sleep(Duration::from_millis(*ms));
        }
    }
    if faults.iter().any(|f| matches!(f, Fault::Cut)) {
        return false;
    }
    if let Some(Fault::Truncate { bytes }) = faults
        .iter()
        .find(|f| matches!(f, Fault::Truncate { .. }))
        .copied()
    {
        let n = bytes.min(frame.len());
        let _ = dst.write_all(&frame[..n]);
        return false;
    }
    let mut out = frame.to_vec();
    for f in &faults {
        if let Fault::CorruptByte { offset, xor } = f {
            let at = offset % out.len();
            out[at] ^= xor;
        }
    }
    let copies = 1 + faults.iter().filter(|f| matches!(f, Fault::Duplicate)).count();
    for _ in 0..copies {
        if dst.write_all(&out).is_err() {
            return false;
        }
    }
    true
}

/// One probabilistic fault draw for a frame.
fn draw(profile: &Profile, rng: &mut XorShift64) -> Option<Fault> {
    match *profile {
        Profile::Passthrough => None,
        Profile::Drop { rate } => rng.chance(rate).then_some(Fault::Cut),
        Profile::Delay { rate, ms } => rng.chance(rate).then_some(Fault::Delay { ms }),
        Profile::Corrupt { rate } => {
            rng.chance(rate).then_some(Fault::CorruptByte { offset: 0, xor: 0xFF })
        }
        Profile::Mixed { rate } => {
            if !rng.chance(rate) {
                return None;
            }
            Some(match rng.below(4) {
                0 => Fault::Cut,
                1 => Fault::Delay { ms: 20 },
                2 => Fault::CorruptByte { offset: 0, xor: 0xFF },
                _ => Fault::Duplicate,
            })
        }
    }
}

/// Kill both sides of a relayed connection; the paired relay thread's
/// next read fails and it exits too.
fn cut(a: &TcpStream, b: &TcpStream) {
    let _ = a.shutdown(Shutdown::Both);
    let _ = b.shutdown(Shutdown::Both);
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn take_frame_splits_and_rejects() {
        let payload = b"hello".to_vec();
        let mut raw = Vec::new();
        raw.extend_from_slice(&wire::MAGIC);
        raw.push(wire::VERSION);
        raw.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        raw.extend_from_slice(&payload);

        // partial header, partial payload, then the whole frame
        let mut buf = raw[..4].to_vec();
        assert_eq!(take_frame(&mut buf), Ok(None));
        buf = raw[..10].to_vec();
        assert_eq!(take_frame(&mut buf), Ok(None));
        buf = raw.clone();
        buf.extend_from_slice(&raw); // two frames back to back
        let f1 = take_frame(&mut buf).unwrap().unwrap();
        assert_eq!(f1, raw);
        let f2 = take_frame(&mut buf).unwrap().unwrap();
        assert_eq!(f2, raw);
        assert!(buf.is_empty());

        // corrupt magic → not a frame
        let mut bad = raw.clone();
        bad[0] ^= 0xFF;
        assert_eq!(take_frame(&mut bad), Err(()));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn profile_draws_are_deterministic_per_seed() {
        let draws = |seed: u64| {
            let mut rng = XorShift64::new(seed);
            (0..64)
                .map(|_| draw(&Profile::Mixed { rate: 0.3 }, &mut rng))
                .collect::<Vec<_>>()
        };
        assert_eq!(draws(42), draws(42));
        assert_ne!(draws(42), draws(43));
        assert!(draws(42).iter().any(|f| f.is_some()));
        assert!(draws(42).iter().any(|f| f.is_none()));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn profile_parse_covers_cli_names() {
        assert_eq!(Profile::parse("none", 0.5, 5), Some(Profile::Passthrough));
        assert_eq!(Profile::parse("drop", 0.5, 5), Some(Profile::Drop { rate: 0.5 }));
        assert_eq!(Profile::parse("delay", 0.5, 5), Some(Profile::Delay { rate: 0.5, ms: 5 }));
        assert_eq!(Profile::parse("corrupt", 0.5, 5), Some(Profile::Corrupt { rate: 0.5 }));
        assert_eq!(Profile::parse("mixed", 0.5, 5), Some(Profile::Mixed { rate: 0.5 }));
        assert_eq!(Profile::parse("bogus", 0.5, 5), None);
    }
}
