//! Network front-end over the [`coordinator`](crate::coordinator) — the
//! paper's client↔server split, realised as three std-only layers:
//!
//! * [`wire`] — length-prefixed binary frame codec (versioned magic
//!   header, varint/length-prefixed encodings, typed decode errors).
//! * [`server`] — a `TcpListener` accept loop sharing one
//!   `Arc<D4mServer>` across a bounded thread-per-connection pool, with
//!   graceful shutdown and per-connection error framing.
//! * [`client`] — [`RemoteD4m`], whose API mirrors `D4mServer::handle`
//!   so in-process call sites run remote by swapping the constructor.
//!
//! `d4m serve --addr HOST:PORT` exposes the server from the CLI and
//! `d4m client --addr HOST:PORT <cmd>` drives it; `rust/tests/net_e2e.rs`
//! pins that remote answers are bit-identical to in-process ones, and
//! `benches/net.rs` records the loopback round-trip and concurrent
//! remote-scan trajectory into `BENCH_net.json`.

pub mod client;
pub mod server;
pub mod wire;

pub use client::RemoteD4m;
pub use server::{serve, NetHandle, NetOpts};
pub use wire::{WireError, WireResult};
