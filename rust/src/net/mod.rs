//! Network front-end over the [`coordinator`](crate::coordinator) — the
//! paper's client↔server split, realised as three std-only layers:
//!
//! * [`wire`] — length-prefixed binary frame codec, **v4**: every frame
//!   carries a client-assigned request id (responses may complete out of
//!   order), cursor messages stream scan results in bounded pages,
//!   compiled plans travel as `Request::Plan`/`OpenPlanCursor` (and are
//!   SSA-revalidated at decode), and version skew surfaces as a typed
//!   [`WireError::Version`] before any payload is read.
//! * [`server`] — a `TcpListener` accept loop sharing one
//!   `Arc<D4mServer>` across a bounded thread-per-connection pool; each
//!   connection is a demux (one reader + bounded workers) so N pipelined
//!   requests from one connection execute concurrently, with
//!   per-connection cursor ownership, orphan-on-disconnect into a
//!   resume-grace window, and load shedding (typed `Overloaded` with a
//!   retry hint) when the pool saturates.
//! * [`client`] — [`RemoteD4m`], a pipelined **self-healing** client
//!   implementing the [`D4mApi`](crate::coordinator::D4mApi) trait, so
//!   call sites written against the in-process coordinator go remote by
//!   swapping the constructor; typed calls retry under a [`RetryPolicy`]
//!   (backoff + jitter + deadline), reconnect transparently, resume
//!   cursors, and refuse to double-apply non-idempotent writes;
//!   `submit()`/`wait(id)` expose the raw pipelining directly.
//!
//! A fourth layer, [`chaos`], is a frame-aware fault-injection proxy
//! (seeded, deterministic schedules: cuts, delays, duplicates,
//! truncations, corruption) that sits between client and server so the
//! client's healing — retry with backoff, reconnect, cursor resume —
//! is exercised reproducibly (`rust/tests/chaos_e2e.rs`, the `degraded`
//! bench leg, and `d4m chaos` from the CLI).
//!
//! `d4m serve --addr HOST:PORT` exposes the server from the CLI and
//! `d4m client --addr HOST:PORT <cmd>` drives it (including
//! `pipeline-bench` and `scan-pages`); `rust/tests/net_e2e.rs` pins that
//! remote answers are bit-identical to in-process ones, and
//! `benches/net.rs` records the round-trip, pipelined, paged-scan and
//! degraded trajectories into `BENCH_net.json`.

pub mod chaos;
pub mod client;
pub mod server;
pub mod wire;

pub use chaos::{ChaosOpts, ChaosProxy, Fault, Profile, ScriptedFault};
pub use client::{RemoteD4m, RetryPolicy};
pub use server::{serve, NetHandle, NetOpts};
pub use wire::{WireError, WireResult};
