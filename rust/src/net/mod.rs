//! Network front-end over the [`coordinator`](crate::coordinator) — the
//! paper's client↔server split, realised as three std-only layers:
//!
//! * [`wire`] — length-prefixed binary frame codec, **v2**: every frame
//!   carries a client-assigned request id (responses may complete out of
//!   order), cursor messages stream scan results in bounded pages, and
//!   version skew surfaces as a typed [`WireError::Version`] before any
//!   payload is read.
//! * [`server`] — a `TcpListener` accept loop sharing one
//!   `Arc<D4mServer>` across a bounded thread-per-connection pool; each
//!   connection is a demux (one reader + bounded workers) so N pipelined
//!   requests from one connection execute concurrently, with
//!   per-connection cursor ownership and reap-on-disconnect.
//! * [`client`] — [`RemoteD4m`], a pipelined client implementing the
//!   [`D4mApi`](crate::coordinator::D4mApi) trait, so call sites written
//!   against the in-process coordinator go remote by swapping the
//!   constructor; `submit()`/`wait(id)` expose the pipelining directly
//!   and `scan_pages` lazily pulls cursor pages.
//!
//! `d4m serve --addr HOST:PORT` exposes the server from the CLI and
//! `d4m client --addr HOST:PORT <cmd>` drives it (including
//! `pipeline-bench` and `scan-pages`); `rust/tests/net_e2e.rs` pins that
//! remote answers are bit-identical to in-process ones, and
//! `benches/net.rs` records the round-trip, pipelined and paged-scan
//! trajectories into `BENCH_net.json`.

pub mod client;
pub mod server;
pub mod wire;

pub use client::RemoteD4m;
pub use server::{serve, NetHandle, NetOpts};
pub use wire::{WireError, WireResult};
