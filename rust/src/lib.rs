//! # D4M 3.0 — Dynamic Distributed Dimensional Data Model
//!
//! A from-scratch reproduction of the D4M 3.0 system (Milechin et al.,
//! 2017) as a three-layer Rust + JAX + Pallas stack:
//!
//! * [`assoc`] — associative arrays, the mathematical core: string-keyed
//!   sparse arrays with an algebra of union-add, intersection-multiply and
//!   key-aligned matrix multiply, plus [`assoc::expr`] — the lazy
//!   expression language whose compiled plans execute server-side in one
//!   round trip.
//! * [`kvstore`] — an embedded Accumulo-class sorted key-value store with
//!   tablets, LSM write path and the server-side iterator framework.
//! * [`arraystore`] — a SciDB-class chunked array store with in-store ops.
//! * [`relational`] — a PostGRES/MySQL-class typed-column engine.
//! * [`connectors`] — D4M database bindings behind one object-safe
//!   [`DbServer`]/[`DbTable`] trait surface: the D4M 2.0 Accumulo schema,
//!   SciDB and SQL connectors, assoc ⇄ engine translation, selector
//!   pushdown ([`TableQuery`]) and paged scans.
//! * [`graphulo`] — in-database GraphBLAS: server-side TableMult (SpGEMM),
//!   BFS, Jaccard and k-truss, plus client-side reference versions.
//! * [`pipeline`] — the streaming ingest orchestrator (sharding, bounded
//!   queues with backpressure, parallel batch writers).
//! * [`polystore`] — BigDAWG-style islands with CAST through assoc arrays.
//! * [`runtime`] — the native dense engine: in-crate cache-blocked f64
//!   GEMM, parallel over row tiles, on the dense-block hot path.
//! * [`coordinator`] — the D4M server: table registry, request routing,
//!   op batching, scan cursors, metrics — behind the object-safe
//!   [`D4mApi`] trait both the in-process server and the remote client
//!   implement.
//! * [`net`] — the network front-end: request-id (v2) wire codec, a
//!   per-connection demux TCP server over the coordinator, and the
//!   pipelined [`RemoteD4m`] client (`submit`/`wait`, streaming
//!   `scan_pages`).
//!
//! See DESIGN.md for the paper-to-module inventory and EXPERIMENTS.md for
//! reproduction results.

pub mod arraystore;
pub mod assoc;
pub mod connectors;
pub mod coordinator;
pub mod error;
pub mod gen;
pub mod graphulo;
pub mod kvstore;
pub mod metrics;
pub mod net;
pub mod pipeline;
pub mod polystore;
pub mod relational;
pub mod runtime;
pub mod util;

pub use assoc::expr::{Plan, PlanOp};
pub use assoc::{Assoc, KeySel};
pub use connectors::{BindOpts, DbServer, DbTable, TableQuery};
pub use coordinator::{D4mApi, ExecHint, MultDest, PlanStats, ScanPages};
pub use error::{D4mError, Result};
pub use net::RemoteD4m;
