//! Workload generators: Graph500-style Kronecker power-law graphs,
//! Erdős–Rényi graphs, and random D4M-schema triples. All deterministic
//! given a seed — every benchmark row in EXPERIMENTS.md is reproducible.

use crate::assoc::Assoc;
use crate::util::XorShift64;

/// Graph500 Kronecker generator parameters (R-MAT a/b/c/d = .57/.19/.19/.05).
#[derive(Debug, Clone, Copy)]
pub struct KroneckerParams {
    /// log2 of vertex count.
    pub scale: u32,
    /// Edges per vertex.
    pub edge_factor: u32,
    pub seed: u64,
}

impl KroneckerParams {
    pub fn new(scale: u32, edge_factor: u32, seed: u64) -> Self {
        KroneckerParams { scale, edge_factor, seed }
    }

    pub fn num_vertices(&self) -> u64 {
        1u64 << self.scale
    }

    pub fn num_edges(&self) -> u64 {
        self.num_vertices() * self.edge_factor as u64
    }
}

/// Generate Kronecker (R-MAT) edges as `(src, dst)` vertex ids.
/// Follows the Graph500 reference recursion with per-level noise.
pub fn kronecker_edges(p: &KroneckerParams) -> Vec<(u64, u64)> {
    const A: f64 = 0.57;
    const B: f64 = 0.19;
    const C: f64 = 0.19;
    let mut rng = XorShift64::new(p.seed);
    let m = p.num_edges();
    let mut edges = Vec::with_capacity(m as usize);
    for _ in 0..m {
        let (mut src, mut dst) = (0u64, 0u64);
        for level in 0..p.scale {
            let bit = 1u64 << (p.scale - 1 - level);
            let r = rng.f64();
            if r < A {
                // (0, 0)
            } else if r < A + B {
                dst |= bit;
            } else if r < A + B + C {
                src |= bit;
            } else {
                src |= bit;
                dst |= bit;
            }
        }
        edges.push((src, dst));
    }
    edges
}

/// Format a vertex id as a D4M key with fixed width (sortable).
pub fn vertex_key(v: u64) -> String {
    format!("v{v:010}")
}

/// Kronecker graph as `(row, col, "1")` string triples (the exploded-edge
/// form D4M ingests).
pub fn kronecker_triples(p: &KroneckerParams) -> Vec<(String, String, String)> {
    kronecker_edges(p)
        .into_iter()
        .map(|(s, d)| (vertex_key(s), vertex_key(d), "1".to_string()))
        .collect()
}

/// Kronecker graph as an unweighted adjacency [`Assoc`] (duplicate edges
/// collapse to their count; self-loops retained, as in Graph500).
pub fn kronecker_assoc(p: &KroneckerParams) -> Assoc {
    let t: Vec<(String, String, f64)> = kronecker_edges(p)
        .into_iter()
        .map(|(s, d)| (vertex_key(s), vertex_key(d), 1.0))
        .collect();
    Assoc::from_triples(&t)
}

/// Erdős–Rényi G(n, m) adjacency as an [`Assoc`].
pub fn erdos_renyi_assoc(n: u64, m: u64, seed: u64) -> Assoc {
    let mut rng = XorShift64::new(seed);
    let t: Vec<(String, String, f64)> = (0..m)
        .map(|_| (vertex_key(rng.below(n)), vertex_key(rng.below(n)), 1.0))
        .collect();
    Assoc::from_triples(&t)
}

/// Random document-like D4M-schema triples: `(doc id, word|<w>, count)`.
/// This is the unstructured-text workload the D4M intro motivates.
pub fn doc_word_triples(
    num_docs: u64,
    words_per_doc: u64,
    vocab: u64,
    seed: u64,
) -> Vec<(String, String, String)> {
    let mut rng = XorShift64::new(seed);
    let mut out = Vec::with_capacity((num_docs * words_per_doc) as usize);
    for d in 0..num_docs {
        for _ in 0..words_per_doc {
            // zipf-ish skew: square the uniform to favour low word ids
            let u = rng.f64();
            let w = ((u * u) * vocab as f64) as u64;
            out.push((
                format!("doc{d:08}"),
                format!("word|w{w:06}"),
                format!("{}", rng.below(5) + 1),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn kronecker_edge_count_and_range() {
        let p = KroneckerParams::new(8, 4, 42);
        let e = kronecker_edges(&p);
        assert_eq!(e.len(), (1 << 8) * 4);
        assert!(e.iter().all(|&(s, d)| s < 256 && d < 256));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn kronecker_deterministic() {
        let p = KroneckerParams::new(6, 4, 7);
        assert_eq!(kronecker_edges(&p), kronecker_edges(&p));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn kronecker_is_skewed() {
        // power-law: max out-degree far above mean
        let p = KroneckerParams::new(10, 16, 1);
        let a = kronecker_assoc(&p);
        let deg = a.sum(2);
        let max = deg.triples().iter().map(|t| t.2).fold(0.0, f64::max);
        let mean = p.num_edges() as f64 / a.row_keys().len() as f64;
        assert!(
            max > 4.0 * mean,
            "expected skew: max {max} vs mean {mean}"
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn vertex_keys_sortable() {
        assert!(vertex_key(2) < vertex_key(10)); // zero-padded
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn erdos_renyi_shape() {
        let a = erdos_renyi_assoc(64, 256, 3);
        assert!(a.nnz() <= 256);
        assert!(a.nnz() > 128); // few collisions at this density
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn doc_word_schema() {
        let t = doc_word_triples(4, 8, 100, 5);
        assert_eq!(t.len(), 32);
        assert!(t.iter().all(|(_, c, _)| c.starts_with("word|")));
    }
}
