//! A tablet: the unit of storage and serving. LSM-style — an in-memory
//! memtable plus immutable sorted runs, flushed and compacted by size
//! thresholds, scanned through the server-side iterator stack.
//!
//! §Perf (EXPERIMENTS.md): the memtable is an **append-only vector,
//! sorted lazily** at scan/flush time rather than a BTreeMap. Writes are
//! a push (~50 ns) instead of an ordered-map insert (~1 µs); the sort
//! cost is paid once per flush/scan, where it is cache-friendly. This is
//! the single-core analogue of Accumulo's lock-free skiplist memtable.
//! Compaction is size-tiered: only the smaller runs merge, so total
//! compaction work stays O(n log n) instead of the quadratic re-merging
//! of a naive merge-all policy.
//!
//! §Reads: scans are **snapshot-isolated and streaming**. The sorted
//! runs are `Arc`-shared frozen segments and the memtable has a cached
//! sorted view, so [`Tablet::snapshot`] is a handful of `Arc` clones
//! from `&self` (plus one clone+sort of the memtable on the first read
//! after a write — amortised across readers by the cache). Everything
//! downstream of the snapshot — the k-way merge, versioning, combiners,
//! filters — runs pull-based over the frozen segments with **no tablet
//! lock held**, so long analytics scans never serialise against writers
//! or other readers. See DESIGN.md §Snapshot/streaming read path.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::sync::{Arc, Mutex};

use super::iterator::{EntryStream, IterConfig, MergeIter};
use super::key::{Entry, RowRange};
use super::storage::DiskRun;

/// One frozen, immutable segment of a tablet: an in-memory sorted run
/// (`Arc`-shared with snapshots) or an on-disk frozen run read lazily
/// through its sparse index. Both expose the same pull-based cursor
/// shape, so the merge/iterator stack upstream never knows the
/// difference — this is the seam the durable engine plugs into.
#[derive(Debug, Clone)]
pub enum Segment {
    Mem(Arc<Vec<Entry>>),
    Disk(Arc<DiskRun>),
}

impl Segment {
    pub fn len(&self) -> usize {
        match self {
            Segment::Mem(r) => r.len(),
            Segment::Disk(d) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes — on-disk segments keep only their index in memory.
    fn mem_bytes(&self) -> usize {
        match self {
            Segment::Mem(r) => r.iter().map(Entry::bytes).sum(),
            Segment::Disk(_) => 0,
        }
    }

    /// Lazy sorted cursor over the rows of `range`.
    pub fn cursor(&self, range: &RowRange) -> EntryStream {
        match self {
            Segment::Mem(r) => Box::new(RunCursor::new(r.clone(), range)),
            Segment::Disk(d) => Box::new(d.cursor(range)),
        }
    }

    /// Stored entries (all versions) whose row falls in `range`.
    fn count_in(&self, range: &RowRange) -> usize {
        match self {
            Segment::Mem(r) => {
                let (lo, hi) = slice_bounds(r, range);
                hi - lo
            }
            Segment::Disk(d) => d.count_in(range),
        }
    }

    /// Append this segment's distinct row keys in `range` to `out`
    /// (consecutive-deduped; the caller merges across segments).
    fn append_row_keys(&self, range: &RowRange, out: &mut Vec<String>) {
        match self {
            Segment::Mem(r) => {
                let mut last: Option<&str> = None;
                for e in slice_range(r, range) {
                    if last != Some(e.key.row.as_str()) {
                        out.push(e.key.row.clone());
                        last = Some(e.key.row.as_str());
                    }
                }
            }
            Segment::Disk(d) => d.row_keys_in(range, out),
        }
    }

    fn as_disk(&self) -> Option<&Arc<DiskRun>> {
        match self {
            Segment::Disk(d) => Some(d),
            Segment::Mem(_) => None,
        }
    }
}

/// Tuning knobs for tablets (defaults sized for tests; benches override).
#[derive(Debug, Clone)]
pub struct TabletConfig {
    /// Flush the memtable to a sorted run when it exceeds this many bytes.
    pub memtable_flush_bytes: usize,
    /// Merge small runs when their count exceeds this.
    pub max_runs: usize,
}

impl Default for TabletConfig {
    fn default() -> Self {
        TabletConfig { memtable_flush_bytes: 4 << 20, max_runs: 8 }
    }
}

/// One tablet of a table.
#[derive(Debug)]
pub struct Tablet {
    /// Append-only buffer; `sorted_upto` marks the prefix already in key
    /// order (sorted lazily on flush).
    memtable: Vec<Entry>,
    sorted_upto: usize,
    memtable_bytes: usize,
    /// Immutable sorted runs, newest first; `Arc`-shared with snapshots.
    /// In-memory tablets hold only `Segment::Mem` runs; durable tablets
    /// hold `Segment::Disk` (plus a transient `Mem` while a checkpoint
    /// is writing the run file — see `freeze_memtable`).
    runs: Vec<Segment>,
    /// Cached sorted view of the memtable for `&self` snapshots.
    /// Writers invalidate it (via `get_mut`, no lock traffic); the first
    /// subsequent snapshot rebuilds it once and later snapshots share
    /// the `Arc`. The interior mutex is held only while cloning an
    /// `Arc` or building the view — never while a scan is consumed.
    mem_view: Mutex<Option<Arc<Vec<Entry>>>>,
    config: TabletConfig,
    /// Counters for introspection/benchmarks.
    pub flushes: u64,
    pub compactions: u64,
}

impl Tablet {
    pub fn new(config: TabletConfig) -> Self {
        Tablet {
            memtable: Vec::new(),
            sorted_upto: 0,
            memtable_bytes: 0,
            runs: Vec::new(),
            mem_view: Mutex::new(None),
            config,
            flushes: 0,
            compactions: 0,
        }
    }

    /// Insert one entry (server-side write path). O(1) amortised.
    pub fn put(&mut self, entry: Entry) {
        self.memtable_bytes += entry.bytes();
        self.memtable.push(entry);
        *self.mem_view.get_mut().unwrap() = None;
        if self.memtable_bytes >= self.config.memtable_flush_bytes {
            self.flush();
        }
    }

    /// Sort the memtable if it has an unsorted suffix. Stable sort keeps
    /// first-written entries first among exact key ties (same cell+ts);
    /// Key order already places newer timestamps first.
    fn ensure_sorted(&mut self) {
        if self.sorted_upto < self.memtable.len() {
            self.memtable.sort_by(|a, b| a.key.cmp(&b.key));
            self.sorted_upto = self.memtable.len();
        }
    }

    /// Force the memtable into a sorted run.
    pub fn flush(&mut self) {
        if self.memtable.is_empty() {
            return;
        }
        let cached = self.mem_view.get_mut().unwrap().take();
        let run = match cached {
            // a snapshot since the last write already sorted exactly
            // these entries — adopt its view as the frozen run instead
            // of sorting the memtable a second time
            Some(v) if v.len() == self.memtable.len() => {
                self.memtable.clear();
                v
            }
            _ => {
                self.ensure_sorted();
                Arc::new(std::mem::take(&mut self.memtable))
            }
        };
        self.sorted_upto = 0;
        self.memtable_bytes = 0;
        self.runs.insert(0, Segment::Mem(run));
        self.flushes += 1;
        if self.runs.len() > self.config.max_runs {
            self.compact();
        }
    }

    /// Durable write path: freeze the memtable as a `Segment::Mem` run
    /// and return the frozen `Arc`, so checkpoint can write the run file
    /// *outside* the tablet lock while readers keep seeing the entries.
    /// Once the file is durable, `replace_mem_with_disk` swaps it in.
    /// No compaction here — merging durable tablets is the disk
    /// compactor's job.
    pub(crate) fn freeze_memtable(&mut self) -> Option<Arc<Vec<Entry>>> {
        if self.memtable.is_empty() {
            return None;
        }
        let cached = self.mem_view.get_mut().unwrap().take();
        let run = match cached {
            Some(v) if v.len() == self.memtable.len() => {
                self.memtable.clear();
                v
            }
            _ => {
                self.ensure_sorted();
                Arc::new(std::mem::take(&mut self.memtable))
            }
        };
        self.sorted_upto = 0;
        self.memtable_bytes = 0;
        self.runs.insert(0, Segment::Mem(run.clone()));
        self.flushes += 1;
        Some(run)
    }

    /// Swap the frozen in-memory run installed by `freeze_memtable` for
    /// its now-durable on-disk twin (matched by `Arc` identity). Open
    /// snapshots keep their `Mem` reference; new snapshots read the file.
    pub(crate) fn replace_mem_with_disk(&mut self, mem: &Arc<Vec<Entry>>, disk: Arc<DiskRun>) {
        for s in &mut self.runs {
            if let Segment::Mem(m) = s {
                if Arc::ptr_eq(m, mem) {
                    *s = Segment::Disk(disk);
                    return;
                }
            }
        }
        debug_assert!(false, "frozen run vanished before its disk swap");
    }

    /// Install recovered on-disk runs (recovery only; replaces nothing).
    pub(crate) fn set_disk_runs(&mut self, runs: Vec<Arc<DiskRun>>) {
        debug_assert!(self.runs.is_empty() && self.memtable.is_empty());
        self.runs = runs.into_iter().map(Segment::Disk).collect();
    }

    /// The tablet's on-disk runs, newest first.
    pub(crate) fn disk_runs(&self) -> Vec<Arc<DiskRun>> {
        self.runs.iter().filter_map(|s| s.as_disk().cloned()).collect()
    }

    /// Replace the disk runs named by `victim_ids` with one merged run
    /// (disk compaction install step). Returns `false` — installing
    /// nothing — unless *every* victim is still present, which guards
    /// against racing table mutations between plan and install.
    pub(crate) fn swap_disk_runs(&mut self, victim_ids: &[u64], merged: Arc<DiskRun>) -> bool {
        let found = self
            .runs
            .iter()
            .filter(|s| matches!(s.as_disk(), Some(d) if victim_ids.contains(&d.file_id())))
            .count();
        if found != victim_ids.len() {
            return false;
        }
        self.runs
            .retain(|s| !matches!(s.as_disk(), Some(d) if victim_ids.contains(&d.file_id())));
        // merged data is the oldest layer among survivors: append last
        self.runs.push(Segment::Disk(merged));
        self.compactions += 1;
        true
    }

    /// Current (unflushed) memtable size in bytes.
    pub(crate) fn memtable_bytes(&self) -> usize {
        self.memtable_bytes
    }

    /// Size-tiered compaction: merge the smallest runs together until at
    /// most `max_runs / 2` remain, leaving large runs untouched (no
    /// quadratic re-merging of the big ones). Frozen runs an open
    /// snapshot still holds stay alive through their `Arc`s — compaction
    /// replaces the tablet's references, never the segments themselves.
    pub fn compact(&mut self) {
        let keep = (self.config.max_runs / 2).max(1);
        if self.runs.len() <= keep {
            return;
        }
        // sort runs by size; merge everything except the `keep` largest
        self.runs.sort_by_key(|r| std::cmp::Reverse(r.len()));
        let small: Vec<Segment> = self.runs.split_off(keep);
        let sources: Vec<EntryStream> = small.into_iter().map(into_entry_iter).collect();
        let merged: Vec<Entry> = MergeIter::new(sources).collect();
        self.runs.push(Segment::Mem(Arc::new(merged)));
        // restore newest-first-ish ordering guarantee is not needed for
        // correctness (versioning is by timestamp, not layer), but keep
        // deterministic order for tests
        self.runs.sort_by_key(|r| std::cmp::Reverse(r.len()));
        self.compactions += 1;
    }

    /// Merge *everything* into one run, dropping superseded versions
    /// (major compaction; useful before scan-heavy phases).
    pub fn compact_major(&mut self) {
        self.ensure_sorted();
        let mut sources: Vec<EntryStream> = Vec::new();
        if !self.memtable.is_empty() {
            let mem = std::mem::take(&mut self.memtable);
            self.sorted_upto = 0;
            self.memtable_bytes = 0;
            sources.push(Box::new(mem.into_iter()));
        }
        *self.mem_view.get_mut().unwrap() = None;
        for r in std::mem::take(&mut self.runs) {
            sources.push(into_entry_iter(r));
        }
        let merged: Vec<Entry> =
            super::iterator::VersioningIter::new(MergeIter::new(sources)).collect();
        self.runs = vec![Segment::Mem(Arc::new(merged))];
        self.compactions += 1;
    }

    /// Number of stored entries across memtable + runs (before versioning).
    pub fn raw_len(&self) -> usize {
        self.memtable.len() + self.runs.iter().map(|r| r.len()).sum::<usize>()
    }

    /// Approximate resident bytes (on-disk segments count nothing —
    /// only their sparse index lives in memory).
    pub fn mem_bytes(&self) -> usize {
        self.memtable_bytes + self.runs.iter().map(Segment::mem_bytes).sum::<usize>()
    }

    /// Freeze the tablet's current contents into an immutable,
    /// cheaply-clonable snapshot. This is the only read-path operation
    /// that needs the tablet lock; everything after it is lock-free.
    pub fn snapshot(&self) -> TabletSnapshot {
        let mut cache = self.mem_view.lock().unwrap();
        let mem = cache
            .get_or_insert_with(|| {
                let mut v = self.memtable.clone();
                // stable sort: first-written entries stay first among
                // exact key ties, matching `ensure_sorted`
                v.sort_by(|a, b| a.key.cmp(&b.key));
                Arc::new(v)
            })
            .clone();
        drop(cache);
        TabletSnapshot { mem, runs: self.runs.clone() }
    }

    /// Materialising scan — a thin `collect()` over [`Tablet::scan_stream`],
    /// kept for tests and small point reads.
    pub fn scan(&self, range: &RowRange, cfg: &IterConfig) -> Vec<Entry> {
        self.scan_stream(range, cfg).collect()
    }

    /// Streaming scan: snapshot acquisition plus a lazy iterator stack.
    /// The returned stream owns its segments (`'static`) — the caller
    /// can drop the tablet lock before pulling a single entry.
    pub fn scan_stream(&self, range: &RowRange, cfg: &IterConfig) -> EntryStream {
        self.snapshot().scan(range, cfg)
    }

    /// Key-only scan: distinct row keys stored in `range`, sorted
    /// ascending. Walks the snapshot's segments as sorted slices — no
    /// k-way merge, no iterator stack — so enumerating the rows of a
    /// paged scan costs one `String` clone per (segment × distinct row)
    /// instead of a full materialising scan. Rows whose cells are all
    /// tombstoned may still be reported (versioning is the per-page
    /// fetch's job); downstream pagination skips their empty pages.
    pub fn row_keys_in(&self, range: &RowRange) -> Vec<String> {
        // the snapshot's cached sorted memtable view restores
        // binary-searched range bounds on every source (the cache is
        // warm after the first read since the last write)
        self.snapshot().row_keys_in(range)
    }
}

/// Immutable point-in-time view of one tablet: the frozen runs plus a
/// sorted memtable view, all `Arc`-shared. Cloning is O(#runs) pointer
/// copies; scans over it never touch the owning tablet again.
#[derive(Debug, Clone)]
pub struct TabletSnapshot {
    mem: Arc<Vec<Entry>>,
    runs: Vec<Segment>,
}

impl TabletSnapshot {
    /// Scan a row range through the server-side iterator stack,
    /// pull-based: entries are cloned out of the frozen segments (or
    /// read block-at-a-time from disk segments) one at a time as the
    /// consumer advances, never into an owned `Vec`.
    pub fn scan(&self, range: &RowRange, cfg: &IterConfig) -> EntryStream {
        let mut sources: Vec<EntryStream> = Vec::with_capacity(1 + self.runs.len());
        // memtable view first: lowest source index wins exact key ties
        sources.push(Box::new(RunCursor::new(self.mem.clone(), range)));
        for run in &self.runs {
            sources.push(run.cursor(range));
        }
        cfg.apply(Box::new(MergeIter::new(sources)))
    }

    /// Stored entries in the snapshot (all versions, before the stack).
    pub fn raw_len(&self) -> usize {
        self.mem.len() + self.runs.iter().map(Segment::len).sum::<usize>()
    }

    /// Stored entries falling inside `range` (all versions) — binary
    /// searched per in-memory segment and index-counted per on-disk
    /// segment, so sizing a scan stays cheap in every layer.
    pub fn raw_len_in(&self, range: &RowRange) -> usize {
        let (lo, hi) = slice_bounds(&self.mem, range);
        (hi - lo) + self.runs.iter().map(|s| s.count_in(range)).sum::<usize>()
    }

    /// Distinct row keys stored in `range`, sorted ascending. Each
    /// segment is sorted, so per-segment consecutive dedup is exact; no
    /// values are cloned and no iterator stack runs. Rows whose cells
    /// are all tombstoned may still be reported.
    pub fn row_keys_in(&self, range: &RowRange) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        let mut last: Option<&str> = None;
        for e in slice_range(&self.mem, range) {
            if last != Some(e.key.row.as_str()) {
                out.push(e.key.row.clone());
                last = Some(e.key.row.as_str());
            }
        }
        for run in &self.runs {
            run.append_row_keys(range, &mut out);
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Lazy cursor over the `[lo, hi)` row-range slice of one frozen
/// segment; clones entries on demand as the merge pulls them.
struct RunCursor {
    run: Arc<Vec<Entry>>,
    pos: usize,
    end: usize,
}

impl RunCursor {
    fn new(run: Arc<Vec<Entry>>, range: &RowRange) -> Self {
        let (pos, end) = slice_bounds(&run, range);
        RunCursor { run, pos, end }
    }
}

impl Iterator for RunCursor {
    type Item = Entry;

    fn next(&mut self) -> Option<Entry> {
        if self.pos >= self.end {
            return None;
        }
        let e = self.run[self.pos].clone();
        self.pos += 1;
        Some(e)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.end - self.pos;
        (n, Some(n))
    }
}

/// Turn a frozen segment into an owned entry iterator: moves the
/// entries when this was the last reference to an in-memory run, falls
/// back to a cloning cursor when an open snapshot still shares it, and
/// streams a disk segment through its block cursor.
fn into_entry_iter(seg: Segment) -> EntryStream {
    match seg {
        Segment::Mem(run) => match Arc::try_unwrap(run) {
            Ok(v) => Box::new(v.into_iter()),
            Err(shared) => {
                let end = shared.len();
                Box::new(RunCursor { run: shared, pos: 0, end })
            }
        },
        Segment::Disk(d) => Box::new(d.cursor(&RowRange::all())),
    }
}

/// Binary-search the `[lo, hi)` index bounds of a sorted run covered by
/// a row range.
fn slice_bounds(run: &[Entry], range: &RowRange) -> (usize, usize) {
    let lo = match &range.start {
        Some(s) => run.partition_point(|e| e.key.row.as_str() < s.as_str()),
        None => 0,
    };
    let hi = match &range.end {
        Some(e) => run.partition_point(|x| x.key.row.as_str() < e.as_str()),
        None => run.len(),
    };
    (lo, hi)
}

/// Binary-search the sub-slice of a sorted run covered by a row range.
fn slice_range<'a>(run: &'a [Entry], range: &RowRange) -> &'a [Entry] {
    let (lo, hi) = slice_bounds(run, range);
    &run[lo..hi]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::key::Key;

    fn small_config() -> TabletConfig {
        TabletConfig { memtable_flush_bytes: 256, max_runs: 2 }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn put_and_scan() {
        let mut t = Tablet::new(TabletConfig::default());
        t.put(Entry::new(Key::cell("r2", "c1", 2), "b"));
        t.put(Entry::new(Key::cell("r1", "c1", 1), "a"));
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].key.row, "r1"); // sorted on scan
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn scan_range_bounds() {
        let mut t = Tablet::new(TabletConfig::default());
        for r in ["d", "a", "c", "b"] {
            t.put(Entry::new(Key::cell(r, "c", 1), "v"));
        }
        let out = t.scan(&RowRange::span("b", "d"), &IterConfig::default());
        let rows: Vec<&str> = out.iter().map(|e| e.key.row.as_str()).collect();
        assert_eq!(rows, vec!["b", "c"]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn versioning_across_flushes() {
        let mut t = Tablet::new(small_config());
        t.put(Entry::new(Key::cell("r", "c", 1), "old"));
        t.flush();
        t.put(Entry::new(Key::cell("r", "c", 2), "new"));
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "new");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn summing_across_flushes() {
        let mut t = Tablet::new(small_config());
        t.put(Entry::new(Key::cell("r", "c", 1), "3"));
        t.flush();
        t.put(Entry::new(Key::cell("r", "c", 2), "4"));
        let cfg = IterConfig { summing: true, ..Default::default() };
        let out = t.scan(&RowRange::all(), &cfg);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "7");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn auto_flush_and_compact() {
        let mut t = Tablet::new(small_config());
        for i in 0..200 {
            t.put(Entry::new(Key::cell(format!("row{i:04}"), "c", i), "value"));
        }
        assert!(t.flushes > 0, "expected auto-flushes");
        assert!(t.compactions > 0, "expected compactions");
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 200);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn tiered_compaction_leaves_large_runs() {
        let mut t = Tablet::new(TabletConfig { memtable_flush_bytes: usize::MAX, max_runs: 2 });
        // one big run
        for i in 0..1000 {
            t.put(Entry::new(Key::cell(format!("big{i:05}"), "c", i), "v"));
        }
        t.flush();
        let big_len = t.runs[0].len();
        // several small runs to trigger tiered merges
        for batch in 0..6 {
            for i in 0..10 {
                t.put(Entry::new(
                    Key::cell(format!("small{batch}{i:03}"), "c", 10_000 + batch * 10 + i),
                    "v",
                ));
            }
            t.flush();
        }
        // the big run must still exist untouched
        assert!(t.runs.iter().any(|r| r.len() == big_len), "big run was re-merged");
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 1060);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn compact_major_single_run_newest() {
        let mut t = Tablet::new(small_config());
        t.put(Entry::new(Key::cell("r", "c", 1), "old"));
        t.flush();
        t.put(Entry::new(Key::cell("r", "c", 2), "new"));
        t.compact_major();
        assert_eq!(t.runs.len(), 1);
        assert!(t.memtable.is_empty());
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "new");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn row_keys_in_distinct_sorted_across_layers() {
        let mut t = Tablet::new(small_config());
        // spread rows across a flushed run and the live memtable, with
        // multiple cells and versions per row
        t.put(Entry::new(Key::cell("b", "c1", 1), "x"));
        t.put(Entry::new(Key::cell("d", "c1", 2), "x"));
        t.flush();
        t.put(Entry::new(Key::cell("a", "c1", 3), "x"));
        t.put(Entry::new(Key::cell("b", "c2", 4), "x"));
        t.put(Entry::new(Key::cell("b", "c1", 5), "newer"));
        assert_eq!(t.row_keys_in(&RowRange::all()), vec!["a", "b", "d"]);
        assert_eq!(t.row_keys_in(&RowRange::span("b", "d")), vec!["b"]);
        // key-only scan agrees with the materialising scan's row set
        let full: Vec<String> = {
            let mut rows: Vec<String> = t
                .scan(&RowRange::all(), &IterConfig::default())
                .into_iter()
                .map(|e| e.key.row)
                .collect();
            rows.dedup();
            rows
        };
        assert_eq!(t.row_keys_in(&RowRange::all()), full);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn interleaved_write_scan_write() {
        let mut t = Tablet::new(TabletConfig::default());
        t.put(Entry::new(Key::cell("b", "c", 1), "1"));
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 1);
        t.put(Entry::new(Key::cell("a", "c", 2), "2"));
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out[0].key.row, "a"); // resorted after the new write
        assert_eq!(out.len(), 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn snapshot_isolated_from_later_writes() {
        let mut t = Tablet::new(small_config());
        t.put(Entry::new(Key::cell("a", "c", 1), "1"));
        t.flush();
        t.put(Entry::new(Key::cell("b", "c", 2), "2"));
        let snap = t.snapshot();
        // mutate after the snapshot: new write, delete, flush, compact
        t.put(Entry::new(Key::cell("c", "c", 3), "3"));
        t.put(Entry::delete(Key::cell("a", "c", 4)));
        t.flush();
        t.compact_major();
        // the snapshot still reads the frozen state
        let out: Vec<Entry> = snap.scan(&RowRange::all(), &IterConfig::default()).collect();
        let rows: Vec<&str> = out.iter().map(|e| e.key.row.as_str()).collect();
        assert_eq!(rows, vec!["a", "b"]);
        assert_eq!(out[0].value, "1");
        // while a fresh scan sees the mutations
        let now = t.scan(&RowRange::all(), &IterConfig::default());
        let rows: Vec<&str> = now.iter().map(|e| e.key.row.as_str()).collect();
        assert_eq!(rows, vec!["b", "c"]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn snapshot_memview_cache_shared_until_write() {
        let mut t = Tablet::new(TabletConfig::default());
        t.put(Entry::new(Key::cell("a", "c", 1), "1"));
        let s1 = t.snapshot();
        let s2 = t.snapshot();
        assert!(Arc::ptr_eq(&s1.mem, &s2.mem), "cache should share the sorted view");
        t.put(Entry::new(Key::cell("b", "c", 2), "2"));
        let s3 = t.snapshot();
        assert!(!Arc::ptr_eq(&s1.mem, &s3.mem), "write must invalidate the view");
        assert_eq!(s3.raw_len(), 2);
        assert_eq!(s1.raw_len(), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn stream_is_lazy_and_matches_collect() {
        let mut t = Tablet::new(small_config());
        for i in 0..50 {
            t.put(Entry::new(Key::cell(format!("r{i:03}"), "c", i), "v"));
        }
        t.flush();
        for i in 50..80 {
            t.put(Entry::new(Key::cell(format!("r{i:03}"), "c", i), "v"));
        }
        let collected = t.scan(&RowRange::all(), &IterConfig::default());
        let mut stream = t.scan_stream(&RowRange::all(), &IterConfig::default());
        // pull a prefix, then write — the stream must be unaffected
        let first = stream.next().unwrap();
        t.put(Entry::new(Key::cell("aaa", "c", 999), "new"));
        let rest: Vec<Entry> = stream.collect();
        assert_eq!(first, collected[0]);
        assert_eq!(rest, collected[1..]);
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        use std::sync::atomic::{AtomicU64, Ordering};
        static N: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "d4m-tablet-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn freeze_then_disk_swap_keeps_readers_whole() {
        let dir = tmp_dir("freeze");
        let mut t = Tablet::new(TabletConfig { memtable_flush_bytes: usize::MAX, max_runs: 8 });
        for i in 0..50u64 {
            t.put(Entry::new(Key::cell(format!("r{i:03}"), "c", i + 1), "v"));
        }
        let reference = t.scan(&RowRange::all(), &IterConfig::default());
        // freeze: entries move from memtable to a Mem segment — scans
        // must see them throughout
        let frozen = t.freeze_memtable().expect("memtable was non-empty");
        assert!(t.memtable.is_empty());
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()), reference);
        let pre_swap = t.snapshot();
        // write the run file and swap it in by Arc identity
        let disk = DiskRun::create(&dir, 1, &frozen).unwrap();
        t.replace_mem_with_disk(&frozen, disk);
        assert!(matches!(t.runs[0], Segment::Disk(_)));
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()), reference);
        // the snapshot taken mid-protocol still reads its Mem segment
        let got: Vec<Entry> = pre_swap.scan(&RowRange::all(), &IterConfig::default()).collect();
        assert_eq!(got, reference);
        // and the freeze is idempotent on an empty memtable
        assert!(t.freeze_memtable().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn mixed_mem_and_disk_segments_merge_transparently() {
        let dir = tmp_dir("mixed");
        let mut t = Tablet::new(TabletConfig { memtable_flush_bytes: usize::MAX, max_runs: 8 });
        // old version on disk, new version in the memtable
        t.put(Entry::new(Key::cell("r", "c", 1), "old"));
        let frozen = t.freeze_memtable().unwrap();
        let disk = DiskRun::create(&dir, 1, &frozen).unwrap();
        t.replace_mem_with_disk(&frozen, disk);
        t.put(Entry::new(Key::cell("r", "c", 2), "new"));
        t.put(Entry::new(Key::cell("s", "c", 3), "7"));
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].value, "new");
        // summing combines across the disk/mem boundary
        t.put(Entry::new(Key::cell("s", "c", 4), "5"));
        let cfg = IterConfig { summing: true, ..Default::default() };
        let summed = t.scan(&RowRange::single("s"), &cfg);
        assert_eq!(summed[0].value, "12");
        // row keys and counts agree across segment kinds
        assert_eq!(t.row_keys_in(&RowRange::all()), vec!["r", "s"]);
        assert_eq!(t.snapshot().raw_len_in(&RowRange::all()), 4);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn swap_disk_runs_requires_all_victims() {
        let dir = tmp_dir("swap");
        let mut t = Tablet::new(TabletConfig { memtable_flush_bytes: usize::MAX, max_runs: 2 });
        let mut all = Vec::new();
        for (id, row) in [(1u64, "a"), (2, "b"), (3, "c")] {
            t.put(Entry::new(Key::cell(row, "c", id), "v"));
            all.push(Entry::new(Key::cell(row, "c", id), "v"));
            let frozen = t.freeze_memtable().unwrap();
            let disk = DiskRun::create(&dir, id, &frozen).unwrap();
            t.replace_mem_with_disk(&frozen, disk);
        }
        // a stale plan naming a missing victim installs nothing
        let merged = DiskRun::create(&dir, 10, &all).unwrap();
        assert!(!t.swap_disk_runs(&[1, 99], merged.clone()));
        assert_eq!(t.disk_runs().len(), 3);
        // a valid plan replaces exactly its victims
        assert!(t.swap_disk_runs(&[1, 2], merged));
        let ids: Vec<u64> = t.disk_runs().iter().map(|d| d.file_id()).collect();
        assert_eq!(ids, vec![3, 10]);
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 3);
        assert_eq!(t.compactions, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
