//! A tablet: the unit of storage and serving. LSM-style — an in-memory
//! memtable plus immutable sorted runs, flushed and compacted by size
//! thresholds, scanned through the server-side iterator stack.
//!
//! §Perf (EXPERIMENTS.md): the memtable is an **append-only vector,
//! sorted lazily** at scan/flush time rather than a BTreeMap. Writes are
//! a push (~50 ns) instead of an ordered-map insert (~1 µs); the sort
//! cost is paid once per flush/scan, where it is cache-friendly. This is
//! the single-core analogue of Accumulo's lock-free skiplist memtable.
//! Compaction is size-tiered: only the smaller runs merge, so total
//! compaction work stays O(n log n) instead of the quadratic re-merging
//! of a naive merge-all policy.
//!
//! §Reads: scans are **snapshot-isolated and streaming**. The sorted
//! runs are `Arc`-shared frozen segments and the memtable has a cached
//! sorted view, so [`Tablet::snapshot`] is a handful of `Arc` clones
//! from `&self` (plus one clone+sort of the memtable on the first read
//! after a write — amortised across readers by the cache). Everything
//! downstream of the snapshot — the k-way merge, versioning, combiners,
//! filters — runs pull-based over the frozen segments with **no tablet
//! lock held**, so long analytics scans never serialise against writers
//! or other readers. See DESIGN.md §Snapshot/streaming read path.

use std::sync::{Arc, Mutex};

use super::iterator::{EntryStream, IterConfig, MergeIter};
use super::key::{Entry, RowRange};

/// Tuning knobs for tablets (defaults sized for tests; benches override).
#[derive(Debug, Clone)]
pub struct TabletConfig {
    /// Flush the memtable to a sorted run when it exceeds this many bytes.
    pub memtable_flush_bytes: usize,
    /// Merge small runs when their count exceeds this.
    pub max_runs: usize,
}

impl Default for TabletConfig {
    fn default() -> Self {
        TabletConfig { memtable_flush_bytes: 4 << 20, max_runs: 8 }
    }
}

/// One tablet of a table.
#[derive(Debug)]
pub struct Tablet {
    /// Append-only buffer; `sorted_upto` marks the prefix already in key
    /// order (sorted lazily on flush).
    memtable: Vec<Entry>,
    sorted_upto: usize,
    memtable_bytes: usize,
    /// Immutable sorted runs, newest first; `Arc`-shared with snapshots.
    runs: Vec<Arc<Vec<Entry>>>,
    /// Cached sorted view of the memtable for `&self` snapshots.
    /// Writers invalidate it (via `get_mut`, no lock traffic); the first
    /// subsequent snapshot rebuilds it once and later snapshots share
    /// the `Arc`. The interior mutex is held only while cloning an
    /// `Arc` or building the view — never while a scan is consumed.
    mem_view: Mutex<Option<Arc<Vec<Entry>>>>,
    config: TabletConfig,
    /// Counters for introspection/benchmarks.
    pub flushes: u64,
    pub compactions: u64,
}

impl Tablet {
    pub fn new(config: TabletConfig) -> Self {
        Tablet {
            memtable: Vec::new(),
            sorted_upto: 0,
            memtable_bytes: 0,
            runs: Vec::new(),
            mem_view: Mutex::new(None),
            config,
            flushes: 0,
            compactions: 0,
        }
    }

    /// Insert one entry (server-side write path). O(1) amortised.
    pub fn put(&mut self, entry: Entry) {
        self.memtable_bytes += entry.bytes();
        self.memtable.push(entry);
        *self.mem_view.get_mut().unwrap() = None;
        if self.memtable_bytes >= self.config.memtable_flush_bytes {
            self.flush();
        }
    }

    /// Sort the memtable if it has an unsorted suffix. Stable sort keeps
    /// first-written entries first among exact key ties (same cell+ts);
    /// Key order already places newer timestamps first.
    fn ensure_sorted(&mut self) {
        if self.sorted_upto < self.memtable.len() {
            self.memtable.sort_by(|a, b| a.key.cmp(&b.key));
            self.sorted_upto = self.memtable.len();
        }
    }

    /// Force the memtable into a sorted run.
    pub fn flush(&mut self) {
        if self.memtable.is_empty() {
            return;
        }
        let cached = self.mem_view.get_mut().unwrap().take();
        let run = match cached {
            // a snapshot since the last write already sorted exactly
            // these entries — adopt its view as the frozen run instead
            // of sorting the memtable a second time
            Some(v) if v.len() == self.memtable.len() => {
                self.memtable.clear();
                v
            }
            _ => {
                self.ensure_sorted();
                Arc::new(std::mem::take(&mut self.memtable))
            }
        };
        self.sorted_upto = 0;
        self.memtable_bytes = 0;
        self.runs.insert(0, run);
        self.flushes += 1;
        if self.runs.len() > self.config.max_runs {
            self.compact();
        }
    }

    /// Size-tiered compaction: merge the smallest runs together until at
    /// most `max_runs / 2` remain, leaving large runs untouched (no
    /// quadratic re-merging of the big ones). Frozen runs an open
    /// snapshot still holds stay alive through their `Arc`s — compaction
    /// replaces the tablet's references, never the segments themselves.
    pub fn compact(&mut self) {
        let keep = (self.config.max_runs / 2).max(1);
        if self.runs.len() <= keep {
            return;
        }
        // sort runs by size; merge everything except the `keep` largest
        self.runs.sort_by_key(|r| std::cmp::Reverse(r.len()));
        let small: Vec<Arc<Vec<Entry>>> = self.runs.split_off(keep);
        let sources: Vec<EntryStream> = small.into_iter().map(into_entry_iter).collect();
        let merged: Vec<Entry> = MergeIter::new(sources).collect();
        self.runs.push(Arc::new(merged));
        // restore newest-first-ish ordering guarantee is not needed for
        // correctness (versioning is by timestamp, not layer), but keep
        // deterministic order for tests
        self.runs.sort_by_key(|r| std::cmp::Reverse(r.len()));
        self.compactions += 1;
    }

    /// Merge *everything* into one run, dropping superseded versions
    /// (major compaction; useful before scan-heavy phases).
    pub fn compact_major(&mut self) {
        self.ensure_sorted();
        let mut sources: Vec<EntryStream> = Vec::new();
        if !self.memtable.is_empty() {
            let mem = std::mem::take(&mut self.memtable);
            self.sorted_upto = 0;
            self.memtable_bytes = 0;
            sources.push(Box::new(mem.into_iter()));
        }
        *self.mem_view.get_mut().unwrap() = None;
        for r in std::mem::take(&mut self.runs) {
            sources.push(into_entry_iter(r));
        }
        let merged: Vec<Entry> =
            super::iterator::VersioningIter::new(MergeIter::new(sources)).collect();
        self.runs = vec![Arc::new(merged)];
        self.compactions += 1;
    }

    /// Number of stored entries across memtable + runs (before versioning).
    pub fn raw_len(&self) -> usize {
        self.memtable.len() + self.runs.iter().map(|r| r.len()).sum::<usize>()
    }

    /// Approximate resident bytes.
    pub fn mem_bytes(&self) -> usize {
        self.memtable_bytes
            + self
                .runs
                .iter()
                .map(|r| r.iter().map(Entry::bytes).sum::<usize>())
                .sum::<usize>()
    }

    /// Freeze the tablet's current contents into an immutable,
    /// cheaply-clonable snapshot. This is the only read-path operation
    /// that needs the tablet lock; everything after it is lock-free.
    pub fn snapshot(&self) -> TabletSnapshot {
        let mut cache = self.mem_view.lock().unwrap();
        let mem = cache
            .get_or_insert_with(|| {
                let mut v = self.memtable.clone();
                // stable sort: first-written entries stay first among
                // exact key ties, matching `ensure_sorted`
                v.sort_by(|a, b| a.key.cmp(&b.key));
                Arc::new(v)
            })
            .clone();
        drop(cache);
        TabletSnapshot { mem, runs: self.runs.clone() }
    }

    /// Materialising scan — a thin `collect()` over [`Tablet::scan_stream`],
    /// kept for tests and small point reads.
    pub fn scan(&self, range: &RowRange, cfg: &IterConfig) -> Vec<Entry> {
        self.scan_stream(range, cfg).collect()
    }

    /// Streaming scan: snapshot acquisition plus a lazy iterator stack.
    /// The returned stream owns its segments (`'static`) — the caller
    /// can drop the tablet lock before pulling a single entry.
    pub fn scan_stream(&self, range: &RowRange, cfg: &IterConfig) -> EntryStream {
        self.snapshot().scan(range, cfg)
    }

    /// Key-only scan: distinct row keys stored in `range`, sorted
    /// ascending. Walks the snapshot's segments as sorted slices — no
    /// k-way merge, no iterator stack — so enumerating the rows of a
    /// paged scan costs one `String` clone per (segment × distinct row)
    /// instead of a full materialising scan. Rows whose cells are all
    /// tombstoned may still be reported (versioning is the per-page
    /// fetch's job); downstream pagination skips their empty pages.
    pub fn row_keys_in(&self, range: &RowRange) -> Vec<String> {
        // the snapshot's cached sorted memtable view restores
        // binary-searched range bounds on every source (the cache is
        // warm after the first read since the last write)
        self.snapshot().row_keys_in(range)
    }
}

/// Immutable point-in-time view of one tablet: the frozen runs plus a
/// sorted memtable view, all `Arc`-shared. Cloning is O(#runs) pointer
/// copies; scans over it never touch the owning tablet again.
#[derive(Debug, Clone)]
pub struct TabletSnapshot {
    mem: Arc<Vec<Entry>>,
    runs: Vec<Arc<Vec<Entry>>>,
}

impl TabletSnapshot {
    /// Scan a row range through the server-side iterator stack,
    /// pull-based: entries are cloned out of the frozen segments one at
    /// a time as the consumer advances, never into an owned `Vec`.
    pub fn scan(&self, range: &RowRange, cfg: &IterConfig) -> EntryStream {
        let mut sources: Vec<EntryStream> = Vec::with_capacity(1 + self.runs.len());
        // memtable view first: lowest source index wins exact key ties
        sources.push(Box::new(RunCursor::new(self.mem.clone(), range)));
        for run in &self.runs {
            sources.push(Box::new(RunCursor::new(run.clone(), range)));
        }
        cfg.apply(Box::new(MergeIter::new(sources)))
    }

    /// Stored entries in the snapshot (all versions, before the stack).
    pub fn raw_len(&self) -> usize {
        self.mem.len() + self.runs.iter().map(|r| r.len()).sum::<usize>()
    }

    /// Stored entries falling inside `range` (all versions) — binary
    /// searched per segment, so sizing a scan costs O(log n) per layer.
    pub fn raw_len_in(&self, range: &RowRange) -> usize {
        let span = |run: &[Entry]| {
            let (lo, hi) = slice_bounds(run, range);
            hi - lo
        };
        span(&self.mem) + self.runs.iter().map(|r| span(r)).sum::<usize>()
    }

    /// Distinct row keys stored in `range`, sorted ascending. Each
    /// segment is sorted, so per-segment consecutive dedup is exact; no
    /// values are cloned and no iterator stack runs. Rows whose cells
    /// are all tombstoned may still be reported.
    pub fn row_keys_in(&self, range: &RowRange) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for run in std::iter::once(&self.mem).chain(self.runs.iter()) {
            let mut last: Option<&str> = None;
            for e in slice_range(run, range) {
                if last != Some(e.key.row.as_str()) {
                    out.push(e.key.row.clone());
                    last = Some(e.key.row.as_str());
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Lazy cursor over the `[lo, hi)` row-range slice of one frozen
/// segment; clones entries on demand as the merge pulls them.
struct RunCursor {
    run: Arc<Vec<Entry>>,
    pos: usize,
    end: usize,
}

impl RunCursor {
    fn new(run: Arc<Vec<Entry>>, range: &RowRange) -> Self {
        let (pos, end) = slice_bounds(&run, range);
        RunCursor { run, pos, end }
    }
}

impl Iterator for RunCursor {
    type Item = Entry;

    fn next(&mut self) -> Option<Entry> {
        if self.pos >= self.end {
            return None;
        }
        let e = self.run[self.pos].clone();
        self.pos += 1;
        Some(e)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.end - self.pos;
        (n, Some(n))
    }
}

/// Turn a frozen run into an owned entry iterator: moves the entries
/// when this was the last reference, falls back to a cloning cursor when
/// an open snapshot still shares the segment.
fn into_entry_iter(run: Arc<Vec<Entry>>) -> EntryStream {
    match Arc::try_unwrap(run) {
        Ok(v) => Box::new(v.into_iter()),
        Err(shared) => {
            let end = shared.len();
            Box::new(RunCursor { run: shared, pos: 0, end })
        }
    }
}

/// Binary-search the `[lo, hi)` index bounds of a sorted run covered by
/// a row range.
fn slice_bounds(run: &[Entry], range: &RowRange) -> (usize, usize) {
    let lo = match &range.start {
        Some(s) => run.partition_point(|e| e.key.row.as_str() < s.as_str()),
        None => 0,
    };
    let hi = match &range.end {
        Some(e) => run.partition_point(|x| x.key.row.as_str() < e.as_str()),
        None => run.len(),
    };
    (lo, hi)
}

/// Binary-search the sub-slice of a sorted run covered by a row range.
fn slice_range<'a>(run: &'a [Entry], range: &RowRange) -> &'a [Entry] {
    let (lo, hi) = slice_bounds(run, range);
    &run[lo..hi]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::key::Key;

    fn small_config() -> TabletConfig {
        TabletConfig { memtable_flush_bytes: 256, max_runs: 2 }
    }

    #[test]
    fn put_and_scan() {
        let mut t = Tablet::new(TabletConfig::default());
        t.put(Entry::new(Key::cell("r2", "c1", 2), "b"));
        t.put(Entry::new(Key::cell("r1", "c1", 1), "a"));
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].key.row, "r1"); // sorted on scan
    }

    #[test]
    fn scan_range_bounds() {
        let mut t = Tablet::new(TabletConfig::default());
        for r in ["d", "a", "c", "b"] {
            t.put(Entry::new(Key::cell(r, "c", 1), "v"));
        }
        let out = t.scan(&RowRange::span("b", "d"), &IterConfig::default());
        let rows: Vec<&str> = out.iter().map(|e| e.key.row.as_str()).collect();
        assert_eq!(rows, vec!["b", "c"]);
    }

    #[test]
    fn versioning_across_flushes() {
        let mut t = Tablet::new(small_config());
        t.put(Entry::new(Key::cell("r", "c", 1), "old"));
        t.flush();
        t.put(Entry::new(Key::cell("r", "c", 2), "new"));
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "new");
    }

    #[test]
    fn summing_across_flushes() {
        let mut t = Tablet::new(small_config());
        t.put(Entry::new(Key::cell("r", "c", 1), "3"));
        t.flush();
        t.put(Entry::new(Key::cell("r", "c", 2), "4"));
        let cfg = IterConfig { summing: true, ..Default::default() };
        let out = t.scan(&RowRange::all(), &cfg);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "7");
    }

    #[test]
    fn auto_flush_and_compact() {
        let mut t = Tablet::new(small_config());
        for i in 0..200 {
            t.put(Entry::new(Key::cell(format!("row{i:04}"), "c", i), "value"));
        }
        assert!(t.flushes > 0, "expected auto-flushes");
        assert!(t.compactions > 0, "expected compactions");
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 200);
    }

    #[test]
    fn tiered_compaction_leaves_large_runs() {
        let mut t = Tablet::new(TabletConfig { memtable_flush_bytes: usize::MAX, max_runs: 2 });
        // one big run
        for i in 0..1000 {
            t.put(Entry::new(Key::cell(format!("big{i:05}"), "c", i), "v"));
        }
        t.flush();
        let big_len = t.runs[0].len();
        // several small runs to trigger tiered merges
        for batch in 0..6 {
            for i in 0..10 {
                t.put(Entry::new(
                    Key::cell(format!("small{batch}{i:03}"), "c", 10_000 + batch * 10 + i),
                    "v",
                ));
            }
            t.flush();
        }
        // the big run must still exist untouched
        assert!(t.runs.iter().any(|r| r.len() == big_len), "big run was re-merged");
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 1060);
    }

    #[test]
    fn compact_major_single_run_newest() {
        let mut t = Tablet::new(small_config());
        t.put(Entry::new(Key::cell("r", "c", 1), "old"));
        t.flush();
        t.put(Entry::new(Key::cell("r", "c", 2), "new"));
        t.compact_major();
        assert_eq!(t.runs.len(), 1);
        assert!(t.memtable.is_empty());
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "new");
    }

    #[test]
    fn row_keys_in_distinct_sorted_across_layers() {
        let mut t = Tablet::new(small_config());
        // spread rows across a flushed run and the live memtable, with
        // multiple cells and versions per row
        t.put(Entry::new(Key::cell("b", "c1", 1), "x"));
        t.put(Entry::new(Key::cell("d", "c1", 2), "x"));
        t.flush();
        t.put(Entry::new(Key::cell("a", "c1", 3), "x"));
        t.put(Entry::new(Key::cell("b", "c2", 4), "x"));
        t.put(Entry::new(Key::cell("b", "c1", 5), "newer"));
        assert_eq!(t.row_keys_in(&RowRange::all()), vec!["a", "b", "d"]);
        assert_eq!(t.row_keys_in(&RowRange::span("b", "d")), vec!["b"]);
        // key-only scan agrees with the materialising scan's row set
        let full: Vec<String> = {
            let mut rows: Vec<String> = t
                .scan(&RowRange::all(), &IterConfig::default())
                .into_iter()
                .map(|e| e.key.row)
                .collect();
            rows.dedup();
            rows
        };
        assert_eq!(t.row_keys_in(&RowRange::all()), full);
    }

    #[test]
    fn interleaved_write_scan_write() {
        let mut t = Tablet::new(TabletConfig::default());
        t.put(Entry::new(Key::cell("b", "c", 1), "1"));
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 1);
        t.put(Entry::new(Key::cell("a", "c", 2), "2"));
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out[0].key.row, "a"); // resorted after the new write
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn snapshot_isolated_from_later_writes() {
        let mut t = Tablet::new(small_config());
        t.put(Entry::new(Key::cell("a", "c", 1), "1"));
        t.flush();
        t.put(Entry::new(Key::cell("b", "c", 2), "2"));
        let snap = t.snapshot();
        // mutate after the snapshot: new write, delete, flush, compact
        t.put(Entry::new(Key::cell("c", "c", 3), "3"));
        t.put(Entry::delete(Key::cell("a", "c", 4)));
        t.flush();
        t.compact_major();
        // the snapshot still reads the frozen state
        let out: Vec<Entry> = snap.scan(&RowRange::all(), &IterConfig::default()).collect();
        let rows: Vec<&str> = out.iter().map(|e| e.key.row.as_str()).collect();
        assert_eq!(rows, vec!["a", "b"]);
        assert_eq!(out[0].value, "1");
        // while a fresh scan sees the mutations
        let now = t.scan(&RowRange::all(), &IterConfig::default());
        let rows: Vec<&str> = now.iter().map(|e| e.key.row.as_str()).collect();
        assert_eq!(rows, vec!["b", "c"]);
    }

    #[test]
    fn snapshot_memview_cache_shared_until_write() {
        let mut t = Tablet::new(TabletConfig::default());
        t.put(Entry::new(Key::cell("a", "c", 1), "1"));
        let s1 = t.snapshot();
        let s2 = t.snapshot();
        assert!(Arc::ptr_eq(&s1.mem, &s2.mem), "cache should share the sorted view");
        t.put(Entry::new(Key::cell("b", "c", 2), "2"));
        let s3 = t.snapshot();
        assert!(!Arc::ptr_eq(&s1.mem, &s3.mem), "write must invalidate the view");
        assert_eq!(s3.raw_len(), 2);
        assert_eq!(s1.raw_len(), 1);
    }

    #[test]
    fn stream_is_lazy_and_matches_collect() {
        let mut t = Tablet::new(small_config());
        for i in 0..50 {
            t.put(Entry::new(Key::cell(format!("r{i:03}"), "c", i), "v"));
        }
        t.flush();
        for i in 50..80 {
            t.put(Entry::new(Key::cell(format!("r{i:03}"), "c", i), "v"));
        }
        let collected = t.scan(&RowRange::all(), &IterConfig::default());
        let mut stream = t.scan_stream(&RowRange::all(), &IterConfig::default());
        // pull a prefix, then write — the stream must be unaffected
        let first = stream.next().unwrap();
        t.put(Entry::new(Key::cell("aaa", "c", 999), "new"));
        let rest: Vec<Entry> = stream.collect();
        assert_eq!(first, collected[0]);
        assert_eq!(rest, collected[1..]);
    }
}
