//! A tablet: the unit of storage and serving. LSM-style — an in-memory
//! memtable plus immutable sorted runs, flushed and compacted by size
//! thresholds, scanned through the server-side iterator stack.
//!
//! §Perf (EXPERIMENTS.md): the memtable is an **append-only vector,
//! sorted lazily** at scan/flush time rather than a BTreeMap. Writes are
//! a push (~50 ns) instead of an ordered-map insert (~1 µs); the sort
//! cost is paid once per flush/scan, where it is cache-friendly. This is
//! the single-core analogue of Accumulo's lock-free skiplist memtable.
//! Compaction is size-tiered: only the smaller runs merge, so total
//! compaction work stays O(n log n) instead of the quadratic re-merging
//! of a naive merge-all policy.

use super::iterator::{IterConfig, MergeIter};
use super::key::{Entry, RowRange};

/// Tuning knobs for tablets (defaults sized for tests; benches override).
#[derive(Debug, Clone)]
pub struct TabletConfig {
    /// Flush the memtable to a sorted run when it exceeds this many bytes.
    pub memtable_flush_bytes: usize,
    /// Merge small runs when their count exceeds this.
    pub max_runs: usize,
}

impl Default for TabletConfig {
    fn default() -> Self {
        TabletConfig { memtable_flush_bytes: 4 << 20, max_runs: 8 }
    }
}

/// One tablet of a table.
#[derive(Debug)]
pub struct Tablet {
    /// Append-only buffer; `sorted_upto` marks the prefix already in key
    /// order (sorted lazily on scan/flush).
    memtable: Vec<Entry>,
    sorted_upto: usize,
    memtable_bytes: usize,
    /// Immutable sorted runs, newest first.
    runs: Vec<Vec<Entry>>,
    config: TabletConfig,
    /// Counters for introspection/benchmarks.
    pub flushes: u64,
    pub compactions: u64,
}

impl Tablet {
    pub fn new(config: TabletConfig) -> Self {
        Tablet {
            memtable: Vec::new(),
            sorted_upto: 0,
            memtable_bytes: 0,
            runs: Vec::new(),
            config,
            flushes: 0,
            compactions: 0,
        }
    }

    /// Insert one entry (server-side write path). O(1) amortised.
    pub fn put(&mut self, entry: Entry) {
        self.memtable_bytes += entry.bytes();
        self.memtable.push(entry);
        if self.memtable_bytes >= self.config.memtable_flush_bytes {
            self.flush();
        }
    }

    /// Sort the memtable if it has an unsorted suffix. Stable sort keeps
    /// first-written entries first among exact key ties (same cell+ts);
    /// Key order already places newer timestamps first.
    fn ensure_sorted(&mut self) {
        if self.sorted_upto < self.memtable.len() {
            self.memtable.sort_by(|a, b| a.key.cmp(&b.key));
            self.sorted_upto = self.memtable.len();
        }
    }

    /// Force the memtable into a sorted run.
    pub fn flush(&mut self) {
        if self.memtable.is_empty() {
            return;
        }
        self.ensure_sorted();
        let run = std::mem::take(&mut self.memtable);
        self.sorted_upto = 0;
        self.memtable_bytes = 0;
        self.runs.insert(0, run);
        self.flushes += 1;
        if self.runs.len() > self.config.max_runs {
            self.compact();
        }
    }

    /// Size-tiered compaction: merge the smallest runs together until at
    /// most `max_runs / 2` remain, leaving large runs untouched (no
    /// quadratic re-merging of the big ones).
    pub fn compact(&mut self) {
        let keep = (self.config.max_runs / 2).max(1);
        if self.runs.len() <= keep {
            return;
        }
        // sort runs by size; merge everything except the `keep` largest
        self.runs.sort_by_key(|r| std::cmp::Reverse(r.len()));
        let small: Vec<Vec<Entry>> = self.runs.split_off(keep);
        let sources: Vec<Box<dyn Iterator<Item = Entry> + Send>> = small
            .into_iter()
            .map(|r| Box::new(r.into_iter()) as Box<dyn Iterator<Item = Entry> + Send>)
            .collect();
        let merged: Vec<Entry> = MergeIter::new(sources).collect();
        self.runs.push(merged);
        // restore newest-first-ish ordering guarantee is not needed for
        // correctness (versioning is by timestamp, not layer), but keep
        // deterministic order for tests
        self.runs.sort_by_key(|r| std::cmp::Reverse(r.len()));
        self.compactions += 1;
    }

    /// Merge *everything* into one run, dropping superseded versions
    /// (major compaction; useful before scan-heavy phases).
    pub fn compact_major(&mut self) {
        self.ensure_sorted();
        let mut sources: Vec<Box<dyn Iterator<Item = Entry> + Send>> = Vec::new();
        if !self.memtable.is_empty() {
            let mem = std::mem::take(&mut self.memtable);
            self.sorted_upto = 0;
            self.memtable_bytes = 0;
            sources.push(Box::new(mem.into_iter()));
        }
        for r in std::mem::take(&mut self.runs) {
            sources.push(Box::new(r.into_iter()));
        }
        let merged: Vec<Entry> =
            super::iterator::VersioningIter::new(MergeIter::new(sources)).collect();
        self.runs = vec![merged];
        self.compactions += 1;
    }

    /// Number of stored entries across memtable + runs (before versioning).
    pub fn raw_len(&self) -> usize {
        self.memtable.len() + self.runs.iter().map(|r| r.len()).sum::<usize>()
    }

    /// Approximate resident bytes.
    pub fn mem_bytes(&self) -> usize {
        self.memtable_bytes
            + self
                .runs
                .iter()
                .map(|r| r.iter().map(Entry::bytes).sum::<usize>())
                .sum::<usize>()
    }

    /// Scan a row range through the iterator stack.
    pub fn scan(&mut self, range: &RowRange, cfg: &IterConfig) -> Vec<Entry> {
        self.scan_iter(range, cfg).collect()
    }

    /// Streaming scan (server-side iterator stack applied).
    pub fn scan_iter(
        &mut self,
        range: &RowRange,
        cfg: &IterConfig,
    ) -> Box<dyn Iterator<Item = Entry> + Send + '_> {
        self.ensure_sorted();
        let mut sources: Vec<Box<dyn Iterator<Item = Entry> + Send>> = Vec::new();
        sources.push(Box::new(slice_range(&self.memtable, range).to_vec().into_iter()));
        for run in &self.runs {
            sources.push(Box::new(slice_range(run, range).to_vec().into_iter()));
        }
        cfg.apply(Box::new(MergeIter::new(sources)))
    }

    /// Key-only scan: distinct row keys stored in `range`, sorted
    /// ascending. Walks the memtable and runs as slices — no `Entry`
    /// cloning, no k-way merge, no value materialisation — so snapshotting
    /// the rows of a paged scan costs one `String` clone per (source ×
    /// distinct row) instead of a full materialising scan. Rows whose
    /// cells are all tombstoned may still be reported (versioning is the
    /// per-page fetch's job); downstream pagination skips their empty
    /// pages.
    pub fn row_keys_in(&mut self, range: &RowRange) -> Vec<String> {
        self.ensure_sorted();
        let mut out: Vec<String> = Vec::new();
        let mut sources: Vec<&[Entry]> = Vec::with_capacity(1 + self.runs.len());
        sources.push(slice_range(&self.memtable, range));
        for run in &self.runs {
            sources.push(slice_range(run, range));
        }
        for src in sources {
            // each source is sorted, so consecutive dedup is exact per source
            let mut last: Option<&str> = None;
            for e in src {
                if last != Some(e.key.row.as_str()) {
                    out.push(e.key.row.clone());
                    last = Some(e.key.row.as_str());
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Binary-search the sub-slice of a sorted run covered by a row range.
fn slice_range<'a>(run: &'a [Entry], range: &RowRange) -> &'a [Entry] {
    let lo = match &range.start {
        Some(s) => run.partition_point(|e| e.key.row.as_str() < s.as_str()),
        None => 0,
    };
    let hi = match &range.end {
        Some(e) => run.partition_point(|x| x.key.row.as_str() < e.as_str()),
        None => run.len(),
    };
    &run[lo..hi]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::key::Key;

    fn small_config() -> TabletConfig {
        TabletConfig { memtable_flush_bytes: 256, max_runs: 2 }
    }

    #[test]
    fn put_and_scan() {
        let mut t = Tablet::new(TabletConfig::default());
        t.put(Entry::new(Key::cell("r2", "c1", 2), "b"));
        t.put(Entry::new(Key::cell("r1", "c1", 1), "a"));
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].key.row, "r1"); // sorted on scan
    }

    #[test]
    fn scan_range_bounds() {
        let mut t = Tablet::new(TabletConfig::default());
        for r in ["d", "a", "c", "b"] {
            t.put(Entry::new(Key::cell(r, "c", 1), "v"));
        }
        let out = t.scan(&RowRange::span("b", "d"), &IterConfig::default());
        let rows: Vec<&str> = out.iter().map(|e| e.key.row.as_str()).collect();
        assert_eq!(rows, vec!["b", "c"]);
    }

    #[test]
    fn versioning_across_flushes() {
        let mut t = Tablet::new(small_config());
        t.put(Entry::new(Key::cell("r", "c", 1), "old"));
        t.flush();
        t.put(Entry::new(Key::cell("r", "c", 2), "new"));
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "new");
    }

    #[test]
    fn summing_across_flushes() {
        let mut t = Tablet::new(small_config());
        t.put(Entry::new(Key::cell("r", "c", 1), "3"));
        t.flush();
        t.put(Entry::new(Key::cell("r", "c", 2), "4"));
        let cfg = IterConfig { summing: true, ..Default::default() };
        let out = t.scan(&RowRange::all(), &cfg);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "7");
    }

    #[test]
    fn auto_flush_and_compact() {
        let mut t = Tablet::new(small_config());
        for i in 0..200 {
            t.put(Entry::new(Key::cell(format!("row{i:04}"), "c", i), "value"));
        }
        assert!(t.flushes > 0, "expected auto-flushes");
        assert!(t.compactions > 0, "expected compactions");
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 200);
    }

    #[test]
    fn tiered_compaction_leaves_large_runs() {
        let mut t = Tablet::new(TabletConfig { memtable_flush_bytes: usize::MAX, max_runs: 2 });
        // one big run
        for i in 0..1000 {
            t.put(Entry::new(Key::cell(format!("big{i:05}"), "c", i), "v"));
        }
        t.flush();
        let big_len = t.runs[0].len();
        // several small runs to trigger tiered merges
        for batch in 0..6 {
            for i in 0..10 {
                t.put(Entry::new(
                    Key::cell(format!("small{batch}{i:03}"), "c", 10_000 + batch * 10 + i),
                    "v",
                ));
            }
            t.flush();
        }
        // the big run must still exist untouched
        assert!(t.runs.iter().any(|r| r.len() == big_len), "big run was re-merged");
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 1060);
    }

    #[test]
    fn compact_major_single_run_newest() {
        let mut t = Tablet::new(small_config());
        t.put(Entry::new(Key::cell("r", "c", 1), "old"));
        t.flush();
        t.put(Entry::new(Key::cell("r", "c", 2), "new"));
        t.compact_major();
        assert_eq!(t.runs.len(), 1);
        assert!(t.memtable.is_empty());
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "new");
    }

    #[test]
    fn row_keys_in_distinct_sorted_across_layers() {
        let mut t = Tablet::new(small_config());
        // spread rows across a flushed run and the live memtable, with
        // multiple cells and versions per row
        t.put(Entry::new(Key::cell("b", "c1", 1), "x"));
        t.put(Entry::new(Key::cell("d", "c1", 2), "x"));
        t.flush();
        t.put(Entry::new(Key::cell("a", "c1", 3), "x"));
        t.put(Entry::new(Key::cell("b", "c2", 4), "x"));
        t.put(Entry::new(Key::cell("b", "c1", 5), "newer"));
        assert_eq!(t.row_keys_in(&RowRange::all()), vec!["a", "b", "d"]);
        assert_eq!(t.row_keys_in(&RowRange::span("b", "d")), vec!["b"]);
        // key-only scan agrees with the materialising scan's row set
        let full: Vec<String> = {
            let mut rows: Vec<String> = t
                .scan(&RowRange::all(), &IterConfig::default())
                .into_iter()
                .map(|e| e.key.row)
                .collect();
            rows.dedup();
            rows
        };
        assert_eq!(t.row_keys_in(&RowRange::all()), full);
    }

    #[test]
    fn interleaved_write_scan_write() {
        let mut t = Tablet::new(TabletConfig::default());
        t.put(Entry::new(Key::cell("b", "c", 1), "1"));
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 1);
        t.put(Entry::new(Key::cell("a", "c", 2), "2"));
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out[0].key.row, "a"); // resorted after the new write
        assert_eq!(out.len(), 2);
    }
}
