//! Accumulo-class key-value store substrate (see DESIGN.md substitutions).
//!
//! An embedded, in-process reimplementation of the pieces of Apache
//! Accumulo that D4M and Graphulo depend on: sorted keys
//! (row/cf/cq/ts-descending), tables sharded into tablets by split
//! points, an LSM write path (memtable → sorted runs → compaction),
//! buffered [`writer::BatchWriter`]s, range scans, and — crucially for
//! Graphulo — the composable **server-side iterator stack**
//! ([`iterator`]) that lets analytics run inside the tablet scan.
//!
//! Reads are snapshot-isolated and streaming: scans freeze `Arc`-shared
//! tablet snapshots under a brief read lock, then pull entries through
//! the iterator stack lazily with no lock held (see DESIGN.md
//! §Snapshot/streaming read path).

pub mod iterator;
pub mod key;
pub mod storage;
pub mod store;
pub mod tablet;
pub mod writer;

pub use iterator::{EntryStream, IterConfig, MergeIter, SummingCombiner, VersioningIter};
pub use key::{Entry, Key, RowRange};
pub use storage::{StorageConfig, StorageCounters};
pub use store::{KvStore, Table, TableSnapshot};
pub use tablet::{Segment, Tablet, TabletConfig, TabletSnapshot};
pub use writer::{BatchWriter, WriterConfig};
