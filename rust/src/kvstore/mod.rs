//! Accumulo-class key-value store substrate (see DESIGN.md substitutions).
//!
//! An embedded, in-process reimplementation of the pieces of Apache
//! Accumulo that D4M and Graphulo depend on: sorted keys
//! (row/cf/cq/ts-descending), tables sharded into tablets by split
//! points, an LSM write path (memtable → sorted runs → compaction),
//! buffered [`writer::BatchWriter`]s, range scans, and — crucially for
//! Graphulo — the composable **server-side iterator stack**
//! ([`iterator`]) that lets analytics run inside the tablet scan.

pub mod iterator;
pub mod key;
pub mod store;
pub mod tablet;
pub mod writer;

pub use iterator::{IterConfig, MergeIter, SummingCombiner, VersioningIter};
pub use key::{Entry, Key, RowRange};
pub use store::{KvStore, Table};
pub use tablet::{Tablet, TabletConfig};
pub use writer::{BatchWriter, WriterConfig};
