//! The key-value store: a set of named tables, each sharded into tablets
//! by split points (the Accumulo tablet-server model, one process).
//!
//! §Reads: the scan path is snapshot-isolated and streaming end to end.
//! [`Table::scan_stream`] read-locks each overlapping tablet just long
//! enough to acquire its [`TabletSnapshot`], then returns a lazy
//! [`EntryStream`] in global key order — no tablet lock is held while
//! results are consumed, so readers never serialise against writers or
//! each other. [`Table::scan`] is the materialising form kept for tests
//! and point reads; on multi-tablet ranges it drains the per-tablet
//! snapshots in parallel with scoped threads (tablets are range-disjoint,
//! so concatenating in tablet order preserves global key order).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use super::iterator::{EntryStream, IterConfig, MergeIter};
use super::key::{Entry, Key, RowRange};
use super::tablet::{Tablet, TabletConfig, TabletSnapshot};
use crate::error::{D4mError, Result};

/// Below this many raw snapshot entries a parallel materialising scan is
/// not worth the thread spawns; drain sequentially instead.
const PARALLEL_SCAN_MIN_ENTRIES: usize = 8192;

/// A table: tablets partitioned by sorted split points. Tablet `i` serves
/// rows in `[splits[i-1], splits[i])` (first/last unbounded).
pub struct Table {
    pub name: String,
    splits: Vec<String>,
    /// `RwLock`, not `Mutex`: concurrent readers acquire snapshots under
    /// a shared lock and only writers take it exclusively.
    tablets: Vec<RwLock<Tablet>>,
    /// Logical clock for auto-timestamps.
    clock: AtomicU64,
}

impl Table {
    fn new(name: &str, splits: Vec<String>, cfg: TabletConfig) -> Self {
        debug_assert!(splits.windows(2).all(|w| w[0] < w[1]));
        let tablets = (0..=splits.len()).map(|_| RwLock::new(Tablet::new(cfg.clone()))).collect();
        Table { name: name.to_string(), splits, tablets, clock: AtomicU64::new(1) }
    }

    /// Index of the tablet serving `row`.
    pub fn tablet_for(&self, row: &str) -> usize {
        self.splits.partition_point(|s| s.as_str() <= row)
    }

    pub fn num_tablets(&self) -> usize {
        self.tablets.len()
    }

    pub fn splits(&self) -> &[String] {
        &self.splits
    }

    /// Next logical timestamp.
    pub fn next_ts(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Write one cell with an auto-assigned timestamp.
    pub fn put(&self, row: &str, cq: &str, value: &str) {
        let ts = self.next_ts();
        self.put_entry(Entry::new(Key::cell(row, cq, ts), value));
    }

    /// Write a fully-formed entry.
    pub fn put_entry(&self, e: Entry) {
        let t = self.tablet_for(&e.key.row);
        self.tablets[t].write().unwrap().put(e);
    }

    /// Write a batch, grouping by tablet so each tablet lock is taken
    /// once. No per-tablet buffers: the single-tablet case (the common
    /// shape — row-sharded ingest workers and every one-tablet table)
    /// is detected with one routing pass, and the scattered case groups
    /// in place with a stable sort by tablet index (insertion order
    /// within a tablet is preserved).
    pub fn put_batch(&self, mut entries: Vec<Entry>) {
        if entries.is_empty() {
            return;
        }
        if self.tablets.len() > 1 {
            let first = self.tablet_for(&entries[0].key.row);
            if !entries.iter().all(|e| self.tablet_for(&e.key.row) == first) {
                entries.sort_by_cached_key(|e| self.tablet_for(&e.key.row));
            }
        }
        let mut it = entries.into_iter().peekable();
        while let Some(e) = it.next() {
            let t = self.tablet_for(&e.key.row);
            let mut tablet = self.tablets[t].write().unwrap();
            tablet.put(e);
            while it.peek().map(|n| self.tablet_for(&n.key.row) == t).unwrap_or(false) {
                tablet.put(it.next().unwrap());
            }
        }
    }

    /// Freeze every tablet overlapping `range` into a point-in-time
    /// [`TableSnapshot`]. Each tablet's read lock is held only for the
    /// `Arc` clones of snapshot acquisition. The snapshot is per-tablet
    /// atomic (Accumulo's isolation unit), not cross-tablet atomic.
    pub fn snapshot_range(&self, range: &RowRange) -> TableSnapshot {
        let mut tablets = Vec::new();
        for (i, tl) in self.tablets.iter().enumerate() {
            if !self.tablet_overlaps(i, range) {
                continue;
            }
            tablets.push(tl.read().unwrap().snapshot());
        }
        TableSnapshot { tablets }
    }

    /// Streaming scan of a row range across all covered tablets, iterator
    /// stack applied server-side, results in global key order. Locks are
    /// dropped before the stream yields its first entry.
    pub fn scan_stream(&self, range: &RowRange, cfg: &IterConfig) -> EntryStream {
        self.snapshot_range(range).stream(range, cfg)
    }

    /// Materialising scan — a `collect()` of [`Table::scan_stream`], kept
    /// for tests and small reads; multi-tablet ranges drain their
    /// per-tablet snapshots in parallel (scoped threads).
    pub fn scan(&self, range: &RowRange, cfg: &IterConfig) -> Vec<Entry> {
        self.snapshot_range(range).collect_entries(range, cfg)
    }

    /// Key-only scan: distinct row keys stored in `range`, sorted. Paged
    /// readers snapshot rows through this instead of a materialising
    /// [`Table::scan`] — no values are cloned and no iterator stack runs.
    /// Tablets are range-disjoint and visited in row order, so per-tablet
    /// results concatenate already sorted.
    pub fn scan_row_keys(&self, range: &RowRange) -> Vec<String> {
        let mut out = Vec::new();
        for (i, tl) in self.tablets.iter().enumerate() {
            if !self.tablet_overlaps(i, range) {
                continue;
            }
            // snapshot under the read lock, walk after it is dropped —
            // the key walk must not stall writers
            let snap = tl.read().unwrap().snapshot();
            out.extend(snap.row_keys_in(range));
        }
        out
    }

    /// Scan one row (materialised; single tablet, small result).
    pub fn scan_row(&self, row: &str, cfg: &IterConfig) -> Vec<Entry> {
        self.scan_row_stream(row, cfg).collect()
    }

    /// Streaming scan of one row: one tablet snapshot, lock dropped
    /// before the first entry is pulled.
    pub fn scan_row_stream(&self, row: &str, cfg: &IterConfig) -> EntryStream {
        let range = RowRange::single(row);
        let t = self.tablet_for(row);
        let snap = self.tablets[t].read().unwrap().snapshot();
        snap.scan(&range, cfg)
    }

    fn tablet_overlaps(&self, i: usize, range: &RowRange) -> bool {
        // tablet i covers [lo_i, hi_i)
        let lo = if i == 0 { None } else { Some(self.splits[i - 1].as_str()) };
        let hi = if i == self.splits.len() { None } else { Some(self.splits[i].as_str()) };
        if let (Some(end), Some(lo)) = (&range.end, lo) {
            if end.as_str() <= lo {
                return false;
            }
        }
        if let (Some(start), Some(hi)) = (&range.start, hi) {
            if start.as_str() >= hi {
                return false;
            }
        }
        true
    }

    /// Flush every tablet's memtable.
    pub fn flush(&self) {
        for t in &self.tablets {
            t.write().unwrap().flush();
        }
    }

    /// Total raw entries (all versions) across tablets.
    pub fn raw_len(&self) -> usize {
        self.tablets.iter().map(|t| t.read().unwrap().raw_len()).sum()
    }

    /// Approximate resident bytes.
    pub fn mem_bytes(&self) -> usize {
        self.tablets.iter().map(|t| t.read().unwrap().mem_bytes()).sum()
    }
}

/// Point-in-time view of the tablets a scan covers, in key order.
/// Cloning shares the frozen segments. Streams and materialised scans
/// built from the same snapshot observe bit-identical data regardless of
/// concurrent writers.
#[derive(Debug, Clone)]
pub struct TableSnapshot {
    tablets: Vec<TabletSnapshot>,
}

impl TableSnapshot {
    /// Lazy stream in global key order: per-tablet streams (each already
    /// running the full iterator stack) k-way merged. Tablets are
    /// range-disjoint and ordered, so the merge degenerates to
    /// concatenation cost-wise while staying correct in general.
    pub fn stream(&self, range: &RowRange, cfg: &IterConfig) -> EntryStream {
        let mut sources: Vec<EntryStream> =
            self.tablets.iter().map(|t| t.scan(range, cfg)).collect();
        match sources.len() {
            0 => Box::new(std::iter::empty()),
            1 => sources.pop().unwrap(),
            _ => Box::new(MergeIter::new(sources)),
        }
    }

    /// Materialise the scan, draining disjoint tablets in parallel with
    /// scoped threads when the range spans several and the snapshot is
    /// big enough to amortise the spawns. Output is concatenated in
    /// tablet order — identical to [`TableSnapshot::stream`] collected.
    pub fn collect_entries(&self, range: &RowRange, cfg: &IterConfig) -> Vec<Entry> {
        // size the decision to the range-restricted work (binary
        // searched per segment), not the whole snapshot — point reads
        // on a big table must not spawn threads
        let work: usize = self.tablets.iter().map(|t| t.raw_len_in(range)).sum();
        if self.tablets.len() <= 1 || work < PARALLEL_SCAN_MIN_ENTRIES {
            return self.stream(range, cfg).collect();
        }
        let mut parts: Vec<Vec<Entry>> = Vec::with_capacity(self.tablets.len());
        std::thread::scope(|s| {
            let handles: Vec<_> = self
                .tablets
                .iter()
                .map(|t| s.spawn(move || t.scan(range, cfg).collect::<Vec<Entry>>()))
                .collect();
            for h in handles {
                parts.push(h.join().expect("parallel scan worker panicked"));
            }
        });
        let total: usize = parts.iter().map(Vec::len).sum();
        let mut out = Vec::with_capacity(total);
        for p in parts {
            out.extend(p);
        }
        out
    }

    /// Stored entries in the snapshot (all versions, before the stack).
    pub fn raw_len(&self) -> usize {
        self.tablets.iter().map(TabletSnapshot::raw_len).sum()
    }
}

/// The store: named tables behind an `Arc` so scanners/writers share it.
#[derive(Default)]
pub struct KvStore {
    tables: RwLock<HashMap<String, Arc<Table>>>,
    tablet_config: TabletConfig,
}

impl KvStore {
    pub fn new() -> Self {
        KvStore::default()
    }

    pub fn with_config(tablet_config: TabletConfig) -> Self {
        KvStore { tables: RwLock::new(HashMap::new()), tablet_config }
    }

    /// Create a table with the given split points (empty = one tablet).
    pub fn create_table(&self, name: &str, splits: Vec<String>) -> Result<Arc<Table>> {
        let mut tables = self.tables.write().unwrap();
        if tables.contains_key(name) {
            return Err(D4mError::AlreadyExists(format!("table {name}")));
        }
        let t = Arc::new(Table::new(name, splits, self.tablet_config.clone()));
        tables.insert(name.to_string(), t.clone());
        Ok(t)
    }

    /// Create if missing, otherwise return the existing table.
    pub fn ensure_table(&self, name: &str, splits: Vec<String>) -> Arc<Table> {
        if let Some(t) = self.table(name) {
            return t;
        }
        self.create_table(name, splits).unwrap_or_else(|_| self.table(name).unwrap())
    }

    pub fn table(&self, name: &str) -> Option<Arc<Table>> {
        self.tables.read().unwrap().get(name).cloned()
    }

    pub fn table_or_err(&self, name: &str) -> Result<Arc<Table>> {
        self.table(name).ok_or_else(|| D4mError::NotFound(format!("table {name}")))
    }

    pub fn drop_table(&self, name: &str) -> Result<()> {
        self.tables
            .write()
            .unwrap()
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| D4mError::NotFound(format!("table {name}")))
    }

    pub fn list_tables(&self) -> Vec<String> {
        let mut v: Vec<String> = self.tables.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_scan_roundtrip() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r1", "c1", "a");
        t.put("r2", "c2", "b");
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn duplicate_create_fails() {
        let store = KvStore::new();
        store.create_table("t", vec![]).unwrap();
        assert!(store.create_table("t", vec![]).is_err());
    }

    #[test]
    fn split_routing() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["m".into()]).unwrap();
        assert_eq!(t.num_tablets(), 2);
        assert_eq!(t.tablet_for("a"), 0);
        assert_eq!(t.tablet_for("m"), 1);
        assert_eq!(t.tablet_for("z"), 1);
    }

    #[test]
    fn scan_across_tablets_in_order() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into(), "p".into()]).unwrap();
        for r in ["z", "a", "m", "q", "h"] {
            t.put(r, "c", "v");
        }
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        let rows: Vec<&str> = out.iter().map(|e| e.key.row.as_str()).collect();
        assert_eq!(rows, vec!["a", "h", "m", "q", "z"]);
    }

    #[test]
    fn scan_range_skips_tablets() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into()]).unwrap();
        t.put("a", "c", "1");
        t.put("z", "c", "2");
        let out = t.scan(&RowRange::span("x", "zz"), &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].key.row, "z");
    }

    #[test]
    fn scan_row_keys_across_tablets() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into(), "p".into()]).unwrap();
        for r in ["z", "a", "m", "q", "h", "a"] {
            t.put(r, "c", "v");
        }
        assert_eq!(t.scan_row_keys(&RowRange::all()), vec!["a", "h", "m", "q", "z"]);
        assert_eq!(t.scan_row_keys(&RowRange::span("h", "r")), vec!["h", "m", "q"]);
    }

    #[test]
    fn overwrite_latest_wins() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "first");
        t.put("r", "c", "second");
        let out = t.scan_row("r", &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "second");
    }

    #[test]
    fn summing_scan() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "2");
        t.put("r", "c", "3");
        let cfg = IterConfig { summing: true, ..Default::default() };
        assert_eq!(t.scan_row("r", &cfg)[0].value, "5");
    }

    #[test]
    fn concurrent_writers() {
        let store = Arc::new(KvStore::new());
        let t = store.create_table("t", vec!["g".into(), "r".into()]).unwrap();
        let hs: Vec<_> = (0..4)
            .map(|w| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for i in 0..500 {
                        t.put(&format!("{}{i:04}", (b'a' + w) as char), "c", "1");
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 2000);
    }

    #[test]
    fn drop_table_works() {
        let store = KvStore::new();
        store.create_table("t", vec![]).unwrap();
        store.drop_table("t").unwrap();
        assert!(store.table("t").is_none());
        assert!(store.drop_table("t").is_err());
    }

    #[test]
    fn put_batch_scattered_across_tablets() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into(), "p".into()]).unwrap();
        let entries: Vec<Entry> = ["z", "a", "m", "q", "h", "b"]
            .iter()
            .map(|r| Entry::new(Key::cell(*r, "c", t.next_ts()), "v"))
            .collect();
        t.put_batch(entries);
        let rows: Vec<String> = t
            .scan(&RowRange::all(), &IterConfig::default())
            .into_iter()
            .map(|e| e.key.row)
            .collect();
        assert_eq!(rows, vec!["a", "b", "h", "m", "q", "z"]);
    }

    #[test]
    fn put_batch_preserves_version_order_within_tablet() {
        // two versions of one cell in a single batch: the later ts must
        // win regardless of the grouping strategy
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into()]).unwrap();
        let e1 = Entry::new(Key::cell("a", "c", t.next_ts()), "old");
        let z = Entry::new(Key::cell("z", "c", t.next_ts()), "far");
        let e2 = Entry::new(Key::cell("a", "c", t.next_ts()), "new");
        t.put_batch(vec![e1, z, e2]);
        let out = t.scan_row("a", &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "new");
    }

    #[test]
    fn table_snapshot_stream_equals_parallel_collect() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into(), "p".into()]).unwrap();
        for i in 0..10_000 {
            t.put(&format!("{}{i:05}", ["a", "j", "r"][i % 3]), "c", &i.to_string());
        }
        t.flush();
        let snap = t.snapshot_range(&RowRange::all());
        // big enough that collect_entries takes the scoped-thread path
        assert!(snap.raw_len() >= PARALLEL_SCAN_MIN_ENTRIES);
        let cfg = IterConfig::default();
        let streamed: Vec<Entry> = snap.stream(&RowRange::all(), &cfg).collect();
        let collected = snap.collect_entries(&RowRange::all(), &cfg);
        assert_eq!(streamed, collected);
        assert!(streamed.windows(2).all(|w| w[0].key < w[1].key));
    }

    #[test]
    fn writer_progresses_while_stream_open() {
        // the stream must not pin any tablet lock: a same-thread write
        // between stream creation and consumption would deadlock if it
        // did
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("a", "c", "1");
        let stream = t.scan_stream(&RowRange::all(), &IterConfig::default());
        t.put("b", "c", "2");
        t.flush();
        let seen: Vec<Entry> = stream.collect();
        assert_eq!(seen.len(), 1, "snapshot must not see the later write");
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 2);
    }
}

impl Table {
    /// Delete one cell (writes a tombstone; older versions become
    /// invisible to scans and are dropped at major compaction).
    pub fn delete(&self, row: &str, cq: &str) {
        let ts = self.next_ts();
        self.put_entry(Entry::delete(Key::cell(row, cq, ts)));
    }
}

#[cfg(test)]
mod delete_tests {
    use super::*;

    #[test]
    fn delete_hides_and_rewrite_restores() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "v1");
        t.delete("r", "c");
        assert!(t.scan_row("r", &IterConfig::default()).is_empty());
        t.put("r", "c", "v2");
        let out = t.scan_row("r", &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "v2");
    }

    #[test]
    fn delete_survives_flush_boundary() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "v1");
        t.flush();
        t.delete("r", "c");
        assert!(t.scan_row("r", &IterConfig::default()).is_empty());
    }
}
