//! The key-value store: a set of named tables, each sharded into tablets
//! by split points (the Accumulo tablet-server model, one process).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::iterator::IterConfig;
use super::key::{Entry, Key, RowRange};
use super::tablet::{Tablet, TabletConfig};
use crate::error::{D4mError, Result};

/// A table: tablets partitioned by sorted split points. Tablet `i` serves
/// rows in `[splits[i-1], splits[i])` (first/last unbounded).
pub struct Table {
    pub name: String,
    splits: Vec<String>,
    tablets: Vec<Mutex<Tablet>>,
    /// Logical clock for auto-timestamps.
    clock: AtomicU64,
}

impl Table {
    fn new(name: &str, splits: Vec<String>, cfg: TabletConfig) -> Self {
        debug_assert!(splits.windows(2).all(|w| w[0] < w[1]));
        let tablets = (0..=splits.len()).map(|_| Mutex::new(Tablet::new(cfg.clone()))).collect();
        Table { name: name.to_string(), splits, tablets, clock: AtomicU64::new(1) }
    }

    /// Index of the tablet serving `row`.
    pub fn tablet_for(&self, row: &str) -> usize {
        self.splits.partition_point(|s| s.as_str() <= row)
    }

    pub fn num_tablets(&self) -> usize {
        self.tablets.len()
    }

    pub fn splits(&self) -> &[String] {
        &self.splits
    }

    /// Next logical timestamp.
    pub fn next_ts(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Write one cell with an auto-assigned timestamp.
    pub fn put(&self, row: &str, cq: &str, value: &str) {
        let ts = self.next_ts();
        self.put_entry(Entry::new(Key::cell(row, cq, ts), value));
    }

    /// Write a fully-formed entry.
    pub fn put_entry(&self, e: Entry) {
        let t = self.tablet_for(&e.key.row);
        self.tablets[t].lock().unwrap().put(e);
    }

    /// Write a batch, grouping by tablet to take each lock once.
    pub fn put_batch(&self, entries: Vec<Entry>) {
        let mut by_tablet: Vec<Vec<Entry>> = (0..self.tablets.len()).map(|_| Vec::new()).collect();
        for e in entries {
            by_tablet[self.tablet_for(&e.key.row)].push(e);
        }
        for (t, batch) in by_tablet.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let mut tablet = self.tablets[t].lock().unwrap();
            for e in batch {
                tablet.put(e);
            }
        }
    }

    /// Scan a row range across all covered tablets, applying the iterator
    /// stack server-side. Results are in global key order.
    pub fn scan(&self, range: &RowRange, cfg: &IterConfig) -> Vec<Entry> {
        let mut out = Vec::new();
        for (i, tl) in self.tablets.iter().enumerate() {
            if !self.tablet_overlaps(i, range) {
                continue;
            }
            let mut t = tl.lock().unwrap();
            out.extend(t.scan(range, cfg));
        }
        out
    }

    /// Key-only scan: distinct row keys stored in `range`, sorted. Paged
    /// readers snapshot rows through this instead of a materialising
    /// [`Table::scan`] — no values are cloned and no iterator stack runs.
    /// Tablets are range-disjoint and visited in row order, so per-tablet
    /// results concatenate already sorted.
    pub fn scan_row_keys(&self, range: &RowRange) -> Vec<String> {
        let mut out = Vec::new();
        for (i, tl) in self.tablets.iter().enumerate() {
            if !self.tablet_overlaps(i, range) {
                continue;
            }
            out.extend(tl.lock().unwrap().row_keys_in(range));
        }
        out
    }

    /// Scan one row.
    pub fn scan_row(&self, row: &str, cfg: &IterConfig) -> Vec<Entry> {
        let range = RowRange::single(row);
        let t = self.tablet_for(row);
        self.tablets[t].lock().unwrap().scan(&range, cfg)
    }

    fn tablet_overlaps(&self, i: usize, range: &RowRange) -> bool {
        // tablet i covers [lo_i, hi_i)
        let lo = if i == 0 { None } else { Some(self.splits[i - 1].as_str()) };
        let hi = if i == self.splits.len() { None } else { Some(self.splits[i].as_str()) };
        if let (Some(end), Some(lo)) = (&range.end, lo) {
            if end.as_str() <= lo {
                return false;
            }
        }
        if let (Some(start), Some(hi)) = (&range.start, hi) {
            if start.as_str() >= hi {
                return false;
            }
        }
        true
    }

    /// Flush every tablet's memtable.
    pub fn flush(&self) {
        for t in &self.tablets {
            t.lock().unwrap().flush();
        }
    }

    /// Total raw entries (all versions) across tablets.
    pub fn raw_len(&self) -> usize {
        self.tablets.iter().map(|t| t.lock().unwrap().raw_len()).sum()
    }

    /// Approximate resident bytes.
    pub fn mem_bytes(&self) -> usize {
        self.tablets.iter().map(|t| t.lock().unwrap().mem_bytes()).sum()
    }
}

/// The store: named tables behind an `Arc` so scanners/writers share it.
#[derive(Default)]
pub struct KvStore {
    tables: RwLock<HashMap<String, Arc<Table>>>,
    tablet_config: TabletConfig,
}

impl KvStore {
    pub fn new() -> Self {
        KvStore::default()
    }

    pub fn with_config(tablet_config: TabletConfig) -> Self {
        KvStore { tables: RwLock::new(HashMap::new()), tablet_config }
    }

    /// Create a table with the given split points (empty = one tablet).
    pub fn create_table(&self, name: &str, splits: Vec<String>) -> Result<Arc<Table>> {
        let mut tables = self.tables.write().unwrap();
        if tables.contains_key(name) {
            return Err(D4mError::AlreadyExists(format!("table {name}")));
        }
        let t = Arc::new(Table::new(name, splits, self.tablet_config.clone()));
        tables.insert(name.to_string(), t.clone());
        Ok(t)
    }

    /// Create if missing, otherwise return the existing table.
    pub fn ensure_table(&self, name: &str, splits: Vec<String>) -> Arc<Table> {
        if let Some(t) = self.table(name) {
            return t;
        }
        self.create_table(name, splits).unwrap_or_else(|_| self.table(name).unwrap())
    }

    pub fn table(&self, name: &str) -> Option<Arc<Table>> {
        self.tables.read().unwrap().get(name).cloned()
    }

    pub fn table_or_err(&self, name: &str) -> Result<Arc<Table>> {
        self.table(name).ok_or_else(|| D4mError::NotFound(format!("table {name}")))
    }

    pub fn drop_table(&self, name: &str) -> Result<()> {
        self.tables
            .write()
            .unwrap()
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| D4mError::NotFound(format!("table {name}")))
    }

    pub fn list_tables(&self) -> Vec<String> {
        let mut v: Vec<String> = self.tables.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_scan_roundtrip() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r1", "c1", "a");
        t.put("r2", "c2", "b");
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn duplicate_create_fails() {
        let store = KvStore::new();
        store.create_table("t", vec![]).unwrap();
        assert!(store.create_table("t", vec![]).is_err());
    }

    #[test]
    fn split_routing() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["m".into()]).unwrap();
        assert_eq!(t.num_tablets(), 2);
        assert_eq!(t.tablet_for("a"), 0);
        assert_eq!(t.tablet_for("m"), 1);
        assert_eq!(t.tablet_for("z"), 1);
    }

    #[test]
    fn scan_across_tablets_in_order() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into(), "p".into()]).unwrap();
        for r in ["z", "a", "m", "q", "h"] {
            t.put(r, "c", "v");
        }
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        let rows: Vec<&str> = out.iter().map(|e| e.key.row.as_str()).collect();
        assert_eq!(rows, vec!["a", "h", "m", "q", "z"]);
    }

    #[test]
    fn scan_range_skips_tablets() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into()]).unwrap();
        t.put("a", "c", "1");
        t.put("z", "c", "2");
        let out = t.scan(&RowRange::span("x", "zz"), &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].key.row, "z");
    }

    #[test]
    fn scan_row_keys_across_tablets() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into(), "p".into()]).unwrap();
        for r in ["z", "a", "m", "q", "h", "a"] {
            t.put(r, "c", "v");
        }
        assert_eq!(t.scan_row_keys(&RowRange::all()), vec!["a", "h", "m", "q", "z"]);
        assert_eq!(t.scan_row_keys(&RowRange::span("h", "r")), vec!["h", "m", "q"]);
    }

    #[test]
    fn overwrite_latest_wins() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "first");
        t.put("r", "c", "second");
        let out = t.scan_row("r", &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "second");
    }

    #[test]
    fn summing_scan() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "2");
        t.put("r", "c", "3");
        let cfg = IterConfig { summing: true, ..Default::default() };
        assert_eq!(t.scan_row("r", &cfg)[0].value, "5");
    }

    #[test]
    fn concurrent_writers() {
        let store = Arc::new(KvStore::new());
        let t = store.create_table("t", vec!["g".into(), "r".into()]).unwrap();
        let hs: Vec<_> = (0..4)
            .map(|w| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for i in 0..500 {
                        t.put(&format!("{}{i:04}", (b'a' + w) as char), "c", "1");
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 2000);
    }

    #[test]
    fn drop_table_works() {
        let store = KvStore::new();
        store.create_table("t", vec![]).unwrap();
        store.drop_table("t").unwrap();
        assert!(store.table("t").is_none());
        assert!(store.drop_table("t").is_err());
    }
}

impl Table {
    /// Delete one cell (writes a tombstone; older versions become
    /// invisible to scans and are dropped at major compaction).
    pub fn delete(&self, row: &str, cq: &str) {
        let ts = self.next_ts();
        self.put_entry(Entry::delete(Key::cell(row, cq, ts)));
    }
}

#[cfg(test)]
mod delete_tests {
    use super::*;

    #[test]
    fn delete_hides_and_rewrite_restores() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "v1");
        t.delete("r", "c");
        assert!(t.scan_row("r", &IterConfig::default()).is_empty());
        t.put("r", "c", "v2");
        let out = t.scan_row("r", &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "v2");
    }

    #[test]
    fn delete_survives_flush_boundary() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "v1");
        t.flush();
        t.delete("r", "c");
        assert!(t.scan_row("r", &IterConfig::default()).is_empty());
    }
}
