//! The key-value store: a set of named tables, each sharded into tablets
//! by split points (the Accumulo tablet-server model, one process).
//!
//! §Reads: the scan path is snapshot-isolated and streaming end to end.
//! [`Table::scan_stream`] read-locks each overlapping tablet just long
//! enough to acquire its [`TabletSnapshot`], then returns a lazy
//! [`EntryStream`] in global key order — no tablet lock is held while
//! results are consumed, so readers never serialise against writers or
//! each other. [`Table::scan`] is the materialising form kept for tests
//! and point reads; on multi-tablet ranges it drains the per-tablet
//! snapshots in parallel with scoped threads (tablets are range-disjoint,
//! so concatenating in tablet order preserves global key order).
//!
//! §Durability: a store opened with [`KvStore::open`] keeps one
//! directory per table holding a write-ahead log, frozen-run files and a
//! manifest (see `storage/`). The write protocol is WAL-first: a batch
//! is appended (and flushed to the OS) before it touches a memtable, so
//! an acknowledged write survives `kill -9`. Checkpoints freeze each
//! memtable as an in-memory segment (readers never see a gap), write it
//! as a run file, swap the segment for its on-disk twin, rotate the WAL
//! and commit the new run list atomically through the manifest. A
//! background compactor merges on-disk runs past `max_runs`, and
//! `put_batch` blocks — bounded, surfacing [`D4mError::Backpressure`] —
//! while the store-wide compaction backlog exceeds its byte budget.
//! Recovery replays every WAL at or above the manifest's floor over the
//! manifest's runs, truncating torn tails at the first bad checksum.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use super::iterator::{EntryStream, IterConfig, MergeIter};
use super::key::{Entry, Key, RowRange};
use super::storage::{
    self, manifest, run, wal, DiskRun, Manifest, StorageConfig, StorageCounters, StorageGate,
    TableStorage, WalState, WalWriter,
};
use super::tablet::{Tablet, TabletConfig, TabletSnapshot};
use crate::error::{D4mError, Result};

/// Below this many raw snapshot entries a parallel materialising scan is
/// not worth the thread spawns; drain sequentially instead.
const PARALLEL_SCAN_MIN_ENTRIES: usize = 8192;

/// A table: tablets partitioned by sorted split points. Tablet `i` serves
/// rows in `[splits[i-1], splits[i])` (first/last unbounded).
pub struct Table {
    pub name: String,
    splits: Vec<String>,
    /// `RwLock`, not `Mutex`: concurrent readers acquire snapshots under
    /// a shared lock and only writers take it exclusively.
    tablets: Vec<RwLock<Tablet>>,
    /// Logical clock for auto-timestamps.
    clock: AtomicU64,
    /// Durable-state handle; `None` for in-memory tables (the default).
    storage: Option<TableStorage>,
}

impl Table {
    fn new(name: &str, splits: Vec<String>, cfg: TabletConfig) -> Self {
        Table::build(name, splits, cfg, None)
    }

    fn build(
        name: &str,
        splits: Vec<String>,
        cfg: TabletConfig,
        storage: Option<TableStorage>,
    ) -> Self {
        debug_assert!(splits.windows(2).all(|w| w[0] < w[1]));
        let tablet_cfg = if storage.is_some() {
            // durable tablets never flush inline: the checkpoint owns
            // freezing (it must rotate the WAL in the same step), and
            // the disk compactor owns merging
            TabletConfig { memtable_flush_bytes: usize::MAX, ..cfg }
        } else {
            cfg
        };
        let tablets = (0..=splits.len())
            .map(|_| RwLock::new(Tablet::new(tablet_cfg.clone())))
            .collect();
        Table {
            name: name.to_string(),
            splits,
            tablets,
            clock: AtomicU64::new(1),
            storage,
        }
    }

    /// Whether writes to this table are logged and checkpointed to disk.
    pub fn is_durable(&self) -> bool {
        self.storage.is_some()
    }

    /// Index of the tablet serving `row`.
    pub fn tablet_for(&self, row: &str) -> usize {
        self.splits.partition_point(|s| s.as_str() <= row)
    }

    pub fn num_tablets(&self) -> usize {
        self.tablets.len()
    }

    pub fn splits(&self) -> &[String] {
        &self.splits
    }

    /// Next logical timestamp.
    pub fn next_ts(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Write one cell with an auto-assigned timestamp.
    pub fn put(&self, row: &str, cq: &str, value: &str) -> Result<()> {
        let ts = self.next_ts();
        self.put_entry(Entry::new(Key::cell(row, cq, ts), value))
    }

    /// Write a fully-formed entry.
    pub fn put_entry(&self, e: Entry) -> Result<()> {
        if self.storage.is_some() {
            return self.put_batch(vec![e]);
        }
        let t = self.tablet_for(&e.key.row);
        self.tablets[t].write().unwrap().put(e);
        Ok(())
    }

    /// Delete one cell (writes a tombstone; older versions become
    /// invisible to scans and are dropped at major compaction).
    pub fn delete(&self, row: &str, cq: &str) -> Result<()> {
        let ts = self.next_ts();
        self.put_entry(Entry::delete(Key::cell(row, cq, ts)))
    }

    /// Write a batch. In-memory tables route it straight to the
    /// tablets; durable tables append it to the WAL first (flushed to
    /// the OS before the call returns, so an acknowledged batch survives
    /// `kill -9`), insert, then checkpoint if a memtable crossed its
    /// flush threshold. Blocks while the store-wide compaction backlog
    /// exceeds its budget, failing with [`D4mError::Backpressure`] after
    /// the configured timeout — in that case the batch was **not**
    /// applied.
    pub fn put_batch(&self, entries: Vec<Entry>) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        let Some(st) = &self.storage else {
            self.route_batch(entries);
            return Ok(());
        };
        match st
            .gate
            .wait_below(st.cfg.backlog_budget_bytes, st.cfg.backpressure_timeout, &self.name)
        {
            Ok(false) => {}
            Ok(true) => st.counters.backpressure_stalls.inc(),
            Err(e) => {
                st.counters.backpressure_stalls.inc();
                return Err(e);
            }
        }
        let need_checkpoint = {
            // `inner` held across append + insert: a concurrent
            // checkpoint can never freeze a memtable holding entries the
            // rotated-away WAL logged but the manifest's runs lack
            let mut inner = st.inner.lock().unwrap();
            inner.wal.append(&entries, st.cfg.group_commit_interval, &st.counters)?;
            self.route_batch(entries);
            self.tablets
                .iter()
                .any(|t| t.read().unwrap().memtable_bytes() >= st.flush_bytes)
        };
        if need_checkpoint {
            self.checkpoint()?;
        }
        Ok(())
    }

    /// Group a batch by tablet so each tablet lock is taken once. No
    /// per-tablet buffers: the single-tablet case (the common shape —
    /// row-sharded ingest workers and every one-tablet table) is
    /// detected with one routing pass, and the scattered case groups in
    /// place with a stable sort by tablet index (insertion order within
    /// a tablet is preserved).
    fn route_batch(&self, mut entries: Vec<Entry>) {
        if self.tablets.len() > 1 {
            let first = self.tablet_for(&entries[0].key.row);
            if !entries.iter().all(|e| self.tablet_for(&e.key.row) == first) {
                entries.sort_by_cached_key(|e| self.tablet_for(&e.key.row));
            }
        }
        let mut it = entries.into_iter().peekable();
        while let Some(e) = it.next() {
            let t = self.tablet_for(&e.key.row);
            let mut tablet = self.tablets[t].write().unwrap();
            tablet.put(e);
            while it.peek().map(|n| self.tablet_for(&n.key.row) == t).unwrap_or(false) {
                tablet.put(it.next().unwrap());
            }
        }
    }

    /// Freeze every tablet overlapping `range` into a point-in-time
    /// [`TableSnapshot`]. Each tablet's read lock is held only for the
    /// `Arc` clones of snapshot acquisition. The snapshot is per-tablet
    /// atomic (Accumulo's isolation unit), not cross-tablet atomic.
    pub fn snapshot_range(&self, range: &RowRange) -> TableSnapshot {
        let mut tablets = Vec::new();
        for (i, tl) in self.tablets.iter().enumerate() {
            if !self.tablet_overlaps(i, range) {
                continue;
            }
            tablets.push(tl.read().unwrap().snapshot());
        }
        TableSnapshot { tablets }
    }

    /// Streaming scan of a row range across all covered tablets, iterator
    /// stack applied server-side, results in global key order. Locks are
    /// dropped before the stream yields its first entry.
    pub fn scan_stream(&self, range: &RowRange, cfg: &IterConfig) -> EntryStream {
        self.snapshot_range(range).stream(range, cfg)
    }

    /// Materialising scan — a `collect()` of [`Table::scan_stream`], kept
    /// for tests and small reads; multi-tablet ranges drain their
    /// per-tablet snapshots in parallel (scoped threads).
    pub fn scan(&self, range: &RowRange, cfg: &IterConfig) -> Vec<Entry> {
        self.snapshot_range(range).collect_entries(range, cfg)
    }

    /// Key-only scan: distinct row keys stored in `range`, sorted. Paged
    /// readers snapshot rows through this instead of a materialising
    /// [`Table::scan`] — no values are cloned and no iterator stack runs.
    /// Tablets are range-disjoint and visited in row order, so per-tablet
    /// results concatenate already sorted.
    pub fn scan_row_keys(&self, range: &RowRange) -> Vec<String> {
        let mut out = Vec::new();
        for (i, tl) in self.tablets.iter().enumerate() {
            if !self.tablet_overlaps(i, range) {
                continue;
            }
            // snapshot under the read lock, walk after it is dropped —
            // the key walk must not stall writers
            let snap = tl.read().unwrap().snapshot();
            out.extend(snap.row_keys_in(range));
        }
        out
    }

    /// Scan one row (materialised; single tablet, small result).
    pub fn scan_row(&self, row: &str, cfg: &IterConfig) -> Vec<Entry> {
        self.scan_row_stream(row, cfg).collect()
    }

    /// Streaming scan of one row: one tablet snapshot, lock dropped
    /// before the first entry is pulled.
    pub fn scan_row_stream(&self, row: &str, cfg: &IterConfig) -> EntryStream {
        let range = RowRange::single(row);
        let t = self.tablet_for(row);
        let snap = self.tablets[t].read().unwrap().snapshot();
        snap.scan(&range, cfg)
    }

    fn tablet_overlaps(&self, i: usize, range: &RowRange) -> bool {
        // tablet i covers [lo_i, hi_i)
        let lo = if i == 0 { None } else { Some(self.splits[i - 1].as_str()) };
        let hi = if i == self.splits.len() { None } else { Some(self.splits[i].as_str()) };
        if let (Some(end), Some(lo)) = (&range.end, lo) {
            if end.as_str() <= lo {
                return false;
            }
        }
        if let (Some(start), Some(hi)) = (&range.start, hi) {
            if start.as_str() >= hi {
                return false;
            }
        }
        true
    }

    /// Flush every tablet's memtable: in-memory tables freeze it into a
    /// sorted run; durable tables run a full [`Table::checkpoint`].
    pub fn flush(&self) -> Result<()> {
        if self.storage.is_some() {
            return self.checkpoint();
        }
        for t in &self.tablets {
            t.write().unwrap().flush();
        }
        Ok(())
    }

    /// Durable checkpoint: freeze every non-empty memtable (readers keep
    /// seeing the entries through the frozen in-memory segment), write
    /// each as a fsync'd run file, swap the segments for their on-disk
    /// twins, rotate the WAL, commit the run list through the manifest,
    /// and delete the superseded logs. With nothing to freeze it just
    /// fsyncs the WAL — which is exactly graceful shutdown's contract.
    pub fn checkpoint(&self) -> Result<()> {
        let Some(st) = &self.storage else {
            return self.flush();
        };
        let mut inner = st.inner.lock().unwrap();
        let mut frozen: Vec<(usize, Arc<Vec<Entry>>)> = Vec::new();
        for (i, tl) in self.tablets.iter().enumerate() {
            if let Some(mem) = tl.write().unwrap().freeze_memtable() {
                frozen.push((i, mem));
            }
        }
        if frozen.is_empty() {
            return inner.wal.sync(&st.counters);
        }
        for (i, mem) in &frozen {
            let id = inner.next_file_id;
            inner.next_file_id += 1;
            let disk = DiskRun::create(&st.dir, id, mem)?;
            self.tablets[*i].write().unwrap().replace_mem_with_disk(mem, disk);
            st.counters.flushes.inc();
        }
        // rotate: appends after this checkpoint land in the next log
        let new_seq = inner.wal.seq() + 1;
        inner.wal = WalWriter::create(&st.dir, new_seq)?;
        inner.wal_floor = new_seq;
        let m = self.build_manifest(&inner);
        manifest::store(&st.dir, &m)?;
        // the old logs are fully superseded by the committed runs
        remove_wals_below(&st.dir, new_seq);
        drop(inner);
        self.refresh_debt();
        Ok(())
    }

    /// One round of disk compaction: find the first tablet with more
    /// than `max_runs` on-disk runs, merge its smallest runs (all
    /// versions and tombstones preserved — dropping superseded versions
    /// is a *major* compaction concern, and summing scans need every
    /// version), install the merged run through the manifest, and delete
    /// the victims. Returns whether any work happened.
    pub(crate) fn compact_disk_once(&self) -> Result<bool> {
        let Some(st) = &self.storage else {
            return Ok(false);
        };
        let keep = (st.max_runs / 2).max(1);
        let mut job: Option<(usize, Vec<Arc<DiskRun>>)> = None;
        for (i, tl) in self.tablets.iter().enumerate() {
            let mut disks = tl.read().unwrap().disk_runs();
            if disks.len() > st.max_runs {
                // merge the smallest, leave the `keep` largest
                // untouched; always at least two victims so every round
                // strictly shrinks the run count
                let n_merge = (disks.len() - keep).max(2).min(disks.len());
                disks.sort_by_key(|r| r.file_bytes());
                disks.truncate(n_merge);
                job = Some((i, disks));
                break;
            }
        }
        let Some((ti, victims)) = job else {
            return Ok(false);
        };
        // merge outside every lock: the victims are immutable files
        let sources: Vec<EntryStream> = victims
            .iter()
            .map(|r| Box::new(r.cursor(&RowRange::all())) as EntryStream)
            .collect();
        let merged: Vec<Entry> = MergeIter::new(sources).collect();
        let file_id = {
            let mut inner = st.inner.lock().unwrap();
            let id = inner.next_file_id;
            inner.next_file_id += 1;
            id
        };
        let merged_run = DiskRun::create(&st.dir, file_id, &merged)?;
        let victim_ids: Vec<u64> = victims.iter().map(|r| r.file_id()).collect();
        let installed = {
            let inner = st.inner.lock().unwrap();
            let swapped = self.tablets[ti]
                .write()
                .unwrap()
                .swap_disk_runs(&victim_ids, merged_run.clone());
            if swapped {
                manifest::store(&st.dir, &self.build_manifest(&inner))?;
            }
            swapped
        };
        if !installed {
            // a racing mutation invalidated the plan; discard our run
            let _ = std::fs::remove_file(merged_run.path());
            return Ok(false);
        }
        for v in &victims {
            // open snapshots keep streaming through their fd (unix
            // unlink semantics); the name is gone for future opens
            let _ = std::fs::remove_file(v.path());
        }
        st.counters.compactions.inc();
        self.refresh_debt();
        Ok(true)
    }

    /// Recompute and publish this table's compaction debt: the bytes of
    /// each tablet's smallest on-disk runs beyond `max_runs`.
    fn refresh_debt(&self) {
        let Some(st) = &self.storage else { return };
        let mut debt = 0u64;
        for tl in &self.tablets {
            let mut sizes: Vec<u64> =
                tl.read().unwrap().disk_runs().iter().map(|r| r.file_bytes()).collect();
            if sizes.len() > st.max_runs {
                sizes.sort_unstable();
                debt += sizes[..sizes.len() - st.max_runs].iter().sum::<u64>();
            }
        }
        st.gate.set(&self.name, debt);
    }

    /// Manifest snapshot of the current run lists. Callers hold `inner`,
    /// which serialises every run-list mutation — the per-tablet reads
    /// here are therefore mutually consistent.
    fn build_manifest(&self, inner: &WalState) -> Manifest {
        let tablet_runs = self
            .tablets
            .iter()
            .map(|tl| tl.read().unwrap().disk_runs().iter().map(|r| r.file_id()).collect())
            .collect();
        Manifest {
            wal_floor: inner.wal_floor,
            clock: self.clock.load(Ordering::Relaxed),
            next_file_id: inner.next_file_id,
            splits: self.splits.clone(),
            tablet_runs,
        }
    }

    /// Create a fresh durable table: directory, empty manifest, first WAL.
    pub(crate) fn create_durable(
        dir: PathBuf,
        name: &str,
        splits: Vec<String>,
        tablet_cfg: &TabletConfig,
        storage_cfg: &StorageConfig,
        counters: Arc<StorageCounters>,
        gate: Arc<StorageGate>,
    ) -> Result<Arc<Table>> {
        std::fs::create_dir_all(&dir)?;
        let m = Manifest {
            wal_floor: 1,
            clock: 1,
            next_file_id: 1,
            splits: splits.clone(),
            tablet_runs: vec![Vec::new(); splits.len() + 1],
        };
        manifest::store(&dir, &m)?;
        let wal = WalWriter::create(&dir, 1)?;
        let st = TableStorage {
            dir,
            cfg: storage_cfg.clone(),
            counters,
            gate,
            flush_bytes: tablet_cfg.memtable_flush_bytes,
            max_runs: tablet_cfg.max_runs,
            inner: Mutex::new(WalState { wal, wal_floor: 1, next_file_id: 1 }),
        };
        Ok(Arc::new(Table::build(name, splits, tablet_cfg.clone(), Some(st))))
    }

    /// Open a durable table from its directory: load the manifest, open
    /// and verify the live runs, sweep orphan files, replay every WAL at
    /// or above the floor (torn tails truncate at the first bad
    /// checksum), and start a fresh log for new appends — a possibly-torn
    /// file is never appended to.
    pub(crate) fn open_durable(
        dir: PathBuf,
        name: &str,
        tablet_cfg: &TabletConfig,
        storage_cfg: &StorageConfig,
        counters: Arc<StorageCounters>,
        gate: Arc<StorageGate>,
    ) -> Result<Arc<Table>> {
        let man = match manifest::load(&dir)? {
            Some(m) => m,
            // directory existed but the manifest was never committed: a
            // table creation that died mid-flight. Treat as fresh.
            None => Manifest {
                wal_floor: 0,
                clock: 1,
                next_file_id: 1,
                splits: Vec::new(),
                tablet_runs: vec![Vec::new()],
            },
        };
        let mut live = std::collections::HashSet::new();
        let mut tablet_disk: Vec<Vec<Arc<DiskRun>>> = Vec::with_capacity(man.tablet_runs.len());
        let mut max_seen_ts = man.clock;
        for ids in &man.tablet_runs {
            let mut runs = Vec::with_capacity(ids.len());
            for &id in ids {
                let r = DiskRun::open(&dir.join(run::run_file_name(id)), id)?;
                max_seen_ts = max_seen_ts.max(r.max_ts());
                live.insert(id);
                runs.push(r);
            }
            tablet_disk.push(runs);
        }
        // sweep: orphan run files (flush/compaction died before its
        // manifest commit) and superseded logs
        let mut wal_seqs: Vec<u64> = Vec::new();
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            let fname = entry.file_name();
            let Some(fname) = fname.to_str() else { continue };
            if let Some(id) = run::parse_run_id(fname) {
                if !live.contains(&id) {
                    let _ = std::fs::remove_file(entry.path());
                }
            } else if let Some(seq) = wal::parse_wal_seq(fname) {
                if seq < man.wal_floor {
                    let _ = std::fs::remove_file(entry.path());
                } else {
                    wal_seqs.push(seq);
                }
            }
        }
        wal_seqs.sort_unstable();
        let mut replayed: Vec<Entry> = Vec::new();
        for &seq in &wal_seqs {
            replayed.extend(wal::replay(&dir.join(wal::wal_file_name(seq)))?);
        }
        for e in &replayed {
            max_seen_ts = max_seen_ts.max(e.key.ts);
        }
        let new_seq = wal_seqs.last().copied().unwrap_or(man.wal_floor).max(man.wal_floor) + 1;
        let wal = WalWriter::create(&dir, new_seq)?;
        let st = TableStorage {
            dir,
            cfg: storage_cfg.clone(),
            counters,
            gate,
            flush_bytes: tablet_cfg.memtable_flush_bytes,
            max_runs: tablet_cfg.max_runs,
            inner: Mutex::new(WalState {
                wal,
                wal_floor: man.wal_floor,
                next_file_id: man.next_file_id,
            }),
        };
        let table = Table::build(name, man.splits.clone(), tablet_cfg.clone(), Some(st));
        for (i, runs) in tablet_disk.into_iter().enumerate() {
            table.tablets[i].write().unwrap().set_disk_runs(runs);
        }
        table.clock.store(max_seen_ts + 1, Ordering::Relaxed);
        // the replayed entries sit in memtables backed by the old WALs
        // (all >= floor, so a crash before the next checkpoint replays
        // them again — the old logs stay until the floor moves past them)
        if !replayed.is_empty() {
            table.route_batch(replayed);
        }
        table.refresh_debt();
        Ok(Arc::new(table))
    }

    /// Total raw entries (all versions) across tablets.
    pub fn raw_len(&self) -> usize {
        self.tablets.iter().map(|t| t.read().unwrap().raw_len()).sum()
    }

    /// Approximate resident bytes (on-disk runs count nothing).
    pub fn mem_bytes(&self) -> usize {
        self.tablets.iter().map(|t| t.read().unwrap().mem_bytes()).sum()
    }
}

/// Delete every `wal-*.log` in `dir` with a sequence below `floor`.
fn remove_wals_below(dir: &Path, floor: u64) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let fname = entry.file_name();
        if let Some(seq) = fname.to_str().and_then(wal::parse_wal_seq) {
            if seq < floor {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

/// Point-in-time view of the tablets a scan covers, in key order.
/// Cloning shares the frozen segments. Streams and materialised scans
/// built from the same snapshot observe bit-identical data regardless of
/// concurrent writers.
#[derive(Debug, Clone)]
pub struct TableSnapshot {
    tablets: Vec<TabletSnapshot>,
}

impl TableSnapshot {
    /// Lazy stream in global key order: per-tablet streams (each already
    /// running the full iterator stack) k-way merged. Tablets are
    /// range-disjoint and ordered, so the merge degenerates to
    /// concatenation cost-wise while staying correct in general.
    pub fn stream(&self, range: &RowRange, cfg: &IterConfig) -> EntryStream {
        let mut sources: Vec<EntryStream> =
            self.tablets.iter().map(|t| t.scan(range, cfg)).collect();
        match sources.len() {
            0 => Box::new(std::iter::empty()),
            1 => sources.pop().unwrap(),
            _ => Box::new(MergeIter::new(sources)),
        }
    }

    /// Materialise the scan, draining disjoint tablets in parallel with
    /// scoped threads when the range spans several and the snapshot is
    /// big enough to amortise the spawns. Output is concatenated in
    /// tablet order — identical to [`TableSnapshot::stream`] collected.
    pub fn collect_entries(&self, range: &RowRange, cfg: &IterConfig) -> Vec<Entry> {
        // size the decision to the range-restricted work (binary
        // searched per segment), not the whole snapshot — point reads
        // on a big table must not spawn threads
        let work: usize = self.tablets.iter().map(|t| t.raw_len_in(range)).sum();
        if self.tablets.len() <= 1 || work < PARALLEL_SCAN_MIN_ENTRIES {
            return self.stream(range, cfg).collect();
        }
        let mut parts: Vec<Vec<Entry>> = Vec::with_capacity(self.tablets.len());
        std::thread::scope(|s| {
            let handles: Vec<_> = self
                .tablets
                .iter()
                .map(|t| s.spawn(move || t.scan(range, cfg).collect::<Vec<Entry>>()))
                .collect();
            for h in handles {
                parts.push(h.join().expect("parallel scan worker panicked"));
            }
        });
        let total: usize = parts.iter().map(Vec::len).sum();
        let mut out = Vec::with_capacity(total);
        for p in parts {
            out.extend(p);
        }
        out
    }

    /// Stored entries in the snapshot (all versions, before the stack).
    pub fn raw_len(&self) -> usize {
        self.tablets.iter().map(TabletSnapshot::raw_len).sum()
    }
}

/// Durable-store state shared by every table: the data directory, the
/// backpressure gate, the counters, and the background compactor.
struct DurableState {
    dir: PathBuf,
    cfg: StorageConfig,
    counters: Arc<StorageCounters>,
    gate: Arc<StorageGate>,
    stop: Arc<AtomicBool>,
    compactor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// The store: named tables behind an `Arc` so scanners/writers share it.
#[derive(Default)]
pub struct KvStore {
    /// `Arc` so the compactor thread can walk the table list without
    /// holding the store itself alive (the store joins it on drop).
    tables: Arc<RwLock<HashMap<String, Arc<Table>>>>,
    tablet_config: TabletConfig,
    durable: Option<DurableState>,
}

impl KvStore {
    pub fn new() -> Self {
        KvStore::default()
    }

    pub fn with_config(tablet_config: TabletConfig) -> Self {
        KvStore { tables: Arc::default(), tablet_config, durable: None }
    }

    /// Open (or initialise) a durable store rooted at `dir`: every
    /// subdirectory holding a manifest is recovered as a table, orphan
    /// files are swept, torn WAL tails are truncated, and the background
    /// compactor starts. Corrupt run files or manifests surface as typed
    /// [`D4mError::Storage`] — never a panic.
    pub fn open(
        dir: impl Into<PathBuf>,
        tablet_config: TabletConfig,
        storage_config: StorageConfig,
    ) -> Result<KvStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let counters = Arc::new(StorageCounters::new());
        let gate = Arc::new(StorageGate::new());
        let mut tables = HashMap::new();
        let mut names: Vec<(String, PathBuf)> = Vec::new();
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            if !entry.file_type()?.is_dir() {
                continue;
            }
            let dname = entry.file_name();
            let Some(dname) = dname.to_str() else { continue };
            let Some(name) = storage::unescape_table_name(dname) else { continue };
            names.push((name, entry.path()));
        }
        names.sort(); // deterministic recovery order
        for (name, path) in names {
            let t = Table::open_durable(
                path,
                &name,
                &tablet_config,
                &storage_config,
                Arc::clone(&counters),
                Arc::clone(&gate),
            )?;
            tables.insert(name, t);
        }
        let tables = Arc::new(RwLock::new(tables));
        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let tables = Arc::clone(&tables);
            let gate = Arc::clone(&gate);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("d4m-compactor".into())
                .spawn(move || compactor_loop(&tables, &gate, &stop))
                .expect("spawn compactor thread")
        };
        Ok(KvStore {
            tables,
            tablet_config,
            durable: Some(DurableState {
                dir,
                cfg: storage_config,
                counters,
                gate,
                stop,
                compactor: Mutex::new(Some(handle)),
            }),
        })
    }

    /// Whether this store persists tables to disk.
    pub fn is_durable(&self) -> bool {
        self.durable.is_some()
    }

    /// The data directory of a durable store.
    pub fn data_dir(&self) -> Option<&Path> {
        self.durable.as_ref().map(|d| d.dir.as_path())
    }

    /// Storage counters of a durable store (for metrics snapshots).
    pub fn storage_counters(&self) -> Option<Arc<StorageCounters>> {
        self.durable.as_ref().map(|d| Arc::clone(&d.counters))
    }

    /// Checkpoint every table: flush memtables to runs and fsync WALs.
    /// Graceful shutdown calls this before acknowledging, so a clean
    /// stop never relies on recovery.
    pub fn checkpoint(&self) -> Result<()> {
        for name in self.list_tables() {
            if let Some(t) = self.table(&name) {
                t.checkpoint()?;
            }
        }
        Ok(())
    }

    /// Create a table with the given split points (empty = one tablet).
    pub fn create_table(&self, name: &str, splits: Vec<String>) -> Result<Arc<Table>> {
        let mut tables = self.tables.write().unwrap();
        if tables.contains_key(name) {
            return Err(D4mError::AlreadyExists(format!("table {name}")));
        }
        let t = match &self.durable {
            Some(d) => Table::create_durable(
                d.dir.join(storage::escape_table_name(name)),
                name,
                splits,
                &self.tablet_config,
                &d.cfg,
                Arc::clone(&d.counters),
                Arc::clone(&d.gate),
            )?,
            None => Arc::new(Table::new(name, splits, self.tablet_config.clone())),
        };
        tables.insert(name.to_string(), t.clone());
        Ok(t)
    }

    /// Create if missing, otherwise return the existing table. Only a
    /// durable store can fail here (directory/WAL creation).
    pub fn ensure_table(&self, name: &str, splits: Vec<String>) -> Result<Arc<Table>> {
        if let Some(t) = self.table(name) {
            return Ok(t);
        }
        match self.create_table(name, splits) {
            Ok(t) => Ok(t),
            Err(D4mError::AlreadyExists(_)) => self.table_or_err(name),
            Err(e) => Err(e),
        }
    }

    pub fn table(&self, name: &str) -> Option<Arc<Table>> {
        self.tables.read().unwrap().get(name).cloned()
    }

    pub fn table_or_err(&self, name: &str) -> Result<Arc<Table>> {
        self.table(name).ok_or_else(|| D4mError::NotFound(format!("table {name}")))
    }

    pub fn drop_table(&self, name: &str) -> Result<()> {
        let t = self
            .tables
            .write()
            .unwrap()
            .remove(name)
            .ok_or_else(|| D4mError::NotFound(format!("table {name}")))?;
        if let Some(d) = &self.durable {
            d.gate.set(name, 0);
            let _ = std::fs::remove_dir_all(d.dir.join(storage::escape_table_name(name)));
        }
        drop(t);
        Ok(())
    }

    pub fn list_tables(&self) -> Vec<String> {
        let mut v: Vec<String> = self.tables.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

impl Drop for KvStore {
    fn drop(&mut self) {
        if let Some(d) = &self.durable {
            d.stop.store(true, Ordering::Relaxed);
            d.gate.poke();
            if let Some(h) = d.compactor.lock().unwrap().take() {
                let _ = h.join();
            }
        }
    }
}

/// Background compaction loop: repeatedly give every table one round of
/// disk compaction; park on the gate (woken by new debt) when a full
/// sweep found nothing to merge. Transient I/O errors are retried on
/// the next sweep — the manifest protocol keeps every intermediate
/// state recoverable.
fn compactor_loop(
    tables: &RwLock<HashMap<String, Arc<Table>>>,
    gate: &StorageGate,
    stop: &AtomicBool,
) {
    while !stop.load(Ordering::Relaxed) {
        let snapshot: Vec<Arc<Table>> = tables.read().unwrap().values().cloned().collect();
        let mut worked = false;
        for t in snapshot {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            if let Ok(true) = t.compact_disk_once() {
                worked = true;
            }
        }
        if !worked {
            gate.wait_for_work(Duration::from_millis(100));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn create_scan_roundtrip() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r1", "c1", "a").unwrap();
        t.put("r2", "c2", "b").unwrap();
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        assert_eq!(out.len(), 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn duplicate_create_fails() {
        let store = KvStore::new();
        store.create_table("t", vec![]).unwrap();
        assert!(store.create_table("t", vec![]).is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn split_routing() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["m".into()]).unwrap();
        assert_eq!(t.num_tablets(), 2);
        assert_eq!(t.tablet_for("a"), 0);
        assert_eq!(t.tablet_for("m"), 1);
        assert_eq!(t.tablet_for("z"), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn scan_across_tablets_in_order() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into(), "p".into()]).unwrap();
        for r in ["z", "a", "m", "q", "h"] {
            t.put(r, "c", "v").unwrap();
        }
        let out = t.scan(&RowRange::all(), &IterConfig::default());
        let rows: Vec<&str> = out.iter().map(|e| e.key.row.as_str()).collect();
        assert_eq!(rows, vec!["a", "h", "m", "q", "z"]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn scan_range_skips_tablets() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into()]).unwrap();
        t.put("a", "c", "1").unwrap();
        t.put("z", "c", "2").unwrap();
        let out = t.scan(&RowRange::span("x", "zz"), &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].key.row, "z");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn scan_row_keys_across_tablets() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into(), "p".into()]).unwrap();
        for r in ["z", "a", "m", "q", "h", "a"] {
            t.put(r, "c", "v").unwrap();
        }
        assert_eq!(t.scan_row_keys(&RowRange::all()), vec!["a", "h", "m", "q", "z"]);
        assert_eq!(t.scan_row_keys(&RowRange::span("h", "r")), vec!["h", "m", "q"]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn overwrite_latest_wins() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "first").unwrap();
        t.put("r", "c", "second").unwrap();
        let out = t.scan_row("r", &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "second");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn summing_scan() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "2").unwrap();
        t.put("r", "c", "3").unwrap();
        let cfg = IterConfig { summing: true, ..Default::default() };
        assert_eq!(t.scan_row("r", &cfg)[0].value, "5");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn concurrent_writers() {
        let store = Arc::new(KvStore::new());
        let t = store.create_table("t", vec!["g".into(), "r".into()]).unwrap();
        let hs: Vec<_> = (0..4)
            .map(|w| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for i in 0..500 {
                        t.put(&format!("{}{i:04}", (b'a' + w) as char), "c", "1").unwrap();
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 2000);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn drop_table_works() {
        let store = KvStore::new();
        store.create_table("t", vec![]).unwrap();
        store.drop_table("t").unwrap();
        assert!(store.table("t").is_none());
        assert!(store.drop_table("t").is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn put_batch_scattered_across_tablets() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into(), "p".into()]).unwrap();
        let entries: Vec<Entry> = ["z", "a", "m", "q", "h", "b"]
            .iter()
            .map(|r| Entry::new(Key::cell(*r, "c", t.next_ts()), "v"))
            .collect();
        t.put_batch(entries).unwrap();
        let rows: Vec<String> = t
            .scan(&RowRange::all(), &IterConfig::default())
            .into_iter()
            .map(|e| e.key.row)
            .collect();
        assert_eq!(rows, vec!["a", "b", "h", "m", "q", "z"]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn put_batch_preserves_version_order_within_tablet() {
        // two versions of one cell in a single batch: the later ts must
        // win regardless of the grouping strategy
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into()]).unwrap();
        let e1 = Entry::new(Key::cell("a", "c", t.next_ts()), "old");
        let z = Entry::new(Key::cell("z", "c", t.next_ts()), "far");
        let e2 = Entry::new(Key::cell("a", "c", t.next_ts()), "new");
        t.put_batch(vec![e1, z, e2]).unwrap();
        let out = t.scan_row("a", &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "new");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn table_snapshot_stream_equals_parallel_collect() {
        let store = KvStore::new();
        let t = store.create_table("t", vec!["h".into(), "p".into()]).unwrap();
        for i in 0..10_000 {
            t.put(&format!("{}{i:05}", ["a", "j", "r"][i % 3]), "c", &i.to_string()).unwrap();
        }
        t.flush().unwrap();
        let snap = t.snapshot_range(&RowRange::all());
        // big enough that collect_entries takes the scoped-thread path
        assert!(snap.raw_len() >= PARALLEL_SCAN_MIN_ENTRIES);
        let cfg = IterConfig::default();
        let streamed: Vec<Entry> = snap.stream(&RowRange::all(), &cfg).collect();
        let collected = snap.collect_entries(&RowRange::all(), &cfg);
        assert_eq!(streamed, collected);
        assert!(streamed.windows(2).all(|w| w[0].key < w[1].key));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn writer_progresses_while_stream_open() {
        // the stream must not pin any tablet lock: a same-thread write
        // between stream creation and consumption would deadlock if it
        // did
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("a", "c", "1").unwrap();
        let stream = t.scan_stream(&RowRange::all(), &IterConfig::default());
        t.put("b", "c", "2").unwrap();
        t.flush().unwrap();
        let seen: Vec<Entry> = stream.collect();
        assert_eq!(seen.len(), 1, "snapshot must not see the later write");
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 2);
    }
}

#[cfg(test)]
mod durable_tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as TestCounter;

    fn tmp_dir(tag: &str) -> PathBuf {
        static N: TestCounter = TestCounter::new(0);
        let d = std::env::temp_dir().join(format!(
            "d4m-store-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        // fresh every time: a leftover dir would be recovered as state
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn small_tablets() -> TabletConfig {
        TabletConfig { memtable_flush_bytes: 512, max_runs: 4 }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn durable_roundtrip_after_checkpoint() {
        let dir = tmp_dir("roundtrip");
        let reference;
        {
            let store =
                KvStore::open(&dir, small_tablets(), StorageConfig::default()).unwrap();
            let t = store.create_table("t", vec!["m".into()]).unwrap();
            assert!(t.is_durable());
            for i in 0..100 {
                t.put(&format!("r{i:04}"), "c", &i.to_string()).unwrap();
            }
            t.checkpoint().unwrap();
            reference = t.scan(&RowRange::all(), &IterConfig::default());
            assert_eq!(reference.len(), 100);
        }
        let store = KvStore::open(&dir, small_tablets(), StorageConfig::default()).unwrap();
        let t = store.table("t").expect("table recovered");
        assert_eq!(t.splits(), &["m".to_string()]);
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()), reference);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn recovery_replays_unflushed_wal() {
        let dir = tmp_dir("replay");
        let reference;
        {
            let store =
                KvStore::open(&dir, TabletConfig::default(), StorageConfig::default()).unwrap();
            let t = store.create_table("t", vec![]).unwrap();
            // batches small enough that no checkpoint triggers: data
            // lives only in the WAL + memtable when the store drops
            for i in 0..30 {
                t.put(&format!("r{i:04}"), "c", "1").unwrap();
            }
            reference = t.scan(&RowRange::all(), &IterConfig::default());
        }
        let store = KvStore::open(&dir, TabletConfig::default(), StorageConfig::default()).unwrap();
        let t = store.table("t").unwrap();
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()), reference);
        // timestamps keep advancing monotonically after recovery
        t.put("zzz", "c", "later").unwrap();
        let latest = t.scan_row("zzz", &IterConfig::default());
        assert!(latest[0].key.ts > reference.last().unwrap().key.ts);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn deletes_and_summing_survive_reopen() {
        let dir = tmp_dir("semantics");
        {
            let store =
                KvStore::open(&dir, small_tablets(), StorageConfig::default()).unwrap();
            let t = store.create_table("t", vec![]).unwrap();
            t.put("gone", "c", "x").unwrap();
            t.checkpoint().unwrap();
            t.delete("gone", "c").unwrap();
            t.put("sum", "c", "3").unwrap();
            t.checkpoint().unwrap();
            t.put("sum", "c", "4").unwrap();
        }
        let store = KvStore::open(&dir, small_tablets(), StorageConfig::default()).unwrap();
        let t = store.table("t").unwrap();
        assert!(t.scan_row("gone", &IterConfig::default()).is_empty(), "tombstone lost");
        let cfg = IterConfig { summing: true, ..Default::default() };
        assert_eq!(t.scan_row("sum", &cfg)[0].value, "7", "a version was lost or doubled");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn checkpoint_rotates_and_prunes_wals() {
        let dir = tmp_dir("rotate");
        let store = KvStore::open(&dir, small_tablets(), StorageConfig::default()).unwrap();
        let t = store.create_table("t", vec![]).unwrap();
        for i in 0..50 {
            t.put(&format!("r{i:04}"), "c", "v").unwrap();
        }
        t.checkpoint().unwrap();
        let tdir = dir.join(storage::escape_table_name("t"));
        let mut wals = 0;
        let mut runs = 0;
        for e in std::fs::read_dir(&tdir).unwrap() {
            let name = e.unwrap().file_name();
            let name = name.to_str().unwrap().to_string();
            if wal::parse_wal_seq(&name).is_some() {
                wals += 1;
            }
            if run::parse_run_id(&name).is_some() {
                runs += 1;
            }
        }
        assert_eq!(wals, 1, "superseded WALs must be deleted after checkpoint");
        assert!(runs >= 1, "checkpoint must have written a run file");
        assert!(store.storage_counters().unwrap().flushes.get() >= 1);
        assert!(store.storage_counters().unwrap().wal_bytes_appended.get() > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn compactor_drains_excess_runs() {
        let dir = tmp_dir("compact");
        let cfg = TabletConfig { memtable_flush_bytes: 128, max_runs: 2 };
        let store = KvStore::open(&dir, cfg, StorageConfig::default()).unwrap();
        let t = store.create_table("t", vec![]).unwrap();
        // every checkpoint makes one run; far more than max_runs
        for batch in 0..8 {
            for i in 0..10 {
                t.put(&format!("r{batch}{i:03}"), "c", "1").unwrap();
            }
            t.checkpoint().unwrap();
        }
        // the background thread owes merges now; wait for it to settle
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let runs = t.tablets[0].read().unwrap().disk_runs().len();
            if runs <= 2 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "compactor left {runs} runs after 10s"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(store.storage_counters().unwrap().compactions.get() >= 1);
        // no data lost across the merges
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 80);
        // and the merged state recovers
        drop(store);
        let store = KvStore::open(
            &dir,
            TabletConfig { memtable_flush_bytes: 128, max_runs: 2 },
            StorageConfig::default(),
        )
        .unwrap();
        let t = store.table("t").unwrap();
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 80);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn backpressure_surfaces_typed_after_timeout() {
        let dir = tmp_dir("backpressure");
        // a standalone durable table has no compactor: debt only grows,
        // so the stall deterministically times out
        let counters = Arc::new(StorageCounters::new());
        let gate = Arc::new(StorageGate::new());
        let tablet_cfg = TabletConfig { memtable_flush_bytes: 64, max_runs: 1 };
        let storage_cfg = StorageConfig {
            group_commit_interval: Duration::ZERO,
            backlog_budget_bytes: 0,
            backpressure_timeout: Duration::from_millis(50),
        };
        std::fs::create_dir_all(&dir).unwrap();
        let t = Table::create_durable(
            dir.join("t"),
            "t",
            vec![],
            &tablet_cfg,
            &storage_cfg,
            Arc::clone(&counters),
            Arc::clone(&gate),
        )
        .unwrap();
        let big = "x".repeat(100);
        // first two batches each auto-checkpoint into a run; the second
        // run exceeds max_runs=1 and puts the table in debt
        t.put("a", "c", &big).unwrap();
        t.put("b", "c", &big).unwrap();
        assert!(gate.total() > 0, "expected compaction debt");
        match t.put("c", "c", &big) {
            Err(D4mError::Backpressure { table, waited_ms }) => {
                assert_eq!(table, "t");
                assert!(waited_ms >= 50);
            }
            other => panic!("expected Backpressure, got {other:?}"),
        }
        assert_eq!(counters.backpressure_stalls.get(), 1);
        // the rejected write was not applied
        assert!(t.scan_row("c", &IterConfig::default()).is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn dropping_durable_table_removes_directory() {
        let dir = tmp_dir("droptable");
        let store = KvStore::open(&dir, small_tablets(), StorageConfig::default()).unwrap();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "v").unwrap();
        drop(t);
        let tdir = dir.join(storage::escape_table_name("t"));
        assert!(tdir.is_dir());
        store.drop_table("t").unwrap();
        assert!(!tdir.exists(), "table directory must be removed");
        drop(store);
        // a reopen does not resurrect the dropped table
        let store = KvStore::open(&dir, small_tablets(), StorageConfig::default()).unwrap();
        assert!(store.table("t").is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn table_names_are_escaped_on_disk() {
        let dir = tmp_dir("escape");
        let name = "../evil/..";
        {
            let store =
                KvStore::open(&dir, small_tablets(), StorageConfig::default()).unwrap();
            let t = store.create_table(name, vec![]).unwrap();
            t.put("r", "c", "v").unwrap();
        }
        // nothing escaped the data dir, and the table recovers by name
        assert!(!dir.parent().unwrap().join("evil").exists());
        let store = KvStore::open(&dir, small_tablets(), StorageConfig::default()).unwrap();
        let t = store.table(name).expect("escaped table recovered");
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[cfg(test)]
mod delete_tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn delete_hides_and_rewrite_restores() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "v1").unwrap();
        t.delete("r", "c").unwrap();
        assert!(t.scan_row("r", &IterConfig::default()).is_empty());
        t.put("r", "c", "v2").unwrap();
        let out = t.scan_row("r", &IterConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "v2");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn delete_survives_flush_boundary() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        t.put("r", "c", "v1").unwrap();
        t.flush().unwrap();
        t.delete("r", "c").unwrap();
        assert!(t.scan_row("r", &IterConfig::default()).is_empty());
    }
}
