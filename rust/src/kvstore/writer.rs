//! BatchWriter — the buffered ingest client of the key-value store,
//! mirroring Accumulo's `BatchWriter`: mutations accumulate in a local
//! buffer grouped by destination tablet and flush when size/count
//! thresholds trip. This is the unit the ingest pipeline parallelises.

use std::sync::Arc;

use super::key::{Entry, Key};
use super::store::Table;
use crate::error::Result;
use crate::metrics::Counter;

/// BatchWriter tuning.
#[derive(Debug, Clone)]
pub struct WriterConfig {
    /// Flush when the buffer reaches this many entries.
    pub max_batch: usize,
    /// Flush when buffered bytes reach this threshold.
    pub max_bytes: usize,
}

impl Default for WriterConfig {
    fn default() -> Self {
        WriterConfig { max_batch: 10_000, max_bytes: 4 << 20 }
    }
}

/// Buffered writer bound to one table.
pub struct BatchWriter {
    table: Arc<Table>,
    buf: Vec<Entry>,
    buf_bytes: usize,
    config: WriterConfig,
    written: Counter,
    flushes: Counter,
}

impl BatchWriter {
    pub fn new(table: Arc<Table>, config: WriterConfig) -> Self {
        BatchWriter {
            table,
            buf: Vec::with_capacity(config.max_batch),
            buf_bytes: 0,
            config,
            written: Counter::new(),
            flushes: Counter::new(),
        }
    }

    /// Queue one mutation (auto-timestamped). Fails only when a
    /// threshold trips and the resulting flush fails (durable tables:
    /// WAL I/O or backpressure) — the buffer is kept, so retrying is
    /// safe.
    pub fn put(&mut self, row: &str, cq: &str, value: &str) -> Result<()> {
        let ts = self.table.next_ts();
        self.put_entry(Entry::new(Key::cell(row, cq, ts), value))
    }

    /// Queue a fully-formed entry.
    pub fn put_entry(&mut self, e: Entry) -> Result<()> {
        self.buf_bytes += e.bytes();
        self.buf.push(e);
        if self.buf.len() >= self.config.max_batch || self.buf_bytes >= self.config.max_bytes {
            return self.flush();
        }
        Ok(())
    }

    /// Push the buffer into the table (grouped by tablet inside
    /// `put_batch` so each tablet lock is taken once per flush).
    /// `put_batch` rejects batches whole, so on failure nothing was
    /// applied and nothing is counted — but the rejected batch is gone;
    /// a caller that wants to retry must re-queue its mutations.
    pub fn flush(&mut self) -> Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let batch = std::mem::take(&mut self.buf);
        self.buf_bytes = 0;
        let n = batch.len() as u64;
        self.table.put_batch(batch)?;
        self.written.add(n);
        self.flushes.inc();
        Ok(())
    }

    /// Total entries pushed to the table so far (excludes buffered).
    pub fn written(&self) -> u64 {
        self.written.get()
    }

    pub fn flushes(&self) -> u64 {
        self.flushes.get()
    }
}

impl Drop for BatchWriter {
    fn drop(&mut self) {
        // best effort: callers that need the error (or the durability
        // guarantee) must flush explicitly before dropping
        let _ = self.flush();
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;
    use crate::kvstore::iterator::IterConfig;
    use crate::kvstore::key::RowRange;
    use crate::kvstore::store::KvStore;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn batches_by_count() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        let mut w = BatchWriter::new(t.clone(), WriterConfig { max_batch: 10, max_bytes: 1 << 30 });
        for i in 0..25 {
            w.put(&format!("r{i:03}"), "c", "v").unwrap();
        }
        assert_eq!(w.flushes(), 2); // two full batches, 5 still buffered
        assert_eq!(w.written(), 20);
        w.flush().unwrap();
        assert_eq!(w.written(), 25);
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 25);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn batches_by_bytes() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        let mut w =
            BatchWriter::new(t.clone(), WriterConfig { max_batch: 1_000_000, max_bytes: 200 });
        for i in 0..20 {
            w.put(&format!("row_number_{i:06}"), "column", "value").unwrap();
        }
        assert!(w.flushes() >= 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn drop_flushes() {
        let store = KvStore::new();
        let t = store.create_table("t", vec![]).unwrap();
        {
            let mut w = BatchWriter::new(t.clone(), WriterConfig::default());
            w.put("r", "c", "v").unwrap();
        } // dropped here
        assert_eq!(t.scan(&RowRange::all(), &IterConfig::default()).len(), 1);
    }
}
