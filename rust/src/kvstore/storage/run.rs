//! On-disk frozen runs.
//!
//! A run file holds one immutable sorted run of entries, the durable
//! twin of the in-memory `Arc<Vec<Entry>>` runs a tablet accumulates:
//!
//! ```text
//! "D4MR" ver(u8)                                 — 5-byte header
//! [payload_len u32][crc32 u32][payload] ...      — entry blocks (~32 KiB)
//! index: varint n_blocks,
//!        per block { varint offset, varint len, varint count,
//!                    str first_row, str last_row },
//!        varint entry_count, varint max_ts
//! [index_offset u64][index_crc u32]["D4MF"]      — 16-byte footer
//! ```
//!
//! Block payloads are a varint count plus encoded entries, sorted by
//! key. `open` verifies everything once — footer magic, index bounds and
//! checksum, every block's checksum — so corruption surfaces as a typed
//! error at recovery time, never mid-scan. Scans then read blocks
//! lazily through the sparse row index, giving `DiskCursor` the same
//! pull-based shape as the in-memory `RunCursor`.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::codec::{self, Reader};
use crate::error::{D4mError, Result};
use crate::kvstore::key::{Entry, RowRange};

pub const RUN_MAGIC: &[u8; 4] = b"D4MR";
pub const RUN_FOOTER_MAGIC: &[u8; 4] = b"D4MF";
pub const RUN_VERSION: u8 = 1;
const HEADER_LEN: u64 = 5;
const FOOTER_LEN: u64 = 16;
/// Target payload bytes per block — small enough that point scans read
/// little, large enough that full scans are few syscalls.
const BLOCK_TARGET_BYTES: usize = 32 << 10;
/// Sanity cap on a single block; an index claiming more is corrupt.
const MAX_BLOCK: u64 = 64 << 20;

/// `run-{file_id:016x}.run`
pub fn run_file_name(file_id: u64) -> String {
    format!("run-{file_id:016x}.run")
}

/// Inverse of [`run_file_name`]; `None` for anything else.
pub fn parse_run_id(name: &str) -> Option<u64> {
    let hex = name.strip_prefix("run-")?.strip_suffix(".run")?;
    if hex.len() != 16 {
        return None;
    }
    u64::from_str_radix(hex, 16).ok()
}

#[derive(Debug, Clone)]
struct BlockMeta {
    offset: u64,
    len: u32,
    count: u32,
    first_row: String,
    last_row: String,
}

/// An opened, verified run file. Immutable and `Arc`-shared exactly like
/// an in-memory run; reads go through a shared seek-locked handle.
#[derive(Debug)]
pub struct DiskRun {
    path: PathBuf,
    file_id: u64,
    file: Mutex<File>,
    blocks: Vec<BlockMeta>,
    entry_count: usize,
    max_ts: u64,
    file_bytes: u64,
}

impl DiskRun {
    /// Write `entries` (sorted by key) as `run-<file_id>.run` in `dir`,
    /// fsync file and directory, and open the result verified.
    pub fn create(dir: &Path, file_id: u64, entries: &[Entry]) -> Result<Arc<DiskRun>> {
        debug_assert!(entries.windows(2).all(|w| matches!(w, [a, b] if a.key <= b.key)));
        let path = dir.join(run_file_name(file_id));
        let file = OpenOptions::new().write(true).create_new(true).open(&path)?;
        let mut out = BufWriter::new(file);
        out.write_all(RUN_MAGIC)?;
        out.write_all(&[RUN_VERSION])?;
        let mut offset = HEADER_LEN;
        let mut metas: Vec<BlockMeta> = Vec::new();
        let mut max_ts = 0u64;
        let mut i = 0usize;
        while i < entries.len() {
            // pick the block span by entry weight, then encode it
            let mut bytes = 0usize;
            let mut end = i;
            while let Some(e) = entries.get(end) {
                if end != i && bytes >= BLOCK_TARGET_BYTES {
                    break;
                }
                bytes += e.bytes();
                end += 1;
            }
            // the inner loop always advances at least one entry, so the
            // block is non-empty whenever the outer condition held
            let block = entries.get(i..end).unwrap_or(&[]);
            let (Some(first), Some(last)) = (block.first(), block.last()) else { break };
            let mut payload = Vec::with_capacity(bytes + 64);
            codec::put_varint(&mut payload, block.len() as u64);
            for e in block {
                max_ts = max_ts.max(e.key.ts);
                codec::put_entry(&mut payload, e);
            }
            out.write_all(&(payload.len() as u32).to_le_bytes())?;
            out.write_all(&codec::crc32(&payload).to_le_bytes())?;
            out.write_all(&payload)?;
            metas.push(BlockMeta {
                offset,
                len: payload.len() as u32,
                count: block.len() as u32,
                first_row: first.key.row.clone(),
                last_row: last.key.row.clone(),
            });
            offset += 8 + payload.len() as u64;
            i = end;
        }
        let mut index = Vec::new();
        codec::put_varint(&mut index, metas.len() as u64);
        for m in &metas {
            codec::put_varint(&mut index, m.offset);
            codec::put_varint(&mut index, m.len as u64);
            codec::put_varint(&mut index, m.count as u64);
            codec::put_str(&mut index, &m.first_row);
            codec::put_str(&mut index, &m.last_row);
        }
        codec::put_varint(&mut index, entries.len() as u64);
        codec::put_varint(&mut index, max_ts);
        out.write_all(&index)?;
        out.write_all(&offset.to_le_bytes())?;
        out.write_all(&codec::crc32(&index).to_le_bytes())?;
        out.write_all(RUN_FOOTER_MAGIC)?;
        out.flush()?;
        out.get_ref().sync_all()?;
        drop(out);
        codec::sync_dir(dir)?;
        DiskRun::open(&path, file_id)
    }

    /// Open and fully verify a run file: footer magic, index bounds and
    /// checksum, block-table sanity, and every block's checksum (one
    /// sequential pass — open happens at recovery/flush/compaction, not
    /// per scan). Corruption is a typed [`D4mError::Storage`].
    pub fn open(path: &Path, file_id: u64) -> Result<Arc<DiskRun>> {
        let bad = |what: &str| D4mError::Storage(format!("{}: {what}", path.display()));
        let mut file = File::open(path)?;
        let file_bytes = file.metadata()?.len();
        if file_bytes < HEADER_LEN + FOOTER_LEN {
            return Err(bad("truncated (shorter than header + footer)"));
        }
        let mut header = [0u8; HEADER_LEN as usize];
        file.read_exact(&mut header)?;
        if !header.starts_with(RUN_MAGIC) {
            return Err(bad("bad magic"));
        }
        if header.get(4) != Some(&RUN_VERSION) {
            return Err(bad("unsupported run version"));
        }
        file.seek(SeekFrom::End(-(FOOTER_LEN as i64)))?;
        let mut footer = [0u8; FOOTER_LEN as usize];
        file.read_exact(&mut footer)?;
        if !footer.ends_with(RUN_FOOTER_MAGIC) {
            return Err(bad("bad footer magic"));
        }
        let index_offset =
            codec::u64_le_at(&footer, 0).ok_or_else(|| bad("truncated footer"))?;
        let index_crc = codec::u32_le_at(&footer, 8).ok_or_else(|| bad("truncated footer"))?;
        let footer_at = file_bytes - FOOTER_LEN;
        if index_offset < HEADER_LEN || index_offset > footer_at {
            return Err(bad("index offset out of range"));
        }
        let index_len = (footer_at - index_offset) as usize;
        let mut index = vec![0u8; index_len];
        file.seek(SeekFrom::Start(index_offset))?;
        file.read_exact(&mut index)?;
        if codec::crc32(&index) != index_crc {
            return Err(bad("index checksum mismatch"));
        }
        let mut r = Reader::new(&index);
        let n_blocks = r.varint()? as usize;
        // each block entry takes at least 5 index bytes, so this bound
        // also caps the allocation below
        if n_blocks > index_len {
            return Err(bad("block count exceeds index size"));
        }
        let mut blocks = Vec::with_capacity(n_blocks);
        let mut counted = 0u64;
        for _ in 0..n_blocks {
            let offset = r.varint()?;
            let len = r.varint()?;
            let count = r.varint()?;
            let first_row = r.str()?;
            let last_row = r.str()?;
            let in_bounds = offset >= HEADER_LEN
                && len <= MAX_BLOCK
                && offset
                    .checked_add(8 + len)
                    .map(|end| end <= index_offset)
                    .unwrap_or(false);
            if !in_bounds || first_row > last_row {
                return Err(bad("block descriptor out of range"));
            }
            counted += count;
            blocks.push(BlockMeta {
                offset,
                len: len as u32,
                count: count as u32,
                first_row,
                last_row,
            });
        }
        let entry_count = r.varint()?;
        let max_ts = r.varint()?;
        if counted != entry_count {
            return Err(bad("block counts disagree with entry count"));
        }
        let run = DiskRun {
            path: path.to_path_buf(),
            file_id,
            file: Mutex::new(file),
            blocks,
            entry_count: entry_count as usize,
            max_ts,
            file_bytes,
        };
        for i in 0..run.blocks.len() {
            run.read_block(i)
                .map_err(|e| bad(&format!("block {i} failed verification ({e})")))?;
        }
        Ok(Arc::new(run))
    }

    pub fn len(&self) -> usize {
        self.entry_count
    }

    pub fn is_empty(&self) -> bool {
        self.entry_count == 0
    }

    /// Newest timestamp stored in the run (clock recovery floor).
    pub fn max_ts(&self) -> u64 {
        self.max_ts
    }

    pub fn file_id(&self) -> u64 {
        self.file_id
    }

    /// On-disk size — the unit of the compaction-backlog accounting.
    pub fn file_bytes(&self) -> u64 {
        self.file_bytes
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read and decode one block (checksum re-verified on every read).
    fn read_block(&self, i: usize) -> Result<Vec<Entry>> {
        let bad = |what: &str| {
            D4mError::Storage(format!("{}: block {i}: {what}", self.path.display()))
        };
        let m = self.blocks.get(i).ok_or_else(|| bad("block index out of range"))?;
        let mut buf = vec![0u8; 8 + m.len as usize];
        {
            let mut f = crate::util::lock_recover(&self.file);
            f.seek(SeekFrom::Start(m.offset))?;
            f.read_exact(&mut buf)?;
        }
        let len = codec::u32_le_at(&buf, 0).ok_or_else(|| bad("truncated block"))?;
        let crc = codec::u32_le_at(&buf, 4).ok_or_else(|| bad("truncated block"))?;
        if len != m.len {
            return Err(bad("length disagrees with index"));
        }
        let payload = buf.get(8..).ok_or_else(|| bad("truncated block"))?;
        if codec::crc32(payload) != crc {
            return Err(bad("checksum mismatch"));
        }
        let mut r = Reader::new(payload);
        let count = r.varint()?;
        if count != m.count as u64 {
            return Err(bad("entry count disagrees with index"));
        }
        let mut out = Vec::with_capacity(m.count as usize);
        for _ in 0..m.count {
            out.push(r.entry()?);
        }
        Ok(out)
    }

    /// Index of the first/one-past-last block overlapping `range`.
    fn block_span(&self, range: &RowRange) -> (usize, usize) {
        let lo = match &range.start {
            Some(s) => self
                .blocks
                .partition_point(|m| m.last_row.as_str() < s.as_str()),
            None => 0,
        };
        let hi = match &range.end {
            Some(e) => self
                .blocks
                .partition_point(|m| m.first_row.as_str() < e.as_str()),
            None => self.blocks.len(),
        };
        (lo, hi.max(lo))
    }

    /// Lazy block-at-a-time cursor over `range`, sorted by key — the
    /// on-disk counterpart of the in-memory `RunCursor`.
    pub fn cursor(self: &Arc<Self>, range: &RowRange) -> DiskCursor {
        let (lo, hi) = self.block_span(range);
        DiskCursor {
            run: Arc::clone(self),
            next_block: lo,
            end_block: hi,
            buf: Vec::new().into_iter(),
            range: range.clone(),
        }
    }

    /// Entries whose row falls in `range` (index-only for fully covered
    /// blocks; boundary blocks are decoded).
    pub fn count_in(&self, range: &RowRange) -> usize {
        if range.start.is_none() && range.end.is_none() {
            return self.entry_count;
        }
        let (lo, hi) = self.block_span(range);
        let mut n = 0usize;
        for (i, m) in self.blocks.iter().enumerate().take(hi).skip(lo) {
            if range.contains(&m.first_row) && range.contains(&m.last_row) {
                n += m.count as usize;
            } else if let Ok(block) = self.read_block(i) {
                n += block.iter().filter(|e| range.contains(&e.key.row)).count();
            }
        }
        n
    }

    /// Append the distinct row keys in `range` (consecutive-deduped; the
    /// caller merges across segments) to `out`.
    pub fn row_keys_in(&self, range: &RowRange, out: &mut Vec<String>) {
        let (lo, hi) = self.block_span(range);
        let mut last: Option<String> = None;
        for i in lo..hi {
            let Ok(block) = self.read_block(i) else { return };
            for e in block {
                if !range.contains(&e.key.row) {
                    continue;
                }
                if last.as_deref() != Some(e.key.row.as_str()) {
                    out.push(e.key.row.clone());
                    last = Some(e.key.row);
                }
            }
        }
    }
}

/// Pull-based streaming cursor over one run file. Blocks are read on
/// demand through the shared handle; an I/O failure mid-scan (the file
/// verified clean at open) ends the stream — iterators cannot carry
/// errors, and upstream consumers treat exhaustion as end-of-data.
pub struct DiskCursor {
    run: Arc<DiskRun>,
    next_block: usize,
    end_block: usize,
    buf: std::vec::IntoIter<Entry>,
    range: RowRange,
}

impl Iterator for DiskCursor {
    type Item = Entry;

    fn next(&mut self) -> Option<Entry> {
        loop {
            for e in self.buf.by_ref() {
                if self.range.contains(&e.key.row) {
                    return Some(e);
                }
                // rows are sorted: once past the end bound, we're done
                if let Some(end) = &self.range.end {
                    if e.key.row.as_str() >= end.as_str() {
                        self.next_block = self.end_block;
                        self.buf = Vec::new().into_iter();
                        return None;
                    }
                }
            }
            if self.next_block >= self.end_block {
                return None;
            }
            let i = self.next_block;
            self.next_block += 1;
            match self.run.read_block(i) {
                Ok(block) => self.buf = block.into_iter(),
                Err(_) => {
                    self.next_block = self.end_block;
                    return None;
                }
            }
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let buffered = self.buf.len();
        let lo = self.next_block.min(self.run.blocks.len());
        let hi = self.end_block.min(self.run.blocks.len());
        let pending: usize = self
            .run
            .blocks
            .get(lo..hi)
            .map_or(0, |bs| bs.iter().map(|m| m.count as usize).sum());
        (0, Some(buffered + pending))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;
    use crate::kvstore::key::Key;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn tmp_dir(tag: &str) -> PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "d4m-run-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sorted_entries(n: usize) -> Vec<Entry> {
        let mut v: Vec<Entry> = (0..n)
            .map(|i| {
                Entry::new(
                    Key::cell(format!("r{:05}", i / 3), format!("c{:03}", i % 3), i as u64 + 1),
                    format!("v{i}"),
                )
            })
            .collect();
        v.sort_by(|a, b| a.key.cmp(&b.key));
        v
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn file_name_roundtrip() {
        assert_eq!(parse_run_id(&run_file_name(42)), Some(42));
        assert_eq!(parse_run_id("run-42.run"), None);
        assert_eq!(parse_run_id(&wal_name_lookalike()), None);
    }

    fn wal_name_lookalike() -> String {
        super::super::wal::wal_file_name(1)
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn create_open_scan_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let entries = sorted_entries(500);
        let run = DiskRun::create(&dir, 1, &entries).unwrap();
        assert_eq!(run.len(), 500);
        assert_eq!(run.max_ts(), 500);
        let scanned: Vec<Entry> = run.cursor(&RowRange::all()).collect();
        assert_eq!(scanned, entries);
        // reopen from cold and scan again
        let reopened = DiskRun::open(&dir.join(run_file_name(1)), 1).unwrap();
        let scanned: Vec<Entry> = reopened.cursor(&RowRange::all()).collect();
        assert_eq!(scanned, entries);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn range_scan_matches_filter() {
        let dir = tmp_dir("range");
        let entries = sorted_entries(900);
        let run = DiskRun::create(&dir, 7, &entries).unwrap();
        for range in [
            RowRange::span("r00050", "r00150"),
            RowRange::single("r00100"),
            RowRange::from("r00290"),
            RowRange::span("zzz", "zzzz"),
        ] {
            let want: Vec<Entry> = entries
                .iter()
                .filter(|e| range.contains(&e.key.row))
                .cloned()
                .collect();
            let got: Vec<Entry> = run.cursor(&range).collect();
            assert_eq!(got, want, "range {range:?}");
            assert_eq!(run.count_in(&range), want.len(), "count {range:?}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn row_keys_dedup_within_run() {
        let dir = tmp_dir("rowkeys");
        let entries = sorted_entries(90); // 3 columns per row
        let run = DiskRun::create(&dir, 3, &entries).unwrap();
        let mut keys = Vec::new();
        run.row_keys_in(&RowRange::all(), &mut keys);
        let mut want: Vec<String> = entries.iter().map(|e| e.key.row.clone()).collect();
        want.dedup();
        assert_eq!(keys, want);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn multi_block_files_have_sparse_index() {
        let dir = tmp_dir("blocks");
        // large values force multiple ~32 KiB blocks
        let mut entries: Vec<Entry> = (0..200)
            .map(|i| {
                Entry::new(
                    Key::cell(format!("r{i:05}"), "c", i as u64 + 1),
                    "x".repeat(1024),
                )
            })
            .collect();
        entries.sort_by(|a, b| a.key.cmp(&b.key));
        let run = DiskRun::create(&dir, 9, &entries).unwrap();
        assert!(run.blocks.len() > 1, "expected multiple blocks");
        let got: Vec<Entry> = run.cursor(&RowRange::all()).collect();
        assert_eq!(got, entries);
        // a narrow range must not decode every block
        let narrow = RowRange::single("r00150");
        let (lo, hi) = run.block_span(&narrow);
        assert!(hi - lo <= 2, "narrow range touched {} blocks", hi - lo);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn empty_run_roundtrip() {
        let dir = tmp_dir("empty");
        let run = DiskRun::create(&dir, 1, &[]).unwrap();
        assert!(run.is_empty());
        assert_eq!(run.cursor(&RowRange::all()).count(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn truncation_every_cut_is_typed_error() {
        let dir = tmp_dir("cut");
        let entries = sorted_entries(40);
        DiskRun::create(&dir, 1, &entries).unwrap();
        let path = dir.join(run_file_name(1));
        let full = std::fs::read(&path).unwrap();
        for cut in 0..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            match DiskRun::open(&path, 1) {
                Err(D4mError::Storage(_)) | Err(D4mError::Io(_)) => {}
                Ok(_) => panic!("cut at {cut} of {} opened clean", full.len()),
                Err(e) => panic!("cut {cut}: unexpected error {e}"),
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bit_flips_never_open_silently_wrong() {
        let dir = tmp_dir("flip");
        let entries = sorted_entries(60);
        DiskRun::create(&dir, 1, &entries).unwrap();
        let path = dir.join(run_file_name(1));
        let full = std::fs::read(&path).unwrap();
        crate::util::forall(200, 0xD15C, |rng| {
            let mut bytes = full.clone();
            let at = rng.below(bytes.len() as u64) as usize;
            bytes[at] ^= 1 << rng.below(8);
            std::fs::write(&path, &bytes).unwrap();
            match DiskRun::open(&path, 1) {
                // every flip lands under a checksum or a verified field:
                // either open rejects it, or (flips confined to crc slack
                // like index padding) the data still scans identically
                Ok(run) => {
                    let got: Vec<Entry> = run.cursor(&RowRange::all()).collect();
                    assert_eq!(got, entries, "flip at byte {at} silently changed data");
                }
                Err(D4mError::Storage(_)) | Err(D4mError::Io(_)) => {}
                Err(e) => panic!("flip at {at}: unexpected error {e}"),
            }
        });
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn garbage_suffix_is_rejected() {
        let dir = tmp_dir("suffix");
        let entries = sorted_entries(10);
        DiskRun::create(&dir, 1, &entries).unwrap();
        let path = dir.join(run_file_name(1));
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"garbage after the footer");
        std::fs::write(&path, &bytes).unwrap();
        // the footer is located from EOF, so a suffix breaks the magic
        assert!(matches!(
            DiskRun::open(&path, 1),
            Err(D4mError::Storage(_))
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn hostile_random_files_never_panic() {
        let dir = tmp_dir("hostile");
        let path = dir.join(run_file_name(1));
        crate::util::forall(200, 0xBADF, |rng| {
            let n = rng.below(4096) as usize;
            let mut bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            if rng.chance(0.3) && bytes.len() >= 5 {
                bytes[..4].copy_from_slice(RUN_MAGIC);
                bytes[4] = RUN_VERSION;
            }
            if rng.chance(0.3) && bytes.len() >= 4 {
                let at = bytes.len() - 4;
                bytes[at..].copy_from_slice(RUN_FOOTER_MAGIC);
            }
            std::fs::write(&path, &bytes).unwrap();
            match DiskRun::open(&path, 1) {
                Err(D4mError::Storage(_)) | Err(D4mError::Io(_)) => {}
                Ok(run) => {
                    // astronomically unlikely, but if it verifies it must scan
                    let _ = run.cursor(&RowRange::all()).count();
                }
                Err(e) => panic!("unexpected error {e}"),
            }
        });
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
