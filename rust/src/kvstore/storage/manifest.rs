//! Table manifest: the single source of truth for which run files are
//! live, which WAL sequences still matter, and how the table is split.
//!
//! Layout: `"D4MM"` ver(u8) `[payload_len u32][crc32 u32][payload]`,
//! payload = varint wal_floor, varint clock, varint next_file_id,
//! splits (varint n + strings), then per tablet (splits + 1 of them) a
//! varint run count and the run file ids **newest first** — the same
//! order `Tablet.runs` holds them. Updates are atomic: write
//! `MANIFEST.tmp`, fsync, rename over `MANIFEST`, fsync the directory.
//! Run files not named here are flush/compaction leftovers and are
//! deleted at open; WAL files with seq < `wal_floor` are fully
//! superseded by the listed runs.

use std::io::Write;
use std::path::Path;

use super::codec::{self, Reader};
use crate::error::{D4mError, Result};

pub const MANIFEST_NAME: &str = "MANIFEST";
const MANIFEST_TMP: &str = "MANIFEST.tmp";
const MAGIC: &[u8; 4] = b"D4MM";
const VERSION: u8 = 1;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// Replay WAL files with seq >= this; delete the rest.
    pub wal_floor: u64,
    /// Logical-clock floor at the time of the last checkpoint (recovery
    /// takes the max of this and every recovered timestamp).
    pub clock: u64,
    /// Next run file id to allocate.
    pub next_file_id: u64,
    /// Tablet split points (the table has `splits.len() + 1` tablets).
    pub splits: Vec<String>,
    /// Per tablet: live run file ids, newest first.
    pub tablet_runs: Vec<Vec<u64>>,
}

/// Load `dir/MANIFEST`. `Ok(None)` means the manifest was never written
/// (a table directory mid-creation); corruption is a typed error.
pub fn load(dir: &Path) -> Result<Option<Manifest>> {
    let path = dir.join(MANIFEST_NAME);
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let bad = |what: &str| D4mError::Storage(format!("{}: {what}", path.display()));
    if bytes.len() < 13 {
        return Err(bad("truncated"));
    }
    if !bytes.starts_with(MAGIC) {
        return Err(bad("bad magic"));
    }
    if bytes.get(4) != Some(&VERSION) {
        return Err(bad("unsupported manifest version"));
    }
    let len = codec::u32_le_at(&bytes, 5).ok_or_else(|| bad("truncated"))? as usize;
    let crc = codec::u32_le_at(&bytes, 9).ok_or_else(|| bad("truncated"))?;
    // the manifest is rename-replaced whole: anything but an exact-length
    // checksummed payload is corruption, including trailing garbage
    if bytes.len() - 13 != len {
        return Err(bad("payload length mismatch"));
    }
    let payload = bytes.get(13..).ok_or_else(|| bad("truncated"))?;
    if codec::crc32(payload) != crc {
        return Err(bad("checksum mismatch"));
    }
    let mut r = Reader::new(payload);
    let wal_floor = r.varint()?;
    let clock = r.varint()?;
    let next_file_id = r.varint()?;
    let n_splits = r.varint()?;
    if n_splits > payload.len() as u64 {
        return Err(bad("split count exceeds payload"));
    }
    let mut splits = Vec::with_capacity(n_splits as usize);
    for _ in 0..n_splits {
        splits.push(r.str()?);
    }
    let n_tablets = r.varint()?;
    if n_tablets != n_splits + 1 {
        return Err(bad("tablet count disagrees with splits"));
    }
    let mut tablet_runs = Vec::with_capacity(n_tablets as usize);
    for _ in 0..n_tablets {
        let n_runs = r.varint()?;
        if n_runs > payload.len() as u64 {
            return Err(bad("run count exceeds payload"));
        }
        let mut runs = Vec::with_capacity(n_runs as usize);
        for _ in 0..n_runs {
            runs.push(r.varint()?);
        }
        tablet_runs.push(runs);
    }
    if !r.is_empty() {
        return Err(bad("trailing bytes in payload"));
    }
    Ok(Some(Manifest { wal_floor, clock, next_file_id, splits, tablet_runs }))
}

/// Atomically replace `dir/MANIFEST` with `m`.
pub fn store(dir: &Path, m: &Manifest) -> Result<()> {
    debug_assert_eq!(m.tablet_runs.len(), m.splits.len() + 1);
    let mut payload = Vec::new();
    codec::put_varint(&mut payload, m.wal_floor);
    codec::put_varint(&mut payload, m.clock);
    codec::put_varint(&mut payload, m.next_file_id);
    codec::put_varint(&mut payload, m.splits.len() as u64);
    for s in &m.splits {
        codec::put_str(&mut payload, s);
    }
    codec::put_varint(&mut payload, m.tablet_runs.len() as u64);
    for runs in &m.tablet_runs {
        codec::put_varint(&mut payload, runs.len() as u64);
        for &id in runs {
            codec::put_varint(&mut payload, id);
        }
    }
    let mut out = Vec::with_capacity(13 + payload.len());
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&codec::crc32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    let tmp = dir.join(MANIFEST_TMP);
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&out)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, dir.join(MANIFEST_NAME))?;
    codec::sync_dir(dir)?;
    Ok(())
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "d4m-manifest-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample() -> Manifest {
        Manifest {
            wal_floor: 9,
            clock: 12345,
            next_file_id: 42,
            splits: vec!["m".into(), "t".into()],
            tablet_runs: vec![vec![7, 3], vec![], vec![41, 40, 2]],
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn roundtrip() {
        let dir = tmp_dir("roundtrip");
        let m = sample();
        store(&dir, &m).unwrap();
        assert_eq!(load(&dir).unwrap(), Some(m));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn missing_is_none() {
        let dir = tmp_dir("missing");
        assert_eq!(load(&dir).unwrap(), None);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn store_replaces_atomically() {
        let dir = tmp_dir("replace");
        store(&dir, &sample()).unwrap();
        let mut m2 = sample();
        m2.wal_floor = 10;
        m2.tablet_runs[0] = vec![50];
        store(&dir, &m2).unwrap();
        assert_eq!(load(&dir).unwrap(), Some(m2));
        assert!(!dir.join(MANIFEST_TMP).exists(), "tmp file left behind");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn truncation_every_cut_is_typed_error() {
        let dir = tmp_dir("cut");
        store(&dir, &sample()).unwrap();
        let path = dir.join(MANIFEST_NAME);
        let full = std::fs::read(&path).unwrap();
        for cut in 0..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            assert!(
                matches!(load(&dir), Err(D4mError::Storage(_))),
                "cut at {cut} did not surface as a typed error"
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn garbage_suffix_is_typed_error() {
        let dir = tmp_dir("suffix");
        store(&dir, &sample()).unwrap();
        let path = dir.join(MANIFEST_NAME);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(load(&dir), Err(D4mError::Storage(_))));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bit_flips_error_never_panic() {
        let dir = tmp_dir("flip");
        store(&dir, &sample()).unwrap();
        let path = dir.join(MANIFEST_NAME);
        let full = std::fs::read(&path).unwrap();
        crate::util::forall(150, 0x3A4F, |rng| {
            let mut bytes = full.clone();
            let at = rng.below(bytes.len() as u64) as usize;
            bytes[at] ^= 1 << rng.below(8);
            std::fs::write(&path, &bytes).unwrap();
            match load(&dir) {
                Err(D4mError::Storage(_)) => {}
                Ok(_) => panic!("flip at {at} loaded clean"),
                Err(e) => panic!("flip at {at}: unexpected error {e}"),
            }
        });
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
