//! Byte-level codec shared by the durable-storage file formats (WAL
//! records, frozen-run blocks, the manifest): the same length-prefixed,
//! little-endian, LEB128-varint style as `net/wire.rs`, plus a CRC-32
//! for on-disk integrity. Every decode path is bounds-checked and
//! returns a typed [`D4mError::Storage`] — hostile or torn bytes must
//! never panic, whatever the cut or flip.

use crate::error::{D4mError, Result};
use crate::kvstore::key::{Entry, Key};

// ------------------------------------------------------------- checksum

/// CRC-32 (IEEE 802.3, the polynomial storage engines conventionally
/// use for block checksums), table-driven.
const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 checksum of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// -------------------------------------------------------------- writing

/// LEB128 varint (the wire codec's integer encoding).
pub fn put_varint(b: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            b.push(byte);
            return;
        }
        b.push(byte | 0x80);
    }
}

/// Length-prefixed UTF-8 string.
pub fn put_str(b: &mut Vec<u8>, s: &str) {
    put_varint(b, s.len() as u64);
    b.extend_from_slice(s.as_bytes());
}

/// One stored entry: row/cf/cq strings, timestamp, tombstone flag, value.
pub fn put_entry(b: &mut Vec<u8>, e: &Entry) {
    put_str(b, &e.key.row);
    put_str(b, &e.key.cf);
    put_str(b, &e.key.cq);
    put_varint(b, e.key.ts);
    b.push(e.tombstone as u8);
    put_str(b, &e.value);
}

// -------------------------------------------------------------- reading

fn corrupt(what: &str) -> D4mError {
    D4mError::Storage(format!("corrupt record: {what}"))
}

/// Little-endian `u32` at byte offset `pos`; `None` when `b` is too
/// short. The fixed-width header fields (record length prefixes, CRCs,
/// index offsets) all read through these two so a torn file surfaces as
/// a recoverable `None`, never a slice panic.
pub fn u32_le_at(b: &[u8], pos: usize) -> Option<u32> {
    let end = pos.checked_add(4)?;
    let arr: [u8; 4] = b.get(pos..end)?.try_into().ok()?;
    Some(u32::from_le_bytes(arr))
}

/// Little-endian `u64` at byte offset `pos`; `None` when `b` is too short.
pub fn u64_le_at(b: &[u8], pos: usize) -> Option<u64> {
    let end = pos.checked_add(8)?;
    let arr: [u8; 8] = b.get(pos..end)?.try_into().ok()?;
    Some(u64::from_le_bytes(arr))
}

/// Bounds-checked reader over a decoded-and-checksummed payload slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| corrupt("truncated"))?;
        let out = self.buf.get(self.pos..end).ok_or_else(|| corrupt("truncated"))?;
        self.pos = end;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(*self.take(1)?.first().ok_or_else(|| corrupt("truncated"))?)
    }

    pub fn varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.u8()?;
            if shift == 63 && byte > 1 {
                return Err(corrupt("varint overflows u64"));
            }
            v |= u64::from(byte & 0x7F) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(corrupt("varint too long"));
            }
        }
    }

    pub fn str(&mut self) -> Result<String> {
        let len = self.varint()?;
        // the length prefix can never exceed the bytes that follow it
        if len > self.remaining() as u64 {
            return Err(corrupt("string length exceeds payload"));
        }
        let raw = self.take(len as usize)?;
        String::from_utf8(raw.to_vec()).map_err(|_| corrupt("string is not UTF-8"))
    }

    pub fn entry(&mut self) -> Result<Entry> {
        let row = self.str()?;
        let cf = self.str()?;
        let cq = self.str()?;
        let ts = self.varint()?;
        let tombstone = match self.u8()? {
            0 => false,
            1 => true,
            _ => return Err(corrupt("bad tombstone flag")),
        };
        let value = self.str()?;
        Ok(Entry { key: Key { row, cf, cq, ts }, value, tombstone })
    }
}

/// fsync a directory so a just-created/renamed entry in it is durable.
pub fn sync_dir(dir: &std::path::Path) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        std::fs::File::open(dir)?.sync_all()?;
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // the canonical check value for CRC-32/IEEE
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn varint_roundtrip() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut b = Vec::new();
            put_varint(&mut b, v);
            assert_eq!(Reader::new(&b).varint().unwrap(), v);
        }
    }

    #[test]
    fn entry_roundtrip() {
        let entries = [
            Entry::new(Key::new("row", "cf", "cq", 42), "value"),
            Entry::new(Key::cell("", "", 0), ""),
            Entry::delete(Key::cell("r", "c", u64::MAX)),
            Entry::new(Key::cell("wörld", "ünï", 7), "émoji ✓"),
        ];
        let mut b = Vec::new();
        for e in &entries {
            put_entry(&mut b, e);
        }
        let mut r = Reader::new(&b);
        for e in &entries {
            assert_eq!(&r.entry().unwrap(), e);
        }
        assert!(r.is_empty());
    }

    #[test]
    fn hostile_bytes_error_never_panic() {
        crate::util::forall(200, 0xC0DE, |rng| {
            let n = rng.below(40) as usize;
            let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            let mut r = Reader::new(&bytes);
            // whatever the bytes, decoding returns Ok or a typed error
            let _ = r.entry();
            let _ = r.varint();
            let _ = r.str();
        });
    }

    #[test]
    fn truncation_every_cut_is_typed() {
        let mut b = Vec::new();
        put_entry(&mut b, &Entry::new(Key::new("row", "cf", "cq", 9), "val"));
        for cut in 0..b.len() {
            let mut r = Reader::new(&b[..cut]);
            assert!(r.entry().is_err(), "cut at {cut} must not decode");
        }
        assert!(Reader::new(&b).entry().is_ok());
    }

    #[test]
    fn fixed_width_reads_are_total() {
        let b = 0x1122_3344_5566_7788u64.to_le_bytes();
        assert_eq!(u64_le_at(&b, 0), Some(0x1122_3344_5566_7788));
        assert_eq!(u32_le_at(&b, 0), Some(0x5566_7788));
        assert_eq!(u32_le_at(&b, 4), Some(0x1122_3344));
        assert_eq!(u32_le_at(&b, 5), None);
        assert_eq!(u64_le_at(&b, 1), None);
        assert_eq!(u32_le_at(&b, usize::MAX), None); // offset overflow
        assert_eq!(u64_le_at(&[], 0), None);
    }
}
