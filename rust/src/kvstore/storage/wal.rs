//! Per-table write-ahead log.
//!
//! File layout: a 13-byte header (`"D4MW"`, version, log sequence number
//! u64 LE), then a stream of records `[payload_len u32 LE][crc32 u32 LE]
//! [payload]` where the payload is a varint entry count followed by the
//! encoded entries. Appends are flushed to the OS before the write is
//! acknowledged — an acknowledged batch survives `SIGKILL` of this
//! process — and fsync'd on the group-commit cadence, which bounds what a
//! *machine* crash can lose. Replay accepts every complete checksummed
//! record from the head and stops at the first torn or corrupt one.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::Path;
use std::time::{Duration, Instant};

use super::codec::{self, Reader};
use super::StorageCounters;
use crate::error::{D4mError, Result};
use crate::kvstore::key::Entry;

pub const WAL_MAGIC: &[u8; 4] = b"D4MW";
pub const WAL_VERSION: u8 = 1;
const HEADER_LEN: usize = 13;
/// Sanity cap on a single record's payload — a length prefix above this
/// is corruption, not a real batch.
const MAX_RECORD: usize = 64 << 20;

/// `wal-{seq:016x}.log`
pub fn wal_file_name(seq: u64) -> String {
    format!("wal-{seq:016x}.log")
}

/// Inverse of [`wal_file_name`]; `None` for anything else.
pub fn parse_wal_seq(name: &str) -> Option<u64> {
    let hex = name.strip_prefix("wal-")?.strip_suffix(".log")?;
    if hex.len() != 16 {
        return None;
    }
    u64::from_str_radix(hex, 16).ok()
}

/// Appender for one live WAL file.
pub struct WalWriter {
    out: BufWriter<File>,
    seq: u64,
    last_fsync: Instant,
}

impl WalWriter {
    /// Create `wal-<seq>.log` in `dir` and fsync both the file and the
    /// directory, so the log exists durably before its first record.
    pub fn create(dir: &Path, seq: u64) -> Result<Self> {
        let path = dir.join(wal_file_name(seq));
        let file = OpenOptions::new().write(true).create_new(true).open(&path)?;
        let mut out = BufWriter::new(file);
        out.write_all(WAL_MAGIC)?;
        out.write_all(&[WAL_VERSION])?;
        out.write_all(&seq.to_le_bytes())?;
        out.flush()?;
        out.get_ref().sync_all()?;
        codec::sync_dir(dir)?;
        Ok(WalWriter { out, seq, last_fsync: Instant::now() })
    }

    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Append one record holding `entries` and flush it to the OS. fsync
    /// runs when `interval` is zero (every append) or when it has elapsed
    /// since the last one (group commit).
    pub fn append(
        &mut self,
        entries: &[Entry],
        interval: Duration,
        counters: &StorageCounters,
    ) -> Result<()> {
        let mut payload = Vec::with_capacity(entries.len() * 48);
        codec::put_varint(&mut payload, entries.len() as u64);
        for e in entries {
            codec::put_entry(&mut payload, e);
        }
        self.out.write_all(&(payload.len() as u32).to_le_bytes())?;
        self.out.write_all(&codec::crc32(&payload).to_le_bytes())?;
        self.out.write_all(&payload)?;
        // hand the record to the OS now: from here on, killing the
        // process cannot take back the acknowledgement
        self.out.flush()?;
        counters.wal_bytes_appended.add((8 + payload.len()) as u64);
        if interval.is_zero() || self.last_fsync.elapsed() >= interval {
            self.out.get_ref().sync_data()?;
            self.last_fsync = Instant::now();
            counters.wal_fsyncs.inc();
        }
        Ok(())
    }

    /// Flush and fsync everything appended so far (checkpoint, graceful
    /// shutdown).
    pub fn sync(&mut self, counters: &StorageCounters) -> Result<()> {
        self.out.flush()?;
        self.out.get_ref().sync_data()?;
        self.last_fsync = Instant::now();
        counters.wal_fsyncs.inc();
        Ok(())
    }
}

/// Replay a WAL file: return the entries of every complete, checksummed
/// record from the head, stopping silently at the first torn or corrupt
/// one — the tail of a crashed log may legitimately be mid-write. A file
/// that was never a WAL of ours (wrong magic or version) is a typed
/// error; a header torn during creation recovers as empty.
pub fn replay(path: &Path) -> Result<Vec<Entry>> {
    let mut bytes = Vec::new();
    File::open(path)?.read_to_end(&mut bytes)?;
    if bytes.len() < HEADER_LEN {
        return Ok(Vec::new());
    }
    if !bytes.starts_with(WAL_MAGIC) {
        return Err(D4mError::Storage(format!(
            "{}: not a WAL (bad magic)",
            path.display()
        )));
    }
    let version = *bytes.get(4).unwrap_or(&0); // len >= HEADER_LEN here
    if version != WAL_VERSION {
        return Err(D4mError::Storage(format!(
            "{}: unsupported WAL version {version}",
            path.display()
        )));
    }
    let mut entries = Vec::new();
    let mut pos = HEADER_LEN;
    while bytes.len() - pos >= 8 {
        let Some(len) = codec::u32_le_at(&bytes, pos).map(|v| v as usize) else { break };
        let Some(crc) = codec::u32_le_at(&bytes, pos + 4) else { break };
        if len > MAX_RECORD || bytes.len() - pos - 8 < len {
            break;
        }
        let Some(payload) = bytes.get(pos + 8..pos + 8 + len) else { break };
        if codec::crc32(payload) != crc {
            break;
        }
        let mut r = Reader::new(payload);
        let Ok(count) = r.varint() else { break };
        let mut record = Vec::new();
        let mut clean = true;
        for _ in 0..count {
            match r.entry() {
                Ok(e) => record.push(e),
                Err(_) => {
                    clean = false;
                    break;
                }
            }
        }
        // a checksummed-but-undecodable record is treated like a torn
        // tail: keep the prefix, stop here
        if !clean {
            break;
        }
        entries.append(&mut record);
        pos += 8 + len;
    }
    Ok(entries)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;
    use crate::kvstore::key::Key;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "d4m-wal-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn entry(i: u64) -> Entry {
        Entry::new(Key::cell(format!("r{i:04}"), format!("c{i}"), i + 1), "1")
    }

    fn counters() -> StorageCounters {
        StorageCounters::new()
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn file_name_roundtrip() {
        assert_eq!(parse_wal_seq(&wal_file_name(7)), Some(7));
        assert_eq!(parse_wal_seq(&wal_file_name(u64::MAX)), Some(u64::MAX));
        assert_eq!(parse_wal_seq("wal-xyz.log"), None);
        assert_eq!(parse_wal_seq("run-0000000000000001.run"), None);
        assert_eq!(parse_wal_seq("wal-1.log"), None);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn append_and_replay() {
        let dir = tmp_dir("roundtrip");
        let c = counters();
        let mut w = WalWriter::create(&dir, 1).unwrap();
        let all: Vec<Entry> = (0..20).map(entry).collect();
        for chunk in all.chunks(7) {
            w.append(chunk, Duration::ZERO, &c).unwrap();
        }
        drop(w);
        let replayed = replay(&dir.join(wal_file_name(1))).unwrap();
        assert_eq!(replayed, all);
        assert!(c.wal_fsyncs.get() >= 3);
        assert!(c.wal_bytes_appended.get() > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn replay_empty_log() {
        let dir = tmp_dir("empty");
        let w = WalWriter::create(&dir, 3).unwrap();
        drop(w);
        assert_eq!(replay(&dir.join(wal_file_name(3))).unwrap(), vec![]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn torn_tail_truncates_to_record_boundary() {
        let dir = tmp_dir("torn");
        let c = counters();
        let mut w = WalWriter::create(&dir, 1).unwrap();
        let all: Vec<Entry> = (0..12).map(entry).collect();
        for chunk in all.chunks(3) {
            w.append(chunk, Duration::ZERO, &c).unwrap();
        }
        drop(w);
        let path = dir.join(wal_file_name(1));
        let full = std::fs::read(&path).unwrap();
        // cut the file at *every* prefix length: replay must never panic
        // and must return a prefix of the appended batches
        for cut in 0..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            // a cut inside the header recovers as empty; past it, as the
            // longest whole-record prefix — always Ok, never a panic
            let entries = replay(&path).unwrap_or_else(|e| panic!("cut {cut}: {e}"));
            assert!(entries.len() % 3 == 0, "cut {cut}: partial record leaked");
            assert_eq!(entries, all[..entries.len()], "cut {cut}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bit_flips_recover_a_prefix_or_error() {
        let dir = tmp_dir("flip");
        let c = counters();
        let mut w = WalWriter::create(&dir, 1).unwrap();
        let all: Vec<Entry> = (0..9).map(entry).collect();
        for chunk in all.chunks(3) {
            w.append(chunk, Duration::ZERO, &c).unwrap();
        }
        drop(w);
        let path = dir.join(wal_file_name(1));
        let full = std::fs::read(&path).unwrap();
        crate::util::forall(150, 0xF11B, |rng| {
            let mut bytes = full.clone();
            let at = rng.below(bytes.len() as u64) as usize;
            bytes[at] ^= 1 << rng.below(8);
            std::fs::write(&path, &bytes).unwrap();
            match replay(&path) {
                // a flip can only shorten the recovered prefix, never
                // invent or reorder entries
                Ok(entries) => {
                    assert!(entries.len() <= all.len());
                    assert_eq!(entries, all[..entries.len()]);
                }
                Err(D4mError::Storage(_)) => {} // flip landed in the header
                Err(e) => panic!("unexpected error {e}"),
            }
        });
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn garbage_suffix_is_dropped() {
        let dir = tmp_dir("garbage");
        let c = counters();
        let mut w = WalWriter::create(&dir, 1).unwrap();
        let all: Vec<Entry> = (0..6).map(entry).collect();
        w.append(&all, Duration::ZERO, &c).unwrap();
        drop(w);
        let path = dir.join(wal_file_name(1));
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"\xDE\xAD\xBE\xEF trailing junk after the last record");
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(replay(&path).unwrap(), all);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn wrong_magic_is_typed_error() {
        let dir = tmp_dir("magic");
        let path = dir.join(wal_file_name(1));
        std::fs::write(&path, b"NOTAWALFILE______________").unwrap();
        assert!(matches!(replay(&path), Err(D4mError::Storage(_))));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
