//! Durable storage engine for the kvstore: per-table write-ahead log,
//! on-disk frozen runs, an atomic manifest, crash recovery, and the
//! backpressure gate that bounds the compaction backlog.
//!
//! The subsystem is deliberately layered under the PR-3 snapshot
//! contract: a frozen run on disk is just another immutable,
//! `Arc`-shared segment with a pull-based cursor, so `MergeIter`,
//! `TabletSnapshot` and every streaming consumer upstream work
//! unchanged whether a run lives in memory or in a file.

pub mod codec;
pub mod manifest;
pub mod run;
pub mod wal;

pub use manifest::Manifest;
pub use run::{DiskCursor, DiskRun};
pub use wal::WalWriter;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{D4mError, Result};
use crate::metrics::Counter;

/// Tuning knobs for a durable [`KvStore`](crate::kvstore::KvStore).
#[derive(Debug, Clone)]
pub struct StorageConfig {
    /// fsync the WAL at most once per this interval (group commit);
    /// `Duration::ZERO` fsyncs every append. Acknowledged appends always
    /// reach the OS before the ack, so killing the *process* loses
    /// nothing either way — the interval bounds what a machine crash can
    /// take with it.
    pub group_commit_interval: Duration,
    /// `put_batch` blocks while the store-wide compaction backlog
    /// (bytes of on-disk runs beyond each tablet's `max_runs`) exceeds
    /// this budget.
    pub backlog_budget_bytes: u64,
    /// How long a blocked `put_batch` waits for the compactor before
    /// failing with a typed [`D4mError::Backpressure`].
    pub backpressure_timeout: Duration,
}

impl Default for StorageConfig {
    fn default() -> Self {
        StorageConfig {
            group_commit_interval: Duration::from_millis(20),
            backlog_budget_bytes: 256 << 20,
            backpressure_timeout: Duration::from_secs(10),
        }
    }
}

/// Storage-side counters, folded into the server metrics snapshot and
/// `d4m client stats`.
#[derive(Debug, Default)]
pub struct StorageCounters {
    pub wal_bytes_appended: Counter,
    pub wal_fsyncs: Counter,
    pub flushes: Counter,
    pub compactions: Counter,
    pub backpressure_stalls: Counter,
}

impl StorageCounters {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Store-wide ingest backpressure gate.
///
/// Each table reports its compaction debt — the bytes of on-disk runs
/// beyond its tablets' `max_runs` — after every flush and compaction.
/// Writers wait on the condvar while the summed debt exceeds the budget;
/// the compactor's progress notifies them. The same condvar doubles as
/// the compactor's work signal: new debt wakes it immediately.
#[derive(Default)]
pub struct StorageGate {
    debt: Mutex<HashMap<String, u64>>,
    cv: Condvar,
}

impl StorageGate {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish `table`'s current debt and wake waiters (writers waiting
    /// for the backlog to drain, and the compactor waiting for work).
    pub fn set(&self, table: &str, bytes: u64) {
        let mut debt = crate::util::lock_recover(&self.debt);
        if bytes == 0 {
            debt.remove(table);
        } else {
            debt.insert(table.to_string(), bytes);
        }
        drop(debt);
        self.cv.notify_all();
    }

    /// Total debt across all tables.
    pub fn total(&self) -> u64 {
        crate::util::lock_recover(&self.debt).values().sum()
    }

    /// Wake everyone without changing state (shutdown).
    pub fn poke(&self) {
        self.cv.notify_all();
    }

    /// Block until total debt is within `budget`. Returns whether the
    /// caller stalled at all; times out as a typed error naming `table`.
    pub fn wait_below(&self, budget: u64, timeout: Duration, table: &str) -> Result<bool> {
        let mut debt = crate::util::lock_recover(&self.debt);
        if debt.values().sum::<u64>() <= budget {
            return Ok(false);
        }
        let start = Instant::now();
        loop {
            let Some(left) = timeout.checked_sub(start.elapsed()) else {
                return Err(D4mError::Backpressure {
                    table: table.to_string(),
                    waited_ms: start.elapsed().as_millis() as u64,
                });
            };
            let (guard, _) = self
                .cv
                .wait_timeout(debt, left.min(Duration::from_millis(50)))
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            debt = guard;
            if debt.values().sum::<u64>() <= budget {
                return Ok(true);
            }
        }
    }

    /// Park the compactor until debt changes somewhere (or `timeout`).
    pub fn wait_for_work(&self, timeout: Duration) {
        let debt = crate::util::lock_recover(&self.debt);
        let _ = self
            .cv
            .wait_timeout(debt, timeout)
            .unwrap_or_else(std::sync::PoisonError::into_inner);
    }
}

/// Per-table durable state, owned by `Table` when its store has a data
/// directory. `inner` serializes WAL appends with checkpoint's rotation
/// — the lock order everywhere is `inner` before any tablet lock.
pub(crate) struct TableStorage {
    pub(crate) dir: PathBuf,
    pub(crate) cfg: StorageConfig,
    pub(crate) counters: std::sync::Arc<StorageCounters>,
    pub(crate) gate: std::sync::Arc<StorageGate>,
    /// Memtable size that triggers a checkpoint (the tablets themselves
    /// are built with an unbounded inline threshold: durable flushes are
    /// checkpoint's job, never `Tablet::flush`'s).
    pub(crate) flush_bytes: usize,
    /// On-disk runs per tablet beyond which the compactor owes a merge.
    pub(crate) max_runs: usize,
    pub(crate) inner: Mutex<WalState>,
}

pub(crate) struct WalState {
    pub(crate) wal: WalWriter,
    /// WAL sequences below this are superseded by the manifest's runs.
    pub(crate) wal_floor: u64,
    pub(crate) next_file_id: u64,
}

/// Escape a table name into a filesystem-safe directory name: bytes in
/// `[A-Za-z0-9_-]` pass through, everything else becomes `%XX`.
/// Reversible and collision-free, and the output can never be `.`,
/// `..`, empty, or contain a path separator.
pub fn escape_table_name(name: &str) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(name.len() + 4);
    for &b in name.as_bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'_' | b'-' => out.push(b as char),
            _ => {
                let _ = write!(out, "%{b:02X}");
            }
        }
    }
    if out.is_empty() {
        out.push('%');
    }
    out
}

/// Inverse of [`escape_table_name`]; `None` for directories we did not
/// create (bad escapes, non-UTF-8 reconstructions).
pub fn unescape_table_name(dir: &str) -> Option<String> {
    if dir == "%" {
        return Some(String::new());
    }
    let mut bytes = Vec::with_capacity(dir.len());
    let mut it = dir.bytes();
    while let Some(b) = it.next() {
        if b == b'%' {
            let hex = |c: u8| (c as char).to_digit(16).map(|d| d as u8);
            let hi = hex(it.next()?)?;
            let lo = hex(it.next()?)?;
            bytes.push(hi * 16 + lo);
        } else {
            bytes.push(b);
        }
    }
    String::from_utf8(bytes).ok()
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn escape_roundtrips() {
        for name in [
            "simple",
            "with.dots",
            "..",
            ".",
            "",
            "path/traversal",
            "emoji✓table",
            "A_b-9",
            "%already%escaped",
            "spaces and\ttabs",
        ] {
            let esc = escape_table_name(name);
            assert!(!esc.is_empty());
            assert!(!esc.contains('/') && !esc.contains('\\'), "{esc}");
            assert_ne!(esc, ".");
            assert_ne!(esc, "..");
            assert_eq!(unescape_table_name(&esc).as_deref(), Some(name), "{esc}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn escape_is_injective_on_tricky_pairs() {
        // '.' escapes, so "a.b" and its escaped form can't collide
        assert_ne!(escape_table_name("a.b"), escape_table_name("a%2Eb"));
        assert_ne!(escape_table_name("x"), escape_table_name("X%"));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn unescape_rejects_foreign_dirs() {
        assert_eq!(unescape_table_name("%zz"), None);
        assert_eq!(unescape_table_name("trailing%"), None);
        assert_eq!(unescape_table_name("%4"), None);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn gate_waits_until_debt_drains() {
        let gate = Arc::new(StorageGate::new());
        gate.set("t", 100);
        assert_eq!(gate.total(), 100);
        // under budget: no wait at all
        assert!(!gate.wait_below(100, Duration::from_millis(1), "t").unwrap());
        // over budget, drained by another thread: stalls then passes
        let g2 = Arc::clone(&gate);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            g2.set("t", 10);
        });
        let stalled = gate.wait_below(50, Duration::from_secs(5), "t").unwrap();
        assert!(stalled);
        h.join().unwrap();
        assert_eq!(gate.total(), 10);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn gate_times_out_typed() {
        let gate = StorageGate::new();
        gate.set("big", 1 << 30);
        match gate.wait_below(1, Duration::from_millis(20), "big") {
            Err(D4mError::Backpressure { table, waited_ms }) => {
                assert_eq!(table, "big");
                assert!(waited_ms >= 20);
            }
            other => panic!("expected Backpressure, got {other:?}"),
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn gate_sums_across_tables() {
        let gate = StorageGate::new();
        gate.set("a", 30);
        gate.set("b", 40);
        assert_eq!(gate.total(), 70);
        gate.set("a", 0);
        assert_eq!(gate.total(), 40);
    }
}
