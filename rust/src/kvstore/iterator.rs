//! The server-side iterator framework — Accumulo's defining extension
//! point and the substrate Graphulo builds on.
//!
//! Iterators are composable transforms over a *sorted* stream of entries,
//! executed inside the tablet scan (server side), so downstream consumers
//! only ever see the transformed stream. The stock stack mirrors
//! Accumulo's: a k-way [`MergeIter`] over memtable + sorted runs, a
//! [`VersioningIter`] keeping the newest version per cell, combiners
//! ([`SummingCombiner`], [`MaxCombiner`]) that fold all versions of a cell
//! into one entry, and value/column [`FilterIter`]s.

use super::key::Entry;

/// A sorted stream of entries. (Rust's `Iterator` with the invariant that
/// items come out in key order.)
pub trait SortedEntryIter: Iterator<Item = Entry> {}
impl<T: Iterator<Item = Entry>> SortedEntryIter for T {}

/// The streaming scan cursor: a boxed, owned (`'static`), `Send` entry
/// iterator in key order. Scans hand these out so results are pulled
/// through the iterator stack lazily — never materialised into a `Vec`,
/// never borrowing a tablet (snapshots own their frozen segments).
pub type EntryStream = Box<dyn Iterator<Item = Entry> + Send>;

// ---------------------------------------------------------------- merge

/// K-way merge of sorted entry streams (binary-heap based).
pub struct MergeIter {
    heap: std::collections::BinaryHeap<HeapItem>,
    sources: Vec<EntryStream>,
}

struct HeapItem {
    entry: Entry,
    src: usize,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.entry.key == other.entry.key
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert for ascending key order.
        // Tie-break on source index so newer layers (lower index) win
        // deterministically for identical keys.
        other
            .entry
            .key
            .cmp(&self.entry.key)
            .then_with(|| other.src.cmp(&self.src))
    }
}

impl MergeIter {
    pub fn new(mut sources: Vec<EntryStream>) -> Self {
        let mut heap = std::collections::BinaryHeap::new();
        for (i, s) in sources.iter_mut().enumerate() {
            if let Some(e) = s.next() {
                heap.push(HeapItem { entry: e, src: i });
            }
        }
        MergeIter { heap, sources }
    }
}

impl Iterator for MergeIter {
    type Item = Entry;

    fn next(&mut self) -> Option<Entry> {
        let top = self.heap.pop()?;
        if let Some(e) = self.sources[top.src].next() {
            self.heap.push(HeapItem { entry: e, src: top.src });
        }
        Some(top.entry)
    }
}

// ----------------------------------------------------------- versioning

/// Keeps only the newest version of each cell (Accumulo's default
/// VersioningIterator with maxVersions = 1). Relies on ts-descending key
/// order: the first entry seen for a cell is the newest.
pub struct VersioningIter<I: Iterator<Item = Entry>> {
    inner: std::iter::Peekable<I>,
}

impl<I: Iterator<Item = Entry>> VersioningIter<I> {
    pub fn new(inner: I) -> Self {
        VersioningIter { inner: inner.peekable() }
    }
}

impl<I: Iterator<Item = Entry>> Iterator for VersioningIter<I> {
    type Item = Entry;

    fn next(&mut self) -> Option<Entry> {
        loop {
            let first = self.inner.next()?;
            while let Some(nxt) = self.inner.peek() {
                if nxt.key.same_cell(&first.key) {
                    self.inner.next();
                } else {
                    break;
                }
            }
            // a tombstone as the newest version deletes the cell
            if !first.tombstone {
                return Some(first);
            }
        }
    }
}

// ------------------------------------------------------------ combiners

/// Folds all versions of a cell into one entry by summing numeric values —
/// Accumulo's SummingCombiner, the iterator Graphulo's TableMult writes
/// through (partial products become sums).
pub struct SummingCombiner<I: Iterator<Item = Entry>> {
    inner: std::iter::Peekable<I>,
}

impl<I: Iterator<Item = Entry>> SummingCombiner<I> {
    pub fn new(inner: I) -> Self {
        SummingCombiner { inner: inner.peekable() }
    }
}

impl<I: Iterator<Item = Entry>> Iterator for SummingCombiner<I> {
    type Item = Entry;

    fn next(&mut self) -> Option<Entry> {
        loop {
            let mut first = self.inner.next()?;
            // a tombstone masks itself and all older versions of the cell
            let mut masked = first.tombstone;
            let mut sum: f64 =
                if masked { 0.0 } else { first.value.parse().unwrap_or(0.0) };
            let mut any = !masked;
            while let Some(nxt) = self.inner.peek() {
                if nxt.key.same_cell(&first.key) {
                    if !masked && !nxt.tombstone {
                        sum += nxt.value.parse::<f64>().unwrap_or(0.0);
                        any = true;
                    }
                    if nxt.tombstone {
                        masked = true;
                    }
                    self.inner.next();
                } else {
                    break;
                }
            }
            if any {
                first.tombstone = false;
                first.value = crate::assoc::io::fmt_num(sum);
                return Some(first);
            }
        }
    }
}

/// Max-combiner across versions (used by string-valued D4M tables).
pub struct MaxCombiner<I: Iterator<Item = Entry>> {
    inner: std::iter::Peekable<I>,
}

impl<I: Iterator<Item = Entry>> MaxCombiner<I> {
    pub fn new(inner: I) -> Self {
        MaxCombiner { inner: inner.peekable() }
    }
}

impl<I: Iterator<Item = Entry>> Iterator for MaxCombiner<I> {
    type Item = Entry;

    fn next(&mut self) -> Option<Entry> {
        loop {
            let mut first = self.inner.next()?;
            let mut masked = first.tombstone;
            let mut best: Option<String> =
                if masked { None } else { Some(first.value.clone()) };
            while let Some(nxt) = self.inner.peek() {
                if nxt.key.same_cell(&first.key) {
                    if !masked && !nxt.tombstone {
                        match &best {
                            Some(b) if &nxt.value <= b => {}
                            _ => best = Some(nxt.value.clone()),
                        }
                    }
                    if nxt.tombstone {
                        masked = true;
                    }
                    self.inner.next();
                } else {
                    break;
                }
            }
            if let Some(v) = best {
                first.tombstone = false;
                first.value = v;
                return Some(first);
            }
        }
    }
}

// -------------------------------------------------------------- filters

/// Predicate filter over entries (column filters, value thresholds, ...).
pub struct FilterIter<I: Iterator<Item = Entry>, F: FnMut(&Entry) -> bool> {
    inner: I,
    pred: F,
}

impl<I: Iterator<Item = Entry>, F: FnMut(&Entry) -> bool> FilterIter<I, F> {
    pub fn new(inner: I, pred: F) -> Self {
        FilterIter { inner, pred }
    }
}

impl<I: Iterator<Item = Entry>, F: FnMut(&Entry) -> bool> Iterator for FilterIter<I, F> {
    type Item = Entry;

    fn next(&mut self) -> Option<Entry> {
        loop {
            let e = self.inner.next()?;
            if (self.pred)(&e) {
                return Some(e);
            }
        }
    }
}

/// Declarative scan-time iterator configuration (what a client attaches to
/// a scanner; mirrors Accumulo's `IteratorSetting` stack).
#[derive(Debug, Clone, Default)]
pub struct IterConfig {
    /// Fold versions with a summing combiner instead of keeping newest.
    pub summing: bool,
    /// Fold versions with a max combiner.
    pub max_combine: bool,
    /// Keep only entries whose column qualifier starts with this prefix.
    pub cq_prefix: Option<String>,
    /// Keep only entries with numeric value >= threshold.
    pub min_value: Option<f64>,
}

impl IterConfig {
    /// Apply this stack to a merged sorted stream. The stack stays lazy:
    /// each combinator wraps the stream and transforms entries as the
    /// consumer pulls them.
    pub fn apply(&self, merged: EntryStream) -> EntryStream {
        let mut out: EntryStream = if self.summing {
            Box::new(SummingCombiner::new(merged))
        } else if self.max_combine {
            Box::new(MaxCombiner::new(merged))
        } else {
            Box::new(VersioningIter::new(merged))
        };
        if let Some(p) = self.cq_prefix.clone() {
            out = Box::new(FilterIter::new(out, move |e| e.key.cq.starts_with(&p)));
        }
        if let Some(t) = self.min_value {
            out = Box::new(FilterIter::new(out, move |e| {
                e.value.parse::<f64>().map(|v| v >= t).unwrap_or(false)
            }));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::key::Key;

    fn e(row: &str, cq: &str, ts: u64, v: &str) -> Entry {
        Entry::new(Key::cell(row, cq, ts), v)
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn merge_interleaves_sorted() {
        let a = vec![e("a", "x", 0, "1"), e("c", "x", 0, "3")];
        let b = vec![e("b", "x", 0, "2"), e("d", "x", 0, "4")];
        let m: Vec<Entry> = MergeIter::new(vec![
            Box::new(a.into_iter()),
            Box::new(b.into_iter()),
        ])
        .collect();
        let rows: Vec<&str> = m.iter().map(|x| x.key.row.as_str()).collect();
        assert_eq!(rows, vec!["a", "b", "c", "d"]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn versioning_keeps_newest() {
        let src = vec![e("r", "c", 9, "new"), e("r", "c", 1, "old"), e("r", "d", 1, "x")];
        let out: Vec<Entry> = VersioningIter::new(src.into_iter()).collect();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].value, "new");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn summing_combiner_sums_versions() {
        let src = vec![e("r", "c", 3, "2"), e("r", "c", 2, "3"), e("r", "c", 1, "5")];
        let out: Vec<Entry> = SummingCombiner::new(src.into_iter()).collect();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "10");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn max_combiner_takes_max() {
        let src = vec![e("r", "c", 2, "apple"), e("r", "c", 1, "zebra")];
        let out: Vec<Entry> = MaxCombiner::new(src.into_iter()).collect();
        assert_eq!(out[0].value, "zebra");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn filter_drops() {
        let src = vec![e("r", "deg|x", 0, "1"), e("r", "word|y", 0, "2")];
        let out: Vec<Entry> =
            FilterIter::new(src.into_iter(), |x| x.key.cq.starts_with("word|")).collect();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "2");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn config_stack_compose() {
        let src = vec![
            e("r", "w|a", 3, "4"),
            e("r", "w|a", 2, "6"),
            e("r", "x|b", 1, "100"),
        ];
        let cfg = IterConfig {
            summing: true,
            cq_prefix: Some("w|".into()),
            min_value: Some(5.0),
            ..Default::default()
        };
        let out: Vec<Entry> = cfg.apply(Box::new(src.into_iter())).collect();
        // versions of (r, w|a) sum to 10, passes min_value; x|b filtered by prefix
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "10");
    }
}

#[cfg(test)]
mod tombstone_tests {
    use super::*;
    use crate::kvstore::key::Key;

    fn e(row: &str, cq: &str, ts: u64, v: &str) -> Entry {
        Entry::new(Key::cell(row, cq, ts), v)
    }

    fn del(row: &str, cq: &str, ts: u64) -> Entry {
        Entry::delete(Key::cell(row, cq, ts))
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn versioning_hides_deleted_cell() {
        let src = vec![del("r", "c", 9), e("r", "c", 1, "old"), e("r", "d", 1, "x")];
        let out: Vec<Entry> = VersioningIter::new(src.into_iter()).collect();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].key.cq, "d");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn write_after_delete_visible() {
        let src = vec![e("r", "c", 10, "new"), del("r", "c", 5), e("r", "c", 1, "old")];
        let out: Vec<Entry> = VersioningIter::new(src.into_iter()).collect();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "new");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn summing_respects_tombstone_mask() {
        // versions: 4 (newest), DELETE at ts 3, 100 at ts 1 -> sum = 4
        let src = vec![e("r", "c", 4, "4"), del("r", "c", 3), e("r", "c", 1, "100")];
        let out: Vec<Entry> = SummingCombiner::new(src.into_iter()).collect();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "4");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn summing_skips_fully_deleted() {
        let src = vec![del("r", "c", 9), e("r", "c", 1, "5"), e("r", "d", 1, "7")];
        let out: Vec<Entry> = SummingCombiner::new(src.into_iter()).collect();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].value, "7");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn max_respects_tombstone() {
        let src = vec![e("r", "c", 4, "b"), del("r", "c", 3), e("r", "c", 1, "z")];
        let out: Vec<Entry> = MaxCombiner::new(src.into_iter()).collect();
        assert_eq!(out[0].value, "b");
    }
}
