//! Accumulo-style keys and entries.
//!
//! A key is `(row, column family, column qualifier, timestamp)`; ordering
//! is lexicographic on the columns with **timestamp descending** (newest
//! version first), exactly as in Accumulo's sorted-key model.

use std::cmp::Ordering;

/// Sorted key of the key-value store.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Key {
    pub row: String,
    /// Column family (D4M schema usually leaves this empty).
    pub cf: String,
    /// Column qualifier (the D4M "column key").
    pub cq: String,
    /// Logical timestamp; larger = newer.
    pub ts: u64,
}

impl Key {
    pub fn new(row: impl Into<String>, cf: impl Into<String>, cq: impl Into<String>, ts: u64) -> Self {
        Key { row: row.into(), cf: cf.into(), cq: cq.into(), ts }
    }

    /// Key with empty column family (the D4M common case).
    pub fn cell(row: impl Into<String>, cq: impl Into<String>, ts: u64) -> Self {
        Key::new(row, "", cq, ts)
    }

    /// True if two keys address the same logical cell (ignoring version).
    pub fn same_cell(&self, other: &Key) -> bool {
        self.row == other.row && self.cf == other.cf && self.cq == other.cq
    }

    /// Approximate size in bytes (for batch/memtable accounting).
    pub fn bytes(&self) -> usize {
        self.row.len() + self.cf.len() + self.cq.len() + 8
    }
}

impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        self.row
            .cmp(&other.row)
            .then_with(|| self.cf.cmp(&other.cf))
            .then_with(|| self.cq.cmp(&other.cq))
            // timestamp DESCENDING: newest version sorts first
            .then_with(|| other.ts.cmp(&self.ts))
    }
}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A stored key-value pair. A `None`-like delete is encoded by
/// `tombstone = true` (Accumulo's delete marker): it supersedes older
/// versions of the cell and is elided from scan output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Entry {
    pub key: Key,
    pub value: String,
    pub tombstone: bool,
}

impl Entry {
    pub fn new(key: Key, value: impl Into<String>) -> Self {
        Entry { key, value: value.into(), tombstone: false }
    }

    /// A delete marker for the cell.
    pub fn delete(key: Key) -> Self {
        Entry { key, value: String::new(), tombstone: true }
    }

    pub fn bytes(&self) -> usize {
        self.key.bytes() + self.value.len() + 1
    }
}

/// A half-open row range `[start, end)`; `None` end = unbounded.
#[derive(Debug, Clone, Default)]
pub struct RowRange {
    pub start: Option<String>,
    pub end: Option<String>,
}

impl RowRange {
    pub fn all() -> Self {
        RowRange::default()
    }

    pub fn from(start: impl Into<String>) -> Self {
        RowRange { start: Some(start.into()), end: None }
    }

    pub fn span(start: impl Into<String>, end: impl Into<String>) -> Self {
        RowRange { start: Some(start.into()), end: Some(end.into()) }
    }

    /// Exactly one row.
    pub fn single(row: &str) -> Self {
        // end = row + lowest following string
        RowRange { start: Some(row.to_string()), end: Some(format!("{row}\0")) }
    }

    /// Inclusive range `[start, end]` (the half-open end is pushed just
    /// past `end` by appending the lowest following string).
    pub fn inclusive(start: impl Into<String>, end: impl Into<String>) -> Self {
        let end = end.into();
        RowRange { start: Some(start.into()), end: Some(format!("{end}\0")) }
    }

    pub fn contains(&self, row: &str) -> bool {
        if let Some(s) = &self.start {
            if row < s.as_str() {
                return false;
            }
        }
        if let Some(e) = &self.end {
            if row >= e.as_str() {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_order_row_then_col() {
        let a = Key::cell("r1", "c1", 0);
        let b = Key::cell("r1", "c2", 0);
        let c = Key::cell("r2", "a", 0);
        assert!(a < b && b < c);
    }

    #[test]
    fn key_order_timestamp_descending() {
        let newer = Key::cell("r", "c", 10);
        let older = Key::cell("r", "c", 5);
        assert!(newer < older, "newest version must sort first");
    }

    #[test]
    fn same_cell_ignores_ts() {
        assert!(Key::cell("r", "c", 1).same_cell(&Key::cell("r", "c", 9)));
        assert!(!Key::cell("r", "c", 1).same_cell(&Key::cell("r", "d", 1)));
    }

    #[test]
    fn range_contains() {
        let r = RowRange::span("b", "d");
        assert!(!r.contains("a"));
        assert!(r.contains("b"));
        assert!(r.contains("c"));
        assert!(!r.contains("d"));
        assert!(RowRange::all().contains("anything"));
    }

    #[test]
    fn range_single() {
        let r = RowRange::single("row7");
        assert!(r.contains("row7"));
        assert!(!r.contains("row70"));
        assert!(!r.contains("row6"));
    }
}
