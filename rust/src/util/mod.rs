//! Small shared utilities: deterministic RNG, sorted-vec helpers, a tiny
//! property-testing harness (`forall`), the shared [`KeySel`] string
//! parser, and human-readable rate formatting.

pub mod bench;
pub mod fasthash;
pub mod rng;

pub use fasthash::{FastHasher, FastMap};
pub use rng::XorShift64;

use crate::assoc::KeySel;

/// Lock a mutex, recovering from poisoning. Every mutex this is used on
/// guards state that stays coherent across a panicking holder (counters,
/// maps, seek-locked file handles — never multi-step invariants), so a
/// poisoned lock is recovered rather than propagated; propagating would
/// turn one worker's panic into a panic in every thread that touches the
/// lock afterwards, including `Drop` impls (see `net::server::ConnGuard`).
pub fn lock_recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Parse the D4M selector string forms shared by the CLI
/// (`scan-pages`/`client query` flags) and the plan expression language
/// (`G('a,:,m,', ':')`). Infallible — every string means *some*
/// selector:
///
/// - `""` or `":"` → [`KeySel::All`]
/// - `"a,:,m,"` (three items, middle `:`) → [`KeySel::Range`]`("a", "m")`
/// - `"pre*"` (single item, trailing `*`) → [`KeySel::Prefix`]`("pre")`
/// - `"a,b,c,"` → [`KeySel::Keys`] (trailing comma optional)
pub fn parse_keysel(s: &str) -> KeySel {
    let s = s.trim();
    if s.is_empty() || s == ":" {
        return KeySel::All;
    }
    let mut items: Vec<&str> = s.split(',').collect();
    // D4M selector strings conventionally end with the separator
    // ("a,b,"), which split() renders as a trailing empty item
    if items.last() == Some(&"") {
        items.pop();
    }
    if items.len() == 1 && items[0] == ":" {
        return KeySel::All;
    }
    if items.len() == 3 && items[1] == ":" {
        return KeySel::Range(items[0].to_string(), items[2].to_string());
    }
    if items.len() == 1 {
        if let Some(prefix) = items[0].strip_suffix('*') {
            return if prefix.is_empty() {
                KeySel::All
            } else {
                KeySel::Prefix(prefix.to_string())
            };
        }
    }
    KeySel::Keys(items.iter().map(|k| k.to_string()).collect())
}

/// Merge two sorted, deduplicated string slices into a sorted, deduplicated
/// union. Returns the union plus, for each input, a mapping from its local
/// indices to union indices. The maps are strictly increasing — the CSR
/// layer relies on that to embed without re-sorting. One key comparison
/// per output element.
pub fn merge_sorted_keys(a: &[String], b: &[String]) -> (Vec<String>, Vec<usize>, Vec<usize>) {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let mut map_a = Vec::with_capacity(a.len());
    let mut map_b = Vec::with_capacity(b.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        let idx = out.len();
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => {
                out.push(a[i].clone());
                map_a.push(idx);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out.push(b[j].clone());
                map_b.push(idx);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out.push(a[i].clone());
                map_a.push(idx);
                map_b.push(idx);
                i += 1;
                j += 1;
            }
        }
    }
    while i < a.len() {
        map_a.push(out.len());
        out.push(a[i].clone());
        i += 1;
    }
    while j < b.len() {
        map_b.push(out.len());
        out.push(b[j].clone());
        j += 1;
    }
    (out, map_a, map_b)
}

/// Intersect two sorted, deduplicated string slices. Returns the
/// intersection plus index maps (intersection index -> local index) for
/// each input.
pub fn intersect_sorted_keys(
    a: &[String],
    b: &[String],
) -> (Vec<String>, Vec<usize>, Vec<usize>) {
    let mut out = Vec::new();
    let mut map_a = Vec::new();
    let mut map_b = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i].clone());
                map_a.push(i);
                map_b.push(j);
                i += 1;
                j += 1;
            }
        }
    }
    (out, map_a, map_b)
}

/// Binary-search a sorted key slice; `Ok(i)` if present, `Err(insert)` if not.
pub fn find_key(keys: &[String], k: &str) -> std::result::Result<usize, usize> {
    keys.binary_search_by(|probe| probe.as_str().cmp(k))
}

/// Format a rate as a human string, e.g. `1.25 M/s`.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} K/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} /s")
    }
}

/// Format a byte count as a human string.
pub fn fmt_bytes(n: usize) -> String {
    const KB: f64 = 1024.0;
    let n = n as f64;
    if n >= KB * KB * KB {
        format!("{:.2} GiB", n / (KB * KB * KB))
    } else if n >= KB * KB {
        format!("{:.2} MiB", n / (KB * KB))
    } else if n >= KB {
        format!("{:.2} KiB", n / KB)
    } else {
        format!("{n:.0} B")
    }
}

/// Minimal deterministic property-test driver (stand-in for `proptest`,
/// which is unavailable offline). Runs `f` on `n` cases generated from a
/// seeded RNG; panics with the failing seed for reproduction.
pub fn forall<F: FnMut(&mut XorShift64)>(n: usize, seed: u64, mut f: F) {
    for case in 0..n {
        let case_seed = seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = XorShift64::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed on case {case} (seed {case_seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn merge_disjoint() {
        let (u, ma, mb) = merge_sorted_keys(&v(&["a", "c"]), &v(&["b", "d"]));
        assert_eq!(u, v(&["a", "b", "c", "d"]));
        assert_eq!(ma, vec![0, 2]);
        assert_eq!(mb, vec![1, 3]);
    }

    #[test]
    fn merge_overlap() {
        let (u, ma, mb) = merge_sorted_keys(&v(&["a", "b"]), &v(&["b", "c"]));
        assert_eq!(u, v(&["a", "b", "c"]));
        assert_eq!(ma, vec![0, 1]);
        assert_eq!(mb, vec![1, 2]);
    }

    #[test]
    fn merge_empty_sides() {
        let (u, ma, mb) = merge_sorted_keys(&[], &v(&["x"]));
        assert_eq!(u, v(&["x"]));
        assert!(ma.is_empty());
        assert_eq!(mb, vec![0]);
        let (u2, ma2, mb2) = merge_sorted_keys(&v(&["x"]), &[]);
        assert_eq!(u2, v(&["x"]));
        assert_eq!(ma2, vec![0]);
        assert!(mb2.is_empty());
    }

    #[test]
    fn intersect_basic() {
        let (x, ia, ib) = intersect_sorted_keys(&v(&["a", "b", "d"]), &v(&["b", "c", "d"]));
        assert_eq!(x, v(&["b", "d"]));
        assert_eq!(ia, vec![1, 2]);
        assert_eq!(ib, vec![0, 2]);
    }

    #[test]
    fn intersect_disjoint_is_empty() {
        let (x, _, _) = intersect_sorted_keys(&v(&["a"]), &v(&["b"]));
        assert!(x.is_empty());
    }

    #[test]
    fn merge_is_union_property() {
        forall(50, 0xD4D4, |rng| {
            let mk = |rng: &mut XorShift64| {
                let mut ks: Vec<String> =
                    (0..rng.below(20)).map(|_| format!("k{:03}", rng.below(30))).collect();
                ks.sort();
                ks.dedup();
                ks
            };
            let a = mk(rng);
            let b = mk(rng);
            let (u, ma, mb) = merge_sorted_keys(&a, &b);
            // sorted + deduped
            assert!(u.windows(2).all(|w| w[0] < w[1]));
            // maps are consistent
            for (i, &ui) in ma.iter().enumerate() {
                assert_eq!(u[ui], a[i]);
            }
            for (j, &uj) in mb.iter().enumerate() {
                assert_eq!(u[uj], b[j]);
            }
            // union contains exactly a ∪ b
            let mut expect: Vec<String> = a.iter().chain(b.iter()).cloned().collect();
            expect.sort();
            expect.dedup();
            assert_eq!(u, expect);
        });
    }

    #[test]
    fn parse_keysel_forms() {
        assert_eq!(parse_keysel(""), KeySel::All);
        assert_eq!(parse_keysel(":"), KeySel::All);
        assert_eq!(parse_keysel(" : "), KeySel::All);
        assert_eq!(parse_keysel("*"), KeySel::All);
        assert_eq!(
            parse_keysel("a,:,m,"),
            KeySel::Range("a".into(), "m".into())
        );
        assert_eq!(
            parse_keysel("a,:,m"),
            KeySel::Range("a".into(), "m".into())
        );
        assert_eq!(parse_keysel("pre*"), KeySel::Prefix("pre".into()));
        assert_eq!(
            parse_keysel("a,b,c,"),
            KeySel::Keys(v(&["a", "b", "c"]))
        );
        assert_eq!(parse_keysel("solo"), KeySel::Keys(v(&["solo"])));
        // a '*' inside a multi-item list is a literal key, not a prefix
        assert_eq!(
            parse_keysel("a*,b,"),
            KeySel::Keys(v(&["a*", "b"]))
        );
    }

    #[test]
    fn parse_keysel_never_panics() {
        forall(300, 0x5E1E_C70F, |rng| {
            let len = rng.below(32) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let s = String::from_utf8_lossy(&bytes).into_owned();
            let _ = parse_keysel(&s);
        });
    }

    #[test]
    fn fmt_rate_units() {
        assert_eq!(fmt_rate(5.0), "5.00 /s");
        assert_eq!(fmt_rate(5_000.0), "5.00 K/s");
        assert_eq!(fmt_rate(5_000_000.0), "5.00 M/s");
        assert_eq!(fmt_rate(5e9), "5.00 G/s");
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
    }
}
