//! Deterministic xorshift64* RNG. All workload generation in this repo is
//! seeded through this so every benchmark row and test is reproducible.

/// xorshift64* — fast, deterministic, good enough for workload synthesis.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a new RNG; a zero seed is remapped (xorshift requires != 0).
    pub fn new(seed: u64) -> Self {
        XorShift64 {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // modulo bias is negligible for the n << 2^64 used here
        self.next_u64() % n
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random lowercase alphanumeric string of length `len`.
    pub fn string(&mut self, len: usize) -> String {
        const ALPHA: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789";
        (0..len)
            .map(|_| ALPHA[self.below(ALPHA.len() as u64) as usize] as char)
            .collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_ok() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShift64::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift64::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_roughly_uniform() {
        let mut r = XorShift64::new(11);
        // Miri executes this interpreter-speed; 2k keeps the mean test
        // meaningful (tolerance loosened accordingly) without the wait.
        let n = if cfg!(miri) { 2_000 } else { 20_000 };
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        let tol = if cfg!(miri) { 0.05 } else { 0.02 };
        assert!((mean - 0.5).abs() < tol, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift64::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn string_charset() {
        let mut r = XorShift64::new(3);
        let s = r.string(64);
        assert_eq!(s.len(), 64);
        assert!(s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit()));
    }
}
