//! Machine-readable benchmark records.
//!
//! Every bench driver appends `{op, n, backend, seconds, entries_per_sec}`
//! objects to a JSON-array file (`BENCH_assoc.json` for the assoc-algebra
//! trajectory), so regressions show up as data instead of scrollback.
//! No JSON dependency offline: records are emitted by hand and appended
//! by splicing before the closing bracket, keeping the file a valid JSON
//! array after every run.

use std::io::Write;
use std::path::Path;

/// One timing record from a bench driver.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    /// Operation name, e.g. `construct`, `add`, `matmul`, `tablemult`.
    pub op: String,
    /// Problem size (input entries / edges).
    pub n: usize,
    /// Backend label, e.g. `naive`, `csr`, `graphulo`, `d4m`.
    pub backend: String,
    /// Wall-clock seconds for the op.
    pub seconds: f64,
    /// Throughput in processed entries per second.
    pub entries_per_sec: f64,
}

impl BenchRecord {
    /// Record an op that processed `entries` items in `seconds`.
    pub fn new(op: &str, n: usize, backend: &str, seconds: f64, entries: usize) -> Self {
        BenchRecord {
            op: op.to_string(),
            n,
            backend: backend.to_string(),
            seconds,
            entries_per_sec: entries as f64 / seconds.max(1e-12),
        }
    }

    fn to_json(&self) -> String {
        format!(
            "{{\"op\":\"{}\",\"n\":{},\"backend\":\"{}\",\"seconds\":{:.6},\"entries_per_sec\":{:.1}}}",
            json_escape(&self.op),
            self.n,
            json_escape(&self.backend),
            self.seconds,
            self.entries_per_sec
        )
    }
}

/// Escape the two characters that can break a JSON string (labels here
/// are ASCII identifiers; control characters don't occur).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            _ => out.push(c),
        }
    }
    out
}

/// Append records to a JSON-array file, creating it if missing. The file
/// is a valid JSON array after every append: existing contents are kept
/// by splicing the new records in before the closing `]`.
pub fn append_records(path: &Path, records: &[BenchRecord]) -> std::io::Result<()> {
    if records.is_empty() {
        return Ok(());
    }
    let body: Vec<String> = records.iter().map(|r| format!("  {}", r.to_json())).collect();
    let body = body.join(",\n");
    let existing = std::fs::read_to_string(path).ok();
    let out = match existing {
        Some(s) if !s.trim().is_empty() => {
            let head = s.trim_end();
            let head = head.strip_suffix(']').unwrap_or(head).trim_end();
            let head = head.strip_suffix(',').unwrap_or(head);
            if head.trim() == "[" {
                format!("[\n{body}\n]\n")
            } else {
                format!("{head},\n{body}\n]\n")
            }
        }
        _ => format!("[\n{body}\n]\n"),
    };
    let mut f = std::fs::File::create(path)?;
    f.write_all(out.as_bytes())
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("d4m_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn record_json_shape() {
        let r = BenchRecord::new("add", 1024, "csr", 0.5, 1024);
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"op\":\"add\""));
        assert!(j.contains("\"n\":1024"));
        assert!(j.contains("\"backend\":\"csr\""));
        assert!(j.contains("\"entries_per_sec\":2048.0"));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn append_creates_then_splices() {
        let p = tmp("append.json");
        let _ = std::fs::remove_file(&p);
        append_records(&p, &[BenchRecord::new("a", 1, "x", 1.0, 1)]).unwrap();
        append_records(&p, &[BenchRecord::new("b", 2, "y", 1.0, 2)]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.trim_start().starts_with('['));
        assert!(s.trim_end().ends_with(']'));
        assert!(s.contains("\"op\":\"a\""));
        assert!(s.contains("\"op\":\"b\""));
        // exactly one array: one '[' and one ']'
        assert_eq!(s.matches('[').count(), 1);
        assert_eq!(s.matches(']').count(), 1);
        // and the comma splice keeps it parseable by eye: 2 objects
        assert_eq!(s.matches("{\"op\"").count(), 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn escape_quotes() {
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
    }
}
