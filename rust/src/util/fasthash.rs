//! A small multiplicative hasher (FxHash-style) for hot-path integer
//! keys. The std default SipHash is DoS-resistant but ~3-5x slower for
//! u64 keys; TableMult's partial-sum combiner does millions of lookups
//! per multiply, where this matters (§Perf).

use std::hash::{BuildHasherDefault, Hasher};

const K: u64 = 0x517C_C1B7_2722_0A95;

/// Multiply-rotate hasher; good distribution for integer keys.
#[derive(Default)]
pub struct FastHasher {
    state: u64,
}

impl Hasher for FastHasher {
    fn finish(&self) -> u64 {
        self.state
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = (self.state.rotate_left(5) ^ b as u64).wrapping_mul(K);
        }
    }

    fn write_u64(&mut self, x: u64) {
        self.state = (self.state.rotate_left(5) ^ x).wrapping_mul(K);
    }

    fn write_u32(&mut self, x: u32) {
        self.write_u64(x as u64);
    }

    fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }
}

/// HashMap with the fast hasher.
pub type FastMap<K, V> = std::collections::HashMap<K, V, BuildHasherDefault<FastHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works() {
        let mut m: FastMap<u64, f64> = FastMap::default();
        for i in 0..10_000u64 {
            *m.entry(i % 1000).or_insert(0.0) += 1.0;
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&7], 10.0);
    }

    #[test]
    fn distributes() {
        // sequential keys should not collide into few buckets: check that
        // hashes differ in their low bits
        use std::hash::Hash;
        let mut low_bits = std::collections::HashSet::new();
        for i in 0..256u64 {
            let mut h = FastHasher::default();
            i.hash(&mut h);
            low_bits.insert(h.finish() & 0xFF);
        }
        assert!(low_bits.len() > 128, "only {} distinct low bytes", low_bits.len());
    }
}
