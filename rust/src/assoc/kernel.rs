//! Kernel pool — process-wide configuration and counters for the
//! parallel blocked algebra kernels (DESIGN.md §Parallel kernels).
//!
//! Every multiplying kernel (SpGEMM, CatKeyMul, server-side TableMult,
//! the array-store `spgemm`, the dense blocked GEMM) reads a
//! [`KernelConfig`] to decide how many `std::thread::scope` workers to
//! fork and when a row is skewed enough to take the cache-blocked
//! accumulator. The process-wide default comes from
//! `available_parallelism` (overridable with `D4M_KERNEL_THREADS` or
//! `d4m serve --kernel-threads`); call sites that need a pinned
//! configuration — tests, benches, the serial baseline legs — pass an
//! explicit config through the `*_with` APIs instead of mutating the
//! global.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::sync::OnceLock;

use crate::error::{D4mError, Result};
use crate::metrics::Counter;

/// Upper bound on configurable worker threads; values above this are
/// treated as absurd and clamped (with a typed [`D4mError::InvalidArg`]
/// surfaced to the caller) rather than spawning a thread storm.
pub const MAX_KERNEL_THREADS: usize = 512;

/// Tuning knobs for the parallel blocked kernels. `Copy`, so call sites
/// snapshot it once per op — a concurrent reconfigure never changes a
/// kernel mid-flight.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelConfig {
    /// Worker threads for row-block parallel kernels (>= 1; 1 = serial).
    pub threads: usize,
    /// Minimum estimated partial products (FLOPs) in an op before worker
    /// threads are forked; below it the spawn overhead dominates.
    pub parallel_cutoff: usize,
    /// Column-tile width of the cache-blocked accumulator (sized so one
    /// f64 tile plus its marker tile stays L2-resident).
    pub tile_cols: usize,
    /// Per-row FLOP estimate above which a row switches from the
    /// full-width marker accumulator to the cache-blocked one.
    pub blocked_row_flops: usize,
}

impl KernelConfig {
    /// Detect a default configuration: `D4M_KERNEL_THREADS` (when set to
    /// a sane value) or `available_parallelism`.
    pub fn detect() -> Self {
        let threads = std::env::var("D4M_KERNEL_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| (1..=MAX_KERNEL_THREADS).contains(&n))
            .unwrap_or_else(default_threads);
        KernelConfig {
            threads,
            parallel_cutoff: 1 << 15,
            tile_cols: 1 << 12,
            blocked_row_flops: 1 << 15,
        }
    }

    /// Snapshot of the process-wide configuration.
    pub fn global() -> Self {
        *global_cell().lock().unwrap()
    }

    /// The global configuration pinned to one thread (the serial
    /// baseline used by equivalence tests and bench legs).
    pub fn serial() -> Self {
        KernelConfig { threads: 1, ..Self::global() }
    }

    /// This configuration with a different thread count.
    pub fn with_threads(self, threads: usize) -> Self {
        KernelConfig { threads, ..self }
    }
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig::detect()
    }
}

fn global_cell() -> &'static std::sync::Mutex<KernelConfig> {
    static CELL: OnceLock<std::sync::Mutex<KernelConfig>> = OnceLock::new();
    CELL.get_or_init(|| std::sync::Mutex::new(KernelConfig::detect()))
}

/// Replace the process-wide kernel configuration (`d4m serve
/// --kernel-threads` plumbs through here). Ops already running keep the
/// snapshot they took.
pub fn configure(cfg: KernelConfig) {
    *global_cell().lock().unwrap() = cfg;
}

/// Hardware default: `available_parallelism`, 1 when undetectable.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Validate a requested worker-thread count. `0` and values above
/// [`MAX_KERNEL_THREADS`] are rejected with a typed
/// [`D4mError::InvalidArg`]; the CLI catches it and clamps to
/// [`default_threads`].
pub fn validated_threads(n: usize) -> Result<usize> {
    if n == 0 || n > MAX_KERNEL_THREADS {
        return Err(D4mError::InvalidArg(format!(
            "kernel-threads must be in 1..={MAX_KERNEL_THREADS}, got {n}"
        )));
    }
    Ok(n)
}

/// Dispatch counters for the metrics snapshot (`kernels.*` keys in
/// `d4m client stats`). Process-global like the config: kernels are a
/// process resource, not a per-server one.
pub struct KernelCounters {
    /// Ops dispatched across worker threads.
    pub parallel_ops: Counter,
    /// Ops that stayed on the calling thread (below the cutoff or a
    /// 1-thread pool).
    pub serial_ops: Counter,
    /// Rows routed through the cache-blocked accumulator.
    pub blocked_rows: Counter,
}

/// The process-wide kernel counters.
pub fn counters() -> &'static KernelCounters {
    static CELL: OnceLock<KernelCounters> = OnceLock::new();
    CELL.get_or_init(|| KernelCounters {
        parallel_ops: Counter::new(),
        serial_ops: Counter::new(),
        blocked_rows: Counter::new(),
    })
}

/// Split `0..weights.len()` items into at most `parts` contiguous blocks
/// of roughly equal total weight. Returns block boundaries
/// `b[0]=0 < b[1] < .. < b[k]=len` (empty blocks are skipped, so every
/// returned block is non-empty; a zero-total input yields one block).
/// Shared by the SpGEMM row partitioner and the dense row-tile split.
pub fn balanced_partition(weights: &[u64], parts: usize) -> Vec<usize> {
    let n = weights.len();
    let mut prefix = Vec::with_capacity(n + 1);
    let mut acc = 0u64;
    prefix.push(0u64);
    for &w in weights {
        acc += w;
        prefix.push(acc);
    }
    let total = acc;
    let mut bounds = vec![0usize];
    if n == 0 || parts <= 1 || total == 0 {
        bounds.push(n);
        return bounds;
    }
    for t in 1..parts {
        let target = total * t as u64 / parts as u64;
        let cut = prefix.partition_point(|&p| p < target).min(n);
        if cut > *bounds.last().unwrap() && cut < n {
            bounds.push(cut);
        }
    }
    bounds.push(n);
    bounds
}

/// Number of scoped workers a kernel should fork for an op with
/// `estimated_flops` total work: 1 (serial) below the cutoff, else the
/// configured thread count. Also bumps the matching dispatch counter so
/// every kernel accounts consistently.
pub fn plan_workers(cfg: &KernelConfig, estimated_flops: u64) -> usize {
    let threads = cfg.threads.max(1);
    if threads <= 1 || estimated_flops < cfg.parallel_cutoff as u64 {
        counters().serial_ops.inc();
        1
    } else {
        counters().parallel_ops.inc();
        threads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn validated_threads_accepts_sane() {
        assert_eq!(validated_threads(1).unwrap(), 1);
        assert_eq!(validated_threads(8).unwrap(), 8);
        assert_eq!(validated_threads(MAX_KERNEL_THREADS).unwrap(), MAX_KERNEL_THREADS);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn validated_threads_rejects_zero_and_absurd() {
        for bad in [0, MAX_KERNEL_THREADS + 1, usize::MAX] {
            match validated_threads(bad) {
                Err(D4mError::InvalidArg(msg)) => {
                    assert!(msg.contains("kernel-threads"), "{msg}")
                }
                other => panic!("expected InvalidArg for {bad}, got {other:?}"),
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn detect_has_at_least_one_thread() {
        let cfg = KernelConfig::detect();
        assert!(cfg.threads >= 1);
        assert!(cfg.tile_cols > 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn balanced_partition_covers_all_items() {
        let w = [5u64, 1, 1, 1, 20, 1, 1, 1, 5, 5];
        for parts in 1..=12 {
            let b = balanced_partition(&w, parts);
            assert_eq!(*b.first().unwrap(), 0);
            assert_eq!(*b.last().unwrap(), w.len());
            assert!(b.windows(2).all(|x| x[0] < x[1]), "{b:?}");
            assert!(b.len() <= parts + 1);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn balanced_partition_empty_and_zero_weight() {
        assert_eq!(balanced_partition(&[], 4), vec![0, 0]);
        assert_eq!(balanced_partition(&[0, 0, 0], 4), vec![0, 3]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn balanced_partition_skewed_isolates_heavy_rows() {
        // one hub row dominating the weight: the partition must not put
        // equal row *counts* in each block
        let mut w = vec![1u64; 64];
        w[0] = 1000;
        let b = balanced_partition(&w, 4);
        // the hub lands alone (or nearly) in the first block
        assert!(b[1] <= 2, "{b:?}");
    }
}
