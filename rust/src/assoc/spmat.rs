//! CSR sparse-matrix core backing numeric associative arrays.
//!
//! Pure index-space kernel layer: no string keys here. All f64 values;
//! explicit zeros are dropped at construction (D4M semantics: zero means
//! "absent").

/// Compressed sparse row matrix, `nr x nc`, f64 values.
#[derive(Debug, Clone, PartialEq)]
pub struct SpMat {
    pub nr: usize,
    pub nc: usize,
    /// Row pointer, length `nr + 1`.
    pub indptr: Vec<usize>,
    /// Column indices per row, sorted within each row.
    pub indices: Vec<usize>,
    /// Values aligned with `indices`.
    pub data: Vec<f64>,
}

impl SpMat {
    /// Empty matrix of the given shape.
    pub fn zeros(nr: usize, nc: usize) -> Self {
        SpMat { nr, nc, indptr: vec![0; nr + 1], indices: Vec::new(), data: Vec::new() }
    }

    /// Build from (row, col, val) triples; duplicates are summed, zeros
    /// (including zero-sums) dropped.
    pub fn from_triples(nr: usize, nc: usize, triples: &[(usize, usize, f64)]) -> Self {
        let mut sorted: Vec<(usize, usize, f64)> = triples.to_vec();
        sorted.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut indptr = vec![0usize; nr + 1];
        let mut indices = Vec::with_capacity(sorted.len());
        let mut data: Vec<f64> = Vec::with_capacity(sorted.len());
        let mut i = 0;
        while i < sorted.len() {
            let (r, c, _) = sorted[i];
            debug_assert!(r < nr && c < nc, "triple ({r},{c}) out of shape ({nr},{nc})");
            let mut v = 0.0;
            while i < sorted.len() && sorted[i].0 == r && sorted[i].1 == c {
                v += sorted[i].2;
                i += 1;
            }
            if v != 0.0 {
                indices.push(c);
                data.push(v);
                indptr[r + 1] += 1;
            }
        }
        for r in 0..nr {
            indptr[r + 1] += indptr[r];
        }
        SpMat { nr, nc, indptr, indices, data }
    }

    /// Number of stored (nonzero) entries.
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Approximate heap footprint in bytes (used for the client-side
    /// memory-cap simulation of Figure 2).
    pub fn mem_bytes(&self) -> usize {
        self.indptr.len() * 8 + self.indices.len() * 8 + self.data.len() * 8
    }

    /// Iterate stored entries of row `r` as `(col, val)`.
    pub fn row(&self, r: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.indices[lo..hi].iter().copied().zip(self.data[lo..hi].iter().copied())
    }

    /// Value at (r, c), or 0.0 if absent.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        match self.indices[lo..hi].binary_search(&c) {
            Ok(i) => self.data[lo + i],
            Err(_) => 0.0,
        }
    }

    /// All stored entries as triples.
    pub fn to_triples(&self) -> Vec<(usize, usize, f64)> {
        let mut out = Vec::with_capacity(self.nnz());
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                out.push((r, c, v));
            }
        }
        out
    }

    /// Transpose (CSR -> CSR of the transpose), O(nnz + nr + nc).
    pub fn transpose(&self) -> SpMat {
        let mut indptr = vec![0usize; self.nc + 1];
        for &c in &self.indices {
            indptr[c + 1] += 1;
        }
        for c in 0..self.nc {
            indptr[c + 1] += indptr[c];
        }
        let mut next = indptr.clone();
        let mut indices = vec![0usize; self.nnz()];
        let mut data = vec![0f64; self.nnz()];
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                let slot = next[c];
                indices[slot] = r;
                data[slot] = v;
                next[c] += 1;
            }
        }
        SpMat { nr: self.nc, nc: self.nr, indptr, indices, data }
    }

    /// Elementwise combine over the union of patterns with `f(a, b)`
    /// (missing entries read as 0). Zeros in the result are dropped.
    /// Both matrices must share a shape.
    pub fn union_combine(&self, other: &SpMat, f: impl Fn(f64, f64) -> f64) -> SpMat {
        assert_eq!((self.nr, self.nc), (other.nr, other.nc), "shape mismatch");
        let mut indptr = vec![0usize; self.nr + 1];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for r in 0..self.nr {
            let (mut i, hi_a) = (self.indptr[r], self.indptr[r + 1]);
            let (mut j, hi_b) = (other.indptr[r], other.indptr[r + 1]);
            while i < hi_a || j < hi_b {
                let (c, v) = if j >= hi_b || (i < hi_a && self.indices[i] < other.indices[j]) {
                    let out = (self.indices[i], f(self.data[i], 0.0));
                    i += 1;
                    out
                } else if i >= hi_a || other.indices[j] < self.indices[i] {
                    let out = (other.indices[j], f(0.0, other.data[j]));
                    j += 1;
                    out
                } else {
                    let out = (self.indices[i], f(self.data[i], other.data[j]));
                    i += 1;
                    j += 1;
                    out
                };
                if v != 0.0 {
                    indices.push(c);
                    data.push(v);
                    indptr[r + 1] += 1;
                }
            }
        }
        for r in 0..self.nr {
            indptr[r + 1] += indptr[r];
        }
        SpMat { nr: self.nr, nc: self.nc, indptr, indices, data }
    }

    /// Elementwise combine over the intersection of patterns.
    pub fn intersect_combine(&self, other: &SpMat, f: impl Fn(f64, f64) -> f64) -> SpMat {
        assert_eq!((self.nr, self.nc), (other.nr, other.nc), "shape mismatch");
        let mut indptr = vec![0usize; self.nr + 1];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for r in 0..self.nr {
            let (mut i, hi_a) = (self.indptr[r], self.indptr[r + 1]);
            let (mut j, hi_b) = (other.indptr[r], other.indptr[r + 1]);
            while i < hi_a && j < hi_b {
                match self.indices[i].cmp(&other.indices[j]) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        let v = f(self.data[i], other.data[j]);
                        if v != 0.0 {
                            indices.push(self.indices[i]);
                            data.push(v);
                            indptr[r + 1] += 1;
                        }
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
        for r in 0..self.nr {
            indptr[r + 1] += indptr[r];
        }
        SpMat { nr: self.nr, nc: self.nc, indptr, indices, data }
    }

    /// Sparse matrix product `self * other` (Gustavson's algorithm with a
    /// dense accumulator row).
    pub fn matmul(&self, other: &SpMat) -> SpMat {
        assert_eq!(self.nc, other.nr, "inner dimension mismatch");
        let mut indptr = vec![0usize; self.nr + 1];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        // dense accumulator + touched-list (classic SpGEMM workspace)
        let mut acc = vec![0f64; other.nc];
        let mut touched: Vec<usize> = Vec::new();
        for r in 0..self.nr {
            for (k, av) in self.row(r) {
                for (c, bv) in other.row(k) {
                    if acc[c] == 0.0 && !touched.contains(&c) {
                        touched.push(c);
                    }
                    acc[c] += av * bv;
                }
            }
            touched.sort_unstable();
            for &c in &touched {
                if acc[c] != 0.0 {
                    indices.push(c);
                    data.push(acc[c]);
                    indptr[r + 1] += 1;
                }
                acc[c] = 0.0;
            }
            touched.clear();
        }
        for r in 0..self.nr {
            indptr[r + 1] += indptr[r];
        }
        SpMat { nr: self.nr, nc: other.nc, indptr, indices, data }
    }

    /// Map all stored values through `f`; zeros in the result are dropped.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> SpMat {
        let mut out = SpMat::zeros(self.nr, self.nc);
        let mut indptr = vec![0usize; self.nr + 1];
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                let fv = f(v);
                if fv != 0.0 {
                    out.indices.push(c);
                    out.data.push(fv);
                    indptr[r + 1] += 1;
                }
            }
        }
        for r in 0..self.nr {
            indptr[r + 1] += indptr[r];
        }
        out.indptr = indptr;
        out
    }

    /// Row sums (length `nr`).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.nr).map(|r| self.row(r).map(|(_, v)| v).sum()).collect()
    }

    /// Column sums (length `nc`).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut out = vec![0f64; self.nc];
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                out[c] += v;
            }
        }
        out
    }

    /// Select a subset of rows/cols by (sorted) index lists, producing the
    /// submatrix in the order given.
    pub fn select(&self, rows: &[usize], cols: &[usize]) -> SpMat {
        // col index -> new position
        let mut colmap = vec![usize::MAX; self.nc];
        for (new, &c) in cols.iter().enumerate() {
            colmap[c] = new;
        }
        let mut triples = Vec::new();
        for (new_r, &r) in rows.iter().enumerate() {
            for (c, v) in self.row(r) {
                if colmap[c] != usize::MAX {
                    triples.push((new_r, colmap[c], v));
                }
            }
        }
        SpMat::from_triples(rows.len(), cols.len(), &triples)
    }

    /// Re-embed this matrix into a larger index space: entry (r, c) moves
    /// to (row_map[r], col_map[c]).
    pub fn embed(&self, nr: usize, nc: usize, row_map: &[usize], col_map: &[usize]) -> SpMat {
        assert_eq!(row_map.len(), self.nr);
        assert_eq!(col_map.len(), self.nc);
        let mut triples = Vec::with_capacity(self.nnz());
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                triples.push((row_map[r], col_map[c], v));
            }
        }
        SpMat::from_triples(nr, nc, &triples)
    }

    /// Dense row-major materialisation (small matrices / runtime bridge).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0f64; self.nr * self.nc];
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                out[r * self.nc + c] = v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{forall, XorShift64};

    fn rand_mat(rng: &mut XorShift64, nr: usize, nc: usize, density: f64) -> SpMat {
        let mut tr = Vec::new();
        for r in 0..nr {
            for c in 0..nc {
                if rng.chance(density) {
                    tr.push((r, c, (rng.below(9) + 1) as f64));
                }
            }
        }
        SpMat::from_triples(nr, nc, &tr)
    }

    #[test]
    fn from_triples_sums_duplicates() {
        let m = SpMat::from_triples(2, 2, &[(0, 0, 1.0), (0, 0, 2.0), (1, 1, 5.0)]);
        assert_eq!(m.get(0, 0), 3.0);
        assert_eq!(m.get(1, 1), 5.0);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn from_triples_drops_zero_sum() {
        let m = SpMat::from_triples(1, 1, &[(0, 0, 1.0), (0, 0, -1.0)]);
        assert_eq!(m.nnz(), 0);
    }

    #[test]
    fn transpose_roundtrip() {
        forall(30, 0xBEEF, |rng| {
            let m = rand_mat(rng, 8, 5, 0.3);
            assert_eq!(m.transpose().transpose(), m);
        });
    }

    #[test]
    fn transpose_entries() {
        let m = SpMat::from_triples(2, 3, &[(0, 2, 7.0), (1, 0, 3.0)]);
        let t = m.transpose();
        assert_eq!(t.get(2, 0), 7.0);
        assert_eq!(t.get(0, 1), 3.0);
        assert_eq!((t.nr, t.nc), (3, 2));
    }

    #[test]
    fn union_combine_add() {
        let a = SpMat::from_triples(1, 3, &[(0, 0, 1.0), (0, 1, 2.0)]);
        let b = SpMat::from_triples(1, 3, &[(0, 1, 3.0), (0, 2, 4.0)]);
        let c = a.union_combine(&b, |x, y| x + y);
        assert_eq!(c.to_triples(), vec![(0, 0, 1.0), (0, 1, 5.0), (0, 2, 4.0)]);
    }

    #[test]
    fn intersect_combine_mult() {
        let a = SpMat::from_triples(1, 3, &[(0, 0, 2.0), (0, 1, 2.0)]);
        let b = SpMat::from_triples(1, 3, &[(0, 1, 3.0), (0, 2, 4.0)]);
        let c = a.intersect_combine(&b, |x, y| x * y);
        assert_eq!(c.to_triples(), vec![(0, 1, 6.0)]);
    }

    #[test]
    fn matmul_identity() {
        forall(20, 0xCAFE, |rng| {
            let m = rand_mat(rng, 6, 6, 0.4);
            let eye = SpMat::from_triples(6, 6, &(0..6).map(|i| (i, i, 1.0)).collect::<Vec<_>>());
            assert_eq!(m.matmul(&eye), m);
            assert_eq!(eye.matmul(&m), m);
        });
    }

    #[test]
    fn matmul_matches_dense() {
        forall(25, 0xD00D, |rng| {
            let a = rand_mat(rng, 5, 7, 0.35);
            let b = rand_mat(rng, 7, 4, 0.35);
            let c = a.matmul(&b);
            let (da, db, dc) = (a.to_dense(), b.to_dense(), c.to_dense());
            for i in 0..5 {
                for j in 0..4 {
                    let want: f64 = (0..7).map(|k| da[i * 7 + k] * db[k * 4 + j]).sum();
                    assert!((dc[i * 4 + j] - want).abs() < 1e-9);
                }
            }
        });
    }

    #[test]
    fn matmul_transpose_distributes() {
        // (A B)^T == B^T A^T
        forall(20, 0xF00D, |rng| {
            let a = rand_mat(rng, 4, 6, 0.4);
            let b = rand_mat(rng, 6, 5, 0.4);
            assert_eq!(a.matmul(&b).transpose(), b.transpose().matmul(&a.transpose()));
        });
    }

    #[test]
    fn row_col_sums() {
        let m = SpMat::from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 2.0), (1, 1, 4.0)]);
        assert_eq!(m.row_sums(), vec![3.0, 4.0]);
        assert_eq!(m.col_sums(), vec![1.0, 6.0]);
    }

    #[test]
    fn select_submatrix() {
        let m = SpMat::from_triples(3, 3, &[(0, 0, 1.0), (1, 1, 2.0), (2, 2, 3.0)]);
        let s = m.select(&[1, 2], &[1, 2]);
        assert_eq!(s.to_triples(), vec![(0, 0, 2.0), (1, 1, 3.0)]);
    }

    #[test]
    fn embed_into_larger() {
        let m = SpMat::from_triples(2, 2, &[(0, 0, 1.0), (1, 1, 2.0)]);
        let e = m.embed(4, 4, &[1, 3], &[0, 2]);
        assert_eq!(e.get(1, 0), 1.0);
        assert_eq!(e.get(3, 2), 2.0);
        assert_eq!(e.nnz(), 2);
    }

    #[test]
    fn map_drops_zeros() {
        let m = SpMat::from_triples(1, 2, &[(0, 0, 1.0), (0, 1, 2.0)]);
        let f = m.map(|v| if v > 1.5 { v } else { 0.0 });
        assert_eq!(f.to_triples(), vec![(0, 1, 2.0)]);
    }

    #[test]
    fn mem_bytes_counts() {
        let m = SpMat::from_triples(1, 2, &[(0, 0, 1.0)]);
        assert_eq!(m.mem_bytes(), 2 * 8 + 8 + 8);
    }
}
