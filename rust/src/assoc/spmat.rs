//! CSR sparse-matrix core backing numeric associative arrays.
//!
//! Pure index-space kernel layer: no string keys here. All f64 values;
//! explicit zeros are dropped at construction (D4M semantics: zero means
//! "absent").
//!
//! §Hot-path invariants (DESIGN.md §CSR hot paths): the algebra layer
//! above only ever selects/embeds through **sorted, unique** index lists
//! (they come from sorted-key merges and intersections), so [`SpMat::select`]
//! and [`SpMat::embed`] build their result CSR directly in O(nnz) without
//! re-sorting. Non-monotone index lists still work — they fall back to the
//! sorting [`SpMat::from_triples`] path. SpGEMM uses a dense accumulator
//! with a boolean marker array (never a `contains` scan), and
//! [`SpMat::matmul_inner`] contracts over a column→row map so callers don't
//! materialise identity-selected submatrices.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use crate::assoc::kernel::{self, KernelConfig};

/// Per-block SpGEMM output: a contiguous run of rows' worth of CSR
/// payload plus the nnz of each row, stitched into one matrix by a
/// prefix sum over the concatenated counts.
struct SpgemmBlock {
    row_nnz: Vec<usize>,
    indices: Vec<usize>,
    data: Vec<f64>,
    blocked_rows: u64,
}

/// Compressed sparse row matrix, `nr x nc`, f64 values.
#[derive(Debug, Clone, PartialEq)]
pub struct SpMat {
    pub nr: usize,
    pub nc: usize,
    /// Row pointer, length `nr + 1`.
    pub indptr: Vec<usize>,
    /// Column indices per row, sorted within each row.
    pub indices: Vec<usize>,
    /// Values aligned with `indices`.
    pub data: Vec<f64>,
}

impl SpMat {
    /// Empty matrix of the given shape.
    pub fn zeros(nr: usize, nc: usize) -> Self {
        SpMat { nr, nc, indptr: vec![0; nr + 1], indices: Vec::new(), data: Vec::new() }
    }

    /// Build from (row, col, val) triples; duplicates are summed, zeros
    /// (including zero-sums) dropped.
    pub fn from_triples(nr: usize, nc: usize, triples: &[(usize, usize, f64)]) -> Self {
        let mut sorted: Vec<(usize, usize, f64)> = triples.to_vec();
        sorted.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        SpMat::from_sorted_triples(nr, nc, &sorted)
    }

    /// Build from triples **already sorted by (row, col)** — the O(nnz)
    /// construction path used when the caller sorted an index permutation
    /// upstream. Duplicates are summed, zeros (including zero-sums)
    /// dropped, exactly as [`SpMat::from_triples`].
    pub fn from_sorted_triples(nr: usize, nc: usize, sorted: &[(usize, usize, f64)]) -> Self {
        debug_assert!(
            sorted.windows(2).all(|w| (w[0].0, w[0].1) <= (w[1].0, w[1].1)),
            "from_sorted_triples requires (row, col)-sorted input"
        );
        let mut indptr = vec![0usize; nr + 1];
        let mut indices = Vec::with_capacity(sorted.len());
        let mut data: Vec<f64> = Vec::with_capacity(sorted.len());
        let mut i = 0;
        while i < sorted.len() {
            let (r, c, _) = sorted[i];
            debug_assert!(r < nr && c < nc, "triple ({r},{c}) out of shape ({nr},{nc})");
            let mut v = 0.0;
            while i < sorted.len() && sorted[i].0 == r && sorted[i].1 == c {
                v += sorted[i].2;
                i += 1;
            }
            if v != 0.0 {
                indices.push(c);
                data.push(v);
                indptr[r + 1] += 1;
            }
        }
        for r in 0..nr {
            indptr[r + 1] += indptr[r];
        }
        SpMat { nr, nc, indptr, indices, data }
    }

    /// Number of stored (nonzero) entries.
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Approximate heap footprint in bytes (used for the client-side
    /// memory-cap simulation of Figure 2).
    pub fn mem_bytes(&self) -> usize {
        self.indptr.len() * 8 + self.indices.len() * 8 + self.data.len() * 8
    }

    /// Iterate stored entries of row `r` as `(col, val)`.
    pub fn row(&self, r: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.indices[lo..hi].iter().copied().zip(self.data[lo..hi].iter().copied())
    }

    /// Value at (r, c), or 0.0 if absent.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        match self.indices[lo..hi].binary_search(&c) {
            Ok(i) => self.data[lo + i],
            Err(_) => 0.0,
        }
    }

    /// All stored entries as triples.
    pub fn to_triples(&self) -> Vec<(usize, usize, f64)> {
        let mut out = Vec::with_capacity(self.nnz());
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                out.push((r, c, v));
            }
        }
        out
    }

    /// Transpose (CSR -> CSR of the transpose), O(nnz + nr + nc).
    pub fn transpose(&self) -> SpMat {
        let mut indptr = vec![0usize; self.nc + 1];
        for &c in &self.indices {
            indptr[c + 1] += 1;
        }
        for c in 0..self.nc {
            indptr[c + 1] += indptr[c];
        }
        let mut next = indptr.clone();
        let mut indices = vec![0usize; self.nnz()];
        let mut data = vec![0f64; self.nnz()];
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                let slot = next[c];
                indices[slot] = r;
                data[slot] = v;
                next[c] += 1;
            }
        }
        SpMat { nr: self.nc, nc: self.nr, indptr, indices, data }
    }

    /// Elementwise combine over the union of patterns with `f(a, b)`
    /// (missing entries read as 0). Zeros in the result are dropped.
    /// Both matrices must share a shape.
    pub fn union_combine(&self, other: &SpMat, f: impl Fn(f64, f64) -> f64) -> SpMat {
        assert_eq!((self.nr, self.nc), (other.nr, other.nc), "shape mismatch");
        let mut indptr = vec![0usize; self.nr + 1];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for r in 0..self.nr {
            let (mut i, hi_a) = (self.indptr[r], self.indptr[r + 1]);
            let (mut j, hi_b) = (other.indptr[r], other.indptr[r + 1]);
            while i < hi_a || j < hi_b {
                let (c, v) = if j >= hi_b || (i < hi_a && self.indices[i] < other.indices[j]) {
                    let out = (self.indices[i], f(self.data[i], 0.0));
                    i += 1;
                    out
                } else if i >= hi_a || other.indices[j] < self.indices[i] {
                    let out = (other.indices[j], f(0.0, other.data[j]));
                    j += 1;
                    out
                } else {
                    let out = (self.indices[i], f(self.data[i], other.data[j]));
                    i += 1;
                    j += 1;
                    out
                };
                if v != 0.0 {
                    indices.push(c);
                    data.push(v);
                    indptr[r + 1] += 1;
                }
            }
        }
        for r in 0..self.nr {
            indptr[r + 1] += indptr[r];
        }
        SpMat { nr: self.nr, nc: self.nc, indptr, indices, data }
    }

    /// Elementwise combine over the intersection of patterns.
    pub fn intersect_combine(&self, other: &SpMat, f: impl Fn(f64, f64) -> f64) -> SpMat {
        assert_eq!((self.nr, self.nc), (other.nr, other.nc), "shape mismatch");
        let mut indptr = vec![0usize; self.nr + 1];
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for r in 0..self.nr {
            let (mut i, hi_a) = (self.indptr[r], self.indptr[r + 1]);
            let (mut j, hi_b) = (other.indptr[r], other.indptr[r + 1]);
            while i < hi_a && j < hi_b {
                match self.indices[i].cmp(&other.indices[j]) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        let v = f(self.data[i], other.data[j]);
                        if v != 0.0 {
                            indices.push(self.indices[i]);
                            data.push(v);
                            indptr[r + 1] += 1;
                        }
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
        for r in 0..self.nr {
            indptr[r + 1] += indptr[r];
        }
        SpMat { nr: self.nr, nc: self.nc, indptr, indices, data }
    }

    /// Per-row work estimate for the contraction: FLOPs (partial
    /// products) each output row costs, used both to balance the
    /// parallel row partition and to pick rows for the blocked
    /// accumulator. `weights[r] += 1` per stored entry so all-empty-B
    /// operands still spread rows across workers.
    fn spgemm_row_work(&self, other: &SpMat, col_to_row: Option<&[usize]>) -> Vec<u64> {
        (0..self.nr)
            .map(|r| {
                let mut w = 0u64;
                for &k in &self.indices[self.indptr[r]..self.indptr[r + 1]] {
                    let br = match col_to_row {
                        Some(map) => map[k],
                        None => k,
                    };
                    if br != usize::MAX {
                        w += 1 + (other.indptr[br + 1] - other.indptr[br]) as u64;
                    }
                }
                w
            })
            .collect()
    }

    /// Gustavson SpGEMM over one contiguous row range `rows`, with
    /// thread-local accumulator state. Two accumulator variants, chosen
    /// per row by its FLOP estimate:
    ///
    /// * **marker** (default): dense `acc` + boolean `seen` marker array
    ///   over all `other.nc` columns + touched list. "First touch of this
    ///   output column" is an O(1) test — a `touched.contains` scan would
    ///   be linear per FLOP — and it stays correct when partial products
    ///   cancel to zero mid-row.
    /// * **blocked** (rows whose FLOP estimate exceeds
    ///   `cfg.blocked_row_flops`): the row's B-row cursors are replayed
    ///   over ascending column tiles of width `cfg.tile_cols`, so the
    ///   accumulator stays cache-resident on dense/skewed rows instead of
    ///   striding an `other.nc`-wide array. Within a tile each column
    ///   still receives its additions in k order, so the result is
    ///   bit-identical to the marker path.
    ///
    /// `col_to_row[k]` names the row of `other` that column `k` of `self`
    /// contracts against (`usize::MAX` = column not in the contraction);
    /// `None` is the identity map (plain matmul, `self.nc == other.nr`).
    fn spgemm_block(
        &self,
        other: &SpMat,
        col_to_row: Option<&[usize]>,
        rows: std::ops::Range<usize>,
        row_work: &[u64],
        cfg: &KernelConfig,
    ) -> SpgemmBlock {
        let tile = cfg.tile_cols.max(1);
        let use_blocking = other.nc > tile;
        let mut out = SpgemmBlock {
            row_nnz: Vec::with_capacity(rows.len()),
            indices: Vec::new(),
            data: Vec::new(),
            blocked_rows: 0,
        };
        // tile-sized accumulator for the blocked path (empty when no row
        // can take it)
        let mut acc = vec![0f64; if use_blocking { tile } else { 0 }];
        let mut seen = vec![false; acc.len()];
        let mut touched: Vec<usize> = Vec::new();
        // marker-path state is allocated lazily: a block of all-blocked
        // rows never pays for the full-width arrays
        let mut wide_acc: Vec<f64> = Vec::new();
        let mut wide_seen: Vec<bool> = Vec::new();
        // per-row B-row cursors for the blocked path
        let mut cursors: Vec<(usize, usize, f64)> = Vec::new();
        for r in rows {
            let before = out.indices.len();
            let blocked = use_blocking && row_work[r] >= cfg.blocked_row_flops as u64;
            if blocked {
                out.blocked_rows += 1;
                cursors.clear();
                for (k, av) in self.row(r) {
                    let br = match col_to_row {
                        Some(map) => map[k],
                        None => k,
                    };
                    if br != usize::MAX {
                        cursors.push((other.indptr[br], other.indptr[br + 1], av));
                    }
                }
                let mut t0 = 0usize;
                while t0 < other.nc {
                    let t1 = (t0 + tile).min(other.nc);
                    for (pos, end, av) in cursors.iter_mut() {
                        while *pos < *end && other.indices[*pos] < t1 {
                            let c = other.indices[*pos] - t0;
                            if !seen[c] {
                                seen[c] = true;
                                touched.push(c);
                            }
                            acc[c] += *av * other.data[*pos];
                            *pos += 1;
                        }
                    }
                    touched.sort_unstable();
                    for &c in &touched {
                        if acc[c] != 0.0 {
                            out.indices.push(t0 + c);
                            out.data.push(acc[c]);
                        }
                        acc[c] = 0.0;
                        seen[c] = false;
                    }
                    touched.clear();
                    t0 = t1;
                }
            } else {
                if wide_acc.is_empty() && other.nc > 0 {
                    wide_acc = vec![0f64; other.nc];
                    wide_seen = vec![false; other.nc];
                }
                for (k, av) in self.row(r) {
                    let br = match col_to_row {
                        Some(map) => {
                            let t = map[k];
                            if t == usize::MAX {
                                continue;
                            }
                            t
                        }
                        None => k,
                    };
                    for (c, bv) in other.row(br) {
                        if !wide_seen[c] {
                            wide_seen[c] = true;
                            touched.push(c);
                        }
                        wide_acc[c] += av * bv;
                    }
                }
                touched.sort_unstable();
                for &c in &touched {
                    if wide_acc[c] != 0.0 {
                        out.indices.push(c);
                        out.data.push(wide_acc[c]);
                    }
                    wide_acc[c] = 0.0;
                    wide_seen[c] = false;
                }
                touched.clear();
            }
            out.row_nnz.push(out.indices.len() - before);
        }
        out
    }

    /// SpGEMM driver: estimates the contraction's total FLOPs, splits
    /// `self`'s rows into contiguous blocks of balanced work (not row
    /// count — skewed matrices balance), runs [`SpMat::spgemm_block`] per
    /// block on `std::thread::scope` workers, and stitches the block
    /// outputs into one CSR with a prefix sum over per-block row nnz.
    /// Every row is computed by exactly one worker with the same
    /// accumulator code the serial path runs, so the result is
    /// bit-identical to `threads = 1` by construction.
    fn spgemm_with(
        &self,
        other: &SpMat,
        col_to_row: Option<&[usize]>,
        cfg: &KernelConfig,
    ) -> SpMat {
        let row_work = self.spgemm_row_work(other, col_to_row);
        let total: u64 = row_work.iter().sum();
        let workers = kernel::plan_workers(cfg, total);
        let blocks: Vec<SpgemmBlock> = if workers <= 1 {
            vec![self.spgemm_block(other, col_to_row, 0..self.nr, &row_work, cfg)]
        } else {
            let bounds = kernel::balanced_partition(&row_work, workers);
            std::thread::scope(|s| {
                let handles: Vec<_> = bounds
                    .windows(2)
                    .map(|w| {
                        let (lo, hi) = (w[0], w[1]);
                        let row_work = &row_work;
                        s.spawn(move || {
                            self.spgemm_block(other, col_to_row, lo..hi, row_work, cfg)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("spgemm worker panicked")).collect()
            })
        };
        let blocked_total: u64 = blocks.iter().map(|b| b.blocked_rows).sum();
        if blocked_total > 0 {
            kernel::counters().blocked_rows.add(blocked_total);
        }
        let nnz: usize = blocks.iter().map(|b| b.indices.len()).sum();
        let mut indptr = Vec::with_capacity(self.nr + 1);
        indptr.push(0usize);
        let mut indices = Vec::with_capacity(nnz);
        let mut data = Vec::with_capacity(nnz);
        let mut at = 0usize;
        for b in blocks {
            for &n in &b.row_nnz {
                at += n;
                indptr.push(at);
            }
            indices.extend_from_slice(&b.indices);
            data.extend_from_slice(&b.data);
        }
        debug_assert_eq!(indptr.len(), self.nr + 1);
        SpMat { nr: self.nr, nc: other.nc, indptr, indices, data }
    }

    /// Sparse matrix product `self * other` (Gustavson's algorithm) under
    /// the process-wide [`KernelConfig`].
    pub fn matmul(&self, other: &SpMat) -> SpMat {
        self.matmul_with(other, &KernelConfig::global())
    }

    /// [`SpMat::matmul`] under an explicit kernel configuration (pinned
    /// thread counts for tests, benches and the serial baseline).
    pub fn matmul_with(&self, other: &SpMat, cfg: &KernelConfig) -> SpMat {
        assert_eq!(self.nc, other.nr, "inner dimension mismatch");
        self.spgemm_with(other, None, cfg)
    }

    /// Column-restricted product: contract column `a_cols[t]` of `self`
    /// against row `b_rows[t]` of `other` for each `t`, ignoring every
    /// other column of `self` and row of `other`. Equivalent to
    /// `self.select(all_rows, a_cols).matmul(other.select(b_rows, all_cols))`
    /// without materialising either submatrix. `a_cols` must be unique.
    pub fn matmul_inner(&self, other: &SpMat, a_cols: &[usize], b_rows: &[usize]) -> SpMat {
        self.matmul_inner_with(other, a_cols, b_rows, &KernelConfig::global())
    }

    /// [`SpMat::matmul_inner`] under an explicit kernel configuration.
    pub fn matmul_inner_with(
        &self,
        other: &SpMat,
        a_cols: &[usize],
        b_rows: &[usize],
        cfg: &KernelConfig,
    ) -> SpMat {
        assert_eq!(a_cols.len(), b_rows.len(), "inner map length mismatch");
        let mut map = vec![usize::MAX; self.nc];
        for (t, &c) in a_cols.iter().enumerate() {
            map[c] = b_rows[t];
        }
        self.spgemm_with(other, Some(&map), cfg)
    }

    /// Map all stored values through `f`; zeros in the result are dropped.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> SpMat {
        let mut indptr = vec![0usize; self.nr + 1];
        let mut indices = Vec::with_capacity(self.nnz());
        let mut data = Vec::with_capacity(self.nnz());
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                let fv = f(v);
                if fv != 0.0 {
                    indices.push(c);
                    data.push(fv);
                    indptr[r + 1] += 1;
                }
            }
        }
        for r in 0..self.nr {
            indptr[r + 1] += indptr[r];
        }
        SpMat { nr: self.nr, nc: self.nc, indptr, indices, data }
    }

    /// Row sums (length `nr`).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.nr).map(|r| self.row(r).map(|(_, v)| v).sum()).collect()
    }

    /// Column sums (length `nc`).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut out = vec![0f64; self.nc];
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                out[c] += v;
            }
        }
        out
    }

    /// Select a subset of rows/cols by index lists, producing the
    /// submatrix in the order given. `cols` must be unique. When `cols`
    /// is strictly increasing (the only shape the key-algebra layer
    /// produces), the result CSR is built directly in O(nnz + |cols|);
    /// otherwise it falls back to the sorting triple path.
    pub fn select(&self, rows: &[usize], cols: &[usize]) -> SpMat {
        // col index -> new position
        let mut colmap = vec![usize::MAX; self.nc];
        for (new, &c) in cols.iter().enumerate() {
            colmap[c] = new;
        }
        if cols.windows(2).all(|w| w[0] < w[1]) {
            // within each source row indices ascend, and a monotone colmap
            // preserves that — direct CSR build, no sort
            let mut indptr = Vec::with_capacity(rows.len() + 1);
            indptr.push(0);
            let mut indices = Vec::new();
            let mut data = Vec::new();
            for &r in rows {
                for (c, v) in self.row(r) {
                    let nc2 = colmap[c];
                    if nc2 != usize::MAX {
                        indices.push(nc2);
                        data.push(v);
                    }
                }
                indptr.push(indices.len());
            }
            return SpMat { nr: rows.len(), nc: cols.len(), indptr, indices, data };
        }
        let mut triples = Vec::new();
        for (new_r, &r) in rows.iter().enumerate() {
            for (c, v) in self.row(r) {
                if colmap[c] != usize::MAX {
                    triples.push((new_r, colmap[c], v));
                }
            }
        }
        SpMat::from_triples(rows.len(), cols.len(), &triples)
    }

    /// Row-only selection: keep the given rows (in the order given), all
    /// columns. A pure per-row slice copy — O(output nnz), no column
    /// remap, no sort.
    pub fn select_rows(&self, rows: &[usize]) -> SpMat {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        indptr.push(0);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for &r in rows {
            let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
            indices.extend_from_slice(&self.indices[lo..hi]);
            data.extend_from_slice(&self.data[lo..hi]);
            indptr.push(indices.len());
        }
        SpMat { nr: rows.len(), nc: self.nc, indptr, indices, data }
    }

    /// Column-only selection over a strictly-increasing unique index
    /// list: keep all rows, remap the kept columns to 0..cols.len().
    /// O(nnz + |cols|).
    pub fn select_cols(&self, cols: &[usize]) -> SpMat {
        debug_assert!(cols.windows(2).all(|w| w[0] < w[1]));
        let mut colmap = vec![usize::MAX; self.nc];
        for (new, &c) in cols.iter().enumerate() {
            colmap[c] = new;
        }
        let mut indptr = Vec::with_capacity(self.nr + 1);
        indptr.push(0);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                let nc2 = colmap[c];
                if nc2 != usize::MAX {
                    indices.push(nc2);
                    data.push(v);
                }
            }
            indptr.push(indices.len());
        }
        SpMat { nr: self.nr, nc: cols.len(), indptr, indices, data }
    }

    /// Re-embed this matrix into a larger index space: entry (r, c) moves
    /// to (row_map[r], col_map[c]). The maps the key-merge layer produces
    /// are strictly increasing, which keeps CSR order intact — that path
    /// is a direct O(nnz + nr) build; non-monotone maps fall back to the
    /// sorting triple path.
    pub fn embed(&self, nr: usize, nc: usize, row_map: &[usize], col_map: &[usize]) -> SpMat {
        assert_eq!(row_map.len(), self.nr);
        assert_eq!(col_map.len(), self.nc);
        let monotone = row_map.windows(2).all(|w| w[0] < w[1])
            && col_map.windows(2).all(|w| w[0] < w[1]);
        if monotone {
            let mut indptr = vec![0usize; nr + 1];
            for r in 0..self.nr {
                indptr[row_map[r] + 1] = self.indptr[r + 1] - self.indptr[r];
            }
            for i in 0..nr {
                indptr[i + 1] += indptr[i];
            }
            let mut indices = Vec::with_capacity(self.nnz());
            let mut data = Vec::with_capacity(self.nnz());
            // rows land in increasing target order, so sequential pushes
            // line up with the prefix-summed indptr
            for r in 0..self.nr {
                for (c, v) in self.row(r) {
                    indices.push(col_map[c]);
                    data.push(v);
                }
            }
            return SpMat { nr, nc, indptr, indices, data };
        }
        let mut triples = Vec::with_capacity(self.nnz());
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                triples.push((row_map[r], col_map[c], v));
            }
        }
        SpMat::from_triples(nr, nc, &triples)
    }

    /// Dense row-major materialisation (small matrices / runtime bridge).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0f64; self.nr * self.nc];
        for r in 0..self.nr {
            for (c, v) in self.row(r) {
                out[r * self.nc + c] = v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{forall, XorShift64};

    fn rand_mat(rng: &mut XorShift64, nr: usize, nc: usize, density: f64) -> SpMat {
        let mut tr = Vec::new();
        for r in 0..nr {
            for c in 0..nc {
                if rng.chance(density) {
                    tr.push((r, c, (rng.below(9) + 1) as f64));
                }
            }
        }
        SpMat::from_triples(nr, nc, &tr)
    }

    /// Reference `select` via the sorting triple path (the pre-rewrite
    /// implementation), used to pin the direct-CSR fast paths.
    fn select_ref(m: &SpMat, rows: &[usize], cols: &[usize]) -> SpMat {
        let mut colmap = vec![usize::MAX; m.nc];
        for (new, &c) in cols.iter().enumerate() {
            colmap[c] = new;
        }
        let mut triples = Vec::new();
        for (new_r, &r) in rows.iter().enumerate() {
            for (c, v) in m.row(r) {
                if colmap[c] != usize::MAX {
                    triples.push((new_r, colmap[c], v));
                }
            }
        }
        SpMat::from_triples(rows.len(), cols.len(), &triples)
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn from_triples_sums_duplicates() {
        let m = SpMat::from_triples(2, 2, &[(0, 0, 1.0), (0, 0, 2.0), (1, 1, 5.0)]);
        assert_eq!(m.get(0, 0), 3.0);
        assert_eq!(m.get(1, 1), 5.0);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn from_triples_drops_zero_sum() {
        let m = SpMat::from_triples(1, 1, &[(0, 0, 1.0), (0, 0, -1.0)]);
        assert_eq!(m.nnz(), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn from_sorted_triples_matches_from_triples() {
        forall(30, 0x50A7, |rng| {
            let mut tr = Vec::new();
            for _ in 0..rng.below(40) {
                tr.push((
                    rng.below(6) as usize,
                    rng.below(6) as usize,
                    (rng.below(5) + 1) as f64,
                ));
            }
            let want = SpMat::from_triples(6, 6, &tr);
            tr.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
            assert_eq!(SpMat::from_sorted_triples(6, 6, &tr), want);
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn transpose_roundtrip() {
        forall(30, 0xBEEF, |rng| {
            let m = rand_mat(rng, 8, 5, 0.3);
            assert_eq!(m.transpose().transpose(), m);
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn transpose_entries() {
        let m = SpMat::from_triples(2, 3, &[(0, 2, 7.0), (1, 0, 3.0)]);
        let t = m.transpose();
        assert_eq!(t.get(2, 0), 7.0);
        assert_eq!(t.get(0, 1), 3.0);
        assert_eq!((t.nr, t.nc), (3, 2));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn union_combine_add() {
        let a = SpMat::from_triples(1, 3, &[(0, 0, 1.0), (0, 1, 2.0)]);
        let b = SpMat::from_triples(1, 3, &[(0, 1, 3.0), (0, 2, 4.0)]);
        let c = a.union_combine(&b, |x, y| x + y);
        assert_eq!(c.to_triples(), vec![(0, 0, 1.0), (0, 1, 5.0), (0, 2, 4.0)]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn intersect_combine_mult() {
        let a = SpMat::from_triples(1, 3, &[(0, 0, 2.0), (0, 1, 2.0)]);
        let b = SpMat::from_triples(1, 3, &[(0, 1, 3.0), (0, 2, 4.0)]);
        let c = a.intersect_combine(&b, |x, y| x * y);
        assert_eq!(c.to_triples(), vec![(0, 1, 6.0)]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn matmul_identity() {
        forall(20, 0xCAFE, |rng| {
            let m = rand_mat(rng, 6, 6, 0.4);
            let eye = SpMat::from_triples(6, 6, &(0..6).map(|i| (i, i, 1.0)).collect::<Vec<_>>());
            assert_eq!(m.matmul(&eye), m);
            assert_eq!(eye.matmul(&m), m);
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn matmul_matches_dense() {
        forall(25, 0xD00D, |rng| {
            let a = rand_mat(rng, 5, 7, 0.35);
            let b = rand_mat(rng, 7, 4, 0.35);
            let c = a.matmul(&b);
            let (da, db, dc) = (a.to_dense(), b.to_dense(), c.to_dense());
            for i in 0..5 {
                for j in 0..4 {
                    let want: f64 = (0..7).map(|k| da[i * 7 + k] * db[k * 4 + j]).sum();
                    assert!((dc[i * 4 + j] - want).abs() < 1e-9);
                }
            }
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn matmul_cancellation_mid_row() {
        // partial products that cancel to zero mid-accumulation must not
        // confuse the marker array (the old `acc == 0.0 && !contains`
        // test re-pushed such columns)
        let a = SpMat::from_triples(1, 2, &[(0, 0, 1.0), (0, 1, 1.0)]);
        let b = SpMat::from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 0, -1.0), (1, 1, 1.0)]);
        let c = a.matmul(&b);
        // row: col0 = 1 - 1 = 0 (dropped), col1 = 1 + 1 = 2
        assert_eq!(c.to_triples(), vec![(0, 1, 2.0)]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn matmul_transpose_distributes() {
        // (A B)^T == B^T A^T
        forall(20, 0xF00D, |rng| {
            let a = rand_mat(rng, 4, 6, 0.4);
            let b = rand_mat(rng, 6, 5, 0.4);
            assert_eq!(a.matmul(&b).transpose(), b.transpose().matmul(&a.transpose()));
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn matmul_inner_matches_select_then_matmul() {
        forall(30, 0x1AB, |rng| {
            let a = rand_mat(rng, 5, 8, 0.35);
            let b = rand_mat(rng, 7, 4, 0.35);
            // a strictly-increasing inner contraction map, as the key
            // intersection produces
            let mut a_cols: Vec<usize> = (0..8).filter(|_| rng.chance(0.5)).collect();
            a_cols.truncate(7);
            let b_rows: Vec<usize> = (0..a_cols.len()).collect();
            let all_rows: Vec<usize> = (0..a.nr).collect();
            let all_cols: Vec<usize> = (0..b.nc).collect();
            let want = a.select(&all_rows, &a_cols).matmul(&b.select(&b_rows, &all_cols));
            assert_eq!(a.matmul_inner(&b, &a_cols, &b_rows), want);
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn row_col_sums() {
        let m = SpMat::from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 2.0), (1, 1, 4.0)]);
        assert_eq!(m.row_sums(), vec![3.0, 4.0]);
        assert_eq!(m.col_sums(), vec![1.0, 6.0]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn select_submatrix() {
        let m = SpMat::from_triples(3, 3, &[(0, 0, 1.0), (1, 1, 2.0), (2, 2, 3.0)]);
        let s = m.select(&[1, 2], &[1, 2]);
        assert_eq!(s.to_triples(), vec![(0, 0, 2.0), (1, 1, 3.0)]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn select_fast_path_matches_reference() {
        forall(40, 0x5E1EC7, |rng| {
            let m = rand_mat(rng, 7, 9, 0.4);
            let rows: Vec<usize> = (0..7).filter(|_| rng.chance(0.6)).collect();
            let cols: Vec<usize> = (0..9).filter(|_| rng.chance(0.6)).collect();
            assert_eq!(m.select(&rows, &cols), select_ref(&m, &rows, &cols));
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn select_nonmonotone_cols_falls_back() {
        let m = SpMat::from_triples(2, 3, &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0)]);
        // reversed column order still produces the reordered submatrix
        let s = m.select(&[0, 1], &[2, 0]);
        assert_eq!(s, select_ref(&m, &[0, 1], &[2, 0]));
        assert_eq!(s.get(0, 0), 2.0);
        assert_eq!(s.get(0, 1), 1.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn select_rows_matches_full_select() {
        forall(30, 0x9085, |rng| {
            let m = rand_mat(rng, 8, 5, 0.4);
            let rows: Vec<usize> = (0..8).filter(|_| rng.chance(0.5)).collect();
            let all_cols: Vec<usize> = (0..5).collect();
            assert_eq!(m.select_rows(&rows), m.select(&rows, &all_cols));
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn select_cols_matches_full_select() {
        forall(30, 0xC01, |rng| {
            let m = rand_mat(rng, 6, 8, 0.4);
            let cols: Vec<usize> = (0..8).filter(|_| rng.chance(0.5)).collect();
            let all_rows: Vec<usize> = (0..6).collect();
            assert_eq!(m.select_cols(&cols), m.select(&all_rows, &cols));
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn embed_into_larger() {
        let m = SpMat::from_triples(2, 2, &[(0, 0, 1.0), (1, 1, 2.0)]);
        let e = m.embed(4, 4, &[1, 3], &[0, 2]);
        assert_eq!(e.get(1, 0), 1.0);
        assert_eq!(e.get(3, 2), 2.0);
        assert_eq!(e.nnz(), 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn embed_nonmonotone_falls_back() {
        let m = SpMat::from_triples(2, 2, &[(0, 0, 1.0), (1, 1, 2.0)]);
        let e = m.embed(4, 4, &[3, 1], &[2, 0]);
        assert_eq!(e.get(3, 2), 1.0);
        assert_eq!(e.get(1, 0), 2.0);
        assert_eq!(e.nnz(), 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn embed_monotone_matches_triple_path() {
        forall(30, 0xE4B, |rng| {
            let m = rand_mat(rng, 5, 4, 0.5);
            // strictly increasing maps into a larger space
            let mut row_map: Vec<usize> = Vec::new();
            let mut base = 0u64;
            for _ in 0..5 {
                base += rng.below(3) + 1;
                row_map.push(base as usize);
            }
            let mut col_map: Vec<usize> = Vec::new();
            base = 0;
            for _ in 0..4 {
                base += rng.below(3) + 1;
                col_map.push(base as usize);
            }
            let (nr, nc) = (row_map[4] + 1, col_map[3] + 1);
            let got = m.embed(nr, nc, &row_map, &col_map);
            let mut triples = Vec::new();
            for r in 0..m.nr {
                for (c, v) in m.row(r) {
                    triples.push((row_map[r], col_map[c], v));
                }
            }
            assert_eq!(got, SpMat::from_triples(nr, nc, &triples));
        });
    }

    // ---------------------------------------------------------------
    // serial-vs-parallel equivalence suite (ISSUE 8): every tested
    // thread count, cutoff, and accumulator variant must produce a CSR
    // bit-identical to the serial marker kernel.

    /// Pinned kernel configs exercised by the equivalence suite.
    fn cfg(
        threads: usize,
        parallel_cutoff: usize,
        tile_cols: usize,
        blocked: usize,
    ) -> KernelConfig {
        KernelConfig { threads, parallel_cutoff, tile_cols, blocked_row_flops: blocked }
    }

    /// Assert full bit-identity (indptr, indices, and data *bits* — not
    /// just float equality) between two matmul results.
    fn assert_bit_identical(got: &SpMat, want: &SpMat) {
        assert_eq!((got.nr, got.nc), (want.nr, want.nc));
        assert_eq!(got.indptr, want.indptr);
        assert_eq!(got.indices, want.indices);
        let gb: Vec<u64> = got.data.iter().map(|v| v.to_bits()).collect();
        let wb: Vec<u64> = want.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(gb, wb);
    }

    /// A skewed matrix: random background plus a few dense "hub" rows,
    /// with non-integer values so float addition order matters.
    fn skewed_mat(rng: &mut XorShift64, nr: usize, nc: usize) -> SpMat {
        let mut tr = Vec::new();
        for r in 0..nr {
            let density = if r % 7 == 0 { 0.9 } else { 0.15 };
            for c in 0..nc {
                if rng.chance(density) {
                    tr.push((r, c, (rng.below(1000) as f64) / 7.0 - 60.0));
                }
            }
        }
        SpMat::from_triples(nr, nc, &tr)
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn spgemm_parallel_bit_identical_across_threads() {
        forall(15, 0x9A11, |rng| {
            let a = skewed_mat(rng, 24, 18);
            let b = skewed_mat(rng, 18, 21);
            let serial = a.matmul_with(&b, &cfg(1, 0, 1 << 12, usize::MAX));
            for threads in [2, 8] {
                // cutoff 0 forces the parallel dispatch even at this size
                let par = a.matmul_with(&b, &cfg(threads, 0, 1 << 12, usize::MAX));
                assert_bit_identical(&par, &serial);
            }
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn spgemm_blocked_accumulator_bit_identical() {
        forall(15, 0xB10C, |rng| {
            let a = skewed_mat(rng, 16, 12);
            let b = skewed_mat(rng, 12, 30);
            let serial = a.matmul_with(&b, &cfg(1, usize::MAX, 1 << 12, usize::MAX));
            // tile_cols 4 splits the 30-column output into 8 tiles;
            // blocked_row_flops 0 routes every row through the blocked
            // accumulator — serially and across threads
            for threads in [1, 2, 8] {
                let blocked = a.matmul_with(&b, &cfg(threads, 0, 4, 0));
                assert_bit_identical(&blocked, &serial);
            }
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn spgemm_cutoff_keeps_result_identical() {
        forall(10, 0xC07F, |rng| {
            let a = skewed_mat(rng, 20, 15);
            let b = skewed_mat(rng, 15, 15);
            let serial = a.matmul_with(&b, &cfg(1, 0, 1 << 12, usize::MAX));
            // below-cutoff parallel config dispatches serially; a mixed
            // config blocks only the hub rows — all identical
            for c in [
                cfg(8, usize::MAX, 1 << 12, usize::MAX),
                cfg(8, 0, 8, 40),
                cfg(2, 1, 1 << 12, 1),
            ] {
                assert_bit_identical(&a.matmul_with(&b, &c), &serial);
            }
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn spgemm_parallel_empty_blocks_and_edge_shapes() {
        // more threads than rows, all-empty leading/trailing rows, and
        // fully empty operands: the stitch step must still produce a
        // well-formed CSR
        let par = cfg(8, 0, 4, 0);
        let ser = cfg(1, usize::MAX, 1 << 12, usize::MAX);
        // rows 0..19 empty except one dense row at the end
        let mut tr = Vec::new();
        for c in 0..9 {
            tr.push((19usize, c, 1.5 + c as f64));
        }
        let a = SpMat::from_triples(20, 9, &tr);
        let b = skewed_mat(&mut XorShift64::new(7), 9, 9);
        assert_bit_identical(&a.matmul_with(&b, &par), &a.matmul_with(&b, &ser));
        // zero-row and zero-col operands
        let z = SpMat::zeros(0, 5);
        let b5 = skewed_mat(&mut XorShift64::new(8), 5, 3);
        assert_bit_identical(&z.matmul_with(&b5, &par), &z.matmul_with(&b5, &ser));
        let e = SpMat::zeros(6, 4);
        let b4 = SpMat::zeros(4, 0);
        let got = e.matmul_with(&b4, &par);
        assert_eq!((got.nr, got.nc, got.nnz()), (6, 0, 0));
        assert_eq!(got.indptr, vec![0; 7]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn matmul_cancellation_mid_row_all_kernels() {
        // partial products cancelling to zero mid-accumulation must drop
        // the column in every kernel variant (marker, blocked, parallel)
        let a = SpMat::from_triples(1, 2, &[(0, 0, 1.0), (0, 1, 1.0)]);
        let b = SpMat::from_triples(2, 2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 0, -1.0), (1, 1, 1.0)]);
        for c in [
            cfg(1, usize::MAX, 1 << 12, usize::MAX),
            cfg(8, 0, 1 << 12, usize::MAX),
            cfg(8, 0, 1, 0),
        ] {
            assert_eq!(a.matmul_with(&b, &c).to_triples(), vec![(0, 1, 2.0)]);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn matmul_inner_parallel_matches_serial() {
        forall(15, 0x17AB, |rng| {
            let a = skewed_mat(rng, 14, 16);
            let b = skewed_mat(rng, 12, 10);
            let a_cols: Vec<usize> = (0..16).filter(|_| rng.chance(0.5)).take(12).collect();
            let b_rows: Vec<usize> = (0..a_cols.len()).collect();
            let serial =
                a.matmul_inner_with(&b, &a_cols, &b_rows, &cfg(1, usize::MAX, 1 << 12, usize::MAX));
            for c in [cfg(8, 0, 1 << 12, usize::MAX), cfg(2, 0, 4, 0)] {
                assert_bit_identical(&a.matmul_inner_with(&b, &a_cols, &b_rows, &c), &serial);
            }
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn map_keeps_single_consistent_structure() {
        // regression: `map` used to allocate an indptr via `SpMat::zeros`
        // and then build (and swap in) a second shadow indptr; the
        // rebuilt single-structure path must stay self-consistent
        forall(20, 0x3A9, |rng| {
            let m = rand_mat(rng, 9, 7, 0.4);
            let doubled = m.map(|v| v * 2.0);
            assert_eq!(doubled.indptr.len(), m.nr + 1);
            assert_eq!(*doubled.indptr.last().unwrap(), doubled.nnz());
            assert_eq!(doubled.indices.len(), doubled.data.len());
            assert_eq!(doubled, m.map(|v| v * 2.0));
            // identity map reproduces the matrix exactly
            assert_eq!(m.map(|v| v), m);
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn map_drops_zeros() {
        let m = SpMat::from_triples(1, 2, &[(0, 0, 1.0), (0, 1, 2.0)]);
        let f = m.map(|v| if v > 1.5 { v } else { 0.0 });
        assert_eq!(f.to_triples(), vec![(0, 1, 2.0)]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn mem_bytes_counts() {
        let m = SpMat::from_triples(1, 2, &[(0, 0, 1.0)]);
        assert_eq!(m.mem_bytes(), 2 * 8 + 8 + 8);
    }
}
