//! Text / exploded-schema utilities — how D4M turns unstructured records
//! into associative arrays (the "D4M schema" for raw data): each CSV
//! column value becomes a column key `column|value` with count 1, so any
//! field is queryable by prefix and the table stays one big sparse array.

use crate::assoc::Assoc;
use crate::error::{D4mError, Result};

/// Explode CSV text into D4M-schema triples: row key = first column,
/// every other cell `(col, val)` becomes the triple
/// `(row, "col|val", "1")`. Empty cells are skipped.
pub fn explode_csv(csv: &str, sep: char) -> Result<Vec<(String, String, String)>> {
    let mut lines = csv.lines().filter(|l| !l.trim().is_empty());
    let header: Vec<&str> = lines
        .next()
        .ok_or_else(|| D4mError::Parse("empty csv".into()))?
        .split(sep)
        .map(str::trim)
        .collect();
    if header.len() < 2 {
        return Err(D4mError::Parse("csv needs a row-key column plus data columns".into()));
    }
    let mut out = Vec::new();
    for (lineno, line) in lines.enumerate() {
        let cells: Vec<&str> = line.split(sep).map(str::trim).collect();
        if cells.len() != header.len() {
            return Err(D4mError::Parse(format!(
                "line {}: {} cells, header has {}",
                lineno + 2,
                cells.len(),
                header.len()
            )));
        }
        let row = cells[0].to_string();
        for (col, val) in header.iter().zip(cells.iter()).skip(1) {
            if !val.is_empty() {
                out.push((row.clone(), format!("{col}|{val}"), "1".to_string()));
            }
        }
    }
    Ok(out)
}

/// Explode CSV straight into an [`Assoc`] (duplicate exploded pairs sum).
pub fn csv_to_assoc(csv: &str, sep: char) -> Result<Assoc> {
    let triples = explode_csv(csv, sep)?;
    let t: Vec<(&str, &str, f64)> =
        triples.iter().map(|(r, c, _)| (r.as_str(), c.as_str(), 1.0)).collect();
    Ok(Assoc::from_triples(&t))
}

/// Tokenise documents into a doc x `word|<token>` count array (D4M's
/// bag-of-words construction). Tokens are lowercased alphanumeric runs.
pub fn docs_to_assoc<'a>(docs: impl IntoIterator<Item = (&'a str, &'a str)>) -> Assoc {
    let mut triples: Vec<(String, String, f64)> = Vec::new();
    for (id, text) in docs {
        for token in tokenize(text) {
            triples.push((id.to_string(), format!("word|{token}"), 1.0));
        }
    }
    Assoc::from_triples(&triples)
}

/// Lowercased alphanumeric tokens.
pub fn tokenize(text: &str) -> Vec<String> {
    text.split(|c: char| !c.is_alphanumeric())
        .filter(|t| !t.is_empty())
        .map(|t| t.to_lowercase())
        .collect()
}

/// Facet query over an exploded-schema array: count of rows per value of
/// `column` (i.e. degrees of the `column|*` keys) — the canonical D4M
/// "pivot" one-liner.
pub fn facet(a: &Assoc, column: &str) -> Vec<(String, f64)> {
    let prefix = format!("{column}|");
    let sel = a.select_cols(&crate::assoc::KeySel::Prefix(prefix.clone()));
    let deg = sel.logical().sum(1);
    deg.triples()
        .into_iter()
        .map(|(_, c, v)| (c.strip_prefix(&prefix).unwrap_or(&c).to_string(), v))
        .collect()
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;

    const CSV: &str = "\
id,color,size
r1,red,small
r2,blue,
r3,red,large
";

    #[test]
    fn explode_basic() {
        let t = explode_csv(CSV, ',').unwrap();
        assert!(t.contains(&("r1".into(), "color|red".into(), "1".into())));
        assert!(t.contains(&("r3".into(), "size|large".into(), "1".into())));
        // empty cell skipped
        assert_eq!(t.iter().filter(|x| x.0 == "r2").count(), 1);
    }

    #[test]
    fn explode_rejects_ragged() {
        assert!(explode_csv("id,a\nr1,x,y\n", ',').is_err());
        assert!(explode_csv("", ',').is_err());
        assert!(explode_csv("id\nr1\n", ',').is_err());
    }

    #[test]
    fn csv_assoc_queryable_by_prefix() {
        let a = csv_to_assoc(CSV, ',').unwrap();
        let reds = a.select_cols(&crate::assoc::KeySel::keys(&["color|red"]));
        assert_eq!(reds.row_keys(), &["r1".to_string(), "r3".to_string()]);
    }

    #[test]
    fn facet_counts() {
        let a = csv_to_assoc(CSV, ',').unwrap();
        let f = facet(&a, "color");
        assert_eq!(f, vec![("blue".to_string(), 1.0), ("red".to_string(), 2.0)]);
    }

    #[test]
    fn tokenizer() {
        assert_eq!(tokenize("Hello, world! hello."), vec!["hello", "world", "hello"]);
    }

    #[test]
    fn docs_bag_of_words() {
        let a = docs_to_assoc([("d1", "cat dog cat"), ("d2", "dog")]);
        assert_eq!(a.get("d1", "word|cat"), 2.0);
        assert_eq!(a.get("d1", "word|dog"), 1.0);
        assert_eq!(a.get("d2", "word|dog"), 1.0);
    }
}
