//! Associative-array I/O: TSV triple files (the D4M exploded-schema
//! interchange format) and a dense pretty-printer for small arrays.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use super::Assoc;
use crate::error::{D4mError, Result};

/// Render a numeric value the way D4M prints it (integers without `.0`).
pub fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Write `(row, col, value)` TSV triples.
pub fn write_tsv(a: &Assoc, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for (r, c, v) in a.str_triples() {
        writeln!(f, "{r}\t{c}\t{v}")?;
    }
    Ok(())
}

/// Read TSV triples into a numeric [`Assoc`]. Values that do not parse as
/// f64 produce a string-valued array (all-or-nothing per file).
pub fn read_tsv(path: &Path) -> Result<Assoc> {
    let f = BufReader::new(std::fs::File::open(path)?);
    let mut rows = Vec::new();
    for (lineno, line) in f.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split('\t').collect();
        if parts.len() != 3 {
            return Err(D4mError::Parse(format!(
                "{}:{}: expected 3 tab-separated fields, got {}",
                path.display(),
                lineno + 1,
                parts.len()
            )));
        }
        rows.push((parts[0].to_string(), parts[1].to_string(), parts[2].to_string()));
    }
    parse_triples(rows)
}

/// Build an Assoc from string triples; numeric if every value parses.
pub fn parse_triples(rows: Vec<(String, String, String)>) -> Result<Assoc> {
    let all_numeric = rows.iter().all(|(_, _, v)| v.parse::<f64>().is_ok());
    if all_numeric {
        let t: Vec<(&str, &str, f64)> = rows
            .iter()
            .map(|(r, c, v)| (r.as_str(), c.as_str(), v.parse::<f64>().unwrap()))
            .collect();
        Ok(Assoc::from_triples(&t))
    } else {
        let t: Vec<(&str, &str, &str)> =
            rows.iter().map(|(r, c, v)| (r.as_str(), c.as_str(), v.as_str())).collect();
        Ok(Assoc::from_str_triples(&t))
    }
}

/// Dense tabular rendering for small arrays (D4M `displayFull`).
pub fn display_full(a: &Assoc) -> String {
    let mut out = String::new();
    let colw = 10usize;
    out.push_str(&" ".repeat(colw));
    for c in a.col_keys() {
        out.push_str(&format!("{c:>colw$}"));
    }
    out.push('\n');
    for r in a.row_keys() {
        out.push_str(&format!("{r:>colw$}"));
        for c in a.col_keys() {
            let s = match a.get_str(r, c) {
                Some(v) => v.to_string(),
                None => {
                    let v = a.get(r, c);
                    if v == 0.0 { String::new() } else { fmt_num(v) }
                }
            };
            out.push_str(&format!("{s:>colw$}"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn fmt_num_integers() {
        assert_eq!(fmt_num(3.0), "3");
        assert_eq!(fmt_num(3.5), "3.5");
        assert_eq!(fmt_num(-2.0), "-2");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn tsv_roundtrip_numeric() {
        let a = Assoc::from_triples(&[("r1", "c1", 1.5), ("r2", "c2", 2.0)]);
        let dir = std::env::temp_dir().join("d4m_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("nums.tsv");
        write_tsv(&a, &p).unwrap();
        let b = read_tsv(&p).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn tsv_roundtrip_strings() {
        let a = Assoc::from_str_triples(&[("r1", "c1", "blue"), ("r2", "c2", "red")]);
        let dir = std::env::temp_dir().join("d4m_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("strs.tsv");
        write_tsv(&a, &p).unwrap();
        let b = read_tsv(&p).unwrap();
        assert_eq!(a.str_triples(), b.str_triples());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn read_rejects_bad_lines() {
        let dir = std::env::temp_dir().join("d4m_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.tsv");
        std::fs::write(&p, "only_two\tfields\n").unwrap();
        assert!(read_tsv(&p).is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn display_full_contains_keys() {
        let a = Assoc::from_triples(&[("alice", "bob", 2.0)]);
        let s = display_full(&a);
        assert!(s.contains("alice") && s.contains("bob") && s.contains('2'));
    }
}
