//! The lazy D4M expression language: client-side plan graphs
//! (`Plan::table("E").select(..).matmul(..)`), the compact text syntax
//! (`sum(E('a,:,b,', ':') * E, 2) => out`), and the flat [`PlanOp`]
//! program both compile to — the unit shipped over the wire as
//! `Request::Plan` and executed server-side with streaming fusion
//! (DESIGN.md §Plan language).
//!
//! The op list is SSA-shaped: `ops[i]` may only reference results of
//! `ops[j]` with `j < i`, and the **last** op's value is the plan
//! result. [`validate_plan`] enforces that shape plus the size and dim
//! caps, and runs on **both** ends — at compile time client-side and
//! again after wire decode server-side — so a hostile peer cannot ship
//! an op list the executor would trip over.
//!
//! The text syntax is lexed and parsed by a plain recursive-descent
//! pipeline with hard input caps ([`MAX_EXPR_LEN`], [`MAX_DEPTH`]):
//! arbitrary bytes never panic — every rejection is a typed
//! [`D4mError::Parse`] naming the byte offset. Grammar:
//!
//! ```text
//! plan    := expr ('=>' IDENT)?
//! expr    := mul (('+' | '-') mul)*
//! mul     := postfix (('*' | '.*') postfix)*
//! postfix := atom ('(' sel ',' sel ')')*
//! atom    := IDENT                       table scan
//!          | FUNC '(' args ')'           sum/scale/transpose/catkeymul/
//!          |                             emin/emax/limit
//!          | '(' expr ')'
//! sel     := STR | ':'                   via util::parse_keysel
//! ```
//!
//! `*` is key-aligned matrix multiply, `.*` elementwise multiply, `+`/`-`
//! the union-pattern elementwise ops. Selector strings use the D4M
//! forms shared with the CLI (`'a,b,'` keys, `'a,:,b,'` range, `'a*'`
//! prefix, `':'` all — [`crate::util::parse_keysel`]). The function
//! names are reserved words: a table cannot be named `sum`, `scale`,
//! `transpose`, `catkeymul`, `emin`, `emax` or `limit`.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::collections::HashMap;
use std::rc::Rc;

use crate::assoc::KeySel;
use crate::error::{D4mError, Result};
use crate::util::parse_keysel;

/// Hard cap on compiled plan length, enforced at compile time and again
/// at wire decode (a hostile peer cannot make the executor walk an
/// unbounded program).
pub const MAX_PLAN_OPS: usize = 1024;
/// Hard cap on text-expression length fed to the parser.
pub const MAX_EXPR_LEN: usize = 64 * 1024;
/// Hard cap on parser recursion depth (nested parentheses / calls).
pub const MAX_DEPTH: usize = 64;

/// One op of a compiled plan. `src`/`a`/`b` are indices of earlier ops
/// (SSA refs); [`validate_plan`] guarantees they point strictly
/// backwards. Wire tags are the variant order (0 = `Load` … 12 =
/// `Store`) — see `net::wire`.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanOp {
    /// Scan a table with pushdown selectors + limit (a leaf).
    Load { table: String, rows: KeySel, cols: KeySel, limit: Option<usize> },
    /// `src(rows, cols)` — subsref of an earlier result. The executor
    /// folds a select over a still-unforced scan into its pushdown
    /// query instead of materialising.
    Select { src: usize, rows: KeySel, cols: KeySel },
    /// Transpose.
    Transpose { src: usize },
    /// Key-aligned matrix multiply `a * b`.
    MatMul { a: usize, b: usize },
    /// Provenance-tracking multiply (string-valued result).
    CatKeyMul { a: usize, b: usize },
    /// Union-pattern elementwise add.
    ElemAdd { a: usize, b: usize },
    /// Union-pattern elementwise subtract.
    ElemSub { a: usize, b: usize },
    /// Intersection-pattern elementwise multiply (`.*`).
    ElemMult { a: usize, b: usize },
    /// Intersection-pattern elementwise min.
    ElemMin { a: usize, b: usize },
    /// Union-pattern elementwise max.
    ElemMax { a: usize, b: usize },
    /// `sum(src, dim)`, dim ∈ {1, 2}. The executor streams a reduce
    /// over a pending matmul without materialising the product.
    Reduce { src: usize, dim: usize },
    /// Scalar multiply.
    Scale { src: usize, factor: f64 },
    /// Write the result into a server table (the one write op; its
    /// presence makes the whole plan non-idempotent).
    Store { src: usize, table: String },
}

/// Check the SSA shape of a compiled plan: non-empty, within
/// [`MAX_PLAN_OPS`], every ref strictly backwards, every reduce dim in
/// {1, 2}. Run client-side at compile time and server-side after wire
/// decode.
pub fn validate_plan(ops: &[PlanOp]) -> Result<()> {
    if ops.is_empty() {
        return Err(D4mError::InvalidArg("empty plan".into()));
    }
    if ops.len() > MAX_PLAN_OPS {
        return Err(D4mError::InvalidArg(format!(
            "plan has {} ops, cap is {MAX_PLAN_OPS}",
            ops.len()
        )));
    }
    for (i, op) in ops.iter().enumerate() {
        let back = |s: usize| -> Result<()> {
            if s >= i {
                return Err(D4mError::InvalidArg(format!(
                    "plan op {i} references slot {s}, which is not strictly before it"
                )));
            }
            Ok(())
        };
        match op {
            PlanOp::Load { .. } => {}
            PlanOp::Select { src, .. }
            | PlanOp::Transpose { src }
            | PlanOp::Scale { src, .. }
            | PlanOp::Store { src, .. } => back(*src)?,
            PlanOp::Reduce { src, dim } => {
                back(*src)?;
                if *dim != 1 && *dim != 2 {
                    return Err(D4mError::InvalidArg(format!(
                        "plan op {i}: reduce dim must be 1 or 2, got {dim}"
                    )));
                }
            }
            PlanOp::MatMul { a, b }
            | PlanOp::CatKeyMul { a, b }
            | PlanOp::ElemAdd { a, b }
            | PlanOp::ElemSub { a, b }
            | PlanOp::ElemMult { a, b }
            | PlanOp::ElemMin { a, b }
            | PlanOp::ElemMax { a, b } => {
                back(*a)?;
                back(*b)?;
            }
        }
    }
    Ok(())
}

/// Whether replaying a plan is safe: true iff it contains no
/// [`PlanOp::Store`]. The healing client and `Request::is_idempotent`
/// gate auto-retry on this.
pub fn plan_is_idempotent(ops: &[PlanOp]) -> bool {
    !ops.iter().any(|op| matches!(op, PlanOp::Store { .. }))
}

// ----------------------------------------------------------------------
// the lazy builder graph

#[derive(Debug)]
enum Node {
    Table { name: String, rows: KeySel, cols: KeySel, limit: Option<usize> },
    Select { src: Rc<Node>, rows: KeySel, cols: KeySel },
    Transpose { src: Rc<Node> },
    Bin { kind: BinKind, a: Rc<Node>, b: Rc<Node> },
    Reduce { src: Rc<Node>, dim: usize },
    Scale { src: Rc<Node>, factor: f64 },
    Store { src: Rc<Node>, table: String },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BinKind {
    MatMul,
    CatKeyMul,
    Add,
    Sub,
    Mult,
    Min,
    Max,
}

/// A lazy D4M expression: a shared-subexpression DAG built by chaining
/// methods (nothing executes until the compiled ops reach a server).
/// Cloning a `Plan` and reusing it as an operand shares the node —
/// [`Plan::compile`] emits each shared subexpression once.
///
/// ```
/// use d4m::assoc::{expr::Plan, KeySel};
/// let g = Plan::table("E");
/// let ops = g
///     .select(KeySel::Range("a".into(), "m".into()), KeySel::All)
///     .matmul(&g)
///     .sum(2)
///     .compile()
///     .unwrap();
/// assert_eq!(ops.len(), 4); // load, select, matmul (load shared), reduce
/// ```
#[derive(Debug, Clone)]
pub struct Plan {
    node: Rc<Node>,
}

impl Plan {
    fn wrap(node: Node) -> Plan {
        Plan { node: Rc::new(node) }
    }

    /// A full scan of `name` — the leaf every expression starts from.
    pub fn table(name: &str) -> Plan {
        Plan::wrap(Node::Table {
            name: name.to_string(),
            rows: KeySel::All,
            cols: KeySel::All,
            limit: None,
        })
    }

    /// `self(rows, cols)` — subsref. On a table leaf the executor folds
    /// the selectors into the pushdown query.
    pub fn select(&self, rows: KeySel, cols: KeySel) -> Plan {
        Plan::wrap(Node::Select { src: self.node.clone(), rows, cols })
    }

    /// Keep at most `n` entries (row-major key order). Valid only
    /// directly on a table scan — the limit is part of the pushdown
    /// query, not an algebraic op.
    pub fn limit(&self, n: usize) -> Result<Plan> {
        match &*self.node {
            Node::Table { name, rows, cols, .. } => Ok(Plan::wrap(Node::Table {
                name: name.clone(),
                rows: rows.clone(),
                cols: cols.clone(),
                limit: Some(n),
            })),
            _ => Err(D4mError::InvalidArg(
                "limit() applies to table scans only".into(),
            )),
        }
    }

    /// Table scan with explicit selectors (one node instead of
    /// `table(..).select(..)` — the common pushdown form).
    pub fn table_sel(name: &str, rows: KeySel, cols: KeySel) -> Plan {
        Plan::wrap(Node::Table { name: name.to_string(), rows, cols, limit: None })
    }

    pub fn transpose(&self) -> Plan {
        Plan::wrap(Node::Transpose { src: self.node.clone() })
    }

    fn bin(&self, kind: BinKind, other: &Plan) -> Plan {
        Plan::wrap(Node::Bin { kind, a: self.node.clone(), b: other.node.clone() })
    }

    /// Key-aligned matrix multiply `self * other`.
    pub fn matmul(&self, other: &Plan) -> Plan {
        self.bin(BinKind::MatMul, other)
    }

    /// Provenance-tracking multiply (string-valued result).
    pub fn catkeymul(&self, other: &Plan) -> Plan {
        self.bin(BinKind::CatKeyMul, other)
    }

    /// Union-pattern elementwise add.
    pub fn add(&self, other: &Plan) -> Plan {
        self.bin(BinKind::Add, other)
    }

    /// Union-pattern elementwise subtract.
    pub fn sub(&self, other: &Plan) -> Plan {
        self.bin(BinKind::Sub, other)
    }

    /// Intersection-pattern elementwise multiply.
    pub fn elem_mult(&self, other: &Plan) -> Plan {
        self.bin(BinKind::Mult, other)
    }

    /// Intersection-pattern elementwise min.
    pub fn elem_min(&self, other: &Plan) -> Plan {
        self.bin(BinKind::Min, other)
    }

    /// Union-pattern elementwise max.
    pub fn elem_max(&self, other: &Plan) -> Plan {
        self.bin(BinKind::Max, other)
    }

    /// `sum(self, dim)`: dim 1 sums down columns, 2 across rows
    /// (validated at [`Plan::compile`]).
    pub fn sum(&self, dim: usize) -> Plan {
        Plan::wrap(Node::Reduce { src: self.node.clone(), dim })
    }

    /// Scalar multiply.
    pub fn scale(&self, factor: f64) -> Plan {
        Plan::wrap(Node::Scale { src: self.node.clone(), factor })
    }

    /// Write the result into server table `table` (`=> table` in the
    /// text syntax). Makes the plan non-idempotent.
    pub fn store_into(&self, table: &str) -> Plan {
        Plan::wrap(Node::Store { src: self.node.clone(), table: table.to_string() })
    }

    /// Parse the compact text syntax into a plan (see the module doc
    /// for the grammar). Hostile-input-safe: any byte sequence either
    /// parses or returns a typed [`D4mError::Parse`] with a position.
    pub fn parse(src: &str) -> Result<Plan> {
        parse_text(src)
    }

    /// Flatten the DAG into the SSA op list shipped as
    /// `Request::Plan`. Shared subexpressions (`Rc` pointer identity)
    /// are emitted once; the result is [`validate_plan`]-clean by
    /// construction or a typed error (bad reduce dim, oversized plan).
    pub fn compile(&self) -> Result<Vec<PlanOp>> {
        let mut ops: Vec<PlanOp> = Vec::new();
        let mut memo: HashMap<usize, usize> = HashMap::new();
        let root = self.node.clone();
        emit(&root, &mut ops, &mut memo)?;
        validate_plan(&ops)?;
        Ok(ops)
    }
}

/// Post-order emit with pointer-identity memoisation. Plans are bounded
/// by [`MAX_PLAN_OPS`] distinct nodes, so recursion depth is bounded
/// too (the parser additionally caps nesting at [`MAX_DEPTH`]).
fn emit(node: &Rc<Node>, ops: &mut Vec<PlanOp>, memo: &mut HashMap<usize, usize>) -> Result<usize> {
    let key = Rc::as_ptr(node) as usize;
    if let Some(&slot) = memo.get(&key) {
        return Ok(slot);
    }
    if ops.len() >= MAX_PLAN_OPS {
        return Err(D4mError::InvalidArg(format!(
            "plan exceeds the {MAX_PLAN_OPS}-op cap"
        )));
    }
    let op = match &**node {
        Node::Table { name, rows, cols, limit } => PlanOp::Load {
            table: name.clone(),
            rows: rows.clone(),
            cols: cols.clone(),
            limit: *limit,
        },
        Node::Select { src, rows, cols } => {
            let s = emit(src, ops, memo)?;
            PlanOp::Select { src: s, rows: rows.clone(), cols: cols.clone() }
        }
        Node::Transpose { src } => {
            let s = emit(src, ops, memo)?;
            PlanOp::Transpose { src: s }
        }
        Node::Bin { kind, a, b } => {
            let sa = emit(a, ops, memo)?;
            let sb = emit(b, ops, memo)?;
            match kind {
                BinKind::MatMul => PlanOp::MatMul { a: sa, b: sb },
                BinKind::CatKeyMul => PlanOp::CatKeyMul { a: sa, b: sb },
                BinKind::Add => PlanOp::ElemAdd { a: sa, b: sb },
                BinKind::Sub => PlanOp::ElemSub { a: sa, b: sb },
                BinKind::Mult => PlanOp::ElemMult { a: sa, b: sb },
                BinKind::Min => PlanOp::ElemMin { a: sa, b: sb },
                BinKind::Max => PlanOp::ElemMax { a: sa, b: sb },
            }
        }
        Node::Reduce { src, dim } => {
            let s = emit(src, ops, memo)?;
            if *dim != 1 && *dim != 2 {
                return Err(D4mError::InvalidArg(format!(
                    "sum dim must be 1 or 2, got {dim}"
                )));
            }
            PlanOp::Reduce { src: s, dim: *dim }
        }
        Node::Scale { src, factor } => {
            let s = emit(src, ops, memo)?;
            PlanOp::Scale { src: s, factor: *factor }
        }
        Node::Store { src, table } => {
            let s = emit(src, ops, memo)?;
            PlanOp::Store { src: s, table: table.clone() }
        }
    };
    if ops.len() >= MAX_PLAN_OPS {
        return Err(D4mError::InvalidArg(format!(
            "plan exceeds the {MAX_PLAN_OPS}-op cap"
        )));
    }
    ops.push(op);
    let slot = ops.len() - 1;
    memo.insert(key, slot);
    Ok(slot)
}

// ----------------------------------------------------------------------
// lexer

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Str(String),
    Num(f64),
    LParen,
    RParen,
    Comma,
    Colon,
    Plus,
    Minus,
    Star,
    DotStar,
    Arrow,
}

fn perr(at: usize, msg: impl Into<String>) -> D4mError {
    D4mError::Parse(format!("plan expr, byte {at}: {}", msg.into()))
}

fn lex(src: &str) -> Result<Vec<(usize, Tok)>> {
    if src.len() > MAX_EXPR_LEN {
        return Err(D4mError::Parse(format!(
            "plan expr is {} bytes, cap is {MAX_EXPR_LEN}",
            src.len()
        )));
    }
    let bytes = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0usize;
    while i < bytes.len() {
        let at = i;
        let b = bytes[i];
        match b {
            b' ' | b'\t' | b'\r' | b'\n' => i += 1,
            b'(' => {
                toks.push((at, Tok::LParen));
                i += 1;
            }
            b')' => {
                toks.push((at, Tok::RParen));
                i += 1;
            }
            b',' => {
                toks.push((at, Tok::Comma));
                i += 1;
            }
            b':' => {
                toks.push((at, Tok::Colon));
                i += 1;
            }
            b'+' => {
                toks.push((at, Tok::Plus));
                i += 1;
            }
            b'-' => {
                toks.push((at, Tok::Minus));
                i += 1;
            }
            b'*' => {
                toks.push((at, Tok::Star));
                i += 1;
            }
            b'.' => {
                if bytes.get(i + 1) == Some(&b'*') {
                    toks.push((at, Tok::DotStar));
                    i += 2;
                } else {
                    return Err(perr(at, "'.' must be followed by '*'"));
                }
            }
            b'=' => {
                if bytes.get(i + 1) == Some(&b'>') {
                    toks.push((at, Tok::Arrow));
                    i += 2;
                } else {
                    return Err(perr(at, "'=' must be followed by '>'"));
                }
            }
            b'\'' => {
                // single-quoted selector string, no escapes
                let start = i + 1;
                let mut j = start;
                while j < bytes.len() && bytes[j] != b'\'' {
                    j += 1;
                }
                if j >= bytes.len() {
                    return Err(perr(at, "unterminated selector string"));
                }
                let s = std::str::from_utf8(&bytes[start..j])
                    .map_err(|_| perr(at, "selector string is not UTF-8"))?;
                toks.push((at, Tok::Str(s.to_string())));
                i = j + 1;
            }
            b'0'..=b'9' => {
                let mut j = i;
                while j < bytes.len() && (bytes[j].is_ascii_digit() || bytes[j] == b'.') {
                    j += 1;
                }
                let s = std::str::from_utf8(&bytes[i..j]).expect("digits are UTF-8");
                let n: f64 =
                    s.parse().map_err(|_| perr(at, format!("bad number '{s}'")))?;
                toks.push((at, Tok::Num(n)));
                i = j;
            }
            b'A'..=b'Z' | b'a'..=b'z' | b'_' => {
                let mut j = i;
                while j < bytes.len()
                    && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_')
                {
                    j += 1;
                }
                let s = std::str::from_utf8(&bytes[i..j]).expect("idents are ASCII");
                toks.push((at, Tok::Ident(s.to_string())));
                i = j;
            }
            _ => return Err(perr(at, format!("unexpected byte 0x{b:02x}"))),
        }
    }
    Ok(toks)
}

// ----------------------------------------------------------------------
// parser

const FUNCS: &[&str] = &["sum", "scale", "transpose", "catkeymul", "emin", "emax", "limit"];

struct Parser {
    toks: Vec<(usize, Tok)>,
    pos: usize,
    end: usize,
    depth: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|(_, t)| t)
    }

    fn at(&self) -> usize {
        self.toks.get(self.pos).map(|(a, _)| *a).unwrap_or(self.end)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).map(|(_, t)| t.clone());
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, want: &Tok, what: &str) -> Result<()> {
        let at = self.at();
        match self.next() {
            Some(t) if &t == want => Ok(()),
            Some(t) => Err(perr(at, format!("expected {what}, found {t:?}"))),
            None => Err(perr(at, format!("expected {what}, found end of input"))),
        }
    }

    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(perr(self.at(), format!("nesting deeper than {MAX_DEPTH}")));
        }
        Ok(())
    }

    fn leave(&mut self) {
        self.depth -= 1;
    }

    /// expr := mul (('+' | '-') mul)*
    fn expr(&mut self) -> Result<Plan> {
        self.enter()?;
        let mut lhs = self.mul()?;
        loop {
            match self.peek() {
                Some(Tok::Plus) => {
                    self.next();
                    let rhs = self.mul()?;
                    lhs = lhs.add(&rhs);
                }
                Some(Tok::Minus) => {
                    self.next();
                    let rhs = self.mul()?;
                    lhs = lhs.sub(&rhs);
                }
                _ => break,
            }
        }
        self.leave();
        Ok(lhs)
    }

    /// mul := postfix (('*' | '.*') postfix)*
    fn mul(&mut self) -> Result<Plan> {
        let mut lhs = self.postfix()?;
        loop {
            match self.peek() {
                Some(Tok::Star) => {
                    self.next();
                    let rhs = self.postfix()?;
                    lhs = lhs.matmul(&rhs);
                }
                Some(Tok::DotStar) => {
                    self.next();
                    let rhs = self.postfix()?;
                    lhs = lhs.elem_mult(&rhs);
                }
                _ => break,
            }
        }
        Ok(lhs)
    }

    /// postfix := atom ('(' sel ',' sel ')')*
    fn postfix(&mut self) -> Result<Plan> {
        let mut e = self.atom()?;
        while self.peek() == Some(&Tok::LParen) {
            self.next();
            let rows = self.sel()?;
            self.expect(&Tok::Comma, "','")?;
            let cols = self.sel()?;
            self.expect(&Tok::RParen, "')'")?;
            e = self.apply_select(e, rows, cols);
        }
        Ok(e)
    }

    /// A select directly on a table leaf folds into the scan node (the
    /// pushdown form); anything else becomes a Select op.
    fn apply_select(&mut self, e: Plan, rows: KeySel, cols: KeySel) -> Plan {
        if let Node::Table { name, rows: KeySel::All, cols: KeySel::All, limit: None } = &*e.node
        {
            return Plan::table_sel(name, rows, cols);
        }
        e.select(rows, cols)
    }

    /// sel := STR | ':'
    fn sel(&mut self) -> Result<KeySel> {
        let at = self.at();
        match self.next() {
            Some(Tok::Str(s)) => Ok(parse_keysel(&s)),
            Some(Tok::Colon) => Ok(KeySel::All),
            Some(t) => Err(perr(at, format!("expected a selector string or ':', found {t:?}"))),
            None => Err(perr(at, "expected a selector, found end of input")),
        }
    }

    fn num(&mut self, what: &str) -> Result<f64> {
        let at = self.at();
        let neg = if self.peek() == Some(&Tok::Minus) {
            self.next();
            true
        } else {
            false
        };
        match self.next() {
            Some(Tok::Num(n)) => Ok(if neg { -n } else { n }),
            Some(t) => Err(perr(at, format!("expected {what}, found {t:?}"))),
            None => Err(perr(at, format!("expected {what}, found end of input"))),
        }
    }

    fn atom(&mut self) -> Result<Plan> {
        let at = self.at();
        match self.next() {
            Some(Tok::LParen) => {
                self.enter()?;
                let e = self.expr()?;
                self.expect(&Tok::RParen, "')'")?;
                self.leave();
                Ok(e)
            }
            Some(Tok::Ident(name)) => {
                if FUNCS.contains(&name.as_str()) {
                    self.func(at, &name)
                } else {
                    Ok(Plan::table(&name))
                }
            }
            Some(t) => Err(perr(at, format!("expected a table, function or '(', found {t:?}"))),
            None => Err(perr(at, "expected an expression, found end of input")),
        }
    }

    fn func(&mut self, at: usize, name: &str) -> Result<Plan> {
        self.enter()?;
        self.expect(&Tok::LParen, "'('")?;
        let out = match name {
            "transpose" => {
                let e = self.expr()?;
                e.transpose()
            }
            "sum" => {
                let e = self.expr()?;
                self.expect(&Tok::Comma, "','")?;
                let d = self.num("a dim (1 or 2)")?;
                if d != 1.0 && d != 2.0 {
                    return Err(perr(at, format!("sum dim must be 1 or 2, got {d}")));
                }
                e.sum(d as usize)
            }
            "scale" => {
                let e = self.expr()?;
                self.expect(&Tok::Comma, "','")?;
                let f = self.num("a scale factor")?;
                e.scale(f)
            }
            "limit" => {
                let e = self.expr()?;
                self.expect(&Tok::Comma, "','")?;
                let n = self.num("a limit")?;
                if n < 0.0 || n.fract() != 0.0 {
                    return Err(perr(at, format!("limit must be a non-negative integer, got {n}")));
                }
                e.limit(n as usize)
                    .map_err(|e| perr(at, e.to_string()))?
            }
            "catkeymul" | "emin" | "emax" => {
                let a = self.expr()?;
                self.expect(&Tok::Comma, "','")?;
                let b = self.expr()?;
                match name {
                    "catkeymul" => a.catkeymul(&b),
                    "emin" => a.elem_min(&b),
                    _ => a.elem_max(&b),
                }
            }
            _ => unreachable!("FUNCS and this match are kept in sync"),
        };
        self.expect(&Tok::RParen, "')'")?;
        self.leave();
        Ok(out)
    }
}

fn parse_text(src: &str) -> Result<Plan> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0, end: src.len(), depth: 0 };
    let mut plan = p.expr()?;
    if p.peek() == Some(&Tok::Arrow) {
        p.next();
        let at = p.at();
        match p.next() {
            Some(Tok::Ident(table)) => plan = plan.store_into(&table),
            Some(t) => return Err(perr(at, format!("expected a table name after '=>', found {t:?}"))),
            None => return Err(perr(at, "expected a table name after '=>'")),
        }
    }
    if let Some(t) = p.peek() {
        return Err(perr(p.at(), format!("trailing input: {t:?}")));
    }
    plan.compile()?; // surface structural errors (bad dim, size) at parse time
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::forall;

    #[test]
    fn builder_compiles_in_ssa_order_with_sharing() {
        let g = Plan::table("G");
        let ops = g
            .select(KeySel::Range("a".into(), "m".into()), KeySel::All)
            .matmul(&g)
            .sum(2)
            .compile()
            .unwrap();
        // load G, select, (G shared -> same slot), matmul, reduce
        assert_eq!(ops.len(), 4);
        assert!(matches!(&ops[0], PlanOp::Load { table, .. } if table == "G"));
        assert!(matches!(&ops[1], PlanOp::Select { src: 0, .. }));
        assert!(matches!(&ops[2], PlanOp::MatMul { a: 1, b: 0 }));
        assert!(matches!(&ops[3], PlanOp::Reduce { src: 2, dim: 2 }));
        validate_plan(&ops).unwrap();
    }

    #[test]
    fn text_and_builder_compile_identically() {
        let text = Plan::parse("sum(G('a,:,m,', ':') * G, 2)").unwrap().compile().unwrap();
        let g = Plan::table("G");
        let built = g
            .select(KeySel::Range("a".into(), "m".into()), KeySel::All)
            .matmul(&g)
            .sum(2)
            .compile()
            .unwrap();
        // the parser folds the select into the scan, the builder emits a
        // distinct Select op — same semantics, assert both validate and
        // reference the same table
        validate_plan(&text).unwrap();
        assert!(matches!(&text[0], PlanOp::Load { table, rows: KeySel::Range(lo, hi), .. }
            if table == "G" && lo == "a" && hi == "m"));
        assert!(matches!(built.last(), Some(PlanOp::Reduce { dim: 2, .. })));
        assert!(matches!(text.last(), Some(PlanOp::Reduce { dim: 2, .. })));
    }

    #[test]
    fn text_ops_cover_the_grammar() {
        let cases = [
            "A + B",
            "A - B",
            "A .* B",
            "A * B * C",
            "transpose(A) * A",
            "scale(sum(A, 1), 0.5)",
            "emin(A, B) + emax(A, B)",
            "catkeymul(A('x*', ':'), B)",
            "limit(A, 10) * B",
            "sum(A('a,b,c,', ':') * B, 2) => out",
            "(A + B) .* (A - B)",
        ];
        for c in cases {
            let ops = Plan::parse(c).unwrap().compile().unwrap();
            validate_plan(&ops).unwrap_or_else(|e| panic!("{c}: {e}"));
        }
    }

    #[test]
    fn store_arrow_emits_store_op_and_kills_idempotency() {
        let ops = Plan::parse("A * B => C").unwrap().compile().unwrap();
        assert!(matches!(ops.last(), Some(PlanOp::Store { table, .. }) if table == "C"));
        assert!(!plan_is_idempotent(&ops));
        let ro = Plan::parse("A * B").unwrap().compile().unwrap();
        assert!(plan_is_idempotent(&ro));
    }

    #[test]
    fn parse_rejections_are_typed_with_position() {
        let bad = [
            "",
            "sum(A)",            // missing dim
            "sum(A, 3)",         // bad dim
            "A('a,' 'b,')",      // missing comma
            "A +",               // dangling op
            "A => ",             // missing store table
            "A) B",              // trailing input
            "'lone selector'",   // selector is not an expression
            "A .+ B",            // bad operator
            "limit(A + B, 5)",   // limit off a non-scan
            "A ('a,', ':'",      // unterminated paren
            "A('a",              // unterminated string
            &"(".repeat(MAX_DEPTH + 2), // nesting bomb
        ];
        for b in bad {
            match Plan::parse(b) {
                Err(D4mError::Parse(msg)) => {
                    assert!(!msg.is_empty(), "empty parse error for {b:?}")
                }
                Err(D4mError::InvalidArg(_)) => {}
                other => panic!("{b:?}: expected a typed parse error, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_input_is_rejected_not_lexed() {
        let huge = "A".repeat(MAX_EXPR_LEN + 1);
        assert!(matches!(Plan::parse(&huge), Err(D4mError::Parse(_))));
    }

    #[test]
    fn random_bytes_never_panic_always_typed() {
        forall(500, 0xD4A1_9E57, |rng| {
            let len = (rng.next_u64() % 80) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() % 256) as u8).collect();
            let s = String::from_utf8_lossy(&bytes).into_owned();
            match Plan::parse(&s) {
                Ok(p) => {
                    p.compile().unwrap(); // parse implies compilable
                }
                Err(D4mError::Parse(_)) | Err(D4mError::InvalidArg(_)) => {}
                Err(other) => panic!("untyped parser error: {other:?}"),
            }
        });
    }

    #[test]
    fn mutated_valid_exprs_never_panic() {
        let seed_expr = "sum(G('a,:,m,', ':') * transpose(H), 2) => out";
        forall(500, 0x5EED_9A25, |rng| {
            let mut b = seed_expr.as_bytes().to_vec();
            let flips = 1 + (rng.next_u64() % 4) as usize;
            for _ in 0..flips {
                let i = (rng.next_u64() as usize) % b.len();
                b[i] = (rng.next_u64() % 256) as u8;
            }
            let s = String::from_utf8_lossy(&b).into_owned();
            match Plan::parse(&s) {
                Ok(p) => {
                    p.compile().unwrap();
                }
                Err(D4mError::Parse(_)) | Err(D4mError::InvalidArg(_)) => {}
                Err(other) => panic!("untyped parser error: {other:?}"),
            }
        });
    }

    #[test]
    fn validate_rejects_forward_and_self_refs() {
        let fwd = vec![
            PlanOp::Load { table: "A".into(), rows: KeySel::All, cols: KeySel::All, limit: None },
            PlanOp::MatMul { a: 0, b: 2 },
        ];
        assert!(validate_plan(&fwd).is_err());
        let selfref = vec![PlanOp::Transpose { src: 0 }];
        assert!(validate_plan(&selfref).is_err());
        assert!(validate_plan(&[]).is_err());
        let bad_dim = vec![
            PlanOp::Load { table: "A".into(), rows: KeySel::All, cols: KeySel::All, limit: None },
            PlanOp::Reduce { src: 0, dim: 3 },
        ];
        assert!(validate_plan(&bad_dim).is_err());
    }
}
