//! Naive map-based associative array backend.
//!
//! Serves two roles:
//!  1. **Oracle** for property tests: same algebra as [`super::Assoc`]
//!     computed the obvious O(n log n)-per-op way over a `BTreeMap`.
//!  2. **"MATLAB-class" backend** for the T-jl benchmark (DESIGN.md):
//!     the D4M.jl paper compared a mature MATLAB implementation against a
//!     new Julia one; we reproduce the *shape* of that comparison by
//!     benchmarking this interpreter-style backend against the tuned CSR
//!     backend on the identical op suite.

use std::collections::BTreeMap;

/// Naive associative array: a sorted map from (row, col) to value.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NaiveAssoc {
    pub cells: BTreeMap<(String, String), f64>,
}

impl NaiveAssoc {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_triples<R: AsRef<str>, C: AsRef<str>>(triples: &[(R, C, f64)]) -> Self {
        let mut cells: BTreeMap<(String, String), f64> = BTreeMap::new();
        for (r, c, v) in triples {
            *cells.entry((r.as_ref().to_string(), c.as_ref().to_string())).or_insert(0.0) += v;
        }
        cells.retain(|_, v| *v != 0.0);
        NaiveAssoc { cells }
    }

    pub fn nnz(&self) -> usize {
        self.cells.len()
    }

    pub fn get(&self, r: &str, c: &str) -> f64 {
        self.cells.get(&(r.to_string(), c.to_string())).copied().unwrap_or(0.0)
    }

    pub fn triples(&self) -> Vec<(String, String, f64)> {
        self.cells.iter().map(|((r, c), v)| (r.clone(), c.clone(), *v)).collect()
    }

    /// Union sum.
    pub fn add(&self, other: &NaiveAssoc) -> NaiveAssoc {
        let mut out = self.cells.clone();
        for (k, v) in &other.cells {
            *out.entry(k.clone()).or_insert(0.0) += v;
        }
        out.retain(|_, v| *v != 0.0);
        NaiveAssoc { cells: out }
    }

    /// Intersection product.
    pub fn elem_mult(&self, other: &NaiveAssoc) -> NaiveAssoc {
        let mut out = BTreeMap::new();
        for (k, v) in &self.cells {
            if let Some(w) = other.cells.get(k) {
                let p = v * w;
                if p != 0.0 {
                    out.insert(k.clone(), p);
                }
            }
        }
        NaiveAssoc { cells: out }
    }

    /// Key-aligned matrix multiply (triple loop over maps).
    pub fn matmul(&self, other: &NaiveAssoc) -> NaiveAssoc {
        // index B by row key for the contraction
        let mut b_rows: BTreeMap<&str, Vec<(&str, f64)>> = BTreeMap::new();
        for ((r, c), v) in &other.cells {
            b_rows.entry(r.as_str()).or_default().push((c.as_str(), *v));
        }
        let mut out: BTreeMap<(String, String), f64> = BTreeMap::new();
        for ((ar, ak), av) in &self.cells {
            if let Some(brow) = b_rows.get(ak.as_str()) {
                for (bc, bv) in brow {
                    *out.entry((ar.clone(), bc.to_string())).or_insert(0.0) += av * bv;
                }
            }
        }
        out.retain(|_, v| *v != 0.0);
        NaiveAssoc { cells: out }
    }

    pub fn transpose(&self) -> NaiveAssoc {
        NaiveAssoc {
            cells: self.cells.iter().map(|((r, c), v)| ((c.clone(), r.clone()), *v)).collect(),
        }
    }

    /// The logical (all values -> 1.0) form, the oracle counterpart of
    /// [`crate::assoc::Assoc::logical`] for string-valued inputs.
    pub fn logical(&self) -> NaiveAssoc {
        NaiveAssoc { cells: self.cells.keys().map(|k| (k.clone(), 1.0)).collect() }
    }

    /// Row selection by arbitrary key predicate (oracle for `KeySel`
    /// selection).
    pub fn select_rows_by(&self, pred: impl Fn(&str) -> bool) -> NaiveAssoc {
        NaiveAssoc {
            cells: self
                .cells
                .iter()
                .filter(|((r, _), _)| pred(r))
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
        }
    }

    /// Column selection by arbitrary key predicate.
    pub fn select_cols_by(&self, pred: impl Fn(&str) -> bool) -> NaiveAssoc {
        NaiveAssoc {
            cells: self
                .cells
                .iter()
                .filter(|((_, c), _)| pred(c))
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
        }
    }

    /// Row selection by inclusive key range.
    pub fn select_row_range(&self, lo: &str, hi: &str) -> NaiveAssoc {
        NaiveAssoc {
            cells: self
                .cells
                .iter()
                .filter(|((r, _), _)| r.as_str() >= lo && r.as_str() <= hi)
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
        }
    }

    pub fn sum_rows(&self) -> BTreeMap<String, f64> {
        let mut out: BTreeMap<String, f64> = BTreeMap::new();
        for ((r, _), v) in &self.cells {
            *out.entry(r.clone()).or_insert(0.0) += v;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)]
    fn add_union_sums() {
        let a = NaiveAssoc::from_triples(&[("r1", "c1", 1.0), ("r1", "c2", 2.0)]);
        let b = NaiveAssoc::from_triples(&[("r1", "c2", 3.0), ("r2", "c1", 4.0)]);
        let c = a.add(&b);
        assert_eq!(c.get("r1", "c2"), 5.0);
        assert_eq!(c.get("r2", "c1"), 4.0);
        assert_eq!(c.nnz(), 3);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn matmul_key_aligned() {
        // A: r1 -> k1; B: k1 -> c1. Product contracts on k1.
        let a = NaiveAssoc::from_triples(&[("r1", "k1", 2.0), ("r1", "zz", 9.0)]);
        let b = NaiveAssoc::from_triples(&[("k1", "c1", 3.0)]);
        let c = a.matmul(&b);
        assert_eq!(c.get("r1", "c1"), 6.0);
        assert_eq!(c.nnz(), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn transpose_involution() {
        let a = NaiveAssoc::from_triples(&[("r", "c", 1.5)]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn row_range() {
        let a = NaiveAssoc::from_triples(&[("a", "c", 1.0), ("m", "c", 2.0), ("z", "c", 3.0)]);
        let s = a.select_row_range("b", "y");
        assert_eq!(s.nnz(), 1);
        assert_eq!(s.get("m", "c"), 2.0);
    }
}
