//! Associative arrays — the mathematical core of D4M.
//!
//! An [`Assoc`] maps pairs of string keys `(row, col)` to values. Values
//! are either numeric (f64) or strings; string values are stored D4M-style
//! as 1-based indices into a sorted value-key table, so the numeric CSR
//! core ([`spmat::SpMat`]) backs both cases.
//!
//! Operations follow the associative-array algebra of the D4M papers:
//! `+` is union (numeric sum on collisions), elementwise `&`/`*` is
//! intersection (numeric product), and matrix multiply contracts over the
//! *intersection* of A's column keys and B's row keys. Key alignment is by
//! string identity, never by position.
//!
//! §Hot paths (DESIGN.md §CSR hot paths): binary ops take a borrowed
//! [`Assoc::numeric_view`] of each operand — a `Cow` that only clones when
//! a string-valued array must be coerced to its logical numeric form, so
//! numeric operands are **never** deep-copied. Construction sorts index
//! permutations over borrowed `&str` keys (one `String` clone per unique
//! key, no per-triple binary search), and key selection binary-searches
//! the sorted key vectors instead of scanning them.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
pub mod expr;
pub mod io;
pub mod kernel;
pub mod text;
pub mod naive;
pub mod spmat;

use std::borrow::Cow;

use crate::error::{D4mError, Result};
use crate::util::{find_key, intersect_sorted_keys, merge_sorted_keys};
use kernel::KernelConfig;
use spmat::SpMat;

/// Associative array: `(row key, col key) -> value`.
#[derive(Debug, Clone, PartialEq)]
pub struct Assoc {
    /// Sorted, unique row keys.
    row_keys: Vec<String>,
    /// Sorted, unique column keys.
    col_keys: Vec<String>,
    /// Numeric core; when `vals` is `Some`, entries are 1-based indices
    /// into it (the D4M string-value encoding).
    mat: SpMat,
    /// Sorted, unique value keys for string-valued arrays.
    vals: Option<Vec<String>>,
}

/// One triple of an associative array, as strings + numeric value.
pub type Triple = (String, String, f64);

/// Sort a permutation of `items` by a borrowed string key, then label each
/// item with the id of its key in the sorted, deduplicated key table.
/// Returns `(sorted unique keys, key id per item)`. One `String` clone per
/// *unique* key — never one per item — and no per-item binary search.
fn dedup_key_ids<T>(items: &[T], key: impl Fn(&T) -> &str) -> (Vec<String>, Vec<usize>) {
    let mut perm: Vec<usize> = (0..items.len()).collect();
    perm.sort_unstable_by(|&i, &j| key(&items[i]).cmp(key(&items[j])));
    let mut keys: Vec<String> = Vec::new();
    let mut ids = vec![0usize; items.len()];
    for &i in &perm {
        let k = key(&items[i]);
        if keys.last().map(|last| last.as_str() != k).unwrap_or(true) {
            keys.push(k.to_string());
        }
        ids[i] = keys.len() - 1;
    }
    (keys, ids)
}

impl Assoc {
    // ------------------------------------------------------------------
    // construction

    /// Empty associative array.
    pub fn empty() -> Self {
        Assoc { row_keys: vec![], col_keys: vec![], mat: SpMat::zeros(0, 0), vals: None }
    }

    /// Build a numeric associative array from `(row, col, value)` triples.
    /// Duplicate `(row, col)` pairs are summed (D4M default collision op);
    /// entries summing to zero are dropped.
    pub fn from_triples<R: AsRef<str>, C: AsRef<str>>(triples: &[(R, C, f64)]) -> Self {
        let (row_keys, row_of) = dedup_key_ids(triples, |t| t.0.as_ref());
        let (col_keys, col_of) = dedup_key_ids(triples, |t| t.1.as_ref());
        let mut perm: Vec<usize> = (0..triples.len()).collect();
        perm.sort_unstable_by_key(|&i| (row_of[i], col_of[i]));
        let sorted: Vec<(usize, usize, f64)> =
            perm.iter().map(|&i| (row_of[i], col_of[i], triples[i].2)).collect();
        let mat = SpMat::from_sorted_triples(row_keys.len(), col_keys.len(), &sorted);
        Assoc { row_keys, col_keys, mat, vals: None }.compacted_owned()
    }

    /// Build a string-valued associative array. Duplicate `(row, col)`
    /// pairs keep the lexicographically greatest value (deterministic).
    pub fn from_str_triples<R: AsRef<str>, C: AsRef<str>, V: AsRef<str>>(
        triples: &[(R, C, V)],
    ) -> Self {
        let (row_keys, row_of) = dedup_key_ids(triples, |t| t.0.as_ref());
        let (col_keys, col_of) = dedup_key_ids(triples, |t| t.1.as_ref());
        let (val_keys, val_of) = dedup_key_ids(triples, |t| t.2.as_ref());
        // keep the max 1-based value index per cell: sort cells, then walk
        // runs (value keys are sorted, so max index = max value)
        let mut cells: Vec<(usize, usize, usize)> =
            (0..triples.len()).map(|i| (row_of[i], col_of[i], val_of[i] + 1)).collect();
        cells.sort_unstable();
        let mut idx: Vec<(usize, usize, f64)> = Vec::with_capacity(cells.len());
        for &(r, c, v) in &cells {
            let same_cell =
                idx.last().map(|last| last.0 == r && last.1 == c).unwrap_or(false);
            if same_cell {
                let last = idx.last_mut().expect("just checked non-empty");
                if (v as f64) > last.2 {
                    last.2 = v as f64;
                }
            } else {
                idx.push((r, c, v as f64));
            }
        }
        let mat = SpMat::from_sorted_triples(row_keys.len(), col_keys.len(), &idx);
        Assoc { row_keys, col_keys, mat, vals: Some(val_keys) }
    }

    /// Build from parallel key/value slices (the D4M `Assoc(r, c, v)` form).
    pub fn new<R: AsRef<str>, C: AsRef<str>>(rows: &[R], cols: &[C], vals: &[f64]) -> Result<Self> {
        if rows.len() != cols.len() || rows.len() != vals.len() {
            return Err(D4mError::InvalidArg(format!(
                "Assoc::new length mismatch: {} rows, {} cols, {} vals",
                rows.len(),
                cols.len(),
                vals.len()
            )));
        }
        let triples: Vec<(&str, &str, f64)> = rows
            .iter()
            .zip(cols.iter())
            .zip(vals.iter())
            .map(|((r, c), v)| (r.as_ref(), c.as_ref(), *v))
            .collect();
        Ok(Assoc::from_triples(&triples))
    }

    /// Internal: construct from pre-sorted key vectors + matrix.
    pub(crate) fn from_parts(
        row_keys: Vec<String>,
        col_keys: Vec<String>,
        mat: SpMat,
        vals: Option<Vec<String>>,
    ) -> Self {
        debug_assert_eq!(mat.nr, row_keys.len());
        debug_assert_eq!(mat.nc, col_keys.len());
        debug_assert!(row_keys.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(col_keys.windows(2).all(|w| w[0] < w[1]));
        Assoc { row_keys, col_keys, mat, vals }
    }

    /// Row/col indices that still hold at least one entry, or `None` when
    /// every row and column is live (the common case — no work to do).
    fn dead_weight(&self) -> Option<(Vec<usize>, Vec<usize>)> {
        let live_rows: Vec<usize> =
            (0..self.mat.nr).filter(|&r| self.mat.indptr[r + 1] > self.mat.indptr[r]).collect();
        let mut live_col_mask = vec![false; self.mat.nc];
        for &c in &self.mat.indices {
            live_col_mask[c] = true;
        }
        let live_cols: Vec<usize> = (0..self.mat.nc).filter(|&c| live_col_mask[c]).collect();
        if live_rows.len() == self.mat.nr && live_cols.len() == self.mat.nc {
            None
        } else {
            Some((live_rows, live_cols))
        }
    }

    fn compact_to(&self, live_rows: &[usize], live_cols: &[usize]) -> Self {
        Assoc {
            row_keys: live_rows.iter().map(|&r| self.row_keys[r].clone()).collect(),
            col_keys: live_cols.iter().map(|&c| self.col_keys[c].clone()).collect(),
            mat: self.mat.select(live_rows, live_cols),
            vals: self.vals.clone(),
        }
    }

    /// Drop rows/cols that have become entirely empty (D4M `condense`).
    pub fn compacted(&self) -> Self {
        match self.dead_weight() {
            None => self.clone(),
            Some((lr, lc)) => self.compact_to(&lr, &lc),
        }
    }

    /// Owned `compacted`: returns `self` unchanged (no clone) when nothing
    /// needs dropping. Every freshly built op result funnels through here.
    pub(crate) fn compacted_owned(self) -> Self {
        match self.dead_weight() {
            None => self,
            Some((lr, lc)) => self.compact_to(&lr, &lc),
        }
    }

    // ------------------------------------------------------------------
    // accessors

    pub fn row_keys(&self) -> &[String] {
        &self.row_keys
    }

    pub fn col_keys(&self) -> &[String] {
        &self.col_keys
    }

    pub fn nnz(&self) -> usize {
        self.mat.nnz()
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.row_keys.len(), self.col_keys.len())
    }

    pub fn is_empty(&self) -> bool {
        self.nnz() == 0
    }

    /// True if this array stores string values.
    pub fn is_string_valued(&self) -> bool {
        self.vals.is_some()
    }

    /// The sorted value-key table of a string-valued array (entries in the
    /// numeric core are 1-based indices into it); `None` when numeric.
    /// Engines use this to ship the value dictionary alongside the data.
    pub fn val_keys(&self) -> Option<&[String]> {
        self.vals.as_deref()
    }

    /// The underlying numeric matrix (string-valued arrays expose their
    /// value indices).
    pub fn matrix(&self) -> &SpMat {
        &self.mat
    }

    /// Approximate heap footprint (keys + matrix), for memory-cap checks.
    pub fn mem_bytes(&self) -> usize {
        let keys: usize = self
            .row_keys
            .iter()
            .chain(self.col_keys.iter())
            .chain(self.vals.iter().flatten())
            .map(|k| k.len() + 24)
            .sum();
        keys + self.mat.mem_bytes()
    }

    /// Numeric value at `(row, col)`; 0.0 if absent. For string-valued
    /// arrays this is the 1-based value index.
    pub fn get(&self, row: &str, col: &str) -> f64 {
        match (find_key(&self.row_keys, row), find_key(&self.col_keys, col)) {
            (Ok(r), Ok(c)) => self.mat.get(r, c),
            _ => 0.0,
        }
    }

    /// String value at `(row, col)` for string-valued arrays.
    pub fn get_str(&self, row: &str, col: &str) -> Option<&str> {
        let vals = self.vals.as_ref()?;
        let v = self.get(row, col);
        if v == 0.0 {
            None
        } else {
            vals.get(v as usize - 1).map(|s| s.as_str())
        }
    }

    /// All triples `(row, col, numeric value)` in row-major key order.
    pub fn triples(&self) -> Vec<Triple> {
        self.mat
            .to_triples()
            .into_iter()
            .map(|(r, c, v)| (self.row_keys[r].clone(), self.col_keys[c].clone(), v))
            .collect()
    }

    /// All triples with string values rendered (numeric arrays render the
    /// number).
    pub fn str_triples(&self) -> Vec<(String, String, String)> {
        self.mat
            .to_triples()
            .into_iter()
            .map(|(r, c, v)| {
                let val = match &self.vals {
                    Some(vals) => vals[v as usize - 1].clone(),
                    None => crate::assoc::io::fmt_num(v),
                };
                (self.row_keys[r].clone(), self.col_keys[c].clone(), val)
            })
            .collect()
    }

    /// Convert a string-valued array to numeric by replacing every stored
    /// value with 1.0 (D4M `logical`/`double(A)` pattern).
    pub fn logical(&self) -> Assoc {
        Assoc {
            row_keys: self.row_keys.clone(),
            col_keys: self.col_keys.clone(),
            mat: self.mat.map(|_| 1.0),
            vals: None,
        }
    }

    /// Borrowed numeric coercion: the operand itself when already numeric
    /// (no clone of keys or matrix), an owned [`Assoc::logical`] only for
    /// string-valued arrays. Every binary op starts here instead of the
    /// old unconditional `self.clone()`.
    pub(crate) fn numeric_view(&self) -> Cow<'_, Assoc> {
        if self.is_string_valued() {
            Cow::Owned(self.logical())
        } else {
            Cow::Borrowed(self)
        }
    }

    // ------------------------------------------------------------------
    // algebra

    /// Union-pattern elementwise combine (shared by add/sub/max).
    fn union_op(&self, other: &Assoc, f: impl Fn(f64, f64) -> f64) -> Assoc {
        let a = self.numeric_view();
        let b = other.numeric_view();
        let (rows, ra, rb) = merge_sorted_keys(&a.row_keys, &b.row_keys);
        let (cols, ca, cb) = merge_sorted_keys(&a.col_keys, &b.col_keys);
        let ea = a.mat.embed(rows.len(), cols.len(), &ra, &ca);
        let eb = b.mat.embed(rows.len(), cols.len(), &rb, &cb);
        Assoc::from_parts(rows, cols, ea.union_combine(&eb, f), None).compacted_owned()
    }

    /// Intersection-pattern elementwise combine (shared by mult/min).
    fn intersect_op(&self, other: &Assoc, f: impl Fn(f64, f64) -> f64) -> Assoc {
        let a = self.numeric_view();
        let b = other.numeric_view();
        let (rows, ra, rb) = intersect_sorted_keys(&a.row_keys, &b.row_keys);
        let (cols, ca, cb) = intersect_sorted_keys(&a.col_keys, &b.col_keys);
        let sa = a.mat.select(&ra, &ca);
        let sb = b.mat.select(&rb, &cb);
        Assoc::from_parts(rows, cols, sa.intersect_combine(&sb, f), None).compacted_owned()
    }

    /// `A + B`: union of patterns, numeric sum on collisions. String-valued
    /// inputs are first converted with [`Assoc::logical`].
    pub fn add(&self, other: &Assoc) -> Assoc {
        self.union_op(other, |x, y| x + y)
    }

    /// Elementwise subtract: union pattern, `a - b`.
    pub fn sub(&self, other: &Assoc) -> Assoc {
        self.union_op(other, |x, y| x - y)
    }

    /// Elementwise multiply (`A & B` / `A .* B`): intersection of patterns,
    /// numeric product.
    pub fn elem_mult(&self, other: &Assoc) -> Assoc {
        self.intersect_op(other, |x, y| x * y)
    }

    /// Elementwise min over the **intersection** of patterns: cells
    /// present on only one side are dropped, matching D4M's
    /// set-intersection semantics for `min` (for the nonnegative values
    /// of logical/count arrays, `min(x, missing=0) = 0` anyway; for
    /// negative values the intersection is a deliberate choice, pinned by
    /// `elem_min_intersection_semantics`).
    pub fn elem_min(&self, other: &Assoc) -> Assoc {
        self.intersect_op(other, f64::min)
    }

    /// Elementwise max over the union of patterns.
    pub fn elem_max(&self, other: &Assoc) -> Assoc {
        self.union_op(other, f64::max)
    }

    /// Matrix multiply `A * B`: contracts over the intersection of A's
    /// column keys and B's row keys (key-aligned, never positional).
    /// The contraction runs through [`SpMat::matmul_inner`] — no
    /// identity-selected submatrices are materialised.
    pub fn matmul(&self, other: &Assoc) -> Assoc {
        self.matmul_with(other, &KernelConfig::global())
    }

    /// [`Assoc::matmul`] under an explicit [`KernelConfig`] (pinned
    /// thread counts for equivalence tests and bench legs).
    pub fn matmul_with(&self, other: &Assoc, cfg: &KernelConfig) -> Assoc {
        let a = self.numeric_view();
        let b = other.numeric_view();
        let (_, ia, ib) = intersect_sorted_keys(&a.col_keys, &b.row_keys);
        let prod = a.mat.matmul_inner_with(&b.mat, &ia, &ib, cfg);
        Assoc::from_parts(a.row_keys.clone(), b.col_keys.clone(), prod, None).compacted_owned()
    }

    /// D4M `CatKeyMul`: like [`Assoc::matmul`] but each output value is the
    /// `;`-joined list of inner keys that contributed (provenance-tracking
    /// multiply). Returns a string-valued array.
    pub fn catkeymul(&self, other: &Assoc) -> Assoc {
        self.catkeymul_with(other, &KernelConfig::global())
    }

    /// [`Assoc::catkeymul`] under an explicit [`KernelConfig`]. Rows of A
    /// split into contiguous nnz-balanced blocks across scoped workers;
    /// each worker accumulates its own ordered cell map over a disjoint
    /// row range, so concatenating the block outputs in range order
    /// reproduces the serial traversal exactly.
    pub fn catkeymul_with(&self, other: &Assoc, cfg: &KernelConfig) -> Assoc {
        let a = self.numeric_view();
        let b = other.numeric_view();
        let (inner, ia, ib) = intersect_sorted_keys(&a.col_keys, &b.row_keys);
        // inverse map: A-column index -> inner index (usize::MAX = not shared)
        let mut inner_of = vec![usize::MAX; a.col_keys.len()];
        for (t, &c) in ia.iter().enumerate() {
            inner_of[c] = t;
        }
        // accumulate contributing key lists per output cell, walking A's
        // rows directly (ia is increasing, so keys arrive in sorted order)
        let block = |rows: std::ops::Range<usize>| -> Vec<((usize, usize), Vec<&str>)> {
            let mut cells: std::collections::BTreeMap<(usize, usize), Vec<&str>> =
                std::collections::BTreeMap::new();
            for r in rows {
                for (c, _) in a.mat.row(r) {
                    let t = inner_of[c];
                    if t == usize::MAX {
                        continue;
                    }
                    for (bc, _) in b.mat.row(ib[t]) {
                        cells.entry((r, bc)).or_default().push(&inner[t]);
                    }
                }
            }
            cells.into_iter().collect()
        };
        let row_work: Vec<u64> = (0..a.mat.nr)
            .map(|r| (a.mat.indptr[r + 1] - a.mat.indptr[r]) as u64)
            .collect();
        let workers = kernel::plan_workers(cfg, row_work.iter().sum());
        let parts: Vec<Vec<((usize, usize), Vec<&str>)>> = if workers <= 1 {
            vec![block(0..a.mat.nr)]
        } else {
            let bounds = kernel::balanced_partition(&row_work, workers);
            std::thread::scope(|s| {
                let handles: Vec<_> = bounds
                    .windows(2)
                    .map(|w| {
                        let block = &block;
                        let (lo, hi) = (w[0], w[1]);
                        s.spawn(move || block(lo..hi))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("catkeymul worker panicked"))
                    .collect()
            })
        };
        let triples: Vec<(String, String, String)> = parts
            .into_iter()
            .flatten()
            .map(|((r, c), keys)| {
                (a.row_keys[r].clone(), b.col_keys[c].clone(), keys.join(";"))
            })
            .collect();
        Assoc::from_str_triples(&triples)
    }

    /// Transpose.
    pub fn transpose(&self) -> Assoc {
        Assoc {
            row_keys: self.col_keys.clone(),
            col_keys: self.row_keys.clone(),
            mat: self.mat.transpose(),
            vals: self.vals.clone(),
        }
    }

    /// Sum along a dimension (D4M `sum(A, dim)`): `dim = 1` sums down
    /// columns (result has single row key `""`), `dim = 2` sums across rows.
    pub fn sum(&self, dim: usize) -> Assoc {
        let a = self.numeric_view();
        match dim {
            1 => {
                let sums = a.mat.col_sums();
                let triples: Vec<(&str, &str, f64)> = a
                    .col_keys
                    .iter()
                    .zip(sums.iter())
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(c, &v)| ("", c.as_str(), v))
                    .collect();
                Assoc::from_triples(&triples)
            }
            2 => {
                let sums = a.mat.row_sums();
                let triples: Vec<(&str, &str, f64)> = a
                    .row_keys
                    .iter()
                    .zip(sums.iter())
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(r, &v)| (r.as_str(), "", v))
                    .collect();
                Assoc::from_triples(&triples)
            }
            _ => panic!("sum dim must be 1 or 2"),
        }
    }

    /// Fused `self.matmul(other).sum(dim)`: the contraction streams
    /// straight into the reduction, one product row at a time — the
    /// product CSR (and its `Assoc`) is never built. This is the kernel
    /// behind the plan executor's select→matmul→reduce fusion
    /// (DESIGN.md §Plan language).
    ///
    /// Bit-identical to the two-step form by construction: per output
    /// cell the additions arrive in the same ascending-k order the
    /// SpGEMM accumulator uses (both its variants), cells that cancel to
    /// exactly `0.0` are dropped exactly where the product would drop
    /// them, and the fold then walks surviving cells in the same
    /// ascending `(row, col)` order `row_sums`/`col_sums` walk the
    /// stored product.
    pub fn matmul_sum(&self, other: &Assoc, dim: usize) -> Assoc {
        assert!(dim == 1 || dim == 2, "sum dim must be 1 or 2");
        let a = self.numeric_view();
        let b = other.numeric_view();
        let (_, ia, ib) = intersect_sorted_keys(&a.col_keys, &b.row_keys);
        // A-column index -> contracted B-row index (usize::MAX = not shared)
        let mut row_of = vec![usize::MAX; a.col_keys.len()];
        for (t, &c) in ia.iter().enumerate() {
            row_of[c] = ib[t];
        }
        let nc = b.mat.nc;
        let mut acc = vec![0f64; nc];
        let mut seen = vec![false; nc];
        let mut touched: Vec<usize> = Vec::new();
        let mut col_tot = vec![0f64; if dim == 1 { nc } else { 0 }];
        let mut row_triples: Vec<(&str, &str, f64)> = Vec::new();
        for r in 0..a.mat.nr {
            for (k, av) in a.mat.row(r) {
                let br = row_of[k];
                if br == usize::MAX {
                    continue;
                }
                for (c, bv) in b.mat.row(br) {
                    if !seen[c] {
                        seen[c] = true;
                        touched.push(c);
                    }
                    acc[c] += av * bv;
                }
            }
            touched.sort_unstable();
            if dim == 1 {
                for &c in &touched {
                    // cells that cancel to 0.0 would not be stored in the
                    // product, so col_sums would never see them
                    if acc[c] != 0.0 {
                        col_tot[c] += acc[c];
                    }
                    acc[c] = 0.0;
                    seen[c] = false;
                }
            } else {
                let mut row_total = 0f64;
                for &c in &touched {
                    if acc[c] != 0.0 {
                        row_total += acc[c];
                    }
                    acc[c] = 0.0;
                    seen[c] = false;
                }
                if row_total != 0.0 {
                    row_triples.push((a.row_keys[r].as_str(), "", row_total));
                }
            }
            touched.clear();
        }
        if dim == 1 {
            let triples: Vec<(&str, &str, f64)> = b
                .col_keys
                .iter()
                .zip(col_tot.iter())
                .filter(|(_, &v)| v != 0.0)
                .map(|(c, &v)| ("", c.as_str(), v))
                .collect();
            Assoc::from_triples(&triples)
        } else {
            Assoc::from_triples(&row_triples)
        }
    }

    /// Scalar multiply.
    pub fn scale(&self, s: f64) -> Assoc {
        let a = self.numeric_view();
        Assoc {
            row_keys: a.row_keys.clone(),
            col_keys: a.col_keys.clone(),
            mat: a.mat.map(|v| v * s),
            vals: None,
        }
        .compacted_owned()
    }

    /// Keep entries whose value satisfies `pred` (D4M `A > t` etc.).
    pub fn filter_values(&self, pred: impl Fn(f64) -> bool) -> Assoc {
        Assoc {
            row_keys: self.row_keys.clone(),
            col_keys: self.col_keys.clone(),
            mat: self.mat.map(|v| if pred(v) { v } else { 0.0 }),
            vals: self.vals.clone(),
        }
        .compacted_owned()
    }

    /// Global sum of all numeric values.
    pub fn total(&self) -> f64 {
        self.mat.data.iter().sum()
    }

    // ------------------------------------------------------------------
    // subsref

    /// Select rows by predicate on the key (D4M `A(rows, :)`).
    pub fn select_rows(&self, sel: &KeySel) -> Assoc {
        let rows = sel.matching_indices(&self.row_keys);
        Assoc {
            row_keys: rows.iter().map(|&r| self.row_keys[r].clone()).collect(),
            col_keys: self.col_keys.clone(),
            mat: self.mat.select_rows(&rows),
            vals: self.vals.clone(),
        }
        .compacted_owned()
    }

    /// Select columns by predicate on the key (D4M `A(:, cols)`).
    pub fn select_cols(&self, sel: &KeySel) -> Assoc {
        let cols = sel.matching_indices(&self.col_keys);
        Assoc {
            row_keys: self.row_keys.clone(),
            col_keys: cols.iter().map(|&c| self.col_keys[c].clone()).collect(),
            mat: self.mat.select_cols(&cols),
            vals: self.vals.clone(),
        }
        .compacted_owned()
    }

    /// `A(rowsel, colsel)`.
    pub fn subsref(&self, rows: &KeySel, cols: &KeySel) -> Assoc {
        self.select_rows(rows).select_cols(cols)
    }
}

/// Key selector for subsref: the D4M `A('a,:,b,', :)` patterns, Rust-shaped.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KeySel {
    /// All keys (`:`).
    All,
    /// An explicit key list.
    Keys(Vec<String>),
    /// Inclusive lexicographic range (D4M `'a,:,b,'`).
    Range(String, String),
    /// Keys with the given prefix (D4M `'a.*'` StartsWith).
    Prefix(String),
}

impl KeySel {
    pub fn keys<S: AsRef<str>>(ks: &[S]) -> Self {
        KeySel::Keys(ks.iter().map(|s| s.as_ref().to_string()).collect())
    }

    pub fn matches(&self, key: &str) -> bool {
        match self {
            KeySel::All => true,
            KeySel::Keys(ks) => ks.iter().any(|k| k == key),
            KeySel::Range(lo, hi) => key >= lo.as_str() && key <= hi.as_str(),
            KeySel::Prefix(p) => key.starts_with(p.as_str()),
        }
    }

    /// Ascending indices of the **sorted** `keys` this selector matches.
    /// `Keys` binary-searches each requested key, `Range` and `Prefix`
    /// binary-search their contiguous bounds — O(log n + matches), never
    /// a full scan of the key vector (the old path tested every key, and
    /// `Keys` paid O(|keys| · |sel|)).
    pub fn matching_indices(&self, keys: &[String]) -> Vec<usize> {
        match self {
            KeySel::All => (0..keys.len()).collect(),
            KeySel::Keys(ks) => {
                let mut idx: Vec<usize> =
                    ks.iter().filter_map(|k| find_key(keys, k).ok()).collect();
                idx.sort_unstable();
                idx.dedup();
                idx
            }
            KeySel::Range(lo, hi) => {
                let l = keys.partition_point(|k| k.as_str() < lo.as_str());
                let h = keys.partition_point(|k| k.as_str() <= hi.as_str());
                (l..h).collect()
            }
            KeySel::Prefix(p) => {
                // keys sharing a prefix are contiguous in sorted order,
                // starting at the first key >= the prefix itself
                let l = keys.partition_point(|k| k.as_str() < p.as_str());
                let mut out = Vec::new();
                for (i, k) in keys[l..].iter().enumerate() {
                    if k.starts_with(p.as_str()) {
                        out.push(l + i);
                    } else {
                        break;
                    }
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests;
