//! Associative arrays — the mathematical core of D4M.
//!
//! An [`Assoc`] maps pairs of string keys `(row, col)` to values. Values
//! are either numeric (f64) or strings; string values are stored D4M-style
//! as 1-based indices into a sorted value-key table, so the numeric CSR
//! core ([`spmat::SpMat`]) backs both cases.
//!
//! Operations follow the associative-array algebra of the D4M papers:
//! `+` is union (numeric sum on collisions), elementwise `&`/`*` is
//! intersection (numeric product), and matrix multiply contracts over the
//! *intersection* of A's column keys and B's row keys. Key alignment is by
//! string identity, never by position.

pub mod io;
pub mod text;
pub mod naive;
pub mod spmat;

use crate::error::{D4mError, Result};
use crate::util::{find_key, intersect_sorted_keys, merge_sorted_keys};
use spmat::SpMat;

/// Associative array: `(row key, col key) -> value`.
#[derive(Debug, Clone, PartialEq)]
pub struct Assoc {
    /// Sorted, unique row keys.
    row_keys: Vec<String>,
    /// Sorted, unique column keys.
    col_keys: Vec<String>,
    /// Numeric core; when `vals` is `Some`, entries are 1-based indices
    /// into it (the D4M string-value encoding).
    mat: SpMat,
    /// Sorted, unique value keys for string-valued arrays.
    vals: Option<Vec<String>>,
}

/// One triple of an associative array, as strings + numeric value.
pub type Triple = (String, String, f64);

impl Assoc {
    // ------------------------------------------------------------------
    // construction

    /// Empty associative array.
    pub fn empty() -> Self {
        Assoc { row_keys: vec![], col_keys: vec![], mat: SpMat::zeros(0, 0), vals: None }
    }

    /// Build a numeric associative array from `(row, col, value)` triples.
    /// Duplicate `(row, col)` pairs are summed (D4M default collision op);
    /// entries summing to zero are dropped.
    pub fn from_triples<R: AsRef<str>, C: AsRef<str>>(triples: &[(R, C, f64)]) -> Self {
        let mut rows: Vec<String> = triples.iter().map(|t| t.0.as_ref().to_string()).collect();
        let mut cols: Vec<String> = triples.iter().map(|t| t.1.as_ref().to_string()).collect();
        rows.sort();
        rows.dedup();
        cols.sort();
        cols.dedup();
        let idx_triples: Vec<(usize, usize, f64)> = triples
            .iter()
            .map(|(r, c, v)| {
                (
                    find_key(&rows, r.as_ref()).unwrap(),
                    find_key(&cols, c.as_ref()).unwrap(),
                    *v,
                )
            })
            .collect();
        let mat = SpMat::from_triples(rows.len(), cols.len(), &idx_triples);
        Assoc { row_keys: rows, col_keys: cols, mat, vals: None }.compacted()
    }

    /// Build a string-valued associative array. Duplicate `(row, col)`
    /// pairs keep the lexicographically greatest value (deterministic).
    pub fn from_str_triples<R: AsRef<str>, C: AsRef<str>, V: AsRef<str>>(
        triples: &[(R, C, V)],
    ) -> Self {
        let mut rows: Vec<String> = triples.iter().map(|t| t.0.as_ref().to_string()).collect();
        let mut cols: Vec<String> = triples.iter().map(|t| t.1.as_ref().to_string()).collect();
        let mut vals: Vec<String> = triples.iter().map(|t| t.2.as_ref().to_string()).collect();
        rows.sort();
        rows.dedup();
        cols.sort();
        cols.dedup();
        vals.sort();
        vals.dedup();
        // keep max value index per cell
        let mut cells: std::collections::BTreeMap<(usize, usize), usize> =
            std::collections::BTreeMap::new();
        for (r, c, v) in triples {
            let ri = find_key(&rows, r.as_ref()).unwrap();
            let ci = find_key(&cols, c.as_ref()).unwrap();
            let vi = find_key(&vals, v.as_ref()).unwrap() + 1; // 1-based
            let e = cells.entry((ri, ci)).or_insert(vi);
            *e = (*e).max(vi);
        }
        let idx_triples: Vec<(usize, usize, f64)> =
            cells.into_iter().map(|((r, c), v)| (r, c, v as f64)).collect();
        let mat = SpMat::from_triples(rows.len(), cols.len(), &idx_triples);
        Assoc { row_keys: rows, col_keys: cols, mat, vals: Some(vals) }
    }

    /// Build from parallel key/value slices (the D4M `Assoc(r, c, v)` form).
    pub fn new<R: AsRef<str>, C: AsRef<str>>(rows: &[R], cols: &[C], vals: &[f64]) -> Result<Self> {
        if rows.len() != cols.len() || rows.len() != vals.len() {
            return Err(D4mError::InvalidArg(format!(
                "Assoc::new length mismatch: {} rows, {} cols, {} vals",
                rows.len(),
                cols.len(),
                vals.len()
            )));
        }
        let triples: Vec<(&str, &str, f64)> = rows
            .iter()
            .zip(cols.iter())
            .zip(vals.iter())
            .map(|((r, c), v)| (r.as_ref(), c.as_ref(), *v))
            .collect();
        Ok(Assoc::from_triples(&triples))
    }

    /// Internal: construct from pre-sorted key vectors + matrix.
    pub(crate) fn from_parts(
        row_keys: Vec<String>,
        col_keys: Vec<String>,
        mat: SpMat,
        vals: Option<Vec<String>>,
    ) -> Self {
        debug_assert_eq!(mat.nr, row_keys.len());
        debug_assert_eq!(mat.nc, col_keys.len());
        debug_assert!(row_keys.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(col_keys.windows(2).all(|w| w[0] < w[1]));
        Assoc { row_keys, col_keys, mat, vals }
    }

    /// Drop rows/cols that have become entirely empty (D4M `condense`).
    pub fn compacted(&self) -> Self {
        let live_rows: Vec<usize> =
            (0..self.mat.nr).filter(|&r| self.mat.indptr[r + 1] > self.mat.indptr[r]).collect();
        let mut live_col_mask = vec![false; self.mat.nc];
        for &c in &self.mat.indices {
            live_col_mask[c] = true;
        }
        let live_cols: Vec<usize> =
            (0..self.mat.nc).filter(|&c| live_col_mask[c]).collect();
        if live_rows.len() == self.mat.nr && live_cols.len() == self.mat.nc {
            return self.clone();
        }
        let mat = self.mat.select(&live_rows, &live_cols);
        Assoc {
            row_keys: live_rows.iter().map(|&r| self.row_keys[r].clone()).collect(),
            col_keys: live_cols.iter().map(|&c| self.col_keys[c].clone()).collect(),
            mat,
            vals: self.vals.clone(),
        }
    }

    // ------------------------------------------------------------------
    // accessors

    pub fn row_keys(&self) -> &[String] {
        &self.row_keys
    }

    pub fn col_keys(&self) -> &[String] {
        &self.col_keys
    }

    pub fn nnz(&self) -> usize {
        self.mat.nnz()
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.row_keys.len(), self.col_keys.len())
    }

    pub fn is_empty(&self) -> bool {
        self.nnz() == 0
    }

    /// True if this array stores string values.
    pub fn is_string_valued(&self) -> bool {
        self.vals.is_some()
    }

    /// The sorted value-key table of a string-valued array (entries in the
    /// numeric core are 1-based indices into it); `None` when numeric.
    /// Engines use this to ship the value dictionary alongside the data.
    pub fn val_keys(&self) -> Option<&[String]> {
        self.vals.as_deref()
    }

    /// The underlying numeric matrix (string-valued arrays expose their
    /// value indices).
    pub fn matrix(&self) -> &SpMat {
        &self.mat
    }

    /// Approximate heap footprint (keys + matrix), for memory-cap checks.
    pub fn mem_bytes(&self) -> usize {
        let keys: usize = self
            .row_keys
            .iter()
            .chain(self.col_keys.iter())
            .chain(self.vals.iter().flatten())
            .map(|k| k.len() + 24)
            .sum();
        keys + self.mat.mem_bytes()
    }

    /// Numeric value at `(row, col)`; 0.0 if absent. For string-valued
    /// arrays this is the 1-based value index.
    pub fn get(&self, row: &str, col: &str) -> f64 {
        match (find_key(&self.row_keys, row), find_key(&self.col_keys, col)) {
            (Ok(r), Ok(c)) => self.mat.get(r, c),
            _ => 0.0,
        }
    }

    /// String value at `(row, col)` for string-valued arrays.
    pub fn get_str(&self, row: &str, col: &str) -> Option<&str> {
        let vals = self.vals.as_ref()?;
        let v = self.get(row, col);
        if v == 0.0 {
            None
        } else {
            vals.get(v as usize - 1).map(|s| s.as_str())
        }
    }

    /// All triples `(row, col, numeric value)` in row-major key order.
    pub fn triples(&self) -> Vec<Triple> {
        self.mat
            .to_triples()
            .into_iter()
            .map(|(r, c, v)| (self.row_keys[r].clone(), self.col_keys[c].clone(), v))
            .collect()
    }

    /// All triples with string values rendered (numeric arrays render the
    /// number).
    pub fn str_triples(&self) -> Vec<(String, String, String)> {
        self.mat
            .to_triples()
            .into_iter()
            .map(|(r, c, v)| {
                let val = match &self.vals {
                    Some(vals) => vals[v as usize - 1].clone(),
                    None => crate::assoc::io::fmt_num(v),
                };
                (self.row_keys[r].clone(), self.col_keys[c].clone(), val)
            })
            .collect()
    }

    /// Convert a string-valued array to numeric by replacing every stored
    /// value with 1.0 (D4M `logical`/`double(A)` pattern).
    pub fn logical(&self) -> Assoc {
        Assoc {
            row_keys: self.row_keys.clone(),
            col_keys: self.col_keys.clone(),
            mat: self.mat.map(|_| 1.0),
            vals: None,
        }
    }

    // ------------------------------------------------------------------
    // algebra

    /// `A + B`: union of patterns, numeric sum on collisions. String-valued
    /// inputs are first converted with [`Assoc::logical`].
    pub fn add(&self, other: &Assoc) -> Assoc {
        let a = if self.is_string_valued() { self.logical() } else { self.clone() };
        let b = if other.is_string_valued() { other.logical() } else { other.clone() };
        let (rows, ra, rb) = merge_sorted_keys(&a.row_keys, &b.row_keys);
        let (cols, ca, cb) = merge_sorted_keys(&a.col_keys, &b.col_keys);
        let ea = a.mat.embed(rows.len(), cols.len(), &ra, &ca);
        let eb = b.mat.embed(rows.len(), cols.len(), &rb, &cb);
        Assoc::from_parts(rows, cols, ea.union_combine(&eb, |x, y| x + y), None).compacted()
    }

    /// Elementwise subtract: union pattern, `a - b`.
    pub fn sub(&self, other: &Assoc) -> Assoc {
        let a = if self.is_string_valued() { self.logical() } else { self.clone() };
        let b = if other.is_string_valued() { other.logical() } else { other.clone() };
        let (rows, ra, rb) = merge_sorted_keys(&a.row_keys, &b.row_keys);
        let (cols, ca, cb) = merge_sorted_keys(&a.col_keys, &b.col_keys);
        let ea = a.mat.embed(rows.len(), cols.len(), &ra, &ca);
        let eb = b.mat.embed(rows.len(), cols.len(), &rb, &cb);
        Assoc::from_parts(rows, cols, ea.union_combine(&eb, |x, y| x - y), None).compacted()
    }

    /// Elementwise multiply (`A & B` / `A .* B`): intersection of patterns,
    /// numeric product.
    pub fn elem_mult(&self, other: &Assoc) -> Assoc {
        let a = if self.is_string_valued() { self.logical() } else { self.clone() };
        let b = if other.is_string_valued() { other.logical() } else { other.clone() };
        let (rows, ra, rb) = intersect_sorted_keys(&a.row_keys, &b.row_keys);
        let (cols, ca, cb) = intersect_sorted_keys(&a.col_keys, &b.col_keys);
        let sa = a.mat.select(&ra, &ca);
        let sb = b.mat.select(&rb, &cb);
        Assoc::from_parts(rows, cols, sa.intersect_combine(&sb, |x, y| x * y), None).compacted()
    }

    /// Elementwise min over the union (missing = 0, so min(x,0)=0 drops —
    /// this matches set-intersection semantics for logical arrays).
    pub fn elem_min(&self, other: &Assoc) -> Assoc {
        let a = if self.is_string_valued() { self.logical() } else { self.clone() };
        let b = if other.is_string_valued() { other.logical() } else { other.clone() };
        let (rows, ra, rb) = intersect_sorted_keys(&a.row_keys, &b.row_keys);
        let (cols, ca, cb) = intersect_sorted_keys(&a.col_keys, &b.col_keys);
        let sa = a.mat.select(&ra, &ca);
        let sb = b.mat.select(&rb, &cb);
        Assoc::from_parts(rows, cols, sa.intersect_combine(&sb, f64::min), None).compacted()
    }

    /// Elementwise max over the union of patterns.
    pub fn elem_max(&self, other: &Assoc) -> Assoc {
        let a = if self.is_string_valued() { self.logical() } else { self.clone() };
        let b = if other.is_string_valued() { other.logical() } else { other.clone() };
        let (rows, ra, rb) = merge_sorted_keys(&a.row_keys, &b.row_keys);
        let (cols, ca, cb) = merge_sorted_keys(&a.col_keys, &b.col_keys);
        let ea = a.mat.embed(rows.len(), cols.len(), &ra, &ca);
        let eb = b.mat.embed(rows.len(), cols.len(), &rb, &cb);
        Assoc::from_parts(rows, cols, ea.union_combine(&eb, f64::max), None).compacted()
    }

    /// Matrix multiply `A * B`: contracts over the intersection of A's
    /// column keys and B's row keys (key-aligned, never positional).
    pub fn matmul(&self, other: &Assoc) -> Assoc {
        let a = if self.is_string_valued() { self.logical() } else { self.clone() };
        let b = if other.is_string_valued() { other.logical() } else { other.clone() };
        let (_, ia, ib) = intersect_sorted_keys(&a.col_keys, &b.row_keys);
        // slice A's cols and B's rows down to the shared inner keys
        let all_rows_a: Vec<usize> = (0..a.mat.nr).collect();
        let all_cols_b: Vec<usize> = (0..b.mat.nc).collect();
        let sa = a.mat.select(&all_rows_a, &ia);
        let sb = b.mat.select(&ib, &all_cols_b);
        Assoc::from_parts(a.row_keys.clone(), b.col_keys.clone(), sa.matmul(&sb), None)
            .compacted()
    }

    /// D4M `CatKeyMul`: like [`Assoc::matmul`] but each output value is the
    /// `;`-joined list of inner keys that contributed (provenance-tracking
    /// multiply). Returns a string-valued array.
    pub fn catkeymul(&self, other: &Assoc) -> Assoc {
        let a = if self.is_string_valued() { self.logical() } else { self.clone() };
        let b = if other.is_string_valued() { other.logical() } else { other.clone() };
        let (inner, ia, ib) = intersect_sorted_keys(&a.col_keys, &b.row_keys);
        let all_rows_a: Vec<usize> = (0..a.mat.nr).collect();
        let all_cols_b: Vec<usize> = (0..b.mat.nc).collect();
        let sa = a.mat.select(&all_rows_a, &ia);
        let sb = b.mat.select(&ib, &all_cols_b);
        // accumulate contributing key lists per output cell
        let mut cells: std::collections::BTreeMap<(usize, usize), Vec<&str>> =
            std::collections::BTreeMap::new();
        for r in 0..sa.nr {
            for (k, _) in sa.row(r) {
                for (c, _) in sb.row(k) {
                    cells.entry((r, c)).or_default().push(&inner[k]);
                }
            }
        }
        let triples: Vec<(String, String, String)> = cells
            .into_iter()
            .map(|((r, c), keys)| {
                (a.row_keys[r].clone(), b.col_keys[c].clone(), keys.join(";"))
            })
            .collect();
        Assoc::from_str_triples(&triples)
    }

    /// Transpose.
    pub fn transpose(&self) -> Assoc {
        Assoc {
            row_keys: self.col_keys.clone(),
            col_keys: self.row_keys.clone(),
            mat: self.mat.transpose(),
            vals: self.vals.clone(),
        }
    }

    /// Sum along a dimension (D4M `sum(A, dim)`): `dim = 1` sums down
    /// columns (result has single row key `""`), `dim = 2` sums across rows.
    pub fn sum(&self, dim: usize) -> Assoc {
        let a = if self.is_string_valued() { self.logical() } else { self.clone() };
        match dim {
            1 => {
                let sums = a.mat.col_sums();
                let triples: Vec<(&str, &str, f64)> = a
                    .col_keys
                    .iter()
                    .zip(sums.iter())
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(c, &v)| ("", c.as_str(), v))
                    .collect();
                Assoc::from_triples(&triples)
            }
            2 => {
                let sums = a.mat.row_sums();
                let triples: Vec<(&str, &str, f64)> = a
                    .row_keys
                    .iter()
                    .zip(sums.iter())
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(r, &v)| (r.as_str(), "", v))
                    .collect();
                Assoc::from_triples(&triples)
            }
            _ => panic!("sum dim must be 1 or 2"),
        }
    }

    /// Scalar multiply.
    pub fn scale(&self, s: f64) -> Assoc {
        let a = if self.is_string_valued() { self.logical() } else { self.clone() };
        Assoc { mat: a.mat.map(|v| v * s), ..a }.compacted()
    }

    /// Keep entries whose value satisfies `pred` (D4M `A > t` etc.).
    pub fn filter_values(&self, pred: impl Fn(f64) -> bool) -> Assoc {
        Assoc {
            row_keys: self.row_keys.clone(),
            col_keys: self.col_keys.clone(),
            mat: self.mat.map(|v| if pred(v) { v } else { 0.0 }),
            vals: self.vals.clone(),
        }
        .compacted()
    }

    /// Global sum of all numeric values.
    pub fn total(&self) -> f64 {
        self.mat.data.iter().sum()
    }

    // ------------------------------------------------------------------
    // subsref

    /// Select rows by predicate on the key (D4M `A(rows, :)`).
    pub fn select_rows(&self, sel: &KeySel) -> Assoc {
        let rows: Vec<usize> = (0..self.row_keys.len())
            .filter(|&r| sel.matches(&self.row_keys[r]))
            .collect();
        let cols: Vec<usize> = (0..self.col_keys.len()).collect();
        Assoc {
            row_keys: rows.iter().map(|&r| self.row_keys[r].clone()).collect(),
            col_keys: self.col_keys.clone(),
            mat: self.mat.select(&rows, &cols),
            vals: self.vals.clone(),
        }
        .compacted()
    }

    /// Select columns by predicate on the key (D4M `A(:, cols)`).
    pub fn select_cols(&self, sel: &KeySel) -> Assoc {
        let rows: Vec<usize> = (0..self.row_keys.len()).collect();
        let cols: Vec<usize> = (0..self.col_keys.len())
            .filter(|&c| sel.matches(&self.col_keys[c]))
            .collect();
        Assoc {
            row_keys: self.row_keys.clone(),
            col_keys: cols.iter().map(|&c| self.col_keys[c].clone()).collect(),
            mat: self.mat.select(&rows, &cols),
            vals: self.vals.clone(),
        }
        .compacted()
    }

    /// `A(rowsel, colsel)`.
    pub fn subsref(&self, rows: &KeySel, cols: &KeySel) -> Assoc {
        self.select_rows(rows).select_cols(cols)
    }
}

/// Key selector for subsref: the D4M `A('a,:,b,', :)` patterns, Rust-shaped.
#[derive(Debug, Clone)]
pub enum KeySel {
    /// All keys (`:`).
    All,
    /// An explicit key list.
    Keys(Vec<String>),
    /// Inclusive lexicographic range (D4M `'a,:,b,'`).
    Range(String, String),
    /// Keys with the given prefix (D4M `'a.*'` StartsWith).
    Prefix(String),
}

impl KeySel {
    pub fn keys<S: AsRef<str>>(ks: &[S]) -> Self {
        KeySel::Keys(ks.iter().map(|s| s.as_ref().to_string()).collect())
    }

    pub fn matches(&self, key: &str) -> bool {
        match self {
            KeySel::All => true,
            KeySel::Keys(ks) => ks.iter().any(|k| k == key),
            KeySel::Range(lo, hi) => key >= lo.as_str() && key <= hi.as_str(),
            KeySel::Prefix(p) => key.starts_with(p.as_str()),
        }
    }
}

#[cfg(test)]
mod tests;
