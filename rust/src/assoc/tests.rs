//! Assoc unit + property tests. The property tests check the CSR-backed
//! [`Assoc`] against the [`naive::NaiveAssoc`] oracle on random inputs.

use super::naive::NaiveAssoc;
use super::*;
use crate::util::{forall, XorShift64};

fn rand_triples(rng: &mut XorShift64, n: usize, keyspace: u64) -> Vec<(String, String, f64)> {
    (0..n)
        .map(|_| {
            (
                format!("r{:02}", rng.below(keyspace)),
                format!("c{:02}", rng.below(keyspace)),
                (rng.below(5) + 1) as f64,
            )
        })
        .collect()
}

fn assoc_pair(rng: &mut XorShift64) -> (Assoc, NaiveAssoc) {
    let n = rng.below(40) as usize;
    let t = rand_triples(rng, n, 12);
    (Assoc::from_triples(&t), NaiveAssoc::from_triples(&t))
}

fn same(a: &Assoc, n: &NaiveAssoc) {
    let mut at = a.triples();
    let mut nt = n.triples();
    at.sort_by(|x, y| (&x.0, &x.1).cmp(&(&y.0, &y.1)));
    nt.sort_by(|x, y| (&x.0, &x.1).cmp(&(&y.0, &y.1)));
    assert_eq!(at.len(), nt.len(), "nnz mismatch: {at:?} vs {nt:?}");
    for (x, y) in at.iter().zip(nt.iter()) {
        assert_eq!((&x.0, &x.1), (&y.0, &y.1));
        assert!((x.2 - y.2).abs() < 1e-9, "value mismatch at {x:?} vs {y:?}");
    }
}

// ---------------------------------------------------------------- unit

#[test]
#[cfg_attr(miri, ignore)]
fn construct_and_get() {
    let a = Assoc::from_triples(&[("r2", "c1", 3.0), ("r1", "c2", 5.0)]);
    assert_eq!(a.get("r2", "c1"), 3.0);
    assert_eq!(a.get("r1", "c2"), 5.0);
    assert_eq!(a.get("r1", "c1"), 0.0);
    assert_eq!(a.shape(), (2, 2));
    // keys come out sorted
    assert_eq!(a.row_keys(), &["r1".to_string(), "r2".to_string()]);
}

#[test]
#[cfg_attr(miri, ignore)]
fn duplicate_triples_sum() {
    let a = Assoc::from_triples(&[("r", "c", 1.0), ("r", "c", 2.5)]);
    assert_eq!(a.get("r", "c"), 3.5);
    assert_eq!(a.nnz(), 1);
}

#[test]
#[cfg_attr(miri, ignore)]
fn empty_assoc() {
    let a = Assoc::empty();
    assert!(a.is_empty());
    assert_eq!(a.shape(), (0, 0));
    let b = Assoc::from_triples(&[("r", "c", 1.0)]);
    same(&a.add(&b), &NaiveAssoc::from_triples(&[("r", "c", 1.0)]));
}

#[test]
#[cfg_attr(miri, ignore)]
fn new_length_mismatch_errors() {
    assert!(Assoc::new(&["a"], &["b", "c"], &[1.0]).is_err());
}

#[test]
#[cfg_attr(miri, ignore)]
fn string_values_roundtrip() {
    let a = Assoc::from_str_triples(&[("r1", "c1", "blue"), ("r2", "c1", "green")]);
    assert!(a.is_string_valued());
    assert_eq!(a.get_str("r1", "c1"), Some("blue"));
    assert_eq!(a.get_str("r2", "c1"), Some("green"));
    assert_eq!(a.get_str("r2", "c2"), None);
}

#[test]
#[cfg_attr(miri, ignore)]
fn string_duplicate_keeps_max() {
    let a = Assoc::from_str_triples(&[("r", "c", "apple"), ("r", "c", "zebra")]);
    assert_eq!(a.get_str("r", "c"), Some("zebra"));
}

#[test]
#[cfg_attr(miri, ignore)]
fn logical_converts_to_ones() {
    let a = Assoc::from_str_triples(&[("r", "c", "x"), ("r", "d", "y")]);
    let l = a.logical();
    assert!(!l.is_string_valued());
    assert_eq!(l.get("r", "c"), 1.0);
    assert_eq!(l.total(), 2.0);
}

#[test]
#[cfg_attr(miri, ignore)]
fn add_disjoint_and_overlapping() {
    let a = Assoc::from_triples(&[("a", "x", 1.0)]);
    let b = Assoc::from_triples(&[("b", "y", 2.0)]);
    let c = a.add(&b);
    assert_eq!(c.get("a", "x"), 1.0);
    assert_eq!(c.get("b", "y"), 2.0);
    let d = a.add(&a);
    assert_eq!(d.get("a", "x"), 2.0);
}

#[test]
#[cfg_attr(miri, ignore)]
fn sub_cancels() {
    let a = Assoc::from_triples(&[("a", "x", 1.0)]);
    let c = a.sub(&a);
    assert!(c.is_empty());
}

#[test]
#[cfg_attr(miri, ignore)]
fn elem_mult_intersects() {
    let a = Assoc::from_triples(&[("r", "c1", 2.0), ("r", "c2", 3.0)]);
    let b = Assoc::from_triples(&[("r", "c2", 4.0), ("r", "c3", 5.0)]);
    let c = a.elem_mult(&b);
    assert_eq!(c.nnz(), 1);
    assert_eq!(c.get("r", "c2"), 12.0);
}

#[test]
#[cfg_attr(miri, ignore)]
fn matmul_key_alignment() {
    // A's col keys and B's row keys only share "k1"
    let a = Assoc::from_triples(&[("r1", "k1", 2.0), ("r1", "k9", 100.0)]);
    let b = Assoc::from_triples(&[("k1", "c1", 3.0), ("zz", "c1", 100.0)]);
    let c = a.matmul(&b);
    assert_eq!(c.nnz(), 1);
    assert_eq!(c.get("r1", "c1"), 6.0);
}

#[test]
#[cfg_attr(miri, ignore)]
fn matmul_sums_paths() {
    let a = Assoc::from_triples(&[("r", "k1", 1.0), ("r", "k2", 1.0)]);
    let b = Assoc::from_triples(&[("k1", "c", 1.0), ("k2", "c", 1.0)]);
    assert_eq!(a.matmul(&b).get("r", "c"), 2.0);
}

#[test]
#[cfg_attr(miri, ignore)]
fn catkeymul_tracks_inner_keys() {
    let a = Assoc::from_triples(&[("r", "k1", 1.0), ("r", "k2", 1.0)]);
    let b = Assoc::from_triples(&[("k1", "c", 1.0), ("k2", "c", 1.0)]);
    let c = a.catkeymul(&b);
    assert_eq!(c.get_str("r", "c"), Some("k1;k2"));
}

#[test]
#[cfg_attr(miri, ignore)]
fn transpose_swaps() {
    let a = Assoc::from_triples(&[("r", "c", 7.0)]);
    let t = a.transpose();
    assert_eq!(t.get("c", "r"), 7.0);
    assert_eq!(t.transpose(), a);
}

#[test]
#[cfg_attr(miri, ignore)]
fn sum_dims() {
    let a = Assoc::from_triples(&[("r1", "c1", 1.0), ("r1", "c2", 2.0), ("r2", "c1", 4.0)]);
    let s1 = a.sum(1); // down columns
    assert_eq!(s1.get("", "c1"), 5.0);
    assert_eq!(s1.get("", "c2"), 2.0);
    let s2 = a.sum(2); // across rows
    assert_eq!(s2.get("r1", ""), 3.0);
    assert_eq!(s2.get("r2", ""), 4.0);
}

#[test]
#[cfg_attr(miri, ignore)]
fn scale_and_filter() {
    let a = Assoc::from_triples(&[("r", "c", 2.0), ("r", "d", 5.0)]);
    assert_eq!(a.scale(2.0).get("r", "d"), 10.0);
    let f = a.filter_values(|v| v > 3.0);
    assert_eq!(f.nnz(), 1);
    assert_eq!(f.get("r", "d"), 5.0);
}

#[test]
#[cfg_attr(miri, ignore)]
fn subsref_selectors() {
    let a = Assoc::from_triples(&[
        ("alice", "c1", 1.0),
        ("bob", "c2", 2.0),
        ("carol", "c1", 3.0),
    ]);
    // range
    let r = a.select_rows(&KeySel::Range("b".into(), "c".into()));
    assert_eq!(r.row_keys(), &["bob".to_string()]);
    // prefix
    let p = a.select_rows(&KeySel::Prefix("ca".into()));
    assert_eq!(p.row_keys(), &["carol".to_string()]);
    // explicit keys
    let k = a.subsref(&KeySel::keys(&["alice", "carol"]), &KeySel::keys(&["c1"]));
    assert_eq!(k.nnz(), 2);
    // all
    assert_eq!(a.subsref(&KeySel::All, &KeySel::All), a);
}

#[test]
#[cfg_attr(miri, ignore)]
fn compacted_drops_empty() {
    let a = Assoc::from_triples(&[("r1", "c1", 1.0), ("r2", "c2", 1.0)]);
    let f = a.filter_values(|v| v > 10.0);
    assert_eq!(f.shape(), (0, 0));
}

#[test]
#[cfg_attr(miri, ignore)]
fn mem_bytes_nonzero() {
    let a = Assoc::from_triples(&[("r", "c", 1.0)]);
    assert!(a.mem_bytes() > 0);
}

// ------------------------------------------------------------ property

#[test]
#[cfg_attr(miri, ignore)]
fn prop_add_matches_oracle() {
    forall(60, 0xA11CE, |rng| {
        let (a, na) = assoc_pair(rng);
        let (b, nb) = assoc_pair(rng);
        same(&a.add(&b), &na.add(&nb));
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_add_commutative() {
    forall(40, 0xC0FFEE, |rng| {
        let (a, _) = assoc_pair(rng);
        let (b, _) = assoc_pair(rng);
        assert_eq!(a.add(&b), b.add(&a));
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_add_associative() {
    forall(40, 0xAB5, |rng| {
        let (a, _) = assoc_pair(rng);
        let (b, _) = assoc_pair(rng);
        let (c, _) = assoc_pair(rng);
        let lhs = a.add(&b).add(&c);
        let rhs = a.add(&b.add(&c));
        // float sums identical here because values are small integers
        assert_eq!(lhs, rhs);
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_elem_mult_matches_oracle() {
    forall(60, 0xE1E, |rng| {
        let (a, na) = assoc_pair(rng);
        let (b, nb) = assoc_pair(rng);
        same(&a.elem_mult(&b), &na.elem_mult(&nb));
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_matmul_matches_oracle() {
    forall(60, 0x3A7, |rng| {
        let (a, na) = assoc_pair(rng);
        let (b, nb) = assoc_pair(rng);
        same(&a.matmul(&b), &na.matmul(&nb));
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_matmul_parallel_matches_oracle() {
    // same oracle, forced through the parallel and blocked kernels:
    // every cutoff is zeroed so even these tiny inputs fan out
    use crate::assoc::kernel::KernelConfig;
    let par = KernelConfig {
        threads: 8,
        parallel_cutoff: 0,
        ..KernelConfig::detect()
    };
    let blocked = KernelConfig { tile_cols: 4, blocked_row_flops: 0, ..par };
    forall(60, 0x3A7, |rng| {
        let (a, na) = assoc_pair(rng);
        let (b, nb) = assoc_pair(rng);
        let want = na.matmul(&nb);
        same(&a.matmul_with(&b, &par), &want);
        same(&a.matmul_with(&b, &blocked), &want);
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_transpose_matches_oracle() {
    forall(40, 0x7A0, |rng| {
        let (a, na) = assoc_pair(rng);
        same(&a.transpose(), &na.transpose());
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_matmul_transpose_identity() {
    // (A B)^T == B^T A^T over key-aligned multiply
    forall(40, 0x919, |rng| {
        let (a, _) = assoc_pair(rng);
        let (b, _) = assoc_pair(rng);
        assert_eq!(a.matmul(&b).transpose(), b.transpose().matmul(&a.transpose()));
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_subsref_range_matches_oracle() {
    forall(40, 0x5E1, |rng| {
        let (a, na) = assoc_pair(rng);
        let lo = format!("r{:02}", rng.below(12));
        let hi = format!("r{:02}", rng.below(12));
        let (lo, hi) = if lo <= hi { (lo, hi) } else { (hi, lo) };
        same(
            &a.select_rows(&KeySel::Range(lo.clone(), hi.clone())),
            &na.select_row_range(&lo, &hi),
        );
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_sum2_matches_oracle_rowsums() {
    forall(40, 0x50F, |rng| {
        let (a, na) = assoc_pair(rng);
        let s = a.sum(2);
        let want = na.sum_rows();
        for (r, v) in want {
            if v != 0.0 {
                assert!((s.get(&r, "") - v).abs() < 1e-9);
            }
        }
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_matmul_sum_fused_bit_identical() {
    // the plan executor's fused reduce: matmul_sum must equal
    // matmul-then-sum to the BIT (assert_eq on the Assoc, no tolerance),
    // numeric and string-valued operands, both dims, including the
    // empty-contraction edge (disjoint inner keys)
    forall(60, 0xF05ED5, |rng| {
        let (a, _) = assoc_pair(rng);
        let (b, _) = assoc_pair(rng);
        let bt = b.transpose(); // rows cXX — real contraction with a's cols
        assert_eq!(a.matmul_sum(&bt, 1), a.matmul(&bt).sum(1));
        assert_eq!(a.matmul_sum(&bt, 2), a.matmul(&bt).sum(2));
        // disjoint inner keys: both sides empty, still identical
        assert_eq!(a.matmul_sum(&b, 1), a.matmul(&b).sum(1));
        // string-valued operands coerce through the same numeric_view
        let (s, _) = str_pair(rng);
        let st = s.transpose();
        assert_eq!(s.matmul_sum(&st, 1), s.matmul(&st).sum(1));
        assert_eq!(a.matmul_sum(&st, 2), a.matmul(&st).sum(2));
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_distributive_matmul_over_add() {
    // A(B + C) == AB + AC
    forall(30, 0xD15, |rng| {
        let (a, _) = assoc_pair(rng);
        let (b, _) = assoc_pair(rng);
        let (c, _) = assoc_pair(rng);
        let lhs = a.matmul(&b.add(&c));
        let rhs = a.matmul(&b).add(&a.matmul(&c));
        // same pattern & values (integer values keep this exact)
        same_assoc(&lhs, &rhs);
    });
}

// ---------------------------------------------------- rewrite equivalence
//
// The clone-free rewrite must be *bit-identical* to the naive BTreeMap
// backend: values here are small integers, so float sums are exact and we
// compare with `==`, not a tolerance.

fn rand_str_triples(rng: &mut XorShift64, n: usize, keyspace: u64) -> Vec<(String, String, String)> {
    (0..n)
        .map(|_| {
            (
                format!("r{:02}", rng.below(keyspace)),
                format!("c{:02}", rng.below(keyspace)),
                format!("v{:02}", rng.below(6)),
            )
        })
        .collect()
}

/// A string-valued assoc plus its logical (1.0-per-cell) naive oracle —
/// the coercion every binary op applies to string-valued operands.
fn str_pair(rng: &mut XorShift64) -> (Assoc, NaiveAssoc) {
    let n = rng.below(40) as usize;
    let t = rand_str_triples(rng, n, 10);
    let a = Assoc::from_str_triples(&t);
    let cells: std::collections::BTreeSet<(String, String)> =
        t.iter().map(|(r, c, _)| (r.clone(), c.clone())).collect();
    let na = NaiveAssoc { cells: cells.into_iter().map(|k| (k, 1.0)).collect() };
    (a, na)
}

fn same_exact(a: &Assoc, n: &NaiveAssoc) {
    // both enumerate in (row, col) key order, so direct comparison pins
    // pattern, order, and exact values at once
    assert_eq!(a.triples(), n.triples());
}

#[test]
#[cfg_attr(miri, ignore)]
fn numeric_view_borrows_numeric_operands() {
    // the acceptance gate for the clone-free coercion: a numeric operand
    // is handed to the algebra as a borrow, never a deep copy
    let a = Assoc::from_triples(&[("r", "c", 1.0)]);
    assert!(matches!(a.numeric_view(), std::borrow::Cow::Borrowed(_)));
    let s = Assoc::from_str_triples(&[("r", "c", "x")]);
    assert!(matches!(s.numeric_view(), std::borrow::Cow::Owned(_)));
    // and the borrowed view is the operand itself, not a reallocation
    match a.numeric_view() {
        std::borrow::Cow::Borrowed(v) => assert!(std::ptr::eq(v, &a)),
        std::borrow::Cow::Owned(_) => unreachable!(),
    }
}

#[test]
#[cfg_attr(miri, ignore)]
fn elem_min_intersection_semantics() {
    // pinned story (doc + behaviour): elem_min keeps only cells present
    // on BOTH sides — set-intersection, not union-min
    let a = Assoc::from_triples(&[("r", "c1", 5.0), ("r", "c2", 2.0)]);
    let b = Assoc::from_triples(&[("r", "c2", 3.0), ("r", "c3", 9.0)]);
    let m = a.elem_min(&b);
    assert_eq!(m.nnz(), 1);
    assert_eq!(m.get("r", "c2"), 2.0);
    // intersection even for negative values, where union-min would have
    // kept the one-sided cell (min(-1, missing=0) = -1)
    let n1 = Assoc::from_triples(&[("r", "c", -1.0)]);
    let n2 = Assoc::from_triples(&[("r", "d", 1.0)]);
    assert!(n1.elem_min(&n2).is_empty());
    // and on the shared pattern the min of negatives is exact
    let p = Assoc::from_triples(&[("r", "c", -4.0)]);
    let q = Assoc::from_triples(&[("r", "c", -2.0)]);
    assert_eq!(p.elem_min(&q).get("r", "c"), -4.0);
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_add_exact_matches_oracle() {
    forall(60, 0xADD1, |rng| {
        let (a, na) = assoc_pair(rng);
        let (b, nb) = assoc_pair(rng);
        same_exact(&a.add(&b), &na.add(&nb));
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_string_valued_add_matches_oracle() {
    forall(50, 0x57A1, |rng| {
        let (a, na) = str_pair(rng);
        let (b, nb) = str_pair(rng);
        same_exact(&a.add(&b), &na.add(&nb));
        // mixed string/numeric operands coerce only the string side
        let (c, nc) = assoc_pair(rng);
        same_exact(&a.add(&c), &na.add(&nc));
        same_exact(&c.add(&b), &nc.add(&nb));
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_string_valued_elem_mult_matches_oracle() {
    forall(50, 0x57A2, |rng| {
        let (a, na) = str_pair(rng);
        let (b, nb) = str_pair(rng);
        same_exact(&a.elem_mult(&b), &na.elem_mult(&nb));
        let (c, nc) = assoc_pair(rng);
        same_exact(&a.elem_mult(&c), &na.elem_mult(&nc));
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_string_valued_matmul_matches_oracle() {
    forall(50, 0x57A3, |rng| {
        let (a, na) = str_pair(rng);
        let (b, nb) = str_pair(rng);
        same_exact(&a.matmul(&b), &na.matmul(&nb));
        let (c, nc) = assoc_pair(rng);
        same_exact(&a.matmul(&c), &na.matmul(&nc));
        same_exact(&c.matmul(&b), &nc.matmul(&nb));
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_string_valued_transpose_keeps_values() {
    forall(40, 0x57A4, |rng| {
        let n = rng.below(30) as usize;
        let t = rand_str_triples(rng, n, 8);
        let a = Assoc::from_str_triples(&t);
        let tr = a.transpose();
        assert!(tr.is_string_valued() || a.is_empty());
        let mut want: Vec<(String, String, String)> =
            a.str_triples().into_iter().map(|(r, c, v)| (c, r, v)).collect();
        want.sort();
        let mut got = tr.str_triples();
        got.sort();
        assert_eq!(got, want);
        assert_eq!(tr.transpose(), a);
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_select_keys_matches_oracle() {
    forall(50, 0x5E1EC7, |rng| {
        let (a, na) = assoc_pair(rng);
        let picks: Vec<String> =
            (0..rng.below(6)).map(|_| format!("r{:02}", rng.below(12))).collect();
        let got = a.select_rows(&KeySel::keys(&picks));
        let want = na.select_rows_by(|r| picks.iter().any(|k| k == r));
        same_exact(&got, &want);
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_select_prefix_matches_oracle() {
    forall(50, 0x9F1, |rng| {
        let (a, na) = assoc_pair(rng);
        let p = format!("r{}", rng.below(2));
        let got = a.select_rows(&KeySel::Prefix(p.clone()));
        let want = na.select_rows_by(|r| r.starts_with(&p));
        same_exact(&got, &want);
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn prop_subsref_matches_oracle() {
    forall(50, 0x5B5, |rng| {
        let (a, na) = assoc_pair(rng);
        let lo = format!("r{:02}", rng.below(12));
        let hi = format!("r{:02}", rng.below(12));
        let (lo, hi) = if lo <= hi { (lo, hi) } else { (hi, lo) };
        let picks: Vec<String> =
            (0..rng.below(6)).map(|_| format!("c{:02}", rng.below(12))).collect();
        let got = a.subsref(
            &KeySel::Range(lo.clone(), hi.clone()),
            &KeySel::keys(&picks),
        );
        let want = na
            .select_rows_by(|r| r >= lo.as_str() && r <= hi.as_str())
            .select_cols_by(|c| picks.iter().any(|k| k == c));
        same_exact(&got, &want);
    });
}

#[test]
#[cfg_attr(miri, ignore)]
fn string_valued_subsref_keeps_values() {
    let a = Assoc::from_str_triples(&[
        ("alice", "c1", "blue"),
        ("bob", "c1", "green"),
        ("bob", "c2", "red"),
    ]);
    let s = a.subsref(&KeySel::Prefix("b".into()), &KeySel::keys(&["c1"]));
    assert!(s.is_string_valued());
    assert_eq!(s.get_str("bob", "c1"), Some("green"));
    assert_eq!(s.nnz(), 1);
}

fn same_assoc(a: &Assoc, b: &Assoc) {
    let mut at = a.triples();
    let mut bt = b.triples();
    at.sort_by(|x, y| (&x.0, &x.1).cmp(&(&y.0, &y.1)));
    bt.sort_by(|x, y| (&x.0, &x.1).cmp(&(&y.0, &y.1)));
    assert_eq!(at.len(), bt.len());
    for (x, y) in at.iter().zip(bt.iter()) {
        assert_eq!((&x.0, &x.1), (&y.0, &y.1));
        assert!((x.2 - y.2).abs() < 1e-9);
    }
}
