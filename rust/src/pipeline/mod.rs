//! Streaming ingest pipeline — the L3 data-pipeline coordination layer.
//!
//! Reproduces the D4M high-rate ingest architecture (Kepner et al. 2014:
//! "Achieving 100,000,000 database inserts per second"): a producer
//! shards parsed triples across N parallel ingest workers, each owning a
//! buffered [`D4mWriter`]; bounded queues between producer and workers
//! provide **backpressure** (a full queue blocks the producer instead of
//! growing without bound). Sharding is by row key so each worker hits a
//! disjoint tablet set.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::connectors::accumulo::D4mTable;
use crate::error::{D4mError, Result};

/// One parsed mutation.
pub type TripleMsg = (String, String, String);

/// Pipeline tuning.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineConfig {
    /// Parallel ingest workers.
    pub num_workers: usize,
    /// Bounded queue depth per worker, in *batches* (backpressure knob).
    pub queue_depth: usize,
    /// Triples per batch message.
    pub batch_size: usize,
    /// Shard by row-key hash (false = round-robin; hash keeps a row's
    /// mutations on one worker, matching tablet affinity).
    pub shard_by_row: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { num_workers: 4, queue_depth: 8, batch_size: 2048, shard_by_row: true }
    }
}

/// Outcome of an ingest run.
#[derive(Debug, Clone, PartialEq)]
pub struct IngestReport {
    pub triples: u64,
    pub elapsed: Duration,
    /// Triples per second (logical mutations; the D4M schema multiplies
    /// physical inserts by ~3x for transpose + degree tables).
    pub rate: f64,
    /// Physical inserts per second (counting schema fan-out).
    pub physical_rate: f64,
    pub per_worker: Vec<u64>,
    /// Producer stalls caused by full queues (backpressure events).
    pub backpressure_stalls: u64,
    pub num_workers: usize,
}

impl std::fmt::Display for IngestReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} triples, {} workers, {:.2?}: {} logical ({} physical), {} stalls",
            self.triples,
            self.num_workers,
            self.elapsed,
            crate::util::fmt_rate(self.rate),
            crate::util::fmt_rate(self.physical_rate),
            self.backpressure_stalls
        )
    }
}

/// The ingest pipeline bound to a destination D4M table.
pub struct IngestPipeline {
    table: Arc<D4mTable>,
    config: PipelineConfig,
}

impl IngestPipeline {
    pub fn new(table: Arc<D4mTable>, config: PipelineConfig) -> Self {
        IngestPipeline { table, config }
    }

    /// Drive the full pipeline over a triple source. Blocks until every
    /// worker has drained and flushed; returns throughput metrics.
    pub fn run(&self, source: impl Iterator<Item = TripleMsg>) -> Result<IngestReport> {
        let n = self.config.num_workers.max(1);
        let schema_fanout = 1
            + self.table.transpose_table().is_some() as u64
            + self.table.degree_table().is_some() as u64;
        let stalls = Arc::new(AtomicU64::new(0));
        let t0 = Instant::now();

        // one bounded channel per worker
        let mut senders: Vec<SyncSender<Vec<TripleMsg>>> = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx): (SyncSender<Vec<TripleMsg>>, Receiver<Vec<TripleMsg>>) =
                sync_channel(self.config.queue_depth);
            senders.push(tx);
            let table = self.table.clone();
            handles.push(std::thread::spawn(move || -> Result<u64> {
                let mut w = table.writer();
                let mut count = 0u64;
                while let Ok(batch) = rx.recv() {
                    for (r, c, v) in &batch {
                        // a write error (durable stores: WAL I/O or
                        // backpressure timeout) kills the worker; its
                        // closed channel fails the producer's next send
                        w.put(r, c, v)?;
                    }
                    count += batch.len() as u64;
                }
                w.flush()?;
                Ok(count)
            }));
        }

        // producer: parse/shard/batch
        let mut batches: Vec<Vec<TripleMsg>> =
            (0..n).map(|_| Vec::with_capacity(self.config.batch_size)).collect();
        let mut total = 0u64;
        for t in source {
            let shard = if self.config.shard_by_row {
                let mut h = DefaultHasher::new();
                t.0.hash(&mut h);
                (h.finish() as usize) % n
            } else {
                (total as usize) % n
            };
            total += 1;
            batches[shard].push(t);
            if batches[shard].len() >= self.config.batch_size {
                let batch = std::mem::replace(
                    &mut batches[shard],
                    Vec::with_capacity(self.config.batch_size),
                );
                send_with_backpressure(&senders[shard], batch, &stalls)?;
            }
        }
        for (shard, batch) in batches.into_iter().enumerate() {
            if !batch.is_empty() {
                send_with_backpressure(&senders[shard], batch, &stalls)?;
            }
        }
        drop(senders); // close channels; workers drain and exit

        let mut per_worker = Vec::with_capacity(n);
        for h in handles {
            per_worker.push(h.join().map_err(|_| D4mError::Pipeline("worker panicked".into()))??);
        }
        let elapsed = t0.elapsed();
        let secs = elapsed.as_secs_f64().max(1e-9);
        Ok(IngestReport {
            triples: total,
            elapsed,
            rate: total as f64 / secs,
            physical_rate: (total * schema_fanout) as f64 / secs,
            per_worker,
            backpressure_stalls: stalls.load(Ordering::Relaxed),
            num_workers: n,
        })
    }
}

/// Send a batch, counting one stall each time the bounded queue is full
/// (then falling back to the blocking send — that *is* the backpressure).
fn send_with_backpressure(
    tx: &SyncSender<Vec<TripleMsg>>,
    batch: Vec<TripleMsg>,
    stalls: &AtomicU64,
) -> Result<()> {
    match tx.try_send(batch) {
        Ok(()) => Ok(()),
        Err(TrySendError::Full(batch)) => {
            stalls.fetch_add(1, Ordering::Relaxed);
            tx.send(batch).map_err(|_| D4mError::Pipeline("worker channel closed".into()))
        }
        Err(TrySendError::Disconnected(_)) => {
            Err(D4mError::Pipeline("worker channel closed".into()))
        }
    }
}

/// Parse a TSV line into a triple (for file-driven ingest).
pub fn parse_tsv_line(line: &str) -> Result<TripleMsg> {
    let mut it = line.split('\t');
    match (it.next(), it.next(), it.next(), it.next()) {
        (Some(r), Some(c), Some(v), None) => Ok((r.to_string(), c.to_string(), v.to_string())),
        _ => Err(D4mError::Parse(format!("bad triple line: {line:?}"))),
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;
    use crate::connectors::{AccumuloConnector, D4mTableConfig};
    use crate::kvstore::{IterConfig, RowRange};

    fn pipeline(workers: usize, queue: usize, batch: usize) -> (AccumuloConnector, IngestPipeline) {
        let acc = AccumuloConnector::new();
        let t = acc.bind("T", &D4mTableConfig::default()).unwrap();
        let p = IngestPipeline::new(
            Arc::new(t),
            PipelineConfig {
                num_workers: workers,
                queue_depth: queue,
                batch_size: batch,
                shard_by_row: true,
            },
        );
        (acc, p)
    }

    fn triples(n: usize) -> Vec<TripleMsg> {
        (0..n)
            .map(|i| (format!("r{i:05}"), format!("c{:03}", i % 97), "1".to_string()))
            .collect()
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn ingests_everything() {
        let (acc, p) = pipeline(4, 4, 64);
        let report = p.run(triples(5_000).into_iter()).unwrap();
        assert_eq!(report.triples, 5_000);
        assert_eq!(report.per_worker.iter().sum::<u64>(), 5_000);
        let t = acc.store().table("T").unwrap();
        // read-side verification streams — nothing materialises
        assert_eq!(t.scan_stream(&RowRange::all(), &IterConfig::default()).count(), 5_000);
        // transpose table populated too (one mirrored entry per triple,
        // spread over the 97 distinct column keys)
        let tt = acc.store().table("T_T").unwrap();
        let mut rows: Vec<String> = tt
            .scan_stream(&RowRange::all(), &IterConfig::default())
            .map(|e| e.key.row)
            .collect();
        assert_eq!(rows.len(), 5_000);
        rows.dedup();
        assert_eq!(rows.len(), 97);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn degree_table_correct_after_parallel_ingest() {
        let (acc, p) = pipeline(4, 4, 128);
        p.run(triples(1_000).into_iter()).unwrap();
        let t = acc.bind("T", &D4mTableConfig::default()).unwrap();
        // every column c000..c096 appears ceil/floor(1000/97) times
        let d = t.degree("c000").unwrap();
        assert!(d >= 10.0 && d <= 11.0, "degree {d}");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn single_worker_works() {
        let (_acc, p) = pipeline(1, 2, 32);
        let report = p.run(triples(500).into_iter()).unwrap();
        assert_eq!(report.triples, 500);
        assert_eq!(report.per_worker.len(), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn backpressure_engages_on_tiny_queue() {
        let (_acc, p) = pipeline(1, 1, 8);
        let report = p.run(triples(4_000).into_iter()).unwrap();
        assert_eq!(report.triples, 4_000);
        assert!(report.backpressure_stalls > 0, "expected stalls with queue_depth=1");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn row_sharding_is_stable() {
        // same row key must always land on the same worker: ingest dup
        // rows and verify the degree table (summing) is exact.
        let (acc, p) = pipeline(4, 4, 16);
        let t: Vec<TripleMsg> = (0..300)
            .map(|i| ("same_row".to_string(), format!("c{i}"), "1".to_string()))
            .collect();
        p.run(t.into_iter()).unwrap();
        let table = acc.store().table("T").unwrap();
        assert_eq!(table.scan_stream(&RowRange::all(), &IterConfig::default()).count(), 300);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn parse_tsv() {
        assert_eq!(
            parse_tsv_line("a\tb\tc").unwrap(),
            ("a".to_string(), "b".to_string(), "c".to_string())
        );
        assert!(parse_tsv_line("a\tb").is_err());
        assert!(parse_tsv_line("a\tb\tc\td").is_err());
    }
}
