//! The D4M coordinator — the L3 server tying everything together: a
//! table registry over the engines, a typed request/response API, an
//! ingest batcher, scan cursors, and per-op metrics. `main.rs` exposes
//! it as a CLI; the object-safe [`D4mApi`] trait ([`api`]) is the
//! surface callers program against — [`D4mServer`] implements it
//! in-process and [`crate::net::RemoteD4m`] implements it over TCP, so
//! a call site goes remote by swapping a constructor.
//!
//! The registry holds [`DbTable`] **trait objects**, so the query path is
//! engine-generic: `Request::Query` carries a [`TableQuery`] whose
//! selectors are pushed down by whichever engine owns the binding. The
//! Graphulo requests (TableMult/BFS/Jaccard/k-truss/PageRank) are
//! in-database algorithms of the key-value substrate and keep their
//! native Accumulo handles — they are server-side iterators, not
//! put/get/query dispatch.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
pub mod api;
pub mod batcher;
pub mod cursor;
pub mod plan;

pub use api::{D4mApi, ScanPages};
pub use cursor::{CursorPage, CursorResume, LOCAL_OWNER};
pub use plan::PlanStats;

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::assoc::expr::PlanOp;
use crate::assoc::Assoc;
use crate::connectors::{AccumuloConnector, D4mTable, D4mTableConfig, DbTable, TableQuery};
use crate::error::{D4mError, Result};
use crate::graphulo::{self, ClientCtx, TableMultOpts};
use crate::kvstore::{KvStore, Table};
use crate::metrics::{names, Histogram, RateMeter, Snapshot};
use crate::pipeline::{IngestPipeline, IngestReport, PipelineConfig, TripleMsg};
use crate::runtime::DenseEngine;

/// Requests the coordinator serves.
///
/// `Request` and [`Response`] derive `Debug`/`Clone`/`PartialEq` so the
/// network codec (`net::wire`) can be property-tested by round-trip
/// equality, and so callers can replay a request verbatim.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Bind (create if needed) a D4M table.
    CreateTable { name: String, splits: Vec<String> },
    /// Ingest triples through the parallel pipeline.
    Ingest { table: String, triples: Vec<TripleMsg>, pipeline: PipelineConfig },
    /// The unified `T(r, c)` query: row/col selectors + limit, pushed
    /// down through the table's [`DbTable`] binding (column selectors
    /// route through the transpose table on the key-value engine).
    Query { table: String, query: TableQuery },
    /// TableMult, unified: where the product goes ([`MultDest`]) and
    /// which execution strategy computes it ([`ExecHint`]). Replaces the
    /// retired `TableMult`/`TableMultClient`/`TableMultDense` triplet
    /// (wire v3 tags 3/4/5 — decoding the retired tags yields a typed
    /// `WireError::Retired`).
    TableMult { a: String, b: String, dest: MultDest, exec: ExecHint },
    /// A compiled expression-language program, executed server-side with
    /// streaming fusion by [`plan`] (see `assoc::expr` for the language
    /// and `DESIGN.md` §Plan language).
    Plan { ops: Vec<PlanOp> },
    /// Server-side BFS.
    Bfs { table: String, seeds: Vec<String>, hops: usize },
    /// Server-side Jaccard into table `out`.
    Jaccard { table: String, out: String },
    /// Server-side k-truss.
    KTruss { table: String, k: usize },
    /// Server-side PageRank (power iteration over table scans).
    PageRank { table: String, opts: graphulo::PageRankOpts },
    /// List tables.
    ListTables,
}

/// Where a [`Request::TableMult`] product lands.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MultDest {
    /// Accumulate into server table `out` (`out += A^T B`, the Graphulo
    /// server-side iterator).
    Table { out: String },
    /// Return the product to the caller as an [`Assoc`].
    Client,
}

/// How a [`Request::TableMult`] is computed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecHint {
    /// Stream the operands through the server-side Graphulo iterator
    /// (the only strategy that can accumulate into a table).
    Stream,
    /// Read both operands into RAM under a byte budget and multiply
    /// client-style (typed [`D4mError::MemoryLimit`] past the budget).
    Memory { limit: usize },
    /// Route through the blocked dense-GEMM path with the given tile
    /// (0 = auto-tile).
    Dense { tile: usize },
}

impl Request {
    /// Whether replaying this request after an ambiguous transport
    /// failure is safe — i.e. executing it twice leaves the server in
    /// the same state as executing it once. The self-healing client
    /// ([`crate::net::RemoteD4m`]) only auto-retries idempotent
    /// requests once the bytes may have reached the server; everything
    /// else surfaces [`D4mError::AmbiguousWrite`].
    ///
    /// Non-idempotent today: `Ingest` (maintains accumulating `_Deg`
    /// degree companions), `TableMult` into a server table (`out += A^T
    /// B` accumulation — client-destined multiplies are pure reads),
    /// `Plan`s containing a `Store` op, and `Jaccard`/`KTruss` (write
    /// server-side result tables mid-computation). `CreateTable` binds
    /// create-if-needed, so it is safe.
    pub fn is_idempotent(&self) -> bool {
        match self {
            Request::CreateTable { .. }
            | Request::Query { .. }
            | Request::Bfs { .. }
            | Request::PageRank { .. }
            | Request::ListTables => true,
            Request::TableMult { dest, .. } => matches!(dest, MultDest::Client),
            Request::Plan { ops } => crate::assoc::expr::plan_is_idempotent(ops),
            Request::Ingest { .. }
            | Request::Jaccard { .. }
            | Request::KTruss { .. } => false,
        }
    }
}

/// Responses.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok,
    Tables(Vec<String>),
    Ingested(IngestReport),
    Assoc(Assoc),
    Distances(BTreeMap<String, usize>),
    Ranks(graphulo::PageRankResult),
    MultStats(graphulo::TableMultStats),
    /// A plan's result plus the executor's fusion counters
    /// ([`PlanStats`] proves what was — and was not — materialised).
    PlanResult { result: Assoc, stats: PlanStats },
}

impl Response {
    /// Unwrap an assoc response; a typed
    /// [`D4mError::UnexpectedResponse`] on variant mismatch — a protocol
    /// bug, distinguishable from a server-reported bad argument.
    pub fn into_assoc(self) -> Result<Assoc> {
        match self {
            Response::Assoc(a) => Ok(a),
            other => Err(D4mError::UnexpectedResponse {
                expected: "Assoc".into(),
                got: other.variant_name().into(),
            }),
        }
    }

    /// Short variant tag for error messages (the payloads can be huge —
    /// never Debug-print them into an error string). Also used by the
    /// remote client's response-shape checks.
    pub(crate) fn variant_name(&self) -> &'static str {
        match self {
            Response::Ok => "Ok",
            Response::Tables(_) => "Tables",
            Response::Ingested(_) => "Ingested",
            Response::Assoc(_) => "Assoc",
            Response::Distances(_) => "Distances",
            Response::Ranks(_) => "Ranks",
            Response::MultStats(_) => "MultStats",
            Response::PlanResult { .. } => "PlanResult",
        }
    }
}

/// The coordinator.
pub struct D4mServer {
    acc: AccumuloConnector,
    /// Bound tables, as engine-generic trait objects.
    tables: Mutex<HashMap<String, Arc<dyn DbTable>>>,
    engine: Option<DenseEngine>,
    /// Per-op latency histograms, keyed by op name.
    op_stats: Mutex<HashMap<&'static str, Arc<Histogram>>>,
    requests: RateMeter,
    /// Live scan cursors (bounded, owned, TTL-evicted — see [`cursor`]).
    cursors: cursor::CursorTable,
}

impl D4mServer {
    /// Start a coordinator with a fresh embedded store and the native
    /// dense engine attached.
    pub fn new() -> Self {
        D4mServer::with_engine(Some(DenseEngine::new()))
    }

    pub fn with_engine(engine: Option<DenseEngine>) -> Self {
        D4mServer {
            acc: AccumuloConnector::new(),
            tables: Mutex::new(HashMap::new()),
            engine,
            op_stats: Mutex::new(HashMap::new()),
            requests: RateMeter::new(),
            cursors: cursor::CursorTable::new(),
        }
    }

    /// Start a coordinator over an existing store — typically a durable
    /// one from [`KvStore::open`]. Tables recovered from disk are
    /// re-bound into the registry (bindings are not persisted, table
    /// contents are): every non-companion table becomes a D4M binding
    /// whose transpose/degree flags mirror which `_T`/`_Deg` companions
    /// survived, so queries and cursors work immediately after restart.
    pub fn with_store(store: Arc<KvStore>) -> Result<Self> {
        let s = D4mServer {
            acc: AccumuloConnector::with_store(store),
            tables: Mutex::new(HashMap::new()),
            engine: Some(DenseEngine::new()),
            op_stats: Mutex::new(HashMap::new()),
            requests: RateMeter::new(),
            cursors: cursor::CursorTable::new(),
        };
        s.rebind_recovered()?;
        Ok(s)
    }

    fn rebind_recovered(&self) -> Result<()> {
        let store = self.acc.store();
        for name in store.list_tables() {
            // companions are reached through their base binding
            let is_companion = ["_T", "_Deg"].iter().any(|suffix| {
                name.strip_suffix(suffix)
                    .map(|base| !base.is_empty() && store.table(base).is_some())
                    .unwrap_or(false)
            });
            if is_companion {
                continue;
            }
            let cfg = D4mTableConfig {
                transpose: store.table(&format!("{name}_T")).is_some(),
                degrees: store.table(&format!("{name}_Deg")).is_some(),
                ..Default::default()
            };
            let t: Arc<dyn DbTable> = Arc::new(self.acc.bind(&name, &cfg)?);
            self.tables.lock().unwrap().insert(name, t);
        }
        Ok(())
    }

    /// Flush every memtable into on-disk runs and fsync the WALs (plain
    /// in-memory flush for non-durable stores). The graceful-shutdown
    /// hook: the net server calls this before acknowledging `Shutdown`,
    /// so an acked shutdown implies nothing is left only in RAM.
    pub fn checkpoint(&self) -> Result<()> {
        self.acc.store().checkpoint()
    }

    pub fn store(&self) -> Arc<KvStore> {
        self.acc.store()
    }

    pub fn has_engine(&self) -> bool {
        self.engine.is_some()
    }

    pub fn engine(&self) -> Option<&DenseEngine> {
        self.engine.as_ref()
    }

    fn hist(&self, op: &'static str) -> Arc<Histogram> {
        self.op_stats
            .lock()
            .unwrap()
            .entry(op)
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// Bind a table on the resident key-value engine, registering the
    /// binding in the trait-object registry. Returns the concrete handle
    /// for the ingest pipeline (which needs the schema-fanout writer).
    fn bind_d4m(&self, name: &str, splits: Vec<String>) -> Result<Arc<D4mTable>> {
        let cfg = D4mTableConfig { splits, ..Default::default() };
        let t = Arc::new(self.acc.bind(name, &cfg)?);
        self.tables
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| {
                let dt: Arc<dyn DbTable> = t.clone();
                dt
            });
        Ok(t)
    }

    fn bound(&self, name: &str) -> Result<Arc<dyn DbTable>> {
        self.tables
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| D4mError::NotFound(format!("table {name} not bound")))
    }

    /// Native substrate table of a bound name (Graphulo operand).
    fn main_table(&self, name: &str) -> Result<Arc<Table>> {
        self.bound(name)?;
        self.acc.store().table_or_err(name)
    }

    /// Native degree table of a bound name.
    fn degree_table(&self, name: &str) -> Result<Arc<Table>> {
        self.bound(name)?;
        self.acc.store().table(&format!("{name}_Deg")).ok_or_else(|| {
            D4mError::InvalidArg(format!("table {name} has no degree table"))
        })
    }

    /// Serve one request.
    pub fn handle(&self, req: Request) -> Result<Response> {
        self.requests.add(1);
        match req {
            Request::CreateTable { name, splits } => {
                self.hist("create").time(|| self.bind_d4m(&name, splits))?;
                Ok(Response::Ok)
            }
            Request::Ingest { table, triples, pipeline } => {
                let t = self.bind_d4m(&table, vec![])?;
                let h = self.hist("ingest");
                let report =
                    h.time(|| IngestPipeline::new(t, pipeline).run(triples.into_iter()))?;
                Ok(Response::Ingested(report))
            }
            Request::Query { table, query } => {
                let t = self.bound(&table)?;
                let a = self.hist("query").time(|| t.query(&query))?;
                Ok(Response::Assoc(a))
            }
            Request::TableMult { a, b, dest, exec } => {
                let ta = self.main_table(&a)?;
                let tb = self.main_table(&b)?;
                match (dest, exec) {
                    (MultDest::Table { out }, ExecHint::Stream) => {
                        let store = self.acc.store();
                        let tc = store.ensure_table(&out, vec![])?;
                        let stats = self.hist("tablemult_server").time(|| {
                            graphulo::table_mult(&ta, &tb, &tc, &TableMultOpts::default())
                        })?;
                        Ok(Response::MultStats(stats))
                    }
                    (MultDest::Client, ExecHint::Memory { limit }) => {
                        let ctx = ClientCtx::with_limit(limit);
                        let c = self
                            .hist("tablemult_client")
                            .time(|| ctx.table_mult(&ta, &tb))?;
                        Ok(Response::Assoc(c))
                    }
                    (MultDest::Client, ExecHint::Dense { tile }) => {
                        let aa = ClientCtx::default().read_table(&ta)?;
                        let bb = ClientCtx::default().read_table(&tb)?;
                        let c = self.hist("tablemult_dense").time(|| {
                            crate::runtime::blocks::assoc_matmul_auto(
                                self.engine.as_ref(),
                                &aa,
                                &bb,
                                tile,
                            )
                        })?;
                        Ok(Response::Assoc(c))
                    }
                    (dest, exec) => Err(D4mError::InvalidArg(format!(
                        "unsupported TableMult combination: {dest:?} with {exec:?} \
                         (Table needs Stream; Client needs Memory or Dense)"
                    ))),
                }
            }
            Request::Plan { ops } => {
                let (result, stats) =
                    self.hist("plan").time(|| self.execute_plan(&ops))?;
                Ok(Response::PlanResult { result, stats })
            }
            Request::Bfs { table, seeds, hops } => {
                let t = self.main_table(&table)?;
                let d = self.hist("bfs").time(|| graphulo::bfs_server(&t, &seeds, hops));
                Ok(Response::Distances(d))
            }
            Request::Jaccard { table, out } => {
                let t = self.main_table(&table)?;
                let deg = self.degree_table(&table)?;
                let store = self.acc.store();
                let a = self
                    .hist("jaccard")
                    .time(|| graphulo::jaccard_server(&store, &t, &deg, &out))?;
                Ok(Response::Assoc(a))
            }
            Request::KTruss { table, k } => {
                let t = self.main_table(&table)?;
                let store = self.acc.store();
                let a = self.hist("ktruss").time(|| -> Result<Assoc> {
                    let sym =
                        graphulo::symmetrise_table(&store, &t, &format!("{table}_sym"))?;
                    graphulo::ktruss_server(&store, &sym, k, &format!("{table}_kt"))
                })?;
                Ok(Response::Assoc(a))
            }
            Request::PageRank { table, opts } => {
                let t = self.main_table(&table)?;
                let r = self.hist("pagerank").time(|| graphulo::pagerank_server(&t, &opts));
                Ok(Response::Ranks(r))
            }
            Request::ListTables => Ok(Response::Tables(self.acc.store().list_tables())),
        }
    }

    // ------------------------------------------------------------------
    // scan cursors (the owned variants; the `D4mApi` impl below uses
    // `LOCAL_OWNER`, the network server one owner id per connection)

    /// Configure the cursor table: cap on simultaneously open cursors and
    /// the idle TTL after which an untouched cursor is evicted.
    pub fn set_cursor_limits(&self, cap: usize, idle_ttl: Duration) {
        self.cursors.configure(cap, idle_ttl);
    }

    /// Configure the resume-grace window: how long a disconnected
    /// owner's cursors stay resumable before the sweep drops them.
    pub fn set_cursor_grace(&self, grace: Duration) {
        self.cursors.set_resume_grace(grace);
    }

    /// How many cursors are currently open (all owners, including
    /// orphans inside their resume-grace window — their snapshots are
    /// still pinned).
    pub fn open_cursor_count(&self) -> usize {
        self.cursors.len()
    }

    /// Sweep expired cursors (idle TTL + orphan grace) now; returns how
    /// many were dropped. The network server calls this on a timer so
    /// eviction doesn't depend on cursor traffic.
    pub fn sweep_cursors(&self) -> usize {
        self.cursors.sweep()
    }

    /// Open a cursor owned by `owner` (see [`cursor`] for the ownership,
    /// cap, TTL and resume rules). Pins a snapshot stream over the bound
    /// table. Returns `(cursor id, resume token)`.
    pub fn open_cursor_owned(
        &self,
        owner: u64,
        table: &str,
        query: &TableQuery,
        page_entries: usize,
    ) -> Result<(u64, u64)> {
        self.requests.add(1);
        let t = self.bound(table)?;
        self.hist("cursor_open").time(|| {
            let stream = t.scan_triples(query)?;
            self.cursors.open(owner, page_entries, stream)
        })
    }

    /// Execute a plan and page its result through the cursor machinery
    /// instead of returning it in one response: the plan runs to
    /// completion server-side (same fusion path as [`Request::Plan`]),
    /// then the result's triples stream out as a normal owned cursor —
    /// resume tokens, ownership, caps and TTL all apply unchanged.
    /// Returns `(cursor id, resume token)`.
    pub fn open_plan_cursor_owned(
        &self,
        owner: u64,
        ops: &[PlanOp],
        page_entries: usize,
    ) -> Result<(u64, u64)> {
        self.requests.add(1);
        let (result, _stats) = self.hist("plan").time(|| self.execute_plan(ops))?;
        self.hist("cursor_open").time(|| {
            let stream: crate::connectors::TripleStream =
                Box::new(result.str_triples().into_iter().map(Ok));
            self.cursors.open(owner, page_entries, stream)
        })
    }

    /// Re-attach an existing cursor to `owner` after a reconnect (see
    /// [`cursor::CursorTable::resume`]). Returns `(cursor id, token)` —
    /// the same values issued at open.
    pub fn resume_cursor_owned(&self, owner: u64, resume: &CursorResume) -> Result<(u64, u64)> {
        self.requests.add(1);
        self.hist("cursor_resume").time(|| self.cursors.resume(owner, resume))
    }

    /// Pull the next page of a cursor owned by `owner`.
    pub fn cursor_next_owned(&self, owner: u64, id: u64) -> Result<cursor::CursorPage> {
        self.requests.add(1);
        self.hist("cursor_next").time(|| self.cursors.next(owner, id))
    }

    /// Close a cursor owned by `owner` (idempotent).
    pub fn cursor_close_owned(&self, owner: u64, id: u64) -> Result<()> {
        self.requests.add(1);
        self.cursors.close(owner, id)
    }

    /// Drop every cursor belonging to `owner` immediately (no resume
    /// grace); returns how many were reaped.
    pub fn reap_cursors(&self, owner: u64) -> usize {
        self.cursors.reap_owner(owner)
    }

    /// Park every cursor belonging to `owner` for the resume-grace
    /// window (connection teardown on the network server: the client
    /// may reconnect and resume). Returns how many were parked; the
    /// sweep drops whatever is not resumed in time.
    pub fn orphan_cursors(&self, owner: u64) -> usize {
        self.cursors.orphan_owner(owner)
    }

    /// Metrics snapshots for every op seen so far. Rates come from each
    /// histogram's own first-to-last-sample span ([`Histogram::rate_per_sec`]),
    /// not the server-lifetime clock — an op exercised once at startup
    /// no longer reads as permanently slow.
    pub fn snapshots(&self) -> Vec<Snapshot> {
        let stats = self.op_stats.lock().unwrap();
        let mut out: Vec<Snapshot> = stats
            .iter()
            .map(|(op, h)| Snapshot {
                name: op.to_string(),
                count: h.count(),
                rate_per_sec: h.rate_per_sec(),
                mean_latency_ns: h.mean_ns(),
                p99_latency_ns: h.quantile_ns(0.99),
            })
            .collect();
        if let Some(c) = self.acc.store().storage_counters() {
            let storage = [
                (names::STORAGE_WAL_BYTES_APPENDED, c.wal_bytes_appended.get()),
                (names::STORAGE_WAL_FSYNCS, c.wal_fsyncs.get()),
                (names::STORAGE_FLUSHES, c.flushes.get()),
                (names::STORAGE_COMPACTIONS, c.compactions.get()),
                (names::STORAGE_BACKPRESSURE_STALLS, c.backpressure_stalls.get()),
            ];
            out.extend(storage.into_iter().map(|(name, count)| Snapshot {
                name: name.to_string(),
                count,
                rate_per_sec: 0.0,
                mean_latency_ns: 0.0,
                p99_latency_ns: 0,
            }));
        }
        let kc = crate::assoc::kernel::counters();
        let kernels = [
            (names::KERNELS_PARALLEL_OPS, kc.parallel_ops.get()),
            (names::KERNELS_SERIAL_OPS, kc.serial_ops.get()),
            (names::KERNELS_BLOCKED_ROWS, kc.blocked_rows.get()),
        ];
        out.extend(kernels.into_iter().map(|(name, count)| Snapshot {
            name: name.to_string(),
            count,
            rate_per_sec: 0.0,
            mean_latency_ns: 0.0,
            p99_latency_ns: 0,
        }));
        let pc = plan::counters();
        let plans = [
            (names::PLAN_OPS, pc.ops.get()),
            (names::PLAN_FUSED_SELECTS, pc.fused_selects.get()),
            (names::PLAN_FUSED_REDUCES, pc.fused_reduces.get()),
            (names::PLAN_INTERMEDIATES, pc.intermediates.get()),
        ];
        out.extend(plans.into_iter().map(|(name, count)| Snapshot {
            name: name.to_string(),
            count,
            rate_per_sec: 0.0,
            mean_latency_ns: 0.0,
            p99_latency_ns: 0,
        }));
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Requests per second over the server's lifetime (the global
    /// throughput meter; per-op rates live in [`D4mServer::snapshots`]).
    pub fn requests_per_sec(&self) -> f64 {
        self.requests.rate()
    }
}

impl Default for D4mServer {
    fn default() -> Self {
        Self::new()
    }
}

impl D4mApi for D4mServer {
    fn handle(&self, req: Request) -> Result<Response> {
        D4mServer::handle(self, req)
    }

    fn open_cursor(&self, table: &str, query: &TableQuery, page_entries: usize) -> Result<u64> {
        self.open_cursor_owned(cursor::LOCAL_OWNER, table, query, page_entries)
            .map(|(id, _token)| id)
    }

    fn open_plan_cursor(&self, ops: &[PlanOp], page_entries: usize) -> Result<u64> {
        self.open_plan_cursor_owned(cursor::LOCAL_OWNER, ops, page_entries)
            .map(|(id, _token)| id)
    }

    fn cursor_next(&self, id: u64) -> Result<cursor::CursorPage> {
        self.cursor_next_owned(cursor::LOCAL_OWNER, id)
    }

    fn cursor_close(&self, id: u64) -> Result<()> {
        self.cursor_close_owned(cursor::LOCAL_OWNER, id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc::KeySel;

    fn server_with_graph() -> D4mServer {
        let s = D4mServer::with_engine(None);
        let triples: Vec<TripleMsg> = vec![
            ("a".into(), "b".into(), "1".into()),
            ("b".into(), "c".into(), "1".into()),
            ("a".into(), "c".into(), "1".into()),
            ("c".into(), "d".into(), "1".into()),
        ];
        s.handle(Request::Ingest {
            table: "G".into(),
            triples,
            pipeline: PipelineConfig { num_workers: 2, ..Default::default() },
        })
        .unwrap();
        s
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn ingest_then_query() {
        let s = server_with_graph();
        let a = s
            .handle(Request::Query { table: "G".into(), query: TableQuery::all() })
            .unwrap()
            .into_assoc()
            .unwrap();
        assert_eq!(a.nnz(), 4);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn query_by_col_via_transpose() {
        let s = server_with_graph();
        let a = s
            .handle(Request::Query {
                table: "G".into(),
                query: TableQuery::all().cols(KeySel::keys(&["c"])),
            })
            .unwrap()
            .into_assoc()
            .unwrap();
        assert_eq!(a.nnz(), 2); // b->c and a->c
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn query_row_range_pushdown() {
        let s = server_with_graph();
        let a = s
            .handle(Request::Query {
                table: "G".into(),
                query: TableQuery::all().rows(KeySel::Range("a".into(), "b".into())),
            })
            .unwrap()
            .into_assoc()
            .unwrap();
        assert_eq!(a.nnz(), 3); // a->b, a->c, b->c
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn into_assoc_mismatch_is_typed_unexpected_response() {
        let s = server_with_graph();
        let r = s.handle(Request::ListTables).unwrap();
        match r.into_assoc() {
            Err(D4mError::UnexpectedResponse { expected, got }) => {
                assert_eq!(expected, "Assoc");
                assert_eq!(got, "Tables");
            }
            other => panic!("expected UnexpectedResponse, got {other:?}"),
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn server_tablemult_vs_client() {
        let s = server_with_graph();
        match s
            .handle(Request::TableMult {
                a: "G".into(),
                b: "G".into(),
                dest: MultDest::Table { out: "C".into() },
                exec: ExecHint::Stream,
            })
            .unwrap()
        {
            Response::MultStats(stats) => assert!(stats.partial_products > 0),
            other => panic!("unexpected {other:?}"),
        }
        let client = s
            .handle(Request::TableMult {
                a: "G".into(),
                b: "G".into(),
                dest: MultDest::Client,
                exec: ExecHint::Memory { limit: usize::MAX },
            })
            .unwrap()
            .into_assoc()
            .unwrap();
        let server = graphulo::read_product(&s.store().table("C").unwrap()).unwrap();
        assert_eq!(client.triples(), server.triples());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn client_memory_wall() {
        let s = server_with_graph();
        let r = s.handle(Request::TableMult {
            a: "G".into(),
            b: "G".into(),
            dest: MultDest::Client,
            exec: ExecHint::Memory { limit: 10 },
        });
        assert!(matches!(r, Err(D4mError::MemoryLimit { .. })));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn tablemult_rejects_unsupported_combinations() {
        let s = server_with_graph();
        // a table destination cannot be computed by the in-RAM paths,
        // and a client destination has no streaming accumulator
        let bad = [
            (MultDest::Table { out: "C".into() }, ExecHint::Memory { limit: 1 }),
            (MultDest::Table { out: "C".into() }, ExecHint::Dense { tile: 0 }),
            (MultDest::Client, ExecHint::Stream),
        ];
        for (dest, exec) in bad {
            let r = s.handle(Request::TableMult {
                a: "G".into(),
                b: "G".into(),
                dest,
                exec,
            });
            assert!(matches!(r, Err(D4mError::InvalidArg(_))), "accepted {r:?}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn tablemult_idempotency_follows_dest() {
        let mult = |dest: MultDest, exec: ExecHint| Request::TableMult {
            a: "G".into(),
            b: "G".into(),
            dest,
            exec,
        };
        assert!(!mult(MultDest::Table { out: "C".into() }, ExecHint::Stream).is_idempotent());
        assert!(mult(MultDest::Client, ExecHint::Memory { limit: 1 }).is_idempotent());
        assert!(mult(MultDest::Client, ExecHint::Dense { tile: 0 }).is_idempotent());
        // plans: read-only replayable, stores not
        let ro = crate::assoc::expr::Plan::table("G").sum(1).compile().unwrap();
        assert!(Request::Plan { ops: ro }.is_idempotent());
        let wr = crate::assoc::expr::Plan::table("G").store_into("X").compile().unwrap();
        assert!(!Request::Plan { ops: wr }.is_idempotent());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bfs_request() {
        let s = server_with_graph();
        match s
            .handle(Request::Bfs { table: "G".into(), seeds: vec!["a".into()], hops: 2 })
            .unwrap()
        {
            Response::Distances(d) => {
                assert_eq!(d.get("d"), Some(&2));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn jaccard_and_ktruss_requests() {
        let s = server_with_graph();
        let j = s
            .handle(Request::Jaccard { table: "G".into(), out: "J".into() })
            .unwrap()
            .into_assoc()
            .unwrap();
        assert!(!j.is_empty());
        let kt = s
            .handle(Request::KTruss { table: "G".into(), k: 3 })
            .unwrap()
            .into_assoc()
            .unwrap();
        // the a-b-c triangle survives
        assert_eq!(kt.get("a", "b"), 1.0);
        assert_eq!(kt.get("c", "d"), 0.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn unknown_table_errors() {
        let s = D4mServer::with_engine(None);
        assert!(s
            .handle(Request::Query { table: "nope".into(), query: TableQuery::all() })
            .is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn metrics_populate() {
        let s = server_with_graph();
        s.handle(Request::Query { table: "G".into(), query: TableQuery::all() }).unwrap();
        let snaps = s.snapshots();
        assert!(snaps.iter().any(|x| x.name == "ingest"));
        assert!(snaps.iter().any(|x| x.name == "query"));
    }

    // ------------------------------------------------------------------
    // cursor lifecycle (in-process; the remote twin lives in net_e2e)

    /// A server with a graph big enough to span several cursor pages.
    fn server_with_bigger_graph() -> D4mServer {
        let s = D4mServer::with_engine(None);
        let triples: Vec<TripleMsg> = (0..40)
            .map(|i| (format!("r{:02}", i % 10), format!("c{:02}", i / 10 * 3 + i % 3), "1".into()))
            .collect();
        s.handle(Request::Ingest {
            table: "G".into(),
            triples,
            pipeline: PipelineConfig { num_workers: 2, ..Default::default() },
        })
        .unwrap();
        s
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn scan_pages_bit_identical_to_query_across_page_boundaries() {
        let s = server_with_bigger_graph();
        let one_shot = D4mApi::query(&s, "G", TableQuery::all()).unwrap();
        assert!(one_shot.nnz() > 3, "graph too small to page");
        // page size 3 forces many page boundaries
        let mut pages = 0usize;
        let mut triples: Vec<TripleMsg> = Vec::new();
        for page in s.scan_pages("G", TableQuery::all(), 3) {
            let p = page.unwrap();
            assert!(p.len() <= 3, "page exceeded page_entries");
            pages += 1;
            triples.extend(p);
        }
        assert!(pages > 1, "expected multiple pages");
        let paged = crate::assoc::io::parse_triples(triples).unwrap();
        assert_eq!(paged, one_shot, "paged scan diverged from one-shot query");
        assert_eq!(paged.matrix(), one_shot.matrix());
        // into_assoc takes the same path
        let again = s.scan_pages("G", TableQuery::all(), 3).into_assoc().unwrap();
        assert_eq!(again, one_shot);
        // drained cursors freed themselves
        assert_eq!(s.open_cursor_count(), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn scan_pages_honours_selectors_and_limit() {
        let s = server_with_bigger_graph();
        let q = TableQuery::all().rows(KeySel::Prefix("r0".into())).limit(5);
        let want = D4mApi::query(&s, "G", q.clone()).unwrap();
        let got = s.scan_pages("G", q, 2).into_assoc().unwrap();
        assert_eq!(got, want);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn cursor_close_releases_snapshot_and_isolates_from_writes() {
        let s = server_with_graph();
        let id = s.open_cursor("G", &TableQuery::all(), 2).unwrap();
        assert_eq!(s.open_cursor_count(), 1);
        // writes after open are invisible to the pinned snapshot...
        s.handle(Request::Ingest {
            table: "G".into(),
            triples: vec![("zz".into(), "zz".into(), "1".into())],
            pipeline: PipelineConfig { num_workers: 1, ..Default::default() },
        })
        .unwrap();
        let mut seen = 0usize;
        loop {
            let p = s.cursor_next(id).unwrap();
            seen += p.triples.len();
            assert!(!p.triples.iter().any(|(r, _, _)| r == "zz"), "snapshot leaked a new write");
            if p.done {
                break;
            }
        }
        assert_eq!(seen, 4, "cursor should see exactly the snapshot's 4 edges");
        // a drained cursor keeps its handle (for resume replay) but the
        // snapshot is released; close frees the handle
        assert_eq!(s.open_cursor_count(), 1);
        s.cursor_close(id).unwrap();
        assert_eq!(s.open_cursor_count(), 0);
        // ...while a fresh cursor sees them
        let id2 = s.open_cursor("G", &TableQuery::all(), 100).unwrap();
        let p = s.cursor_next(id2).unwrap();
        assert!(p.triples.iter().any(|(r, _, _)| r == "zz"));
        assert!(p.done);
        s.cursor_close(id2).unwrap();
        // explicit close releases; double close is idempotent
        let id3 = s.open_cursor("G", &TableQuery::all(), 1).unwrap();
        assert_eq!(s.open_cursor_count(), 1);
        s.cursor_close(id3).unwrap();
        assert_eq!(s.open_cursor_count(), 0);
        s.cursor_close(id3).unwrap();
        // a closed cursor is gone
        assert!(matches!(s.cursor_next(id3), Err(D4mError::NotFound(_))));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn cursor_cap_rejects_excess_opens() {
        let s = server_with_graph();
        s.set_cursor_limits(2, Duration::from_secs(300));
        let a = s.open_cursor("G", &TableQuery::all(), 1).unwrap();
        let _b = s.open_cursor("G", &TableQuery::all(), 1).unwrap();
        // a saturated cursor table sheds with a typed retry hint — the
        // self-healing client backs off and retries instead of failing
        match s.open_cursor("G", &TableQuery::all(), 1) {
            Err(D4mError::Overloaded { retry_after_ms }) => assert!(retry_after_ms > 0),
            other => panic!("expected the cap to shed with Overloaded, got {other:?}"),
        }
        // closing one frees a slot
        s.cursor_close(a).unwrap();
        s.open_cursor("G", &TableQuery::all(), 1).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn cursor_idle_ttl_evicts() {
        let s = server_with_graph();
        s.set_cursor_limits(8, Duration::from_millis(20));
        let id = s.open_cursor("G", &TableQuery::all(), 1).unwrap();
        assert_eq!(s.open_cursor_count(), 1);
        std::thread::sleep(Duration::from_millis(60));
        // any cursor op sweeps: the expired cursor is gone
        assert!(matches!(s.cursor_next(id), Err(D4mError::NotFound(_))));
        assert_eq!(s.open_cursor_count(), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn cursor_ownership_is_enforced_and_reaped() {
        let s = server_with_graph();
        let (id, _token) = s.open_cursor_owned(7, "G", &TableQuery::all(), 2).unwrap();
        // another owner can neither read nor close it
        assert!(matches!(s.cursor_next_owned(8, id), Err(D4mError::NotFound(_))));
        s.cursor_close_owned(8, id).unwrap(); // idempotent no-op for non-owners
        assert_eq!(s.open_cursor_count(), 1);
        // the owner's teardown reaps it
        assert_eq!(s.reap_cursors(7), 1);
        assert_eq!(s.open_cursor_count(), 0);
    }

    // ------------------------------------------------------------------
    // cursor resume (the reconnect story; the over-TCP twin lives in
    // the chaos e2e suite)

    #[test]
    #[cfg_attr(miri, ignore)]
    fn cursor_resume_continues_bit_identically() {
        let s = server_with_bigger_graph();
        let one_shot = D4mApi::query(&s, "G", TableQuery::all()).unwrap();
        let (id, token) = s.open_cursor_owned(7, "G", &TableQuery::all(), 3).unwrap();
        // owner 7 pulls two pages, acks both, then "disconnects"
        let mut triples: Vec<TripleMsg> = Vec::new();
        for _ in 0..2 {
            let p = s.cursor_next_owned(7, id).unwrap();
            assert!(!p.done, "graph too small");
            triples.extend(p.triples);
        }
        assert_eq!(s.orphan_cursors(7), 1);
        // a new connection (owner 9) resumes with the token and drains
        let resume = CursorResume { cursor: id, token, pages_acked: 2 };
        let (rid, _) = s.resume_cursor_owned(9, &resume).unwrap();
        assert_eq!(rid, id, "resume must re-attach the same cursor id");
        loop {
            let p = s.cursor_next_owned(9, id).unwrap();
            triples.extend(p.triples);
            if p.done {
                break;
            }
        }
        s.cursor_close_owned(9, id).unwrap();
        let resumed = crate::assoc::io::parse_triples(triples).unwrap();
        assert_eq!(resumed, one_shot, "resumed scan diverged from one-shot query");
        assert_eq!(s.open_cursor_count(), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn cursor_resume_replays_a_lost_page() {
        let s = server_with_bigger_graph();
        let one_shot = D4mApi::query(&s, "G", TableQuery::all()).unwrap();
        let (id, token) = s.open_cursor_owned(7, "G", &TableQuery::all(), 3).unwrap();
        let first = s.cursor_next_owned(7, id).unwrap();
        // second page is pulled server-side but the reply is "lost":
        // the client never acks it
        let lost = s.cursor_next_owned(7, id).unwrap();
        s.orphan_cursors(7);
        let resume = CursorResume { cursor: id, token, pages_acked: 1 };
        s.resume_cursor_owned(9, &resume).unwrap();
        // the next pull replays the lost page verbatim
        let replayed = s.cursor_next_owned(9, id).unwrap();
        assert_eq!(replayed, lost, "replay must be the buffered page, bit-identical");
        let mut triples = first.triples;
        triples.extend(replayed.triples);
        loop {
            let p = s.cursor_next_owned(9, id).unwrap();
            triples.extend(p.triples);
            if p.done {
                break;
            }
        }
        s.cursor_close_owned(9, id).unwrap();
        let resumed = crate::assoc::io::parse_triples(triples).unwrap();
        assert_eq!(resumed, one_shot);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn cursor_resume_replays_a_lost_done_page() {
        let s = server_with_graph();
        let (id, token) = s.open_cursor_owned(7, "G", &TableQuery::all(), 100).unwrap();
        let done_page = s.cursor_next_owned(7, id).unwrap();
        assert!(done_page.done);
        // the done reply is lost; the cursor handle must survive (the
        // snapshot itself is already released) so the resume can replay
        s.orphan_cursors(7);
        let resume = CursorResume { cursor: id, token, pages_acked: 0 };
        s.resume_cursor_owned(9, &resume).unwrap();
        let replayed = s.cursor_next_owned(9, id).unwrap();
        assert_eq!(replayed, done_page);
        s.cursor_close_owned(9, id).unwrap();
        assert_eq!(s.open_cursor_count(), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn cursor_resume_rejects_bad_token_and_gaps() {
        let s = server_with_bigger_graph();
        let (id, token) = s.open_cursor_owned(7, "G", &TableQuery::all(), 3).unwrap();
        s.cursor_next_owned(7, id).unwrap();
        s.orphan_cursors(7);
        // wrong token: NotFound, revealing nothing
        let bad = CursorResume { cursor: id, token: token.wrapping_add(1), pages_acked: 1 };
        assert!(matches!(s.resume_cursor_owned(9, &bad), Err(D4mError::NotFound(_))));
        // acked more pages than served: protocol error
        let gap = CursorResume { cursor: id, token, pages_acked: 5 };
        assert!(matches!(s.resume_cursor_owned(9, &gap), Err(D4mError::InvalidArg(_))));
        // acked too few (more than one page behind): protocol error —
        // the server only buffers the last page
        let (id2, token2) = s.open_cursor_owned(7, "G", &TableQuery::all(), 2).unwrap();
        s.cursor_next_owned(7, id2).unwrap();
        s.cursor_next_owned(7, id2).unwrap();
        s.cursor_next_owned(7, id2).unwrap();
        s.orphan_cursors(7);
        let gap2 = CursorResume { cursor: id2, token: token2, pages_acked: 1 };
        assert!(matches!(s.resume_cursor_owned(9, &gap2), Err(D4mError::InvalidArg(_))));
        // a valid resume still works after the failed attempts
        let ok = CursorResume { cursor: id, token, pages_acked: 1 };
        s.resume_cursor_owned(9, &ok).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn orphaned_cursors_expire_after_grace() {
        let s = server_with_graph();
        s.set_cursor_grace(Duration::from_millis(20));
        let (id, token) = s.open_cursor_owned(7, "G", &TableQuery::all(), 2).unwrap();
        assert_eq!(s.orphan_cursors(7), 1);
        // inside the grace window the cursor still counts (snapshot
        // pinned) and is resumable
        assert_eq!(s.open_cursor_count(), 1);
        std::thread::sleep(Duration::from_millis(60));
        // past the deadline the sweep drops it — no cursor traffic needed
        assert_eq!(s.sweep_cursors(), 1);
        assert_eq!(s.open_cursor_count(), 0);
        let resume = CursorResume { cursor: id, token, pages_acked: 0 };
        assert!(matches!(s.resume_cursor_owned(9, &resume), Err(D4mError::NotFound(_))));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn reap_is_immediate_but_orphan_keeps_resumable() {
        let s = server_with_graph();
        let (_id, _) = s.open_cursor_owned(7, "G", &TableQuery::all(), 2).unwrap();
        let (id2, token2) = s.open_cursor_owned(8, "G", &TableQuery::all(), 2).unwrap();
        // reap drops owner 7's cursor with no grace
        assert_eq!(s.reap_cursors(7), 1);
        assert_eq!(s.open_cursor_count(), 1);
        // orphan parks owner 8's cursor; it resumes fine within grace
        assert_eq!(s.orphan_cursors(8), 1);
        let resume = CursorResume { cursor: id2, token: token2, pages_acked: 0 };
        let (rid, _) = s.resume_cursor_owned(9, &resume).unwrap();
        assert_eq!(rid, id2);
        // ...and the old owner can no longer touch it
        assert!(matches!(s.cursor_next_owned(8, id2), Err(D4mError::NotFound(_))));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn open_cursor_unknown_table_is_not_found() {
        let s = D4mServer::with_engine(None);
        assert!(matches!(
            s.open_cursor("nope", &TableQuery::all(), 8),
            Err(D4mError::NotFound(_))
        ));
    }
}
