//! The D4M coordinator — the L3 server tying everything together: a
//! table registry over the engines, a typed request/response API, an
//! ingest batcher, and per-op metrics. `main.rs` exposes it as a CLI;
//! [`D4mServer::handle`] is the single entry point a network front-end
//! would call.
//!
//! The registry holds [`DbTable`] **trait objects**, so the query path is
//! engine-generic: `Request::Query` carries a [`TableQuery`] whose
//! selectors are pushed down by whichever engine owns the binding. The
//! Graphulo requests (TableMult/BFS/Jaccard/k-truss/PageRank) are
//! in-database algorithms of the key-value substrate and keep their
//! native Accumulo handles — they are server-side iterators, not
//! put/get/query dispatch.

pub mod batcher;

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::assoc::Assoc;
use crate::connectors::{AccumuloConnector, D4mTable, D4mTableConfig, DbTable, TableQuery};
use crate::error::{D4mError, Result};
use crate::graphulo::{self, ClientCtx, TableMultOpts};
use crate::kvstore::{KvStore, Table};
use crate::metrics::{Histogram, RateMeter, Snapshot};
use crate::pipeline::{IngestPipeline, IngestReport, PipelineConfig, TripleMsg};
use crate::runtime::PjrtEngine;

/// Requests the coordinator serves.
///
/// `Request` and [`Response`] derive `Debug`/`Clone`/`PartialEq` so the
/// network codec (`net::wire`) can be property-tested by round-trip
/// equality, and so callers can replay a request verbatim.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Bind (create if needed) a D4M table.
    CreateTable { name: String, splits: Vec<String> },
    /// Ingest triples through the parallel pipeline.
    Ingest { table: String, triples: Vec<TripleMsg>, pipeline: PipelineConfig },
    /// The unified `T(r, c)` query: row/col selectors + limit, pushed
    /// down through the table's [`DbTable`] binding (column selectors
    /// route through the transpose table on the key-value engine).
    Query { table: String, query: TableQuery },
    /// Server-side Graphulo TableMult: `out += A^T B`.
    TableMult { a: String, b: String, out: String },
    /// Client-side D4M TableMult with a RAM budget.
    TableMultClient { a: String, b: String, memory_limit: usize },
    /// Client-side TableMult routed through the PJRT dense path.
    TableMultDense { a: String, b: String, tile: usize },
    /// Server-side BFS.
    Bfs { table: String, seeds: Vec<String>, hops: usize },
    /// Server-side Jaccard into table `out`.
    Jaccard { table: String, out: String },
    /// Server-side k-truss.
    KTruss { table: String, k: usize },
    /// Server-side PageRank (power iteration over table scans).
    PageRank { table: String, opts: graphulo::PageRankOpts },
    /// List tables.
    ListTables,
}

/// Responses.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok,
    Tables(Vec<String>),
    Ingested(IngestReport),
    Assoc(Assoc),
    Distances(BTreeMap<String, usize>),
    Ranks(graphulo::PageRankResult),
    MultStats(graphulo::TableMultStats),
}

impl Response {
    /// Unwrap an assoc response; a typed error on variant mismatch.
    pub fn into_assoc(self) -> Result<Assoc> {
        match self {
            Response::Assoc(a) => Ok(a),
            other => Err(D4mError::InvalidArg(format!(
                "expected Assoc response, got {}",
                other.variant_name()
            ))),
        }
    }

    /// Short variant tag for error messages (the payloads can be huge —
    /// never Debug-print them into an error string). Also used by the
    /// remote client's response-shape checks.
    pub(crate) fn variant_name(&self) -> &'static str {
        match self {
            Response::Ok => "Ok",
            Response::Tables(_) => "Tables",
            Response::Ingested(_) => "Ingested",
            Response::Assoc(_) => "Assoc",
            Response::Distances(_) => "Distances",
            Response::Ranks(_) => "Ranks",
            Response::MultStats(_) => "MultStats",
        }
    }
}

/// The coordinator.
pub struct D4mServer {
    acc: AccumuloConnector,
    /// Bound tables, as engine-generic trait objects.
    tables: Mutex<HashMap<String, Arc<dyn DbTable>>>,
    engine: Option<PjrtEngine>,
    /// Per-op latency histograms, keyed by op name.
    op_stats: Mutex<HashMap<&'static str, Arc<Histogram>>>,
    requests: RateMeter,
}

impl D4mServer {
    /// Start a coordinator with a fresh embedded store; tries to attach
    /// the PJRT engine (optional — dense ops degrade to CSR without it).
    pub fn new() -> Self {
        D4mServer::with_engine(PjrtEngine::new(PjrtEngine::default_dir()).ok())
    }

    pub fn with_engine(engine: Option<PjrtEngine>) -> Self {
        D4mServer {
            acc: AccumuloConnector::new(),
            tables: Mutex::new(HashMap::new()),
            engine,
            op_stats: Mutex::new(HashMap::new()),
            requests: RateMeter::new(),
        }
    }

    pub fn store(&self) -> Arc<KvStore> {
        self.acc.store()
    }

    pub fn has_engine(&self) -> bool {
        self.engine.is_some()
    }

    pub fn engine(&self) -> Option<&PjrtEngine> {
        self.engine.as_ref()
    }

    fn hist(&self, op: &'static str) -> Arc<Histogram> {
        self.op_stats
            .lock()
            .unwrap()
            .entry(op)
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// Bind a table on the resident key-value engine, registering the
    /// binding in the trait-object registry. Returns the concrete handle
    /// for the ingest pipeline (which needs the schema-fanout writer).
    fn bind_d4m(&self, name: &str, splits: Vec<String>) -> Result<Arc<D4mTable>> {
        let cfg = D4mTableConfig { splits, ..Default::default() };
        let t = Arc::new(self.acc.bind(name, &cfg)?);
        self.tables
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| {
                let dt: Arc<dyn DbTable> = t.clone();
                dt
            });
        Ok(t)
    }

    fn bound(&self, name: &str) -> Result<Arc<dyn DbTable>> {
        self.tables
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| D4mError::NotFound(format!("table {name} not bound")))
    }

    /// Native substrate table of a bound name (Graphulo operand).
    fn main_table(&self, name: &str) -> Result<Arc<Table>> {
        self.bound(name)?;
        self.acc.store().table_or_err(name)
    }

    /// Native degree table of a bound name.
    fn degree_table(&self, name: &str) -> Result<Arc<Table>> {
        self.bound(name)?;
        self.acc.store().table(&format!("{name}_Deg")).ok_or_else(|| {
            D4mError::InvalidArg(format!("table {name} has no degree table"))
        })
    }

    /// Serve one request.
    pub fn handle(&self, req: Request) -> Result<Response> {
        self.requests.add(1);
        match req {
            Request::CreateTable { name, splits } => {
                self.hist("create").time(|| self.bind_d4m(&name, splits))?;
                Ok(Response::Ok)
            }
            Request::Ingest { table, triples, pipeline } => {
                let t = self.bind_d4m(&table, vec![])?;
                let h = self.hist("ingest");
                let report =
                    h.time(|| IngestPipeline::new(t, pipeline).run(triples.into_iter()))?;
                Ok(Response::Ingested(report))
            }
            Request::Query { table, query } => {
                let t = self.bound(&table)?;
                let a = self.hist("query").time(|| t.query(&query))?;
                Ok(Response::Assoc(a))
            }
            Request::TableMult { a, b, out } => {
                let ta = self.main_table(&a)?;
                let tb = self.main_table(&b)?;
                let store = self.acc.store();
                let tc = store.ensure_table(&out, vec![]);
                let stats = self.hist("tablemult_server").time(|| {
                    graphulo::table_mult(&ta, &tb, &tc, &TableMultOpts::default())
                })?;
                Ok(Response::MultStats(stats))
            }
            Request::TableMultClient { a, b, memory_limit } => {
                let ta = self.main_table(&a)?;
                let tb = self.main_table(&b)?;
                let ctx = ClientCtx::with_limit(memory_limit);
                let c = self
                    .hist("tablemult_client")
                    .time(|| ctx.table_mult(&ta, &tb))?;
                Ok(Response::Assoc(c))
            }
            Request::TableMultDense { a, b, tile } => {
                let ta = self.main_table(&a)?;
                let tb = self.main_table(&b)?;
                let aa = ClientCtx::default().read_table(&ta)?;
                let bb = ClientCtx::default().read_table(&tb)?;
                let c = self.hist("tablemult_dense").time(|| {
                    crate::runtime::blocks::assoc_matmul_auto(self.engine.as_ref(), &aa, &bb, tile)
                })?;
                Ok(Response::Assoc(c))
            }
            Request::Bfs { table, seeds, hops } => {
                let t = self.main_table(&table)?;
                let d = self.hist("bfs").time(|| graphulo::bfs_server(&t, &seeds, hops));
                Ok(Response::Distances(d))
            }
            Request::Jaccard { table, out } => {
                let t = self.main_table(&table)?;
                let deg = self.degree_table(&table)?;
                let store = self.acc.store();
                let a = self
                    .hist("jaccard")
                    .time(|| graphulo::jaccard_server(&store, &t, &deg, &out))?;
                Ok(Response::Assoc(a))
            }
            Request::KTruss { table, k } => {
                let t = self.main_table(&table)?;
                let store = self.acc.store();
                let a = self.hist("ktruss").time(|| -> Result<Assoc> {
                    let sym =
                        graphulo::symmetrise_table(&store, &t, &format!("{table}_sym"))?;
                    graphulo::ktruss_server(&store, &sym, k, &format!("{table}_kt"))
                })?;
                Ok(Response::Assoc(a))
            }
            Request::PageRank { table, opts } => {
                let t = self.main_table(&table)?;
                let r = self.hist("pagerank").time(|| graphulo::pagerank_server(&t, &opts));
                Ok(Response::Ranks(r))
            }
            Request::ListTables => Ok(Response::Tables(self.acc.store().list_tables())),
        }
    }

    /// Metrics snapshots for every op seen so far. Rates come from each
    /// histogram's own first-to-last-sample span ([`Histogram::rate_per_sec`]),
    /// not the server-lifetime clock — an op exercised once at startup
    /// no longer reads as permanently slow.
    pub fn snapshots(&self) -> Vec<Snapshot> {
        let stats = self.op_stats.lock().unwrap();
        let mut out: Vec<Snapshot> = stats
            .iter()
            .map(|(op, h)| Snapshot {
                name: op.to_string(),
                count: h.count(),
                rate_per_sec: h.rate_per_sec(),
                mean_latency_ns: h.mean_ns(),
                p99_latency_ns: h.quantile_ns(0.99),
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Requests per second over the server's lifetime (the global
    /// throughput meter; per-op rates live in [`D4mServer::snapshots`]).
    pub fn requests_per_sec(&self) -> f64 {
        self.requests.rate()
    }
}

impl Default for D4mServer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assoc::KeySel;

    fn server_with_graph() -> D4mServer {
        let s = D4mServer::with_engine(None);
        let triples: Vec<TripleMsg> = vec![
            ("a".into(), "b".into(), "1".into()),
            ("b".into(), "c".into(), "1".into()),
            ("a".into(), "c".into(), "1".into()),
            ("c".into(), "d".into(), "1".into()),
        ];
        s.handle(Request::Ingest {
            table: "G".into(),
            triples,
            pipeline: PipelineConfig { num_workers: 2, ..Default::default() },
        })
        .unwrap();
        s
    }

    #[test]
    fn ingest_then_query() {
        let s = server_with_graph();
        let a = s
            .handle(Request::Query { table: "G".into(), query: TableQuery::all() })
            .unwrap()
            .into_assoc()
            .unwrap();
        assert_eq!(a.nnz(), 4);
    }

    #[test]
    fn query_by_col_via_transpose() {
        let s = server_with_graph();
        let a = s
            .handle(Request::Query {
                table: "G".into(),
                query: TableQuery::all().cols(KeySel::keys(&["c"])),
            })
            .unwrap()
            .into_assoc()
            .unwrap();
        assert_eq!(a.nnz(), 2); // b->c and a->c
    }

    #[test]
    fn query_row_range_pushdown() {
        let s = server_with_graph();
        let a = s
            .handle(Request::Query {
                table: "G".into(),
                query: TableQuery::all().rows(KeySel::Range("a".into(), "b".into())),
            })
            .unwrap()
            .into_assoc()
            .unwrap();
        assert_eq!(a.nnz(), 3); // a->b, a->c, b->c
    }

    #[test]
    fn into_assoc_mismatch_is_error_not_panic() {
        let s = server_with_graph();
        let r = s.handle(Request::ListTables).unwrap();
        assert!(matches!(r.into_assoc(), Err(D4mError::InvalidArg(_))));
    }

    #[test]
    fn server_tablemult_vs_client() {
        let s = server_with_graph();
        match s
            .handle(Request::TableMult { a: "G".into(), b: "G".into(), out: "C".into() })
            .unwrap()
        {
            Response::MultStats(stats) => assert!(stats.partial_products > 0),
            other => panic!("unexpected {other:?}"),
        }
        let client = s
            .handle(Request::TableMultClient {
                a: "G".into(),
                b: "G".into(),
                memory_limit: usize::MAX,
            })
            .unwrap()
            .into_assoc()
            .unwrap();
        let server = graphulo::read_product(&s.store().table("C").unwrap()).unwrap();
        assert_eq!(client.triples(), server.triples());
    }

    #[test]
    fn client_memory_wall() {
        let s = server_with_graph();
        let r = s.handle(Request::TableMultClient {
            a: "G".into(),
            b: "G".into(),
            memory_limit: 10,
        });
        assert!(matches!(r, Err(D4mError::MemoryLimit { .. })));
    }

    #[test]
    fn bfs_request() {
        let s = server_with_graph();
        match s
            .handle(Request::Bfs { table: "G".into(), seeds: vec!["a".into()], hops: 2 })
            .unwrap()
        {
            Response::Distances(d) => {
                assert_eq!(d.get("d"), Some(&2));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn jaccard_and_ktruss_requests() {
        let s = server_with_graph();
        let j = s
            .handle(Request::Jaccard { table: "G".into(), out: "J".into() })
            .unwrap()
            .into_assoc()
            .unwrap();
        assert!(!j.is_empty());
        let kt = s
            .handle(Request::KTruss { table: "G".into(), k: 3 })
            .unwrap()
            .into_assoc()
            .unwrap();
        // the a-b-c triangle survives
        assert_eq!(kt.get("a", "b"), 1.0);
        assert_eq!(kt.get("c", "d"), 0.0);
    }

    #[test]
    fn unknown_table_errors() {
        let s = D4mServer::with_engine(None);
        assert!(s
            .handle(Request::Query { table: "nope".into(), query: TableQuery::all() })
            .is_err());
    }

    #[test]
    fn metrics_populate() {
        let s = server_with_graph();
        s.handle(Request::Query { table: "G".into(), query: TableQuery::all() }).unwrap();
        let snaps = s.snapshots();
        assert!(snaps.iter().any(|x| x.name == "ingest"));
        assert!(snaps.iter().any(|x| x.name == "query"));
    }
}
