//! The session-oriented coordinator API — one object-safe trait,
//! [`D4mApi`], implemented by both the in-process
//! [`D4mServer`](super::D4mServer) and the remote
//! [`RemoteD4m`](crate::net::RemoteD4m), so every call site (CLI,
//! examples, tests, benches) programs against the trait and goes remote
//! by swapping a constructor:
//!
//! ```text
//! let api: &dyn D4mApi = &D4mServer::new();           // in-process
//! let api: &dyn D4mApi = &RemoteD4m::connect(addr)?;  // remote
//! api.query("G", TableQuery::all())?;                  // identical code
//! ```
//!
//! The trait has two required surfaces: [`D4mApi::handle`] (the one-shot
//! request/response dispatch every [`Request`] variant routes through)
//! and the three **cursor ops** ([`D4mApi::open_cursor`] /
//! [`D4mApi::cursor_next`] / [`D4mApi::cursor_close`]) that stream scan
//! results in bounded pages instead of materialising a whole [`Assoc`]
//! in one response. Everything else — one typed wrapper per request
//! variant, plus the [`D4mApi::scan_pages`] paged-scan iterator — is a
//! default method over those two.
//!
//! Typed wrappers fail with [`D4mError::UnexpectedResponse`] when the
//! response variant does not match the request — distinguishable from a
//! server-side [`D4mError::InvalidArg`], so remote shape-checks can tell
//! a protocol bug from a bad argument.

use std::collections::BTreeMap;

use crate::assoc::expr::{self, PlanOp};
use crate::assoc::Assoc;
use crate::connectors::TableQuery;
use crate::error::{D4mError, Result};
use crate::graphulo::{PageRankOpts, PageRankResult, TableMultStats};
use crate::pipeline::{IngestReport, PipelineConfig, TripleMsg};

use super::cursor::CursorPage;
use super::plan::PlanStats;
use super::{ExecHint, MultDest, Request, Response};

/// The coordinator surface, object-safe. See the module docs.
pub trait D4mApi: Send + Sync {
    /// Serve one coordinator request (the single dispatch point every
    /// typed wrapper routes through).
    fn handle(&self, req: Request) -> Result<Response>;

    // ------------------------------------------------------------------
    // cursor ops (streaming scans)

    /// Open a scan cursor over `table` for `query`: the server pins a
    /// snapshot stream and returns a cursor id whose pages carry at most
    /// `page_entries` raw stored triples each. Drain with
    /// [`D4mApi::cursor_next`] (or the [`D4mApi::scan_pages`] iterator);
    /// an abandoned cursor is evicted after the server's idle TTL.
    fn open_cursor(&self, table: &str, query: &TableQuery, page_entries: usize) -> Result<u64>;

    /// Pull the next page of an open cursor. When [`CursorPage::done`]
    /// is set the stream is exhausted and its snapshot released; send
    /// [`D4mApi::cursor_close`] to free the cursor handle (the server
    /// retains it briefly so a lost `done` reply is replayable after a
    /// reconnect — see `coordinator::cursor`).
    fn cursor_next(&self, cursor: u64) -> Result<CursorPage>;

    /// Close a cursor early, releasing its snapshot. Idempotent.
    fn cursor_close(&self, cursor: u64) -> Result<()>;

    /// Execute a plan server-side and page its **result** back through
    /// the cursor machinery instead of one big response: same
    /// ownership/cap/TTL/resume rules as [`D4mApi::open_cursor`], same
    /// `cursor_next`/`cursor_close` drain. The plan runs to completion
    /// (with streaming fusion) before the first page is served.
    fn open_plan_cursor(&self, ops: &[PlanOp], page_entries: usize) -> Result<u64>;

    // ------------------------------------------------------------------
    // typed wrappers — one per request variant

    /// Bind (create if needed) a D4M table.
    fn create_table(&self, name: &str, splits: Vec<String>) -> Result<()> {
        match self.handle(Request::CreateTable { name: name.into(), splits })? {
            Response::Ok => Ok(()),
            other => Err(unexpected("Ok", &other)),
        }
    }

    /// Ingest triples through the parallel pipeline.
    fn ingest(
        &self,
        table: &str,
        triples: Vec<TripleMsg>,
        pipeline: PipelineConfig,
    ) -> Result<IngestReport> {
        match self.handle(Request::Ingest { table: table.into(), triples, pipeline })? {
            Response::Ingested(r) => Ok(r),
            other => Err(unexpected("Ingested", &other)),
        }
    }

    /// The unified `T(r, c)` query, materialised in one response.
    fn query(&self, table: &str, query: TableQuery) -> Result<Assoc> {
        self.handle(Request::Query { table: table.into(), query })?.into_assoc()
    }

    /// Server-side Graphulo TableMult: `out += A^T B`.
    fn tablemult(&self, a: &str, b: &str, out: &str) -> Result<TableMultStats> {
        let req = Request::TableMult {
            a: a.into(),
            b: b.into(),
            dest: MultDest::Table { out: out.into() },
            exec: ExecHint::Stream,
        };
        match self.handle(req)? {
            Response::MultStats(s) => Ok(s),
            other => Err(unexpected("MultStats", &other)),
        }
    }

    /// Client-side D4M TableMult with a RAM budget.
    fn tablemult_client(&self, a: &str, b: &str, memory_limit: usize) -> Result<Assoc> {
        self.handle(Request::TableMult {
            a: a.into(),
            b: b.into(),
            dest: MultDest::Client,
            exec: ExecHint::Memory { limit: memory_limit },
        })?
        .into_assoc()
    }

    /// Client-side TableMult routed through the blocked dense-GEMM path.
    fn tablemult_dense(&self, a: &str, b: &str, tile: usize) -> Result<Assoc> {
        self.handle(Request::TableMult {
            a: a.into(),
            b: b.into(),
            dest: MultDest::Client,
            exec: ExecHint::Dense { tile },
        })?
        .into_assoc()
    }

    /// Execute a compiled plan server-side in **one round trip**,
    /// returning the final value plus the executor's fusion counters.
    fn plan(&self, ops: &[PlanOp]) -> Result<(Assoc, PlanStats)> {
        match self.handle(Request::Plan { ops: ops.to_vec() })? {
            Response::PlanResult { result, stats } => Ok((result, stats)),
            other => Err(unexpected("PlanResult", &other)),
        }
    }

    /// Parse, compile, and execute a plan from the compact text syntax
    /// (see [`crate::assoc::expr`]). Parse errors surface as
    /// [`D4mError::Parse`] before anything touches the server.
    fn plan_expr(&self, src: &str) -> Result<(Assoc, PlanStats)> {
        let ops = expr::Plan::parse(src)?.compile()?;
        self.plan(&ops)
    }

    /// Server-side BFS.
    fn bfs(&self, table: &str, seeds: &[&str], hops: usize) -> Result<BTreeMap<String, usize>> {
        let seeds = seeds.iter().map(|s| s.to_string()).collect();
        match self.handle(Request::Bfs { table: table.into(), seeds, hops })? {
            Response::Distances(d) => Ok(d),
            other => Err(unexpected("Distances", &other)),
        }
    }

    /// Server-side Jaccard into table `out`.
    fn jaccard(&self, table: &str, out: &str) -> Result<Assoc> {
        self.handle(Request::Jaccard { table: table.into(), out: out.into() })?.into_assoc()
    }

    /// Server-side k-truss.
    fn ktruss(&self, table: &str, k: usize) -> Result<Assoc> {
        self.handle(Request::KTruss { table: table.into(), k })?.into_assoc()
    }

    /// Server-side PageRank.
    fn pagerank(&self, table: &str, opts: PageRankOpts) -> Result<PageRankResult> {
        match self.handle(Request::PageRank { table: table.into(), opts })? {
            Response::Ranks(r) => Ok(r),
            other => Err(unexpected("Ranks", &other)),
        }
    }

    /// List tables.
    fn list_tables(&self) -> Result<Vec<String>> {
        match self.handle(Request::ListTables)? {
            Response::Tables(t) => Ok(t),
            other => Err(unexpected("Tables", &other)),
        }
    }

    /// Lazily-paged scan: a [`ScanPages`] iterator that opens a cursor on
    /// first pull and fetches one bounded page per step. (On `&dyn
    /// D4mApi`, construct with [`ScanPages::new`].)
    fn scan_pages(&self, table: &str, query: TableQuery, page_entries: usize) -> ScanPages<'_>
    where
        Self: Sized,
    {
        ScanPages::new(self, table, query, page_entries)
    }

    /// Lazily-paged plan: execute `ops` server-side and stream the
    /// result back one bounded page per pull, exactly like
    /// [`D4mApi::scan_pages`] but sourced from a plan cursor. (On `&dyn
    /// D4mApi`, construct with [`ScanPages::plan`].)
    fn plan_pages(&self, ops: &[PlanOp], page_entries: usize) -> ScanPages<'_>
    where
        Self: Sized,
    {
        ScanPages::plan(self, ops, page_entries)
    }
}

fn unexpected(expected: &str, got: &Response) -> D4mError {
    D4mError::UnexpectedResponse {
        expected: expected.into(),
        got: got.variant_name().into(),
    }
}

/// Iterator over cursor pages — the client end of a streaming scan.
///
/// Each `next()` is one `CursorNext` round trip yielding at most
/// `page_entries` raw stored triples, so peak per-pull payload stays
/// bounded regardless of table size. [`ScanPages::into_assoc`] drains
/// the pages and runs the string-vs-numeric inference once over the
/// assembled set, which makes the result **bit-identical** to the
/// one-shot [`D4mApi::query`] for the same query against the same table
/// state. Dropping an unfinished iterator closes its cursor
/// (best-effort), releasing the server-side snapshot promptly.
pub struct ScanPages<'a> {
    api: &'a dyn D4mApi,
    source: PageSource,
    page_entries: usize,
    cursor: Option<u64>,
    finished: bool,
}

/// What a [`ScanPages`] cursor is opened over: a table scan or a
/// server-side plan whose result is paged back.
enum PageSource {
    Table { table: String, query: TableQuery },
    Plan { ops: Vec<PlanOp> },
}

impl<'a> ScanPages<'a> {
    /// Build a paged scan over `api` (cursor opened lazily on first pull).
    pub fn new(api: &'a dyn D4mApi, table: &str, query: TableQuery, page_entries: usize) -> Self {
        ScanPages {
            api,
            source: PageSource::Table { table: table.into(), query },
            page_entries: page_entries.max(1),
            cursor: None,
            finished: false,
        }
    }

    /// Build a paged plan execution over `api` (plan runs when the
    /// cursor opens on first pull; pages carry the plan's result).
    pub fn plan(api: &'a dyn D4mApi, ops: &[PlanOp], page_entries: usize) -> Self {
        ScanPages {
            api,
            source: PageSource::Plan { ops: ops.to_vec() },
            page_entries: page_entries.max(1),
            cursor: None,
            finished: false,
        }
    }

    /// Drain every page into one associative array (see the type docs
    /// for the bit-identity contract with [`D4mApi::query`]).
    pub fn into_assoc(mut self) -> Result<Assoc> {
        let mut triples: Vec<TripleMsg> = Vec::new();
        for page in &mut self {
            triples.extend(page?);
        }
        crate::assoc::io::parse_triples(triples)
    }
}

impl Iterator for ScanPages<'_> {
    type Item = Result<Vec<TripleMsg>>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.finished {
            return None;
        }
        let id = match self.cursor {
            Some(id) => id,
            None => {
                let opened = match &self.source {
                    PageSource::Table { table, query } => {
                        self.api.open_cursor(table, query, self.page_entries)
                    }
                    PageSource::Plan { ops } => self.api.open_plan_cursor(ops, self.page_entries),
                };
                match opened {
                    Ok(id) => {
                        self.cursor = Some(id);
                        id
                    }
                    Err(e) => {
                        self.finished = true;
                        return Some(Err(e));
                    }
                }
            }
        };
        match self.api.cursor_next(id) {
            Ok(page) => {
                if page.done {
                    // final page delivered: free the cursor handle now
                    // (the server retains done cursors for resume
                    // replay until closed or swept)
                    self.finished = true;
                    self.cursor = None;
                    let _ = self.api.cursor_close(id);
                    if page.triples.is_empty() {
                        return None;
                    }
                }
                Some(Ok(page.triples))
            }
            Err(e) => {
                self.finished = true;
                self.cursor = None;
                Some(Err(e))
            }
        }
    }
}

impl Drop for ScanPages<'_> {
    fn drop(&mut self) {
        if let Some(id) = self.cursor.take() {
            // abandoned mid-scan: release the server-side snapshot now
            // rather than waiting for the idle TTL
            let _ = self.api.cursor_close(id);
        }
    }
}
