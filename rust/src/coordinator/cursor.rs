//! Server-side scan cursors: bounded, owned, evictable, **resumable**
//! handles over live snapshot [`TripleStream`]s.
//!
//! A cursor is opened against a bound table's
//! [`DbTable::scan_triples`](crate::connectors::DbTable::scan_triples)
//! stream and drained page by page (at most `page_entries` triples per
//! [`CursorPage`]). The table enforces four protections so an abandoned
//! cursor can never pin a snapshot forever:
//!
//! * **ownership** — every cursor belongs to the owner id that opened it
//!   (the network server assigns one per connection; in-process callers
//!   use [`LOCAL_OWNER`]). Ops from any other owner see `NotFound`, and
//!   `reap_owner` (surfaced as `D4mServer::reap_cursors`) drops every
//!   cursor of a disconnected owner at once.
//! * **cap** — at most `cap` cursors may be open server-wide; the N+1th
//!   open is refused with a typed [`D4mError::Overloaded`] carrying a
//!   retry hint instead of accumulating pinned snapshots.
//! * **idle TTL** — a cursor untouched for `idle_ttl` is evicted by the
//!   next sweep (every cursor op sweeps, and the network server also
//!   sweeps from a background timer so an idle connection's leaked
//!   cursors are reaped on an otherwise-quiet server).
//! * **resume grace** — a disconnected owner's cursors are not dropped
//!   immediately: `orphan_owner` parks them for a short grace window in
//!   which a reconnecting client holding the cursor's resume token
//!   (issued at open) can re-attach to the same pinned snapshot and
//!   continue, bit-identical to an uninterrupted scan. Orphans past
//!   their grace deadline are dropped by the sweep.
//!
//! §Cursor state machine (DESIGN.md §Fault model): `open → (next)* →
//! done → close`, where `done` means the stream is exhausted — the
//! snapshot is released at once but the cursor *handle* is retained
//! (with a buffered copy of the final page) until an explicit close,
//! TTL eviction, or grace expiry, so a client that lost the `done`
//! reply can still resume and have it replayed. Every `next` buffers
//! the page it returns; a resume whose `pages_acked` is one short of
//! the pages served replays that buffered page instead of losing it.
//! `next` is one-at-a-time per cursor: while a page is being pulled the
//! cursor is checked out of the table, so a concurrent `next` on the
//! same id reports `NotFound` rather than interleaving pages, and a
//! resume that lands mid-pull is asked to retry with
//! [`D4mError::Overloaded`].

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::collections::{HashMap, HashSet};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::connectors::TripleStream;
use crate::error::{D4mError, Result};
use crate::pipeline::TripleMsg;

/// Default cap on simultaneously open cursors.
pub const DEFAULT_CURSOR_CAP: usize = 64;
/// Default idle TTL before an untouched cursor is evicted.
pub const DEFAULT_CURSOR_TTL: Duration = Duration::from_secs(300);
/// Default grace window in which a disconnected owner's cursors stay
/// resumable before the sweep drops them.
pub const DEFAULT_RESUME_GRACE: Duration = Duration::from_secs(3);
/// `retry_after_ms` hint sent with [`D4mError::Overloaded`] when the
/// cursor table is saturated or a resume races an in-flight pull.
pub const CURSOR_RETRY_AFTER_MS: u64 = 100;
/// Byte budget per page: a pull stops early once the accumulated triple
/// bytes reach this, whatever `page_entries` says — so a hostile or
/// careless `page_entries` cannot make one `next` materialise the whole
/// table (and a page always fits the 256 MiB wire frame cap with a wide
/// margin).
pub const PAGE_BYTE_BUDGET: usize = 64 << 20;
/// Owner id used by in-process callers (the network server hands every
/// connection a distinct nonzero owner).
pub const LOCAL_OWNER: u64 = 0;

/// One page of cursor results.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CursorPage {
    /// Raw stored `(row, col, value)` triples, row-major order — at most
    /// the cursor's `page_entries` of them (fewer when
    /// [`PAGE_BYTE_BUDGET`] cuts a page of large values short).
    pub triples: Vec<TripleMsg>,
    /// True when the stream is exhausted and its snapshot released. The
    /// cursor handle itself survives until an explicit `CursorClose`
    /// (which [`ScanPages`](crate::coordinator::api::ScanPages) sends
    /// automatically), TTL eviction, or resume-grace expiry — so a lost
    /// `done` reply is replayable after a reconnect.
    pub done: bool,
}

/// Client-supplied token re-attaching a cursor after a reconnect: the
/// cursor id, the secret issued with `CursorOpened`, and how many pages
/// the client has fully received. A resume with `pages_acked` equal to
/// the pages served continues the stream; one page short replays the
/// buffered last page (the reply was lost in flight); any other gap is
/// a protocol error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CursorResume {
    pub cursor: u64,
    pub token: u64,
    pub pages_acked: u64,
}

struct CursorState {
    owner: u64,
    /// Resume secret issued at open; a reconnecting client must present
    /// it to take the cursor over.
    token: u64,
    page_entries: usize,
    /// `None` once the stream is exhausted (`done` served) — the
    /// snapshot is released immediately, only the handle + buffered
    /// last page linger for resume.
    stream: Option<TripleStream>,
    /// Pages produced by fresh pulls (replays don't count).
    served: u64,
    /// Copy of the most recently pulled page, for replay after a lost
    /// reply. Replaced on every fresh pull.
    last_page: Option<CursorPage>,
    /// Set by a resume that found `pages_acked == served - 1`: the next
    /// `next` re-delivers `last_page` instead of pulling.
    replay: bool,
    /// Set when the owner disconnected: drop at this deadline unless a
    /// resume re-attaches first.
    orphan_deadline: Option<Instant>,
    last_used: Instant,
}

struct Inner {
    next_id: u64,
    cap: usize,
    idle_ttl: Duration,
    resume_grace: Duration,
    /// Token source — not cryptographic, just unguessable enough that a
    /// buggy client cannot resume someone else's cursor by accident.
    rng: crate::util::XorShift64,
    cursors: HashMap<u64, CursorState>,
    /// Cursors checked out by an in-flight `next` (id → (owner, token)).
    /// A close/reap/resume that lands mid-pull cannot find the cursor in
    /// `cursors`; recording the checkout here lets it leave a mark (or,
    /// for resume, verify the token and ask the client to retry).
    busy: HashMap<u64, (u64, u64)>,
    /// Checked-out cursors whose close/reap arrived mid-pull: dropped at
    /// reinsert time instead of resurrected (a successful `close` must
    /// release the snapshot even when it races a concurrent `next`).
    closing: HashSet<u64>,
    /// Checked-out cursors whose owner disconnected mid-pull: reinserted
    /// as orphans with this grace deadline instead of dropped.
    orphaning: HashMap<u64, Instant>,
}

impl Inner {
    /// Drop every cursor idle past the TTL and every orphan past its
    /// grace deadline. Run on every cursor op *and* from the network
    /// server's background timer, so leaked cursors are reaped even on a
    /// quiet server. Returns how many were dropped.
    fn sweep(&mut self, now: Instant) -> usize {
        let ttl = self.idle_ttl;
        let before = self.cursors.len();
        self.cursors.retain(|_, c| {
            let grace_ok = match c.orphan_deadline {
                Some(deadline) => now < deadline,
                None => true,
            };
            now.duration_since(c.last_used) < ttl && grace_ok
        });
        before - self.cursors.len()
    }
}

/// The registry of live cursors (one per [`D4mServer`](super::D4mServer)).
pub(crate) struct CursorTable {
    inner: Mutex<Inner>,
}

impl CursorTable {
    pub(crate) fn new() -> Self {
        // seed the token source from wall-clock nanos: distinct per
        // process, and good enough for accident-proofing (see `rng` doc)
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x0D4A11CE);
        CursorTable {
            inner: Mutex::new(Inner {
                next_id: 1,
                cap: DEFAULT_CURSOR_CAP,
                idle_ttl: DEFAULT_CURSOR_TTL,
                resume_grace: DEFAULT_RESUME_GRACE,
                rng: crate::util::XorShift64::new(seed | 1),
                cursors: HashMap::new(),
                busy: HashMap::new(),
                closing: HashSet::new(),
                orphaning: HashMap::new(),
            }),
        }
    }

    pub(crate) fn configure(&self, cap: usize, idle_ttl: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.cap = cap.max(1);
        g.idle_ttl = idle_ttl;
    }

    pub(crate) fn set_resume_grace(&self, grace: Duration) {
        self.inner.lock().unwrap().resume_grace = grace;
    }

    pub(crate) fn len(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.cursors.len() + g.busy.len()
    }

    /// Sweep expired cursors now (TTL + orphan grace); returns how many
    /// were dropped. The network server calls this from its accept-loop
    /// timer so eviction doesn't depend on cursor traffic.
    pub(crate) fn sweep(&self) -> usize {
        self.inner.lock().unwrap().sweep(Instant::now())
    }

    /// Open a cursor; returns `(id, resume token)`.
    pub(crate) fn open(
        &self,
        owner: u64,
        page_entries: usize,
        stream: TripleStream,
    ) -> Result<(u64, u64)> {
        let mut g = self.inner.lock().unwrap();
        g.sweep(Instant::now());
        let open = g.cursors.len() + g.busy.len();
        if open >= g.cap {
            // typed shed, not InvalidArg: the client did nothing wrong —
            // the table is saturated and the open is safe to retry
            return Err(D4mError::Overloaded { retry_after_ms: CURSOR_RETRY_AFTER_MS });
        }
        let id = g.next_id;
        g.next_id += 1;
        let token = g.rng.next_u64();
        g.cursors.insert(
            id,
            CursorState {
                owner,
                token,
                page_entries: page_entries.max(1),
                stream: Some(stream),
                served: 0,
                last_page: None,
                replay: false,
                orphan_deadline: None,
                last_used: Instant::now(),
            },
        );
        Ok((id, token))
    }

    /// Re-attach a cursor after a reconnect. The token must match the
    /// one issued at open; `pages_acked` positions the stream (continue,
    /// or replay the buffered last page). On success the cursor belongs
    /// to `new_owner` and its orphan mark is cleared.
    pub(crate) fn resume(&self, new_owner: u64, r: &CursorResume) -> Result<(u64, u64)> {
        let mut g = self.inner.lock().unwrap();
        g.sweep(Instant::now());
        if let Some(&(_, token)) = g.busy.get(&r.cursor) {
            // mid-pull for its (dead) previous owner: the pull finishes
            // and reinserts shortly — ask the client to retry
            if token == r.token && !g.closing.contains(&r.cursor) {
                return Err(D4mError::Overloaded { retry_after_ms: CURSOR_RETRY_AFTER_MS });
            }
            return Err(not_found(r.cursor));
        }
        let c = match g.cursors.get_mut(&r.cursor) {
            Some(c) if c.token == r.token => c,
            _ => return Err(not_found(r.cursor)),
        };
        if r.pages_acked == c.served {
            c.replay = false;
        } else if r.pages_acked + 1 == c.served && c.last_page.is_some() {
            c.replay = true;
        } else {
            return Err(D4mError::InvalidArg(format!(
                "cursor {} resume gap: client acked {} pages but server served {}",
                r.cursor, r.pages_acked, c.served
            )));
        }
        c.owner = new_owner;
        c.orphan_deadline = None;
        c.last_used = Instant::now();
        Ok((r.cursor, c.token))
    }

    /// Pull the next page. The cursor is checked out of the table while
    /// the stream is pulled, so the table lock is never held across the
    /// (possibly slow) pull and other connections' cursor ops proceed; a
    /// close/reap landing mid-pull marks the checkout and the cursor is
    /// dropped (or orphaned) instead of reinserted. The page stops at
    /// `page_entries` triples or [`PAGE_BYTE_BUDGET`] bytes, whichever
    /// comes first. A pending replay returns the buffered page without
    /// touching the stream; a finished cursor returns an empty `done`
    /// page (idempotent).
    pub(crate) fn next(&self, owner: u64, id: u64) -> Result<CursorPage> {
        let mut st = {
            let mut g = self.inner.lock().unwrap();
            g.sweep(Instant::now());
            match g.cursors.get_mut(&id) {
                Some(c) if c.owner == owner => {
                    c.last_used = Instant::now();
                    if c.replay {
                        c.replay = false;
                        // buffered page guaranteed by `resume`
                        return Ok(c.last_page.clone().unwrap_or_default());
                    }
                    if c.stream.is_none() {
                        // drained: the done page was already delivered
                        // and acked — answer idempotently
                        return Ok(CursorPage { triples: Vec::new(), done: true });
                    }
                }
                _ => return Err(not_found(id)),
            }
            // stream pull needed: check the cursor out
            let c = g.cursors.remove(&id).expect("checked above");
            g.busy.insert(id, (c.owner, c.token));
            c
        };
        let stream = st.stream.as_mut().expect("checked out with a live stream");
        let mut triples = Vec::with_capacity(st.page_entries.min(4096));
        let mut bytes = 0usize;
        let mut done = false;
        let mut err = None;
        for _ in 0..st.page_entries {
            match stream.next() {
                Some(Ok(t)) => {
                    bytes += t.0.len() + t.1.len() + t.2.len();
                    triples.push(t);
                    if bytes >= PAGE_BYTE_BUDGET {
                        break;
                    }
                }
                // a stream error poisons the cursor: report it once and
                // leave the cursor freed
                Some(Err(e)) => {
                    err = Some(e);
                    break;
                }
                None => {
                    done = true;
                    break;
                }
            }
        }
        if done {
            // release the snapshot now; the handle + buffered page stay
            st.stream = None;
        }
        let mut g = self.inner.lock().unwrap();
        g.busy.remove(&id);
        let closed_mid_pull = g.closing.remove(&id);
        let orphaned_mid_pull = g.orphaning.remove(&id);
        match err {
            Some(e) => Err(e),
            None => {
                let page = CursorPage { triples, done };
                if !closed_mid_pull {
                    st.served += 1;
                    st.last_page = Some(page.clone());
                    st.orphan_deadline = orphaned_mid_pull;
                    st.last_used = Instant::now();
                    g.cursors.insert(id, st);
                }
                Ok(page)
            }
        }
    }

    /// Close a cursor, releasing its snapshot. Idempotent: closing an
    /// unknown/already-freed id is `Ok` (a pipelined close may race TTL
    /// eviction). A close racing a concurrent `next` on the same cursor
    /// marks the checkout so the cursor is dropped when the pull
    /// finishes — never resurrected.
    pub(crate) fn close(&self, owner: u64, id: u64) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        g.sweep(Instant::now());
        if g.cursors.get(&id).map(|c| c.owner) == Some(owner) {
            g.cursors.remove(&id);
        } else if g.busy.get(&id).map(|&(o, _)| o) == Some(owner) {
            g.closing.insert(id);
        }
        Ok(())
    }

    /// Drop every cursor belonging to `owner` immediately (no resume
    /// grace), including checked-out ones (marked, dropped at reinsert
    /// time). Returns how many were reaped.
    pub(crate) fn reap_owner(&self, owner: u64) -> usize {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        let before = inner.cursors.len();
        inner.cursors.retain(|_, c| c.owner != owner);
        let mut reaped = before - inner.cursors.len();
        for (&id, &(o, _)) in inner.busy.iter() {
            if o == owner && inner.closing.insert(id) {
                reaped += 1;
            }
        }
        reaped
    }

    /// Park every cursor belonging to `owner` (connection teardown) for
    /// the resume-grace window: a reconnecting client presenting the
    /// resume token re-attaches; otherwise the sweep drops them at the
    /// deadline. Returns how many were parked.
    pub(crate) fn orphan_owner(&self, owner: u64) -> usize {
        let mut g = self.inner.lock().unwrap();
        let deadline = Instant::now() + g.resume_grace;
        let mut parked = 0usize;
        for c in g.cursors.values_mut() {
            if c.owner == owner && c.orphan_deadline.is_none() {
                c.orphan_deadline = Some(deadline);
                parked += 1;
            }
        }
        let busy_ids: Vec<u64> = g
            .busy
            .iter()
            .filter(|&(_, &(o, _))| o == owner)
            .map(|(&id, _)| id)
            .collect();
        for id in busy_ids {
            if !g.closing.contains(&id) && g.orphaning.insert(id, deadline).is_none() {
                parked += 1;
            }
        }
        parked
    }
}

fn not_found(id: u64) -> D4mError {
    D4mError::NotFound(format!("cursor {id} (closed, expired, or not yours)"))
}
