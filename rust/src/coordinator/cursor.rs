//! Server-side scan cursors: bounded, owned, evictable handles over live
//! snapshot [`TripleStream`]s.
//!
//! A cursor is opened against a bound table's
//! [`DbTable::scan_triples`](crate::connectors::DbTable::scan_triples)
//! stream and drained page by page (at most `page_entries` triples per
//! [`CursorPage`]). The table enforces three protections so an abandoned
//! cursor can never pin a snapshot forever:
//!
//! * **ownership** — every cursor belongs to the owner id that opened it
//!   (the network server assigns one per connection; in-process callers
//!   use [`LOCAL_OWNER`]). Ops from any other owner see `NotFound`, and
//!   `reap_owner` (surfaced as `D4mServer::reap_cursors`) drops every
//!   cursor of a disconnected owner at once.
//! * **cap** — at most `cap` cursors may be open server-wide; the N+1th
//!   open is refused with a typed error instead of accumulating pinned
//!   snapshots.
//! * **idle TTL** — a cursor untouched for `idle_ttl` is evicted on the
//!   next cursor op (open/next/close all sweep), releasing its snapshot.
//!
//! §Cursor state machine (DESIGN.md §Wire v2): `open → (next)* → done`,
//! where `done` is reached by draining the stream (the server frees the
//! cursor itself and sets [`CursorPage::done`]), an explicit close, a
//! stream error (the cursor is poisoned and freed), TTL eviction, or
//! owner reap. `next` is one-at-a-time per cursor: while a page is being
//! pulled the cursor is checked out of the table, so a concurrent `next`
//! on the same id reports `NotFound` rather than interleaving pages.

use std::collections::{HashMap, HashSet};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::connectors::TripleStream;
use crate::error::{D4mError, Result};
use crate::pipeline::TripleMsg;

/// Default cap on simultaneously open cursors.
pub const DEFAULT_CURSOR_CAP: usize = 64;
/// Default idle TTL before an untouched cursor is evicted.
pub const DEFAULT_CURSOR_TTL: Duration = Duration::from_secs(300);
/// Byte budget per page: a pull stops early once the accumulated triple
/// bytes reach this, whatever `page_entries` says — so a hostile or
/// careless `page_entries` cannot make one `next` materialise the whole
/// table (and a page always fits the 256 MiB wire frame cap with a wide
/// margin).
pub const PAGE_BYTE_BUDGET: usize = 64 << 20;
/// Owner id used by in-process callers (the network server hands every
/// connection a distinct nonzero owner).
pub const LOCAL_OWNER: u64 = 0;

/// One page of cursor results.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CursorPage {
    /// Raw stored `(row, col, value)` triples, row-major order — at most
    /// the cursor's `page_entries` of them (fewer when
    /// [`PAGE_BYTE_BUDGET`] cuts a page of large values short).
    pub triples: Vec<TripleMsg>,
    /// True when the stream is exhausted. The server has already freed
    /// the cursor; a trailing `CursorClose` is unnecessary but harmless.
    pub done: bool,
}

struct CursorState {
    owner: u64,
    page_entries: usize,
    stream: TripleStream,
    last_used: Instant,
}

struct Inner {
    next_id: u64,
    cap: usize,
    idle_ttl: Duration,
    cursors: HashMap<u64, CursorState>,
    /// Cursors checked out by an in-flight `next` (id → owner). A close
    /// or reap that lands mid-pull cannot find the cursor in `cursors`;
    /// recording the checkout here lets it leave a mark instead of
    /// silently missing.
    busy: HashMap<u64, u64>,
    /// Checked-out cursors whose close/reap arrived mid-pull: dropped at
    /// reinsert time instead of resurrected (a successful `close` must
    /// release the snapshot even when it races a concurrent `next`).
    closing: HashSet<u64>,
}

impl Inner {
    /// Drop every cursor idle past the TTL (run on every cursor op — the
    /// table needs no background thread to stay bounded).
    fn evict_idle(&mut self, now: Instant) {
        let ttl = self.idle_ttl;
        self.cursors.retain(|_, c| now.duration_since(c.last_used) < ttl);
    }
}

/// The registry of live cursors (one per [`D4mServer`](super::D4mServer)).
pub(crate) struct CursorTable {
    inner: Mutex<Inner>,
}

impl CursorTable {
    pub(crate) fn new() -> Self {
        CursorTable {
            inner: Mutex::new(Inner {
                next_id: 1,
                cap: DEFAULT_CURSOR_CAP,
                idle_ttl: DEFAULT_CURSOR_TTL,
                cursors: HashMap::new(),
                busy: HashMap::new(),
                closing: HashSet::new(),
            }),
        }
    }

    pub(crate) fn configure(&self, cap: usize, idle_ttl: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.cap = cap.max(1);
        g.idle_ttl = idle_ttl;
    }

    pub(crate) fn len(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.cursors.len() + g.busy.len()
    }

    pub(crate) fn open(
        &self,
        owner: u64,
        page_entries: usize,
        stream: TripleStream,
    ) -> Result<u64> {
        let mut g = self.inner.lock().unwrap();
        g.evict_idle(Instant::now());
        let open = g.cursors.len() + g.busy.len();
        if open >= g.cap {
            return Err(D4mError::InvalidArg(format!(
                "cursor cap reached: {open} cursors open (cap {}) — drain or close \
                 existing cursors before opening more",
                g.cap
            )));
        }
        let id = g.next_id;
        g.next_id += 1;
        g.cursors.insert(
            id,
            CursorState {
                owner,
                page_entries: page_entries.max(1),
                stream,
                last_used: Instant::now(),
            },
        );
        Ok(id)
    }

    /// Pull the next page. The cursor is checked out of the table while
    /// the stream is pulled, so the table lock is never held across the
    /// (possibly slow) pull and other connections' cursor ops proceed; a
    /// close/reap landing mid-pull marks the checkout and the cursor is
    /// dropped instead of reinserted. The page stops at `page_entries`
    /// triples or [`PAGE_BYTE_BUDGET`] bytes, whichever comes first.
    pub(crate) fn next(&self, owner: u64, id: u64) -> Result<CursorPage> {
        let mut st = {
            let mut g = self.inner.lock().unwrap();
            g.evict_idle(Instant::now());
            match g.cursors.remove(&id) {
                Some(c) if c.owner == owner => {
                    g.busy.insert(id, owner);
                    c
                }
                Some(c) => {
                    // someone else's cursor: put it back, reveal nothing
                    g.cursors.insert(id, c);
                    return Err(not_found(id));
                }
                None => return Err(not_found(id)),
            }
        };
        let mut triples = Vec::with_capacity(st.page_entries.min(4096));
        let mut bytes = 0usize;
        let mut done = false;
        let mut err = None;
        for _ in 0..st.page_entries {
            match st.stream.next() {
                Some(Ok(t)) => {
                    bytes += t.0.len() + t.1.len() + t.2.len();
                    triples.push(t);
                    if bytes >= PAGE_BYTE_BUDGET {
                        break;
                    }
                }
                // a stream error poisons the cursor: report it once and
                // leave the cursor freed
                Some(Err(e)) => {
                    err = Some(e);
                    break;
                }
                None => {
                    done = true;
                    break;
                }
            }
        }
        let mut g = self.inner.lock().unwrap();
        g.busy.remove(&id);
        let closed_mid_pull = g.closing.remove(&id);
        match err {
            Some(e) => Err(e),
            None => {
                if !done && !closed_mid_pull {
                    st.last_used = Instant::now();
                    g.cursors.insert(id, st);
                }
                Ok(CursorPage { triples, done })
            }
        }
    }

    /// Close a cursor, releasing its snapshot. Idempotent: closing an
    /// unknown/already-freed id is `Ok` (a drained cursor frees itself,
    /// and a pipelined close may race the final page). A close racing a
    /// concurrent `next` on the same cursor marks the checkout so the
    /// cursor is dropped when the pull finishes — never resurrected.
    pub(crate) fn close(&self, owner: u64, id: u64) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        g.evict_idle(Instant::now());
        if g.cursors.get(&id).map(|c| c.owner) == Some(owner) {
            g.cursors.remove(&id);
        } else if g.busy.get(&id) == Some(&owner) {
            g.closing.insert(id);
        }
        Ok(())
    }

    /// Drop every cursor belonging to `owner` (connection teardown),
    /// including checked-out ones (marked, dropped at reinsert time).
    /// Returns how many were reaped.
    pub(crate) fn reap_owner(&self, owner: u64) -> usize {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        let before = inner.cursors.len();
        inner.cursors.retain(|_, c| c.owner != owner);
        let mut reaped = before - inner.cursors.len();
        for (&id, &o) in inner.busy.iter() {
            if o == owner && inner.closing.insert(id) {
                reaped += 1;
            }
        }
        reaped
    }
}

fn not_found(id: u64) -> D4mError {
    D4mError::NotFound(format!("cursor {id} (closed, expired, or not yours)"))
}
