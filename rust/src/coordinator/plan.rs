//! Server-side streaming plan executor (DESIGN.md §Plan language).
//!
//! Walks a validated [`PlanOp`] program (`assoc::expr`) slot by slot.
//! Each slot is one of three states, and the state machine exists to
//! keep work *lazy* until an op genuinely needs a value:
//!
//! * **Scan** — a table name plus a pushdown [`TableQuery`]; nothing has
//!   touched the engine yet. A `Select` whose source is a sole-use,
//!   still-unfiltered scan folds its selectors into the query instead of
//!   materialising (the classic predicate pushdown).
//! * **Pending** — a matmul whose only consumer is a `Reduce`: the
//!   operands are forced (scan timing stays identical to the eager
//!   walk), but the product is never built — the reduce streams the
//!   contraction through [`Assoc::matmul_sum`], which is bit-identical
//!   to matmul-then-sum by construction.
//! * **Val** — a materialised [`Assoc`].
//!
//! [`PlanStats`] reports what the fusion actually did — `intermediates`
//! counts materialised results of non-leaf ops that are not the plan's
//! result, so `intermediates == 0` on a fused select→matmul→reduce plan
//! is the proof that nothing was built that the answer didn't need. The
//! same four counts accumulate process-wide in [`counters`] and surface
//! as `plan.*` rows in [`D4mServer::snapshots`].

use std::fmt;
use std::sync::{Arc, OnceLock};

use crate::assoc::expr::{validate_plan, PlanOp};
use crate::assoc::{Assoc, KeySel};
use crate::connectors::TableQuery;
use crate::error::{D4mError, Result};
use crate::metrics::Counter;
use crate::pipeline::{IngestPipeline, PipelineConfig};

use super::D4mServer;

/// Per-plan execution counters, returned with every plan result.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PlanStats {
    /// Ops in the executed program.
    pub ops: u64,
    /// `Select` ops folded into a scan's pushdown query.
    pub fused_selects: u64,
    /// `Reduce` ops streamed through a pending matmul without building
    /// the product.
    pub fused_reduces: u64,
    /// Materialised non-leaf op results that were not the plan's result
    /// — 0 means the fused path built nothing the answer didn't need.
    pub intermediates: u64,
}

impl fmt::Display for PlanStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} ops, {} fused selects, {} fused reduces, {} intermediates",
            self.ops, self.fused_selects, self.fused_reduces, self.intermediates
        )
    }
}

/// Process-wide plan-executor counters (the [`PlanStats`] fields,
/// accumulated across every plan served; `plan.*` in stats output).
pub struct PlanCounters {
    pub ops: Counter,
    pub fused_selects: Counter,
    pub fused_reduces: Counter,
    pub intermediates: Counter,
}

pub fn counters() -> &'static PlanCounters {
    static CELL: OnceLock<PlanCounters> = OnceLock::new();
    CELL.get_or_init(|| PlanCounters {
        ops: Counter::new(),
        fused_selects: Counter::new(),
        fused_reduces: Counter::new(),
        intermediates: Counter::new(),
    })
}

/// One plan slot: the executor's lazy value states (module doc).
enum Slot {
    /// A not-yet-run table scan with its pushdown query.
    Scan { table: String, query: TableQuery },
    /// A matmul deferred into its consuming reduce: operands forced,
    /// product never built.
    Pending(Arc<Assoc>, Arc<Assoc>),
    /// A materialised value.
    Val(Arc<Assoc>),
    /// Consumed by a fusion (folded scan source, drained pending mul) —
    /// unreachable afterwards because fusion requires sole use.
    Taken,
}

/// Materialise slot `i`. Scans run their pushdown query through the
/// same [`crate::connectors::DbTable::query`] path `Request::Query`
/// takes, so a plan answer is bit-identical to the sequential
/// round-trip answer.
fn force(server: &D4mServer, slots: &mut [Slot], i: usize) -> Result<Arc<Assoc>> {
    match slots.get(i) {
        Some(Slot::Val(a)) => Ok(a.clone()),
        Some(Slot::Scan { table, query }) => {
            let t = server.bound(table)?;
            let a = Arc::new(t.query(query)?);
            if let Some(slot) = slots.get_mut(i) {
                *slot = Slot::Val(a.clone());
            }
            Ok(a)
        }
        Some(Slot::Pending(..)) | Some(Slot::Taken) | None => Err(D4mError::InvalidArg(
            format!("plan executor invariant violated: slot {i} referenced after fusion"),
        )),
    }
}

fn scan_is_unfiltered(q: &TableQuery) -> bool {
    matches!(q.rows, KeySel::All) && matches!(q.cols, KeySel::All) && q.limit.is_none()
}

impl D4mServer {
    /// Execute a validated plan; returns the final value and the fusion
    /// counters. Revalidates the op list first (defense in depth — the
    /// wire layer already validated, in-process callers may not have).
    pub fn execute_plan(&self, ops: &[PlanOp]) -> Result<(Assoc, PlanStats)> {
        validate_plan(ops)?;
        let n = ops.len();

        // reference counts: fusion is only legal on sole-use slots
        let mut uses = vec![0usize; n];
        for op in ops {
            match op {
                PlanOp::Load { .. } => {}
                PlanOp::Select { src, .. }
                | PlanOp::Transpose { src }
                | PlanOp::Reduce { src, .. }
                | PlanOp::Scale { src, .. }
                | PlanOp::Store { src, .. } => uses[*src] += 1,
                PlanOp::MatMul { a, b }
                | PlanOp::CatKeyMul { a, b }
                | PlanOp::ElemAdd { a, b }
                | PlanOp::ElemSub { a, b }
                | PlanOp::ElemMult { a, b }
                | PlanOp::ElemMin { a, b }
                | PlanOp::ElemMax { a, b } => {
                    uses[*a] += 1;
                    uses[*b] += 1;
                }
            }
        }

        // result slots: the last op, plus — through a trailing Store
        // chain — the value being stored (a store's output IS its input,
        // so materialising it is not "an intermediate")
        let mut is_result = vec![false; n];
        let mut i = n - 1;
        is_result[i] = true;
        while let PlanOp::Store { src, .. } = &ops[i] {
            is_result[*src] = true;
            i = *src;
        }

        // matmuls whose sole consumer is a Reduce: defer the product
        let mut deferred_mul = vec![false; n];
        for op in ops {
            if let PlanOp::Reduce { src, .. } = op {
                if uses[*src] == 1 && matches!(ops[*src], PlanOp::MatMul { .. }) {
                    deferred_mul[*src] = true;
                }
            }
        }

        let mut stats = PlanStats { ops: n as u64, ..Default::default() };
        let mut slots: Vec<Slot> = Vec::with_capacity(n);
        for (i, op) in ops.iter().enumerate() {
            // count a computed non-leaf value that isn't the plan result
            let computed = |v: Arc<Assoc>, stats: &mut PlanStats| {
                if !is_result[i] {
                    stats.intermediates += 1;
                }
                Slot::Val(v)
            };
            let slot = match op {
                PlanOp::Load { table, rows, cols, limit } => {
                    let mut q = TableQuery::all()
                        .rows(rows.clone())
                        .cols(cols.clone());
                    q.limit = *limit;
                    Slot::Scan { table: table.clone(), query: q }
                }
                PlanOp::Select { src, rows, cols } => {
                    let foldable = uses[*src] == 1
                        && matches!(&slots[*src], Slot::Scan { query, .. } if scan_is_unfiltered(query));
                    if foldable {
                        let taken = std::mem::replace(&mut slots[*src], Slot::Taken);
                        let Slot::Scan { table, query } = taken else {
                            // can't happen: `foldable` just matched the
                            // slot as a Scan — but a typed error beats a
                            // panic if the executor is ever restructured
                            return Err(D4mError::InvalidArg(
                                "plan executor invariant violated: fused select \
                                 source is not a scan"
                                    .into(),
                            ));
                        };
                        stats.fused_selects += 1;
                        Slot::Scan {
                            table,
                            query: query.rows(rows.clone()).cols(cols.clone()),
                        }
                    } else {
                        let a = force(self, &mut slots, *src)?;
                        computed(Arc::new(a.subsref(rows, cols)), &mut stats)
                    }
                }
                PlanOp::Transpose { src } => {
                    let a = force(self, &mut slots, *src)?;
                    computed(Arc::new(a.transpose()), &mut stats)
                }
                PlanOp::MatMul { a, b } => {
                    // operands are forced HERE even when the product is
                    // deferred, so scan timing (snapshot pinning order)
                    // matches the eager walk exactly
                    let aa = force(self, &mut slots, *a)?;
                    let bb = force(self, &mut slots, *b)?;
                    if deferred_mul[i] {
                        Slot::Pending(aa, bb)
                    } else {
                        computed(Arc::new(aa.matmul(&bb)), &mut stats)
                    }
                }
                PlanOp::CatKeyMul { a, b } => {
                    let aa = force(self, &mut slots, *a)?;
                    let bb = force(self, &mut slots, *b)?;
                    computed(Arc::new(aa.catkeymul(&bb)), &mut stats)
                }
                PlanOp::ElemAdd { a, b }
                | PlanOp::ElemSub { a, b }
                | PlanOp::ElemMult { a, b }
                | PlanOp::ElemMin { a, b }
                | PlanOp::ElemMax { a, b } => {
                    let aa = force(self, &mut slots, *a)?;
                    let bb = force(self, &mut slots, *b)?;
                    let v = match op {
                        PlanOp::ElemAdd { .. } => aa.add(&bb),
                        PlanOp::ElemSub { .. } => aa.sub(&bb),
                        PlanOp::ElemMult { .. } => aa.elem_mult(&bb),
                        PlanOp::ElemMin { .. } => aa.elem_min(&bb),
                        _ => aa.elem_max(&bb),
                    };
                    computed(Arc::new(v), &mut stats)
                }
                PlanOp::Reduce { src, dim } => {
                    let fused = match &slots[*src] {
                        Slot::Pending(aa, bb) => Some(Arc::new(aa.matmul_sum(bb, *dim))),
                        _ => None,
                    };
                    match fused {
                        Some(v) => {
                            slots[*src] = Slot::Taken;
                            stats.fused_reduces += 1;
                            computed(v, &mut stats)
                        }
                        None => {
                            let a = force(self, &mut slots, *src)?;
                            computed(Arc::new(a.sum(*dim)), &mut stats)
                        }
                    }
                }
                PlanOp::Scale { src, factor } => {
                    let a = force(self, &mut slots, *src)?;
                    computed(Arc::new(a.scale(*factor)), &mut stats)
                }
                PlanOp::Store { src, table } => {
                    let v = force(self, &mut slots, *src)?;
                    let t = self.bind_d4m(table, vec![])?;
                    IngestPipeline::new(t, PipelineConfig::default())
                        .run(v.str_triples().into_iter())?;
                    // pass the stored value through as this op's value
                    Slot::Val(v)
                }
            };
            slots.push(slot);
        }

        let result = force(self, &mut slots, n - 1)?;
        let c = counters();
        c.ops.add(stats.ops);
        c.fused_selects.add(stats.fused_selects);
        c.fused_reduces.add(stats.fused_reduces);
        c.intermediates.add(stats.intermediates);
        let result = Arc::try_unwrap(result).unwrap_or_else(|a| (*a).clone());
        Ok((result, stats))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::super::{D4mApi, Request, Response};
    use super::*;
    use crate::assoc::expr::Plan;
    use crate::pipeline::TripleMsg;

    /// Numeric graph: r00..r09 x c00..c11, values 1..5.
    fn server_with_matrix() -> D4mServer {
        let s = D4mServer::with_engine(None);
        let triples: Vec<TripleMsg> = (0..60)
            .map(|i| {
                (
                    format!("r{:02}", i % 10),
                    format!("c{:02}", (i * 7) % 12),
                    format!("{}", i % 5 + 1),
                )
            })
            .collect();
        s.handle(Request::Ingest {
            table: "A".into(),
            triples,
            pipeline: PipelineConfig { num_workers: 2, ..Default::default() },
        })
        .unwrap();
        // B = a second table sharing A's column keys as row keys, so
        // A * B contracts non-trivially
        let triples: Vec<TripleMsg> = (0..50)
            .map(|i| {
                (
                    format!("c{:02}", i % 12),
                    format!("k{:02}", (i * 3) % 8),
                    format!("{}", i % 4 + 1),
                )
            })
            .collect();
        s.handle(Request::Ingest {
            table: "B".into(),
            triples,
            pipeline: PipelineConfig { num_workers: 2, ..Default::default() },
        })
        .unwrap();
        s
    }

    fn q_all() -> TableQuery {
        TableQuery::all()
    }

    // ---------------------------------------------------- bit-identity
    //
    // every plan answer must equal the answer assembled from the
    // equivalent sequential Request round trips, compared with
    // assert_eq! on the Assoc — pattern, keys, and exact f64 bits

    #[test]
    #[cfg_attr(miri, ignore)]
    fn fused_select_matmul_reduce_matches_sequential_with_zero_intermediates() {
        let s = server_with_matrix();
        let rows = KeySel::Range("r00".into(), "r06".into());

        // sequential: Query(A, rows) -> Query(B) -> matmul -> sum
        let a = s.query("A", q_all().rows(rows.clone())).unwrap();
        let b = s.query("B", q_all()).unwrap();
        let want = a.matmul(&b).sum(2);

        // plan: one round trip, select folded, product never built
        let ops = Plan::table("A")
            .select(rows, KeySel::All)
            .matmul(&Plan::table("B"))
            .sum(2)
            .compile()
            .unwrap();
        let (got, stats) = s.execute_plan(&ops).unwrap();
        assert_eq!(got, want, "plan diverged from sequential");
        assert_eq!(stats.ops, 4);
        assert_eq!(stats.fused_selects, 1, "select was not folded into the scan");
        assert_eq!(stats.fused_reduces, 1, "reduce did not stream the matmul");
        assert_eq!(stats.intermediates, 0, "fused path materialised an intermediate");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn fused_reduce_dim1_matches_sequential() {
        let s = server_with_matrix();
        let a = s.query("A", q_all()).unwrap();
        let b = s.query("B", q_all()).unwrap();
        let want = a.matmul(&b).sum(1);
        let ops = Plan::table("A").matmul(&Plan::table("B")).sum(1).compile().unwrap();
        let (got, stats) = s.execute_plan(&ops).unwrap();
        assert_eq!(got, want);
        assert_eq!(stats.fused_reduces, 1);
        assert_eq!(stats.intermediates, 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn shared_matmul_is_not_fused_and_counts_an_intermediate() {
        let s = server_with_matrix();
        let a = s.query("A", q_all()).unwrap();
        let b = s.query("B", q_all()).unwrap();
        let prod = a.matmul(&b);
        let want = prod.sum(2).add(&prod.scale(2.0).sum(1));
        // the product feeds two consumers — fusing the reduce would
        // recompute the contraction, so the executor materialises it
        let p = Plan::table("A").matmul(&Plan::table("B"));
        let ops = p.sum(2).add(&p.scale(2.0).sum(1)).compile().unwrap();
        let (got, stats) = s.execute_plan(&ops).unwrap();
        assert_eq!(got, want);
        assert_eq!(stats.fused_reduces, 0);
        assert!(stats.intermediates > 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn limit_is_pushed_down_and_select_after_limit_is_not_folded() {
        let s = server_with_matrix();
        let cols = KeySel::Prefix("c0".into());
        // sequential: limited scan, then client-side subsref — the
        // order matters (limit first, select after)
        let limited = s.query("A", q_all().limit(13)).unwrap();
        let want = limited.subsref(&KeySel::All, &cols);
        let ops = Plan::table("A")
            .limit(13)
            .unwrap()
            .select(KeySel::All, cols)
            .compile()
            .unwrap();
        let (got, stats) = s.execute_plan(&ops).unwrap();
        assert_eq!(got, want);
        assert_eq!(stats.fused_selects, 0, "folding across a limit changes semantics");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn string_valued_tables_flow_through_plans() {
        let s = D4mServer::with_engine(None);
        let triples: Vec<TripleMsg> = vec![
            ("a".into(), "x".into(), "red".into()),
            ("a".into(), "y".into(), "green".into()),
            ("b".into(), "x".into(), "blue".into()),
        ];
        s.handle(Request::Ingest {
            table: "S".into(),
            triples,
            pipeline: PipelineConfig { num_workers: 1, ..Default::default() },
        })
        .unwrap();
        let sv = s.query("S", q_all()).unwrap();
        assert!(sv.is_string_valued(), "fixture must be string-valued");
        // plain load round-trips the string values
        let (got, _) = s.execute_plan(&Plan::table("S").compile().unwrap()).unwrap();
        assert_eq!(got, sv);
        // algebra on string-valued operands coerces exactly like the
        // sequential path
        let want = sv.transpose().matmul(&sv).sum(2);
        let p = Plan::table("S");
        let ops = p.transpose().matmul(&p).sum(2).compile().unwrap();
        let (got, stats) = s.execute_plan(&ops).unwrap();
        assert_eq!(got, want);
        assert_eq!(stats.fused_reduces, 1);
        // catkeymul provenance strings, bit-identical
        let want = sv.transpose().catkeymul(&sv);
        let ops = p.transpose().catkeymul(&p).compile().unwrap();
        let (got, _) = s.execute_plan(&ops).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn elementwise_transpose_scale_chain_matches_sequential() {
        let s = server_with_matrix();
        let a = s.query("A", q_all()).unwrap();
        let want = a
            .add(&a.scale(0.5))
            .elem_mult(&a)
            .sub(&a.elem_min(&a.elem_max(&a.transpose().transpose())))
            .sum(1);
        let p = Plan::table("A");
        let ops = p
            .add(&p.scale(0.5))
            .elem_mult(&p)
            .sub(&p.elem_min(&p.elem_max(&p.transpose().transpose())))
            .sum(1)
            .compile()
            .unwrap();
        let (got, _) = s.execute_plan(&ops).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn parsed_text_plan_matches_built_plan() {
        let s = server_with_matrix();
        let built = Plan::table("A")
            .select(KeySel::Range("r00".into(), "r06".into()), KeySel::All)
            .matmul(&Plan::table("B"))
            .sum(2)
            .compile()
            .unwrap();
        let parsed = Plan::parse("sum(A('r00,:,r06,', ':') * B, 2)")
            .unwrap()
            .compile()
            .unwrap();
        let (want, _) = s.execute_plan(&built).unwrap();
        let (got, _) = s.execute_plan(&parsed).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn store_into_writes_a_readable_table_and_passes_value_through() {
        let s = server_with_matrix();
        let a = s.query("A", q_all()).unwrap();
        let b = s.query("B", q_all()).unwrap();
        let want = a.matmul(&b);
        let ops = Plan::table("A")
            .matmul(&Plan::table("B"))
            .store_into("C")
            .compile()
            .unwrap();
        let (got, stats) = s.execute_plan(&ops).unwrap();
        assert_eq!(got, want, "store must pass the stored value through");
        // the store target is a real bound table now
        let read_back = s.query("C", q_all()).unwrap();
        assert_eq!(read_back, want, "stored product must read back bit-identically");
        // the stored product is the result, not an intermediate
        assert_eq!(stats.intermediates, 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn plan_request_roundtrips_through_handle() {
        let s = server_with_matrix();
        let ops = Plan::table("A").matmul(&Plan::table("B")).sum(2).compile().unwrap();
        let (want, want_stats) = s.execute_plan(&ops).unwrap();
        match s.handle(Request::Plan { ops }).unwrap() {
            Response::PlanResult { result, stats } => {
                assert_eq!(result, want);
                assert_eq!(stats, want_stats);
            }
            other => panic!("expected PlanResult, got {other:?}"),
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn plan_pages_cursor_is_bit_identical_to_one_shot() {
        let s = server_with_matrix();
        let ops = Plan::table("A").matmul(&Plan::table("B")).compile().unwrap();
        let (want, _) = s.execute_plan(&ops).unwrap();
        // page size 3 forces many pages through the cursor machinery
        let mut triples: Vec<TripleMsg> = Vec::new();
        let mut pages = 0usize;
        for page in s.plan_pages(&ops, 3) {
            let p = page.unwrap();
            assert!(p.len() <= 3);
            pages += 1;
            triples.extend(p);
        }
        assert!(pages > 1, "result too small to page");
        let paged = crate::assoc::io::parse_triples(triples).unwrap();
        assert_eq!(paged, want);
        assert_eq!(s.open_cursor_count(), 0, "drained plan cursor must free itself");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn plan_trait_entry_points_work() {
        let s = server_with_matrix();
        let ops = Plan::table("A").sum(1).compile().unwrap();
        let (want, want_stats) = s.execute_plan(&ops).unwrap();
        let (got, stats) = s.plan(&ops).unwrap();
        assert_eq!(got, want);
        assert_eq!(stats, want_stats);
        let (got, _) = s.plan_expr("sum(A, 1)").unwrap();
        assert_eq!(got, want);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn plan_errors_are_typed() {
        let s = server_with_matrix();
        // unknown table
        let ops = Plan::table("nope").sum(1).compile().unwrap();
        assert!(matches!(s.execute_plan(&ops), Err(D4mError::NotFound(_))));
        // structurally invalid op list (built by hand, skipping compile)
        let bad = vec![PlanOp::Transpose { src: 0 }];
        assert!(matches!(s.execute_plan(&bad), Err(D4mError::InvalidArg(_))));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn plan_counters_surface_in_snapshots() {
        let s = server_with_matrix();
        let before = counters().fused_reduces.get();
        let ops = Plan::table("A").matmul(&Plan::table("B")).sum(2).compile().unwrap();
        s.execute_plan(&ops).unwrap();
        assert!(counters().fused_reduces.get() > before);
        let snaps = s.snapshots();
        for key in ["plan.ops", "plan.fused_selects", "plan.fused_reduces", "plan.intermediates"] {
            assert!(snaps.iter().any(|x| x.name == key), "missing {key} in snapshots");
        }
    }
}
