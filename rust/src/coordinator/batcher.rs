//! Ingest op batcher: coalesces many small ingest requests destined for
//! the same table into one pipeline run. Front-ends that receive triples
//! one-at-a-time (e.g. a socket server) push through this to recover
//! batch-writer throughput — the dynamic-batching idea of serving
//! systems applied to mutations.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::connectors::accumulo::D4mTable;
use crate::error::Result;
use crate::pipeline::{IngestPipeline, PipelineConfig, TripleMsg};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Flush a table's pending batch when it reaches this many triples.
    pub max_triples: usize,
    /// Flush all pending batches older than this.
    pub max_age: Duration,
    /// Pipeline used for the flush.
    pub pipeline: PipelineConfig,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_triples: 50_000,
            max_age: Duration::from_millis(100),
            pipeline: PipelineConfig::default(),
        }
    }
}

struct Pending {
    triples: Vec<TripleMsg>,
    since: Instant,
}

/// The batcher. Not thread-safe by itself — callers own it behind their
/// front-end loop (one batcher per accepting thread).
pub struct OpBatcher {
    policy: BatchPolicy,
    pending: HashMap<String, Pending>,
    tables: HashMap<String, Arc<D4mTable>>,
    /// Total triples flushed.
    pub flushed: u64,
    /// Flush operations performed.
    pub flush_ops: u64,
}

impl OpBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        OpBatcher {
            policy,
            pending: HashMap::new(),
            tables: HashMap::new(),
            flushed: 0,
            flush_ops: 0,
        }
    }

    /// Register a destination table.
    pub fn register(&mut self, name: &str, table: Arc<D4mTable>) {
        self.tables.insert(name.to_string(), table);
    }

    /// Queue one triple; flushes the table's batch if it filled.
    pub fn push(&mut self, table: &str, triple: TripleMsg) -> Result<()> {
        let p = self
            .pending
            .entry(table.to_string())
            .or_insert_with(|| Pending { triples: Vec::new(), since: Instant::now() });
        p.triples.push(triple);
        if p.triples.len() >= self.policy.max_triples {
            self.flush_table(table)?;
        }
        Ok(())
    }

    /// Flush one table's pending batch through the pipeline.
    pub fn flush_table(&mut self, table: &str) -> Result<()> {
        let Some(p) = self.pending.remove(table) else {
            return Ok(());
        };
        if p.triples.is_empty() {
            return Ok(());
        }
        let t = self
            .tables
            .get(table)
            .cloned()
            .ok_or_else(|| crate::error::D4mError::NotFound(format!("batcher table {table}")))?;
        let n = p.triples.len() as u64;
        IngestPipeline::new(t, self.policy.pipeline.clone()).run(p.triples.into_iter())?;
        self.flushed += n;
        self.flush_ops += 1;
        Ok(())
    }

    /// Flush every batch older than the age policy (call from a timer).
    pub fn tick(&mut self) -> Result<()> {
        let now = Instant::now();
        let stale: Vec<String> = self
            .pending
            .iter()
            .filter(|(_, p)| now.duration_since(p.since) >= self.policy.max_age)
            .map(|(k, _)| k.clone())
            .collect();
        for t in stale {
            self.flush_table(&t)?;
        }
        Ok(())
    }

    /// Flush everything (shutdown path).
    pub fn flush_all(&mut self) -> Result<()> {
        let tables: Vec<String> = self.pending.keys().cloned().collect();
        for t in tables {
            self.flush_table(&t)?;
        }
        Ok(())
    }

    pub fn pending_len(&self, table: &str) -> usize {
        self.pending.get(table).map(|p| p.triples.len()).unwrap_or(0)
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;
    use crate::connectors::{AccumuloConnector, D4mTableConfig};

    fn batcher(max: usize) -> (AccumuloConnector, OpBatcher) {
        let acc = AccumuloConnector::new();
        let t = Arc::new(acc.bind("T", &D4mTableConfig::default()).unwrap());
        let mut b = OpBatcher::new(BatchPolicy {
            max_triples: max,
            max_age: Duration::from_millis(1),
            pipeline: PipelineConfig { num_workers: 2, ..Default::default() },
        });
        b.register("T", t);
        (acc, b)
    }

    fn trip(i: usize) -> TripleMsg {
        (format!("r{i:04}"), "c".into(), "1".into())
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn size_triggered_flush() {
        let (acc, mut b) = batcher(10);
        for i in 0..25 {
            b.push("T", trip(i)).unwrap();
        }
        assert_eq!(b.flush_ops, 2);
        assert_eq!(b.flushed, 20);
        assert_eq!(b.pending_len("T"), 5);
        b.flush_all().unwrap();
        assert_eq!(b.flushed, 25);
        let t = acc.bind("T", &D4mTableConfig::default()).unwrap();
        assert_eq!(t.count(), 25);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn age_triggered_flush() {
        let (_acc, mut b) = batcher(1_000_000);
        b.push("T", trip(0)).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        b.tick().unwrap();
        assert_eq!(b.flushed, 1);
        assert_eq!(b.pending_len("T"), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn unknown_table_flush_errors() {
        let (_acc, mut b) = batcher(2);
        b.pending.insert(
            "ghost".into(),
            super::Pending { triples: vec![trip(0)], since: Instant::now() },
        );
        assert!(b.flush_table("ghost").is_err());
    }
}
