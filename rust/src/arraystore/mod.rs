//! SciDB-class array-store substrate (see DESIGN.md substitutions).
//!
//! A chunked 2-D array database: arrays are declared with integer
//! dimensions and a chunk size; cells carry one or more named f64
//! attributes; operations (`subarray`, `filter`, `spgemm`, `sum`) execute
//! *inside the store*, chunk at a time — reproducing SciDB's "compute on
//! the data without exporting it" model that the D4M-SciDB connector
//! leverages.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::collections::{BTreeMap, HashMap};
use std::sync::{Mutex, RwLock};

use crate::assoc::kernel::{self, KernelConfig};
use crate::error::{D4mError, Result};

/// Schema of a 2-D array: dimension bounds and attribute names.
#[derive(Debug, Clone)]
pub struct ArraySchema {
    pub name: String,
    /// Dimension extents: valid coordinates are `[0, shape.0) x [0, shape.1)`.
    pub shape: (u64, u64),
    /// Square chunk edge length.
    pub chunk: u64,
    /// Attribute names (each cell stores one f64 per attribute).
    pub attrs: Vec<String>,
}

impl ArraySchema {
    pub fn new(name: &str, shape: (u64, u64), chunk: u64, attrs: &[&str]) -> Self {
        assert!(chunk > 0, "chunk must be positive");
        ArraySchema {
            name: name.to_string(),
            shape,
            chunk,
            attrs: attrs.iter().map(|s| s.to_string()).collect(),
        }
    }

    fn chunk_of(&self, i: u64, j: u64) -> (u64, u64) {
        (i / self.chunk, j / self.chunk)
    }
}

/// One cell value: per-attribute f64s.
pub type Cell = Vec<f64>;

/// A chunk: sparse map from in-chunk coordinates to cells.
#[derive(Debug, Default, Clone)]
pub struct Chunk {
    pub cells: BTreeMap<(u64, u64), Cell>,
}

/// A stored array: schema + chunk map.
pub struct StoredArray {
    pub schema: ArraySchema,
    chunks: Mutex<HashMap<(u64, u64), Chunk>>,
}

impl StoredArray {
    fn new(schema: ArraySchema) -> Self {
        StoredArray { schema, chunks: Mutex::new(HashMap::new()) }
    }

    /// Insert one cell (all attributes).
    pub fn put(&self, i: u64, j: u64, cell: Cell) -> Result<()> {
        if i >= self.schema.shape.0 || j >= self.schema.shape.1 {
            return Err(D4mError::InvalidArg(format!(
                "coordinate ({i},{j}) outside array shape {:?}",
                self.schema.shape
            )));
        }
        if cell.len() != self.schema.attrs.len() {
            return Err(D4mError::InvalidArg(format!(
                "cell has {} attrs, schema {} wants {}",
                cell.len(),
                self.schema.name,
                self.schema.attrs.len()
            )));
        }
        let ck = self.schema.chunk_of(i, j);
        self.chunks.lock().unwrap().entry(ck).or_default().cells.insert((i, j), cell);
        Ok(())
    }

    /// Bulk insert; chunk-aligned grouping is done internally (this is the
    /// fast-ingest path the Samsi 2016 benchmark measures).
    pub fn put_batch(&self, cells: Vec<(u64, u64, Cell)>) -> Result<()> {
        // group by chunk first, then take the lock once
        let mut grouped: HashMap<(u64, u64), Vec<(u64, u64, Cell)>> = HashMap::new();
        for (i, j, c) in cells {
            if i >= self.schema.shape.0 || j >= self.schema.shape.1 {
                return Err(D4mError::InvalidArg(format!("coordinate ({i},{j}) out of bounds")));
            }
            if c.len() != self.schema.attrs.len() {
                return Err(D4mError::InvalidArg("attr arity mismatch".into()));
            }
            grouped.entry(self.schema.chunk_of(i, j)).or_default().push((i, j, c));
        }
        let mut chunks = self.chunks.lock().unwrap();
        for (ck, group) in grouped {
            let chunk = chunks.entry(ck).or_default();
            for (i, j, c) in group {
                chunk.cells.insert((i, j), c);
            }
        }
        Ok(())
    }

    /// Number of stored cells.
    pub fn count(&self) -> usize {
        self.chunks.lock().unwrap().values().map(|c| c.cells.len()).sum()
    }

    pub fn num_chunks(&self) -> usize {
        self.chunks.lock().unwrap().len()
    }

    /// Read one cell.
    pub fn get(&self, i: u64, j: u64) -> Option<Cell> {
        let ck = self.schema.chunk_of(i, j);
        self.chunks.lock().unwrap().get(&ck).and_then(|c| c.cells.get(&(i, j)).cloned())
    }

    /// All cells of attribute `attr` as triples (sorted by coordinate).
    pub fn scan_attr(&self, attr: &str) -> Result<Vec<(u64, u64, f64)>> {
        let ai = self.attr_index(attr)?;
        let chunks = self.chunks.lock().unwrap();
        let mut out: Vec<(u64, u64, f64)> = chunks
            .values()
            .flat_map(|c| c.cells.iter().map(move |(&(i, j), cell)| (i, j, cell[ai])))
            .collect();
        out.sort_by_key(|&(i, j, _)| (i, j));
        Ok(out)
    }

    fn attr_index(&self, attr: &str) -> Result<usize> {
        self.schema
            .attrs
            .iter()
            .position(|a| a == attr)
            .ok_or_else(|| D4mError::NotFound(format!("attribute {attr}")))
    }

    // ------------------------------------------------------ in-store ops

    /// `subarray(lo, hi)` — the SciDB window op; executes chunk-at-a-time,
    /// only touching chunks that overlap the window.
    pub fn subarray(&self, lo: (u64, u64), hi: (u64, u64)) -> Result<Vec<(u64, u64, Cell)>> {
        let chunks = self.chunks.lock().unwrap();
        let c = self.schema.chunk;
        let mut out = Vec::new();
        for (&(ci, cj), chunk) in chunks.iter() {
            // chunk bounding box vs window
            let (clo_i, clo_j) = (ci * c, cj * c);
            if clo_i > hi.0 || clo_j > hi.1 || clo_i + c <= lo.0 || clo_j + c <= lo.1 {
                continue;
            }
            for (&(i, j), cell) in &chunk.cells {
                if i >= lo.0 && i <= hi.0 && j >= lo.1 && j <= hi.1 {
                    out.push((i, j, cell.clone()));
                }
            }
        }
        out.sort_by_key(|&(i, j, _)| (i, j));
        Ok(out)
    }

    /// `filter(attr, pred)` executed in-store.
    pub fn filter(&self, attr: &str, pred: impl Fn(f64) -> bool) -> Result<Vec<(u64, u64, f64)>> {
        Ok(self.scan_attr(attr)?.into_iter().filter(|&(_, _, v)| pred(v)).collect())
    }

    /// In-store aggregate: sum of an attribute.
    pub fn sum(&self, attr: &str) -> Result<f64> {
        Ok(self.scan_attr(attr)?.into_iter().map(|(_, _, v)| v).sum())
    }
}

/// The array store: named arrays.
#[derive(Default)]
pub struct ArrayStore {
    arrays: RwLock<HashMap<String, std::sync::Arc<StoredArray>>>,
}

impl ArrayStore {
    pub fn new() -> Self {
        ArrayStore::default()
    }

    pub fn create(&self, schema: ArraySchema) -> Result<std::sync::Arc<StoredArray>> {
        let mut arrays = self.arrays.write().unwrap();
        if arrays.contains_key(&schema.name) {
            return Err(D4mError::AlreadyExists(format!("array {}", schema.name)));
        }
        let name = schema.name.clone();
        let a = std::sync::Arc::new(StoredArray::new(schema));
        arrays.insert(name, a.clone());
        Ok(a)
    }

    pub fn array(&self, name: &str) -> Option<std::sync::Arc<StoredArray>> {
        self.arrays.read().unwrap().get(name).cloned()
    }

    pub fn array_or_err(&self, name: &str) -> Result<std::sync::Arc<StoredArray>> {
        self.array(name).ok_or_else(|| D4mError::NotFound(format!("array {name}")))
    }

    pub fn drop_array(&self, name: &str) -> Result<()> {
        self.arrays
            .write()
            .unwrap()
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| D4mError::NotFound(format!("array {name}")))
    }

    pub fn list(&self) -> Vec<String> {
        let mut v: Vec<String> = self.arrays.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// In-store sparse matrix multiply `C = A * B` on attribute 0 —
    /// SciDB's `spgemm()` AFL operator. The result array is created with
    /// the given name (attribute "val"), computed without any data leaving
    /// the store.
    pub fn spgemm(&self, a: &str, b: &str, out: &str) -> Result<std::sync::Arc<StoredArray>> {
        let a = self.array_or_err(a)?;
        let b = self.array_or_err(b)?;
        if a.schema.shape.1 != b.schema.shape.0 {
            return Err(D4mError::Shape(format!(
                "spgemm inner mismatch: {:?} x {:?}",
                a.schema.shape, b.schema.shape
            )));
        }
        let attr_a = 0usize;
        // index B rows
        let mut b_rows: HashMap<u64, Vec<(u64, f64)>> = HashMap::new();
        {
            let chunks = b.chunks.lock().unwrap();
            for chunk in chunks.values() {
                for (&(i, j), cell) in &chunk.cells {
                    b_rows.entry(i).or_default().push((j, cell[0]));
                }
            }
        }
        // snapshot A's matched cells with per-cell work estimates, so
        // the chunk locks are released before the product loop and the
        // kernel pool can partition by actual FLOPs
        let mut cells_a: Vec<(u64, u64, f64)> = Vec::new();
        let mut weights: Vec<u64> = Vec::new();
        {
            let chunks = a.chunks.lock().unwrap();
            for chunk in chunks.values() {
                for (&(i, k), cell) in &chunk.cells {
                    if let Some(brow) = b_rows.get(&k) {
                        cells_a.push((i, k, cell[attr_a]));
                        weights.push(1 + brow.len() as u64);
                    }
                }
            }
        }
        let cfg = KernelConfig::global();
        let total: u64 = weights.iter().sum();
        let workers = kernel::plan_workers(&cfg, total);
        let product = |cells: &[(u64, u64, f64)]| -> HashMap<(u64, u64), f64> {
            let mut m: HashMap<(u64, u64), f64> = HashMap::new();
            for &(i, k, av) in cells {
                for &(j, bv) in &b_rows[&k] {
                    *m.entry((i, j)).or_insert(0.0) += av * bv;
                }
            }
            m
        };
        let acc: HashMap<(u64, u64), f64> = if workers <= 1 {
            product(&cells_a)
        } else {
            let bounds = kernel::balanced_partition(&weights, workers);
            let parts: Vec<HashMap<(u64, u64), f64>> = std::thread::scope(|s| {
                let product = &product;
                let handles: Vec<_> = bounds
                    .windows(2)
                    .map(|w| {
                        let slice = &cells_a[w[0]..w[1]];
                        s.spawn(move || product(slice))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let mut merged: HashMap<(u64, u64), f64> = HashMap::new();
            for part in parts {
                for (cell, v) in part {
                    *merged.entry(cell).or_insert(0.0) += v;
                }
            }
            merged
        };
        let schema = ArraySchema::new(
            out,
            (a.schema.shape.0, b.schema.shape.1),
            a.schema.chunk,
            &["val"],
        );
        let c = self.create(schema)?;
        let cells: Vec<(u64, u64, Cell)> = acc
            .into_iter()
            .filter(|&(_, v)| v != 0.0)
            .map(|((i, j), v)| (i, j, vec![v]))
            .collect();
        c.put_batch(cells)?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with(name: &str, shape: (u64, u64), chunk: u64) -> (ArrayStore, std::sync::Arc<StoredArray>) {
        let s = ArrayStore::new();
        let a = s.create(ArraySchema::new(name, shape, chunk, &["val"])).unwrap();
        (s, a)
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn put_get_roundtrip() {
        let (_s, a) = store_with("a", (100, 100), 10);
        a.put(5, 7, vec![3.5]).unwrap();
        assert_eq!(a.get(5, 7), Some(vec![3.5]));
        assert_eq!(a.get(5, 8), None);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bounds_checked() {
        let (_s, a) = store_with("a", (10, 10), 4);
        assert!(a.put(10, 0, vec![1.0]).is_err());
        assert!(a.put(0, 0, vec![1.0, 2.0]).is_err()); // arity
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn chunking_counts() {
        let (_s, a) = store_with("a", (100, 100), 10);
        a.put(1, 1, vec![1.0]).unwrap(); // chunk (0,0)
        a.put(11, 1, vec![1.0]).unwrap(); // chunk (1,0)
        a.put(2, 2, vec![1.0]).unwrap(); // chunk (0,0)
        assert_eq!(a.num_chunks(), 2);
        assert_eq!(a.count(), 3);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn subarray_window() {
        let (_s, a) = store_with("a", (100, 100), 10);
        for i in 0..20 {
            a.put(i, i, vec![i as f64]).unwrap();
        }
        let w = a.subarray((5, 5), (9, 9)).unwrap();
        assert_eq!(w.len(), 5);
        assert_eq!(w[0].0, 5);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn filter_in_store() {
        let (_s, a) = store_with("a", (10, 10), 4);
        a.put(0, 0, vec![1.0]).unwrap();
        a.put(1, 1, vec![5.0]).unwrap();
        let f = a.filter("val", |v| v > 2.0).unwrap();
        assert_eq!(f, vec![(1, 1, 5.0)]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn missing_attr_errors() {
        let (_s, a) = store_with("a", (10, 10), 4);
        assert!(a.scan_attr("nope").is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn spgemm_matches_dense() {
        let s = ArrayStore::new();
        let a = s.create(ArraySchema::new("a", (2, 3), 2, &["val"])).unwrap();
        let b = s.create(ArraySchema::new("b", (3, 2), 2, &["val"])).unwrap();
        // A = [[1,2,0],[0,0,3]]; B = [[1,0],[0,1],[1,1]]
        a.put(0, 0, vec![1.0]).unwrap();
        a.put(0, 1, vec![2.0]).unwrap();
        a.put(1, 2, vec![3.0]).unwrap();
        b.put(0, 0, vec![1.0]).unwrap();
        b.put(1, 1, vec![1.0]).unwrap();
        b.put(2, 0, vec![1.0]).unwrap();
        b.put(2, 1, vec![1.0]).unwrap();
        let c = s.spgemm("a", "b", "c").unwrap();
        assert_eq!(c.get(0, 0), Some(vec![1.0]));
        assert_eq!(c.get(0, 1), Some(vec![2.0]));
        assert_eq!(c.get(1, 0), Some(vec![3.0]));
        assert_eq!(c.get(1, 1), Some(vec![3.0]));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn spgemm_large_crosses_parallel_cutoff() {
        // dense ones: work = nnz(A) * (1 + 16) ≈ 70k partial products,
        // above the default parallel cutoff, so the sharded accumulator
        // path runs; C[i][j] must be exactly the inner dimension
        let s = ArrayStore::new();
        let a = s.create(ArraySchema::new("a", (256, 16), 32, &["val"])).unwrap();
        let b = s.create(ArraySchema::new("b", (16, 16), 32, &["val"])).unwrap();
        for i in 0..256 {
            for k in 0..16 {
                a.put(i, k, vec![1.0]).unwrap();
            }
        }
        for k in 0..16 {
            for j in 0..16 {
                b.put(k, j, vec![1.0]).unwrap();
            }
        }
        let c = s.spgemm("a", "b", "c").unwrap();
        for &(i, j) in &[(0u64, 0u64), (128, 7), (255, 15)] {
            assert_eq!(c.get(i, j), Some(vec![16.0]), "({i},{j})");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn spgemm_shape_mismatch() {
        let s = ArrayStore::new();
        s.create(ArraySchema::new("a", (2, 3), 2, &["val"])).unwrap();
        s.create(ArraySchema::new("b", (2, 2), 2, &["val"])).unwrap();
        assert!(s.spgemm("a", "b", "c").is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn sum_aggregate() {
        let (_s, a) = store_with("a", (10, 10), 4);
        a.put(0, 0, vec![1.5]).unwrap();
        a.put(1, 1, vec![2.5]).unwrap();
        assert_eq!(a.sum("val").unwrap(), 4.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn duplicate_array_errors() {
        let s = ArrayStore::new();
        s.create(ArraySchema::new("a", (4, 4), 2, &["v"])).unwrap();
        assert!(s.create(ArraySchema::new("a", (4, 4), 2, &["v"])).is_err());
    }
}
