//! Relational substrate (PostGRES/MySQL stand-in, see DESIGN.md).
//!
//! A small typed-column relational engine: tables with named, typed
//! columns, `INSERT`-style appends, and `SELECT` scans with predicates,
//! projection and ORDER BY. Enough surface for the D4M SQL connector to
//! round-trip associative arrays through a relational schema.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::collections::HashMap;
use std::sync::{Mutex, RwLock};

use crate::error::{D4mError, Result};

/// Column types supported by the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColType {
    Text,
    Float,
    Int,
}

/// A single value.
#[derive(Debug, Clone, PartialEq)]
pub enum SqlValue {
    Text(String),
    Float(f64),
    Int(i64),
    Null,
}

impl SqlValue {
    pub fn type_of(&self) -> Option<ColType> {
        match self {
            SqlValue::Text(_) => Some(ColType::Text),
            SqlValue::Float(_) => Some(ColType::Float),
            SqlValue::Int(_) => Some(ColType::Int),
            SqlValue::Null => None,
        }
    }

    pub fn as_text(&self) -> Option<&str> {
        match self {
            SqlValue::Text(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            SqlValue::Float(f) => Some(*f),
            SqlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

impl std::fmt::Display for SqlValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SqlValue::Text(s) => write!(f, "{s}"),
            SqlValue::Float(x) => write!(f, "{}", crate::assoc::io::fmt_num(*x)),
            SqlValue::Int(i) => write!(f, "{i}"),
            SqlValue::Null => write!(f, "NULL"),
        }
    }
}

/// Table schema: ordered (name, type) pairs.
#[derive(Debug, Clone)]
pub struct TableSchema {
    pub name: String,
    pub columns: Vec<(String, ColType)>,
}

impl TableSchema {
    pub fn new(name: &str, columns: &[(&str, ColType)]) -> Self {
        TableSchema {
            name: name.to_string(),
            columns: columns.iter().map(|(n, t)| (n.to_string(), *t)).collect(),
        }
    }

    pub fn col_index(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|(n, _)| n == name)
    }
}

/// A row is a vector of values aligned with the schema columns.
pub type Row = Vec<SqlValue>;

/// Row predicate for SELECT ... WHERE.
pub type Predicate = Box<dyn Fn(&Row) -> bool + Send + Sync>;

/// A stored relational table.
pub struct RelTable {
    pub schema: TableSchema,
    rows: Mutex<Vec<Row>>,
    /// Secondary equality indexes: column position -> text value -> row
    /// positions (rows are append-only, so positions never go stale).
    indexes: Mutex<HashMap<usize, HashMap<String, Vec<usize>>>>,
}

impl RelTable {
    fn new(schema: TableSchema) -> Self {
        RelTable { schema, rows: Mutex::new(Vec::new()), indexes: Mutex::new(HashMap::new()) }
    }

    /// INSERT one row (type-checked against the schema; NULL always ok).
    pub fn insert(&self, row: Row) -> Result<()> {
        if row.len() != self.schema.columns.len() {
            return Err(D4mError::InvalidArg(format!(
                "insert arity {} != schema arity {}",
                row.len(),
                self.schema.columns.len()
            )));
        }
        for (v, (name, ty)) in row.iter().zip(self.schema.columns.iter()) {
            if let Some(vt) = v.type_of() {
                if vt != *ty {
                    return Err(D4mError::InvalidArg(format!(
                        "column {name}: expected {ty:?}, got {vt:?}"
                    )));
                }
            }
        }
        let pos = {
            let mut rows = self.rows.lock().unwrap();
            rows.push(row);
            rows.len() - 1
        };
        self.index_rows(pos, pos + 1);
        Ok(())
    }

    /// Bulk INSERT.
    pub fn insert_batch(&self, rows: Vec<Row>) -> Result<()> {
        for r in &rows {
            if r.len() != self.schema.columns.len() {
                return Err(D4mError::InvalidArg("insert arity mismatch".into()));
            }
        }
        let (start, end) = {
            let mut stored = self.rows.lock().unwrap();
            let start = stored.len();
            stored.extend(rows);
            (start, stored.len())
        };
        self.index_rows(start, end);
        Ok(())
    }

    /// Maintain every existing index for freshly appended rows
    /// `[start, end)`. Never holds two locks at once, so it cannot
    /// deadlock against `create_index` (which holds `rows` while
    /// publishing); a row double-counted by both paths is deduplicated
    /// at lookup.
    fn index_rows(&self, start: usize, end: usize) {
        let cols: Vec<usize> = {
            let indexes = self.indexes.lock().unwrap();
            if indexes.is_empty() {
                return;
            }
            indexes.keys().copied().collect()
        };
        let texts: Vec<(usize, usize, String)> = {
            let rows = self.rows.lock().unwrap();
            let mut out = Vec::new();
            for pos in start..end {
                for &ci in &cols {
                    if let Some(k) = rows[pos][ci].as_text() {
                        out.push((ci, pos, k.to_string()));
                    }
                }
            }
            out
        };
        let mut indexes = self.indexes.lock().unwrap();
        for (ci, pos, k) in texts {
            if let Some(map) = indexes.get_mut(&ci) {
                map.entry(k).or_default().push(pos);
            }
        }
    }

    /// Build (or rebuild) an equality index over a TEXT column. Inserts
    /// maintain it from then on; [`RelTable::select_by_key`] answers
    /// point lookups through it without a full-table predicate pass.
    pub fn create_index(&self, col: &str) -> Result<()> {
        let ci = self
            .schema
            .col_index(col)
            .ok_or_else(|| D4mError::NotFound(format!("column {col}")))?;
        // hold the rows lock across snapshot *and* publish: a concurrent
        // insert either lands before the scan (and is in the snapshot) or
        // blocks until the index is visible (and maintains it) — no row
        // can slip between the two. `index_rows` never holds two locks,
        // so taking `indexes` while holding `rows` cannot deadlock.
        let rows = self.rows.lock().unwrap();
        let mut map: HashMap<String, Vec<usize>> = HashMap::new();
        for (i, r) in rows.iter().enumerate() {
            if let Some(k) = r[ci].as_text() {
                map.entry(k.to_string()).or_default().push(i);
            }
        }
        self.indexes.lock().unwrap().insert(ci, map);
        drop(rows);
        Ok(())
    }

    /// Is there an index over `col`?
    pub fn has_index(&self, col: &str) -> bool {
        match self.schema.col_index(col) {
            Some(ci) => self.indexes.lock().unwrap().contains_key(&ci),
            None => false,
        }
    }

    /// Distinct values stored in the index over `col` (unsorted), or
    /// `None` when no such index exists. One clone per distinct key —
    /// cheaper than projecting every row.
    pub fn index_keys(&self, col: &str) -> Option<Vec<String>> {
        let ci = self.schema.col_index(col)?;
        let indexes = self.indexes.lock().unwrap();
        indexes.get(&ci).map(|m| m.keys().cloned().collect())
    }

    /// Rows whose indexed `col` equals any of `keys`, via the equality
    /// index (requires [`RelTable::create_index`]). Results come back in
    /// insertion order, as a full-scan SELECT would return them.
    pub fn select_by_key(&self, col: &str, keys: &[String]) -> Result<Vec<Row>> {
        let ci = self
            .schema
            .col_index(col)
            .ok_or_else(|| D4mError::NotFound(format!("column {col}")))?;
        let mut pos: Vec<usize> = {
            let indexes = self.indexes.lock().unwrap();
            let idx = indexes
                .get(&ci)
                .ok_or_else(|| D4mError::NotFound(format!("no index on column {col}")))?;
            keys.iter()
                .flat_map(|k| idx.get(k.as_str()).into_iter().flatten().copied())
                .collect()
        };
        pos.sort_unstable();
        pos.dedup();
        let rows = self.rows.lock().unwrap();
        Ok(pos.into_iter().map(|i| rows[i].clone()).collect())
    }

    pub fn count(&self) -> usize {
        self.rows.lock().unwrap().len()
    }

    /// SELECT `projection` FROM self WHERE `pred` ORDER BY `order_by`.
    /// `projection = None` means `*`.
    pub fn select(
        &self,
        projection: Option<&[&str]>,
        pred: Option<&Predicate>,
        order_by: Option<&str>,
    ) -> Result<Vec<Row>> {
        let proj_idx: Option<Vec<usize>> = match projection {
            None => None,
            Some(cols) => Some(
                cols.iter()
                    .map(|c| {
                        self.schema
                            .col_index(c)
                            .ok_or_else(|| D4mError::NotFound(format!("column {c}")))
                    })
                    .collect::<Result<Vec<_>>>()?,
            ),
        };
        let order_idx = match order_by {
            None => None,
            Some(c) => Some(
                self.schema
                    .col_index(c)
                    .ok_or_else(|| D4mError::NotFound(format!("column {c}")))?,
            ),
        };
        let rows = self.rows.lock().unwrap();
        let mut selected: Vec<Row> = rows
            .iter()
            .filter(|r| pred.map(|p| p(r)).unwrap_or(true))
            .cloned()
            .collect();
        drop(rows);
        if let Some(oi) = order_idx {
            selected.sort_by(|a, b| cmp_sql(&a[oi], &b[oi]));
        }
        Ok(match proj_idx {
            None => selected,
            Some(idx) => selected
                .into_iter()
                .map(|r| idx.iter().map(|&i| r[i].clone()).collect())
                .collect(),
        })
    }
}

fn cmp_sql(a: &SqlValue, b: &SqlValue) -> std::cmp::Ordering {
    use std::cmp::Ordering::*;
    match (a, b) {
        (SqlValue::Text(x), SqlValue::Text(y)) => x.cmp(y),
        (SqlValue::Int(x), SqlValue::Int(y)) => x.cmp(y),
        (SqlValue::Float(x), SqlValue::Float(y)) => x.partial_cmp(y).unwrap_or(Equal),
        (SqlValue::Null, SqlValue::Null) => Equal,
        (SqlValue::Null, _) => Less,
        (_, SqlValue::Null) => Greater,
        // mixed numerics
        _ => match (a.as_f64(), b.as_f64()) {
            (Some(x), Some(y)) => x.partial_cmp(&y).unwrap_or(Equal),
            _ => Equal,
        },
    }
}

/// The relational database: named tables.
#[derive(Default)]
pub struct RelDb {
    tables: RwLock<HashMap<String, std::sync::Arc<RelTable>>>,
}

impl RelDb {
    pub fn new() -> Self {
        RelDb::default()
    }

    pub fn create_table(&self, schema: TableSchema) -> Result<std::sync::Arc<RelTable>> {
        let mut tables = self.tables.write().unwrap();
        if tables.contains_key(&schema.name) {
            return Err(D4mError::AlreadyExists(format!("table {}", schema.name)));
        }
        let name = schema.name.clone();
        let t = std::sync::Arc::new(RelTable::new(schema));
        tables.insert(name, t.clone());
        Ok(t)
    }

    pub fn table(&self, name: &str) -> Option<std::sync::Arc<RelTable>> {
        self.tables.read().unwrap().get(name).cloned()
    }

    pub fn table_or_err(&self, name: &str) -> Result<std::sync::Arc<RelTable>> {
        self.table(name).ok_or_else(|| D4mError::NotFound(format!("table {name}")))
    }

    pub fn drop_table(&self, name: &str) -> Result<()> {
        self.tables
            .write()
            .unwrap()
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| D4mError::NotFound(format!("table {name}")))
    }

    pub fn list(&self) -> Vec<String> {
        let mut v: Vec<String> = self.tables.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tripled() -> (RelDb, std::sync::Arc<RelTable>) {
        let db = RelDb::new();
        let t = db
            .create_table(TableSchema::new(
                "edges",
                &[("src", ColType::Text), ("dst", ColType::Text), ("w", ColType::Float)],
            ))
            .unwrap();
        t.insert(vec![
            SqlValue::Text("a".into()),
            SqlValue::Text("b".into()),
            SqlValue::Float(1.0),
        ])
        .unwrap();
        t.insert(vec![
            SqlValue::Text("b".into()),
            SqlValue::Text("c".into()),
            SqlValue::Float(2.0),
        ])
        .unwrap();
        (db, t)
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn insert_select_all() {
        let (_db, t) = tripled();
        let rows = t.select(None, None, None).unwrap();
        assert_eq!(rows.len(), 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn type_checking() {
        let (_db, t) = tripled();
        assert!(t
            .insert(vec![SqlValue::Float(1.0), SqlValue::Text("x".into()), SqlValue::Float(1.0)])
            .is_err());
        assert!(t.insert(vec![SqlValue::Text("x".into())]).is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn null_passes_types() {
        let (_db, t) = tripled();
        t.insert(vec![SqlValue::Null, SqlValue::Text("y".into()), SqlValue::Null]).unwrap();
        assert_eq!(t.count(), 3);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn where_and_projection() {
        let (_db, t) = tripled();
        let pred: Predicate = Box::new(|r| r[2].as_f64().unwrap_or(0.0) > 1.5);
        let rows = t.select(Some(&["src"]), Some(&pred), None).unwrap();
        assert_eq!(rows, vec![vec![SqlValue::Text("b".into())]]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn order_by() {
        let (_db, t) = tripled();
        let rows = t.select(Some(&["w"]), None, Some("w")).unwrap();
        let ws: Vec<f64> = rows.iter().map(|r| r[0].as_f64().unwrap()).collect();
        assert_eq!(ws, vec![1.0, 2.0]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn unknown_column_errors() {
        let (_db, t) = tripled();
        assert!(t.select(Some(&["nope"]), None, None).is_err());
        assert!(t.select(None, None, Some("nope")).is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn index_point_lookup_matches_predicate_scan() {
        let (_db, t) = tripled();
        t.create_index("src").unwrap();
        assert!(t.has_index("src"));
        assert!(!t.has_index("dst"));
        let got = t.select_by_key("src", &["b".to_string(), "nope".to_string()]).unwrap();
        let pred: Predicate = Box::new(|r| r[0].as_text() == Some("b"));
        let want = t.select(None, Some(&pred), None).unwrap();
        assert_eq!(got, want);
        let mut keys = t.index_keys("src").unwrap();
        keys.sort();
        assert_eq!(keys, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn index_maintained_by_inserts() {
        let (_db, t) = tripled();
        t.create_index("src").unwrap();
        t.insert(vec![
            SqlValue::Text("b".into()),
            SqlValue::Text("d".into()),
            SqlValue::Float(3.0),
        ])
        .unwrap();
        t.insert_batch(vec![vec![
            SqlValue::Text("e".into()),
            SqlValue::Text("f".into()),
            SqlValue::Float(4.0),
        ]])
        .unwrap();
        assert_eq!(t.select_by_key("src", &["b".to_string()]).unwrap().len(), 2);
        assert_eq!(t.select_by_key("src", &["e".to_string()]).unwrap().len(), 1);
        assert_eq!(t.index_keys("src").unwrap().len(), 3);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn index_errors_without_create() {
        let (_db, t) = tripled();
        assert!(t.select_by_key("src", &["a".to_string()]).is_err());
        assert!(t.create_index("nope").is_err());
        assert!(t.index_keys("src").is_none());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn db_registry() {
        let (db, _t) = tripled();
        assert_eq!(db.list(), vec!["edges".to_string()]);
        assert!(db.create_table(TableSchema::new("edges", &[])).is_err());
        db.drop_table("edges").unwrap();
        assert!(db.table("edges").is_none());
    }
}
