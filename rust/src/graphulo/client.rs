//! Client-side D4M reference implementations — the "D4M" series of
//! Figure 2 and the baselines for every Graphulo algorithm.
//!
//! These pull tables into client memory as associative arrays and compute
//! with the assoc algebra. A configurable **memory budget** models the
//! client RAM wall the paper's figure shows: when materialised operands +
//! product exceed the budget, the op fails with
//! [`D4mError::MemoryLimit`] instead of completing.

use std::sync::Arc;

use crate::assoc::Assoc;
use crate::error::{D4mError, Result};
use crate::kvstore::{IterConfig, RowRange, Table};

/// Client-side compute context with a RAM budget (bytes).
#[derive(Debug, Clone)]
pub struct ClientCtx {
    pub memory_limit: usize,
}

impl Default for ClientCtx {
    fn default() -> Self {
        // effectively unlimited for tests; benches set real caps
        ClientCtx { memory_limit: usize::MAX }
    }
}

impl ClientCtx {
    pub fn with_limit(memory_limit: usize) -> Self {
        ClientCtx { memory_limit }
    }

    fn charge(&self, used: usize) -> Result<()> {
        if used > self.memory_limit {
            Err(D4mError::MemoryLimit { used, limit: self.memory_limit })
        } else {
            Ok(())
        }
    }

    /// Pull a whole table into an assoc, charging its footprint.
    pub fn read_table(&self, t: &Arc<Table>) -> Result<Assoc> {
        let cfg = IterConfig { summing: true, ..Default::default() };
        let a =
            crate::connectors::accumulo::entries_to_assoc(t.scan_stream(&RowRange::all(), &cfg))?;
        self.charge(a.mem_bytes())?;
        Ok(a)
    }

    /// Client-side TableMult: read A and B fully, compute `A^T * B` in
    /// RAM. Charges |A| + |B| + |C| against the budget — the Figure-2
    /// memory wall.
    pub fn table_mult(&self, a: &Arc<Table>, b: &Arc<Table>) -> Result<Assoc> {
        let aa = self.read_table(a)?;
        let bb = self.read_table(b)?;
        self.charge(aa.mem_bytes() + bb.mem_bytes())?;
        let c = aa.transpose().matmul(&bb);
        self.charge(aa.mem_bytes() + bb.mem_bytes() + c.mem_bytes())?;
        Ok(c)
    }

    /// Client-side TableMult over already-materialised assocs (used by the
    /// assoc-level benches where the store is not involved).
    pub fn assoc_mult(&self, a: &Assoc, b: &Assoc) -> Result<Assoc> {
        self.charge(a.mem_bytes() + b.mem_bytes())?;
        let c = a.transpose().matmul(b);
        self.charge(a.mem_bytes() + b.mem_bytes() + c.mem_bytes())?;
        Ok(c)
    }
}

/// Client-side BFS over an adjacency assoc: returns `(vertex -> hop)` for
/// all vertices reached within `k` hops of the seeds (hop 0 = seed).
pub fn bfs_assoc(
    adj: &Assoc,
    seeds: &[String],
    k: usize,
) -> std::collections::BTreeMap<String, usize> {
    let mut dist: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
    let mut frontier: Vec<String> = Vec::new();
    for s in seeds {
        if dist.insert(s.clone(), 0).is_none() {
            frontier.push(s.clone());
        }
    }
    for hop in 1..=k {
        // v_{t+1} = frontier * A, restricted to unvisited
        if frontier.is_empty() {
            break;
        }
        let sel = crate::assoc::KeySel::Keys(frontier.clone());
        let rows = adj.select_rows(&sel);
        let mut next = Vec::new();
        for c in rows.col_keys() {
            if !dist.contains_key(c) {
                dist.insert(c.clone(), hop);
                next.push(c.clone());
            }
        }
        frontier = next;
    }
    dist
}

/// Client-side Jaccard coefficients between column vertices of an
/// unweighted adjacency assoc: `J(i,j) = |N(i) ∩ N(j)| / |N(i) ∪ N(j)|`
/// for i < j with nonzero intersection.
pub fn jaccard_assoc(adj: &Assoc) -> Assoc {
    let a = adj.logical();
    let n = a.transpose().matmul(&a); // co-occurrence counts
    let deg = a.sum(1); // column degrees, row key ""
    let mut out = Vec::new();
    for (i, j, nij) in n.triples() {
        if i >= j {
            continue; // upper triangle only
        }
        let di = deg.get("", &i);
        let dj = deg.get("", &j);
        let denom = di + dj - nij;
        if denom > 0.0 {
            out.push((i, j, nij / denom));
        }
    }
    Assoc::from_triples(&out)
}

/// Client-side k-truss: iteratively remove edges supported by fewer than
/// `k - 2` triangles until fixpoint. Input and output are undirected
/// adjacency assocs (the input is symmetrised internally).
pub fn ktruss_assoc(adj: &Assoc, k: usize) -> Assoc {
    let mut a = adj.logical().elem_max(&adj.logical().transpose()); // symmetrise
    // drop self loops: they inflate support counts
    let t: Vec<(String, String, f64)> =
        a.triples().into_iter().filter(|(r, c, _)| r != c).collect();
    a = Assoc::from_triples(&t);
    let need = (k.saturating_sub(2)) as f64;
    loop {
        if a.is_empty() {
            return a;
        }
        // support(i,j) = number of common neighbours = (A*A)(i,j) on edges
        let a2 = a.matmul(&a);
        let support = a2.elem_mult(&a); // restrict to existing edges
        let keep = support.filter_values(|v| v >= need);
        // rebuild adjacency from surviving edges
        let kept_edges: Vec<(String, String, f64)> =
            keep.triples().into_iter().map(|(r, c, _)| (r, c, 1.0)).collect();
        let next = Assoc::from_triples(&kept_edges);
        if next.triples() == a.triples() {
            return next;
        }
        a = next;
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;

    fn path_graph() -> Assoc {
        // a -> b -> c -> d
        Assoc::from_triples(&[("a", "b", 1.0), ("b", "c", 1.0), ("c", "d", 1.0)])
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bfs_hops() {
        let g = path_graph();
        let d = bfs_assoc(&g, &["a".into()], 2);
        assert_eq!(d.get("a"), Some(&0));
        assert_eq!(d.get("b"), Some(&1));
        assert_eq!(d.get("c"), Some(&2));
        assert_eq!(d.get("d"), None); // beyond k
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bfs_multiple_seeds() {
        let g = path_graph();
        let d = bfs_assoc(&g, &["a".into(), "c".into()], 1);
        assert_eq!(d.len(), 4); // a,c seeds + b,d at hop 1
        assert_eq!(d.get("d"), Some(&1));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bfs_early_exhaustion() {
        let g = Assoc::from_triples(&[("a", "b", 1.0)]);
        let d = bfs_assoc(&g, &["a".into()], 10);
        assert_eq!(d.len(), 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn jaccard_shared_neighbourhood() {
        // r1 -> {x, y}; r2 -> {x, y}; r3 -> {y, z}
        let g = Assoc::from_triples(&[
            ("r1", "x", 1.0),
            ("r1", "y", 1.0),
            ("r2", "x", 1.0),
            ("r2", "y", 1.0),
            ("r3", "y", 1.0),
            ("r3", "z", 1.0),
        ]);
        let j = jaccard_assoc(&g);
        // x,y co-occur in r1,r2; deg x=2, deg y=3 -> 2/(2+3-2) = 2/3
        assert!((j.get("x", "y") - 2.0 / 3.0).abs() < 1e-9);
        // y,z co-occur in r3 only; deg y=3, deg z=1 -> 1/3
        assert!((j.get("y", "z") - 1.0 / 3.0).abs() < 1e-9);
        // x,z never co-occur
        assert_eq!(j.get("x", "z"), 0.0);
        // upper triangle only
        assert_eq!(j.get("y", "x"), 0.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn ktruss_triangle_survives_k3() {
        // triangle a-b-c plus dangling edge c-d
        let g = Assoc::from_triples(&[
            ("a", "b", 1.0),
            ("b", "c", 1.0),
            ("a", "c", 1.0),
            ("c", "d", 1.0),
        ]);
        let t3 = ktruss_assoc(&g, 3);
        // the triangle survives, the dangling edge does not
        assert_eq!(t3.get("a", "b"), 1.0);
        assert_eq!(t3.get("b", "a"), 1.0); // symmetrised
        assert_eq!(t3.get("c", "d"), 0.0);
        assert_eq!(t3.get("d", "c"), 0.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn ktruss_k4_kills_single_triangle() {
        let g = Assoc::from_triples(&[("a", "b", 1.0), ("b", "c", 1.0), ("a", "c", 1.0)]);
        let t4 = ktruss_assoc(&g, 4);
        assert!(t4.is_empty());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn ktruss_k4_keeps_k4_clique() {
        // complete graph on 4 vertices: every edge in 2 triangles
        let vs = ["a", "b", "c", "d"];
        let mut t = vec![];
        for i in 0..4 {
            for j in (i + 1)..4 {
                t.push((vs[i], vs[j], 1.0));
            }
        }
        let g = Assoc::from_triples(&t);
        let t4 = ktruss_assoc(&g, 4);
        assert_eq!(t4.nnz(), 12); // all 6 edges, symmetrised
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn memory_limit_trips() {
        let ctx = ClientCtx::with_limit(64);
        let a = Assoc::from_triples(&[("r", "c", 1.0), ("r2", "c2", 2.0)]);
        match ctx.assoc_mult(&a, &a) {
            Err(D4mError::MemoryLimit { used, limit }) => {
                assert!(used > limit);
            }
            other => panic!("expected MemoryLimit, got {other:?}"),
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn memory_unlimited_succeeds() {
        let ctx = ClientCtx::default();
        let a = Assoc::from_triples(&[("k", "i", 1.0), ("k", "j", 1.0)]);
        let c = ctx.assoc_mult(&a, &a).unwrap();
        assert_eq!(c.get("i", "j"), 1.0);
    }
}
