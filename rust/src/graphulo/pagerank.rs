//! PageRank / power iteration on D4M tables — the "eigensolver for large
//! sparse matrix" application of the D4M-Accumulo architecture (Huang
//! 2015, cited by the paper) expressed with Graphulo primitives:
//! each iteration is one pass of row scans against the *transpose*
//! table (in-edges), never materialising the adjacency client-side.

// unwrap/expect are disallowed repo-wide (clippy.toml); this module's
// call sites predate the policy and are tracked for burn-down in
// EXPERIMENTS.md — never-panic modules carry no such allow.
#![allow(clippy::disallowed_methods)]
use std::collections::BTreeMap;
use std::sync::Arc;

use crate::assoc::Assoc;
use crate::kvstore::{IterConfig, RowRange, Table};

/// Options for the power iteration.
#[derive(Debug, Clone, PartialEq)]
pub struct PageRankOpts {
    pub damping: f64,
    pub max_iters: usize,
    /// L1 convergence threshold.
    pub tol: f64,
}

impl Default for PageRankOpts {
    fn default() -> Self {
        PageRankOpts { damping: 0.85, max_iters: 200, tol: 1e-9 }
    }
}

/// Result of a PageRank run.
#[derive(Debug, Clone, PartialEq)]
pub struct PageRankResult {
    pub scores: BTreeMap<String, f64>,
    pub iterations: usize,
    pub converged: bool,
}

/// Server-side PageRank over the edge table `t` (rows = sources, cq =
/// destinations). One full scan per iteration streams the transition
/// contributions; only the rank vector (O(|V|)) is client-resident.
pub fn pagerank_server(t: &Arc<Table>, opts: &PageRankOpts) -> PageRankResult {
    let cfg = IterConfig::default();
    let all = RowRange::all();
    // every pass streams the SAME table snapshot: the vertex set and
    // degree maps built below stay exhaustive even if concurrent
    // writers add edges (or whole vertices) while the solver iterates
    let snap = t.snapshot_range(&all);
    // vertex set + out-degrees from one streaming scan
    let mut out_deg: BTreeMap<String, f64> = BTreeMap::new();
    let mut vertices: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    for e in snap.stream(&all, &cfg) {
        *out_deg.entry(e.key.row.clone()).or_insert(0.0) += 1.0;
        vertices.insert(e.key.row);
        vertices.insert(e.key.cq);
    }
    let n = vertices.len();
    if n == 0 {
        return PageRankResult { scores: BTreeMap::new(), iterations: 0, converged: true };
    }
    let mut rank: BTreeMap<String, f64> =
        vertices.iter().map(|v| (v.clone(), 1.0 / n as f64)).collect();

    for iter in 0..opts.max_iters {
        // contributions streamed from one scan of the edge table
        let mut next: BTreeMap<String, f64> = vertices
            .iter()
            .map(|v| (v.clone(), (1.0 - opts.damping) / n as f64))
            .collect();
        let mut dangling = 0.0;
        // one streaming edge scan of the pinned snapshot per iteration;
        // only the rank vector (O(|V|)) is client-resident
        for e in snap.stream(&all, &cfg) {
            let r = rank[&e.key.row];
            let d = out_deg[&e.key.row];
            *next.get_mut(&e.key.cq).unwrap() += opts.damping * r / d;
        }
        // dangling mass: vertices with no out-edges spread uniformly
        for v in &vertices {
            if !out_deg.contains_key(v) {
                dangling += rank[v];
            }
        }
        if dangling > 0.0 {
            let share = opts.damping * dangling / n as f64;
            for val in next.values_mut() {
                *val += share;
            }
        }
        let delta: f64 = vertices.iter().map(|v| (next[v] - rank[v]).abs()).sum();
        rank = next;
        if delta < opts.tol {
            return PageRankResult { scores: rank, iterations: iter + 1, converged: true };
        }
    }
    PageRankResult { scores: rank, iterations: opts.max_iters, converged: false }
}

/// Client-side reference: power iteration with the assoc algebra
/// (P = D^-1 A; r <- d * P^T r + teleport).
pub fn pagerank_assoc(adj: &Assoc, opts: &PageRankOpts) -> PageRankResult {
    let a = adj.logical();
    // vertex set = union of row and col keys
    let mut vertices: Vec<String> = a.row_keys().to_vec();
    vertices.extend(a.col_keys().iter().cloned());
    vertices.sort();
    vertices.dedup();
    let n = vertices.len();
    if n == 0 {
        return PageRankResult { scores: BTreeMap::new(), iterations: 0, converged: true };
    }
    let deg = a.sum(2); // out-degrees
    let mut rank: BTreeMap<String, f64> =
        vertices.iter().map(|v| (v.clone(), 1.0 / n as f64)).collect();
    for iter in 0..opts.max_iters {
        // r_row: assoc 1 x |V| of current ranks normalised by degree
        let scaled: Vec<(String, String, f64)> = vertices
            .iter()
            .filter_map(|v| {
                let d = deg.get(v, "");
                if d > 0.0 {
                    Some(("r".to_string(), v.clone(), rank[v] / d))
                } else {
                    None
                }
            })
            .collect();
        let r_row = Assoc::from_triples(&scaled);
        let spread = r_row.matmul(&a); // 1 x |V| contributions
        let dangling: f64 =
            vertices.iter().filter(|v| deg.get(v, "") == 0.0).map(|v| rank[v]).sum();
        let base = (1.0 - opts.damping) / n as f64 + opts.damping * dangling / n as f64;
        let mut next: BTreeMap<String, f64> = BTreeMap::new();
        for v in &vertices {
            next.insert(v.clone(), base + opts.damping * spread.get("r", v));
        }
        let delta: f64 = vertices.iter().map(|v| (next[v] - rank[v]).abs()).sum();
        rank = next;
        if delta < opts.tol {
            return PageRankResult { scores: rank, iterations: iter + 1, converged: true };
        }
    }
    PageRankResult { scores: rank, iterations: opts.max_iters, converged: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connectors::{AccumuloConnector, D4mTableConfig};

    fn star_graph() -> Assoc {
        // a, b, c all point at hub
        Assoc::from_triples(&[
            ("a", "hub", 1.0),
            ("b", "hub", 1.0),
            ("c", "hub", 1.0),
            ("hub", "a", 1.0),
        ])
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn server_matches_client() {
        let g = star_graph();
        let acc = AccumuloConnector::new();
        let t = acc.bind("G", &D4mTableConfig::default()).unwrap();
        t.put_assoc(&g).unwrap();
        let opts = PageRankOpts::default();
        let srv = pagerank_server(&t.main(), &opts);
        let cli = pagerank_assoc(&g, &opts);
        assert_eq!(srv.converged, cli.converged);
        for (v, s) in &srv.scores {
            assert!((s - cli.scores[v]).abs() < 1e-8, "{v}: {s} vs {}", cli.scores[v]);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn hub_ranks_highest() {
        let g = star_graph();
        let r = pagerank_assoc(&g, &PageRankOpts::default());
        assert!(r.converged);
        let hub = r.scores["hub"];
        for (v, s) in &r.scores {
            if v != "hub" {
                assert!(hub > *s, "hub {hub} should beat {v} {s}");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn scores_sum_to_one() {
        let g = crate::gen::kronecker_assoc(&crate::gen::KroneckerParams::new(6, 4, 5));
        let r = pagerank_assoc(&g, &PageRankOpts::default());
        let total: f64 = r.scores.values().sum();
        assert!((total - 1.0).abs() < 1e-6, "total {total}");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn server_scores_sum_to_one_with_dangling() {
        // b has no out-edges: dangling mass must be redistributed
        let g = Assoc::from_triples(&[("a", "b", 1.0)]);
        let acc = AccumuloConnector::new();
        let t = acc.bind("G", &D4mTableConfig::default()).unwrap();
        t.put_assoc(&g).unwrap();
        let r = pagerank_server(&t.main(), &PageRankOpts::default());
        let total: f64 = r.scores.values().sum();
        assert!((total - 1.0).abs() < 1e-6, "total {total}");
        assert!(r.scores["b"] > r.scores["a"]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn empty_graph() {
        let acc = AccumuloConnector::new();
        let t = acc.bind("E", &D4mTableConfig::default()).unwrap();
        let r = pagerank_server(&t.main(), &PageRankOpts::default());
        assert!(r.converged);
        assert!(r.scores.is_empty());
    }
}
