//! Graphulo — in-database GraphBLAS analytics (the paper's §II second
//! addition and Figure 2).
//!
//! * [`tablemult`] — server-side sparse matrix multiply (`C += A^T B`)
//!   streamed through the store's iterator stack with bounded memory.
//! * [`algorithms`] — BFS, Jaccard, k-truss built on TableMult + scans,
//!   all executed inside the store.
//! * [`client`] — the client-side D4M baselines (full-table pulls into
//!   associative arrays) with the RAM budget that reproduces Figure 2's
//!   memory wall.

pub mod algorithms;
pub mod pagerank;
pub mod client;
pub mod tablemult;

pub use algorithms::{bfs_server, jaccard_server, ktruss_server, symmetrise_table};
pub use pagerank::{pagerank_assoc, pagerank_server, PageRankOpts, PageRankResult};
pub use client::{bfs_assoc, jaccard_assoc, ktruss_assoc, ClientCtx};
pub use tablemult::{read_product, table_mult, TableMultOpts, TableMultStats};
