//! Server-side Graphulo graph algorithms (Hutchison et al. 2016): BFS,
//! Jaccard and k-truss executed *inside* the store via scans, server-side
//! iterators and [`super::tablemult::table_mult`] — "without first
//! transferring a partial set of results to local memory" (the paper).
//!
//! Each algorithm has a client-side counterpart in [`super::client`];
//! tests assert they agree.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::assoc::io::fmt_num;
use crate::error::Result;
use crate::kvstore::{BatchWriter, IterConfig, KvStore, RowRange, Table, WriterConfig};

use super::tablemult::{table_mult, TableMultOpts};

/// Server-side BFS from `seeds`, `k` hops, over the edge table (rows are
/// source vertices, cq are destinations). Only the frontier is resident
/// client-side; neighbourhood expansion is row scans in the store.
pub fn bfs_server(table: &Arc<Table>, seeds: &[String], k: usize) -> BTreeMap<String, usize> {
    let cfg = IterConfig::default();
    let mut dist: BTreeMap<String, usize> = BTreeMap::new();
    let mut frontier: Vec<String> = Vec::new();
    for s in seeds {
        if dist.insert(s.clone(), 0).is_none() {
            frontier.push(s.clone());
        }
    }
    for hop in 1..=k {
        if frontier.is_empty() {
            break;
        }
        let mut next = Vec::new();
        for v in &frontier {
            // streaming row scan: neighbours are pulled one at a time
            // out of a tablet snapshot, never into a per-row Vec
            for e in table.scan_row_stream(v, &cfg) {
                let dst = e.key.cq;
                if !dist.contains_key(&dst) {
                    dist.insert(dst.clone(), hop);
                    next.push(dst);
                }
            }
        }
        frontier = next;
    }
    dist
}

/// Server-side Jaccard: `N = A^T A` by TableMult into a temp table, then
/// a streaming pass over N combining with the degree table:
/// `J(i,j) = N(i,j) / (deg(i) + deg(j) - N(i,j))`, `i < j`.
///
/// `edge` is the main table (rows = vertices, cq = neighbours); `deg` the
/// D4M-schema degree table (row = vertex, cq = "deg"). The result is
/// written into `out` and also returned as an assoc.
pub fn jaccard_server(
    store: &Arc<KvStore>,
    edge: &Arc<Table>,
    deg: &Arc<Table>,
    out_name: &str,
) -> Result<crate::assoc::Assoc> {
    // N = A^T A  (contract over rows = shared neighbours... rows of the
    // edge table are source vertices; A^T A counts, for each vertex pair
    // (i, j), the sources pointing at both).
    let n_table = store.ensure_table(&format!("{out_name}_N"), vec![])?;
    let opts = TableMultOpts { logical: true, ..Default::default() };
    table_mult(edge, edge, &n_table, &opts)?;

    // degree lookup (streamed once into a map; degree tables are O(|V|),
    // the small side — Graphulo does the same with a scan-time cache)
    let deg_cfg = IterConfig { summing: true, ..Default::default() };
    let mut degree: BTreeMap<String, f64> = BTreeMap::new();
    for e in deg.scan_stream(&RowRange::all(), &deg_cfg) {
        if e.key.cq == "deg" {
            degree.insert(e.key.row, e.value.parse().unwrap_or(0.0));
        }
    }

    // streaming combine pass over N: one entry of N resident at a time,
    // writes into `out` while the scan cursor is open
    let out = store.ensure_table(out_name, vec![])?;
    let mut w = BatchWriter::new(out.clone(), WriterConfig::default());
    let sum_cfg = IterConfig { summing: true, ..Default::default() };
    for e in n_table.scan_stream(&RowRange::all(), &sum_cfg) {
        let (i, j) = (e.key.row.as_str(), e.key.cq.as_str());
        if i >= j {
            continue;
        }
        let nij: f64 = e.value.parse().unwrap_or(0.0);
        let di = degree.get(i).copied().unwrap_or(0.0);
        let dj = degree.get(j).copied().unwrap_or(0.0);
        let denom = di + dj - nij;
        if denom > 0.0 && nij > 0.0 {
            w.put(i, j, &fmt_num(nij / denom))?;
        }
    }
    w.flush()?;
    let cfg = IterConfig::default();
    crate::connectors::accumulo::entries_to_assoc(out.scan_stream(&RowRange::all(), &cfg))
}

/// Server-side k-truss: iterate `support = (A*A) ∧ A`, drop edges with
/// support < k-2, rewrite the surviving edges into a fresh generation
/// table, until fixpoint. Tables named `{base}_gen{n}`.
///
/// Input table must hold a symmetric, loop-free adjacency (use
/// [`symmetrise_table`] first if needed). Returns the surviving adjacency.
pub fn ktruss_server(
    store: &Arc<KvStore>,
    adj: &Arc<Table>,
    k: usize,
    base: &str,
) -> Result<crate::assoc::Assoc> {
    let need = k.saturating_sub(2) as f64;
    let cfg = IterConfig { summing: true, ..Default::default() };
    let mut current = adj.clone();
    let mut generation = 0usize;
    loop {
        // A^T A over a symmetric A equals A*A; TableMult contracts rows.
        let a2 = store.ensure_table(&format!("{base}_gen{generation}_sq"), vec![])?;
        table_mult(&current, &current, &a2, &TableMultOpts::default())?;

        // stream A merge-joined with A2 (both scans are key-sorted), keep
        // edges whose support >= need. One pass, no per-edge row scans.
        let next = store.ensure_table(&format!("{base}_gen{}", generation + 1), vec![])?;
        let mut w = BatchWriter::new(next.clone(), WriterConfig::default());
        let mut kept = 0usize;
        let mut total = 0usize;
        let mut sq = a2.scan_stream(&RowRange::all(), &cfg).peekable();
        for e in current.scan_stream(&RowRange::all(), &cfg) {
            total += 1;
            let edge_cell = (&e.key.row, &e.key.cq);
            // advance A2 to the first cell >= edge_cell
            while sq
                .peek()
                .map(|x| (&x.key.row, &x.key.cq) < edge_cell)
                .unwrap_or(false)
            {
                sq.next();
            }
            let support = match sq.peek() {
                Some(x) if (&x.key.row, &x.key.cq) == edge_cell => {
                    x.value.parse::<f64>().unwrap_or(0.0)
                }
                _ => 0.0,
            };
            if support >= need {
                w.put(&e.key.row, &e.key.cq, "1")?;
                kept += 1;
            }
        }
        w.flush()?;
        generation += 1;
        if kept == total {
            // fixpoint
            return crate::connectors::accumulo::entries_to_assoc(
                next.scan_stream(&RowRange::all(), &cfg),
            );
        }
        if kept == 0 {
            return Ok(crate::assoc::Assoc::empty());
        }
        current = next;
    }
}

/// Write the symmetric closure of an edge table (minus self-loops) into a
/// new table — the preprocessing step for k-truss.
pub fn symmetrise_table(
    store: &Arc<KvStore>,
    edge: &Arc<Table>,
    out_name: &str,
) -> Result<Arc<Table>> {
    let out = store.ensure_table(out_name, vec![])?;
    let mut w = BatchWriter::new(out.clone(), WriterConfig::default());
    let cfg = IterConfig::default();
    for e in edge.scan_stream(&RowRange::all(), &cfg) {
        if e.key.row != e.key.cq {
            w.put(&e.key.row, &e.key.cq, "1")?;
            w.put(&e.key.cq, &e.key.row, "1")?;
        }
    }
    w.flush()?;
    Ok(out)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic by design
mod tests {
    use super::*;
    use crate::assoc::Assoc;
    use crate::connectors::{AccumuloConnector, D4mTableConfig};
    use crate::graphulo::client;

    fn store_with_graph(a: &Assoc) -> (Arc<KvStore>, Arc<Table>, Arc<Table>) {
        let store = Arc::new(KvStore::new());
        let acc = AccumuloConnector::with_store(store.clone());
        let t = acc.bind("G", &D4mTableConfig::default()).unwrap();
        t.put_assoc(a).unwrap();
        (store, t.main(), t.degree_table().unwrap())
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bfs_server_matches_client() {
        let g = Assoc::from_triples(&[
            ("a", "b", 1.0),
            ("b", "c", 1.0),
            ("b", "d", 1.0),
            ("d", "e", 1.0),
        ]);
        let (_s, t, _d) = store_with_graph(&g);
        let server = bfs_server(&t, &["a".into()], 3);
        let client = client::bfs_assoc(&g, &["a".into()], 3);
        assert_eq!(server, client);
        assert_eq!(server.get("e"), Some(&3));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn jaccard_server_matches_client() {
        let g = Assoc::from_triples(&[
            ("r1", "x", 1.0),
            ("r1", "y", 1.0),
            ("r2", "x", 1.0),
            ("r2", "y", 1.0),
            ("r3", "y", 1.0),
            ("r3", "z", 1.0),
        ]);
        let (s, t, d) = store_with_graph(&g);
        let server = jaccard_server(&s, &t, &d, "J").unwrap();
        let client = client::jaccard_assoc(&g);
        let (st, ct) = (server.triples(), client.triples());
        assert_eq!(st.len(), ct.len());
        for (a, b) in st.iter().zip(ct.iter()) {
            assert_eq!((&a.0, &a.1), (&b.0, &b.1));
            assert!((a.2 - b.2).abs() < 1e-9, "{a:?} vs {b:?}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn ktruss_server_matches_client() {
        // triangle + dangling edge, symmetrised in-store
        let g = Assoc::from_triples(&[
            ("a", "b", 1.0),
            ("b", "c", 1.0),
            ("a", "c", 1.0),
            ("c", "d", 1.0),
        ]);
        let (s, t, _d) = store_with_graph(&g);
        let sym = symmetrise_table(&s, &t, "G_sym").unwrap();
        let server = ktruss_server(&s, &sym, 3, "KT").unwrap();
        let client = client::ktruss_assoc(&g, 3);
        assert_eq!(server.triples(), client.triples());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn ktruss_server_empty_when_no_truss() {
        let g = Assoc::from_triples(&[("a", "b", 1.0), ("b", "c", 1.0)]); // path, no triangle
        let (s, t, _d) = store_with_graph(&g);
        let sym = symmetrise_table(&s, &t, "S").unwrap();
        let out = ktruss_server(&s, &sym, 3, "K").unwrap();
        assert!(out.is_empty());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bfs_server_disconnected() {
        let g = Assoc::from_triples(&[("a", "b", 1.0), ("x", "y", 1.0)]);
        let (_s, t, _d) = store_with_graph(&g);
        let d = bfs_server(&t, &["a".into()], 5);
        assert!(!d.contains_key("x") && !d.contains_key("y"));
    }
}
